"""Training launcher. On CPU runs reduced configs end-to-end (synthetic
data, checkpointing, resume); on a real cluster the same entry point lowers
the full config onto the production mesh (see dryrun.py for the mesh/sharding
used at scale).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-32b --reduced \
        --steps 20 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_reduced
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.distributed import compression
from repro.models import registry
from repro.training.checkpoint import CheckpointManager
from repro.training.optimizer import OptimizerConfig, init_opt_state
from repro.training.train_loop import TrainConfig, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-32b")
    ap.add_argument("--reduced", action="store_true", default=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--compression", default="none",
                    choices=["none", "bf16", "int8"])
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--resume", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    ocfg = OptimizerConfig(lr=args.lr, warmup_steps=5, total_steps=args.steps)
    tcfg = TrainConfig(microbatches=args.microbatches, remat=False,
                       compression=args.compression)
    data = SyntheticLM(DataConfig(
        vocab_size=cfg.vocab_size, seq_len=args.seq, global_batch=args.batch,
        frontend=cfg.frontend,
        d_model=cfg.d_model,
        frontend_len=args.seq // 2 if cfg.frontend != "none" else 0))

    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(params, ocfg)
    err = compression.init_error_feedback(params)
    step_fn = jax.jit(make_train_step(cfg, ocfg, tcfg))
    start = 0

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if mgr and args.resume and mgr.latest_step() is not None:
        st = mgr.restore({"params": params, "opt": opt, "err": err})
        params, opt, err = st["params"], st["opt"], st["err"]
        start = st["host"]["data_step"]
        print(f"resumed from step {start}")

    for i in range(start, args.steps):
        t0 = time.perf_counter()
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        if cfg.family == "encdec":
            batch["extra_embeds"] = batch.get(
                "extra_embeds",
                jnp.zeros((args.batch, args.seq // 2, cfg.d_model), jnp.bfloat16))
        params, opt, err, m = step_fn(params, opt, err, batch)
        dt = time.perf_counter() - t0
        print(f"step {i:4d} loss {float(m['loss']):.4f} "
              f"gnorm {float(m['grad_norm']):.3f} lr {float(m['lr']):.2e} "
              f"{dt*1e3:.0f}ms")
        if mgr and (i + 1) % args.ckpt_every == 0:
            mgr.save(i + 1, {"params": params, "opt": opt, "err": err,
                             "host": {"data_step": i + 1}})
    if mgr:
        mgr.wait()
    return params


if __name__ == "__main__":
    main()
