"""Tuned XLA flag profiles + host-allocator hygiene (DESIGN.md §11).

A *profile* is a named bundle of XLA_FLAGS and process-environment
settings that shape how XLA schedules the compiled step around data
movement: the latency-hiding scheduler, pipelined collectives, combine
thresholds sized so collective fusion does not serialize against the
swap/COW DMA stream, and the tcmalloc / logging hygiene the staging
buffers want on the host side.

The flags must be in the environment BEFORE jax (and through it XLA)
initializes, so this module deliberately imports no jax: callers apply a
profile from a pre-import bootstrap (``serve.py --xla-profile`` when run
as ``__main__``; ``benchmarks/run.py --xla-profile`` before it imports
the bench modules). ``apply_profile`` appends to any user-provided
XLA_FLAGS rather than clobbering them, and records the active profile in
``REPRO_XLA_PROFILE`` so bench artifacts can report what they ran under
(BENCH_SCHEMA.md).

``LD_PRELOAD`` (tcmalloc) cannot take effect from inside a running
process — ``shell_exports`` emits the full launch-script preamble for
operators who want the allocator swap too (SNIPPETS.md provenance:
MaxText's serving/training launch environments).
"""
from __future__ import annotations

import os
from typing import Dict, List

_ENV_KEY = "REPRO_XLA_PROFILE"

# Combine thresholds follow the MaxText serving recipe: all-gather fuses
# aggressively (1 GiB) because gathered params are consumed immediately;
# reduce-scatter stays fine-grained (32 MiB) so it pipelines into the
# backward/collective stream instead of forming one monolithic barrier.
PROFILES: Dict[str, dict] = {
    # no-op baseline: whatever the environment already had
    "default": {"xla_flags": [], "env": {}},
    # latency-hiding serving profile: overlap collectives + DMA with
    # compute, double-buffer while-loop state, keep rematerialization off
    # the (inference) graphs, and silence host-allocator noise
    "latency_hiding": {
        "xla_flags": [
            "--xla_gpu_enable_latency_hiding_scheduler=true",
            "--xla_gpu_enable_highest_priority_async_stream=true",
            "--xla_gpu_all_reduce_combine_threshold_bytes=134217728",
            "--xla_gpu_all_gather_combine_threshold_bytes=1073741824",
            "--xla_gpu_reduce_scatter_combine_threshold_bytes=33554432",
            "--xla_gpu_enable_pipelined_all_gather=true",
            "--xla_gpu_enable_pipelined_reduce_scatter=true",
            "--xla_gpu_enable_pipelined_all_reduce=true",
            "--xla_gpu_enable_while_loop_double_buffering=true",
            "--xla_gpu_enable_all_gather_combine_by_dim=false",
            "--xla_gpu_enable_reduce_scatter_combine_by_dim=false",
            "--xla_disable_hlo_passes=rematerialization",
        ],
        "env": {
            "TF_CPP_MIN_LOG_LEVEL": "4",
            "TCMALLOC_LARGE_ALLOC_REPORT_THRESHOLD": "60000000000",
        },
    },
}

# shell-level preamble (launch scripts only): the allocator swap needs
# LD_PRELOAD before the interpreter starts, not just before jax does
_TCMALLOC_SO = "/usr/lib/x86_64-linux-gnu/libtcmalloc.so.4"


def profile_names() -> List[str]:
    return sorted(PROFILES)


def profile_flags(name: str) -> List[str]:
    """The XLA_FLAGS tokens a profile contributes (no env mutation)."""
    return list(PROFILES[name]["xla_flags"])


def apply_profile(name: str) -> dict:
    """Install a profile into the process environment. Appends to any
    existing XLA_FLAGS (user flags win by coming first — XLA takes the
    last occurrence of a repeated flag, and ours are appended only when
    not already present) and setdefault()s the hygiene env vars. Must run
    before jax initializes to have any effect on compilation; calling it
    later still records the profile name for artifact reporting.

    Returns {"profile", "xla_flags", "env", "late"} — ``late`` is True
    when jax was already imported, i.e. the flags may not have reached
    XLA for this process."""
    prof = PROFILES[name]
    import sys
    late = "jax" in sys.modules
    existing = os.environ.get("XLA_FLAGS", "")
    added = [f for f in prof["xla_flags"]
             if f.split("=", 1)[0] not in existing]
    if added:
        os.environ["XLA_FLAGS"] = (existing + " " + " ".join(added)).strip()
    for k, v in prof["env"].items():
        os.environ.setdefault(k, v)
    os.environ[_ENV_KEY] = name
    return {"profile": name, "xla_flags": added,
            "env": dict(prof["env"]), "late": late}


def active_profile() -> str:
    """The profile this process (or a parent launcher) applied; 'default'
    when none was."""
    return os.environ.get(_ENV_KEY, "default")


def shell_exports(name: str) -> str:
    """Launch-script preamble for a profile, tcmalloc preload included
    (the parts ``apply_profile`` cannot do from inside the process)."""
    prof = PROFILES[name]
    lines = [f"export LD_PRELOAD={_TCMALLOC_SO}"]
    for k, v in prof["env"].items():
        lines.append(f"export {k}={v}")
    if prof["xla_flags"]:
        lines.append('export XLA_FLAGS="$XLA_FLAGS '
                     + " ".join(prof["xla_flags"]) + '"')
    lines.append(f"export {_ENV_KEY}={name}")
    return "\n".join(lines)
