import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape) cell
on the production mesh, print memory/cost analysis, extract roofline terms.

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-32b \
        --shape decode_32k --mesh single
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh both

Results are cached as JSON under experiments/dryrun/ (one file per cell);
existing files are skipped so the 40-cell x 2-mesh sweep is resumable.

The two os.environ lines above MUST stay the first statements in this module:
jax locks the device count at first initialization.
"""
import argparse
import json
import time
import traceback
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config
from repro.configs.base import ModelConfig, ServingConfig, ShapeConfig
from repro.core.descriptor import FrameDescriptor
from repro.distributed import sharding as shd
from repro.launch.mesh import make_production_mesh, mesh_context
from repro.models import registry
from repro.roofline import analysis
from repro.training.optimizer import OptimizerConfig, OptState
from repro.training.train_loop import TrainConfig, make_train_step

BLOCK_TOKENS = 64          # BLOCKALIGN quantum: 64 tok x kv_width ~ tau bytes

# ---- §Perf variant knobs (set per run_cell call) --------------------------
VARIANT_OPTS = {}

VARIANTS = {
    # hillclimb iterations (EXPERIMENTS.md §Perf)
    "bf16scores":  {"score_dtype": "bfloat16"},
    "accbf16":     {"accum_dtype": "bfloat16"},
    "both16":      {"score_dtype": "bfloat16", "accum_dtype": "bfloat16"},
    "ep_off":      {"ep_off": True},
    "cf10":        {"capacity_factor": 1.0},
    "noremat":     {"no_remat": True},
    "mb4":         {"microbatches": 4},
    "ropeil":      {"rope_pairing": "interleaved"},
    "ropeil16":    {"rope_pairing": "interleaved", "score_dtype": "bfloat16"},
    "epfix":       {},   # post-fix MoE EP resharding (code default now)
    "timechunk":   {},   # post-fix xlstm chunked-time remat (code default)
    "notimechunk": {"time_chunk": 0},
    "qpsum":       {"q_model_constraint": True},
    "qpsum16":     {"q_model_constraint": True, "score_dtype": "bfloat16"},
}
OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


# ---------------------------------------------------------------------------
# geometry helpers
# ---------------------------------------------------------------------------

def serving_plan(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Decode-cell geometry: window, far-view, pool sizing, semantics tag."""
    if shape.name == "long_500k":
        if cfg.sub_quadratic:
            # native sub-quadratic (SSM/hybrid): bounded window on attention
            # sites, O(1) recurrent state; dense long-context is native.
            return dict(near_window=512, farview=False, semantics="native")
        # full-attention archs: paper's optional bounded-budget policy
        return dict(near_window=512, farview=True, cap=64, sv_chunk=128,
                    semantics="bounded-budget")
    # decode_32k: dense semantics — kernel width = full history
    return dict(near_window=shape.seq_len, farview=False, semantics="dense")


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


def decode_geometry(cfg: ModelConfig, shape: ShapeConfig, groups: int) -> dict:
    plan = serving_plan(cfg, shape)
    W = plan["near_window"]
    bt = BLOCK_TOKENS
    NB = -(-W // bt) + 1
    B_loc = max(1, shape.global_batch // groups)
    g_eff = min(groups, shape.global_batch)
    if plan.get("farview"):
        blocks_per_seq = NB + plan["sv_chunk"] // bt + 2
        max_chunks = _round_up((shape.seq_len - W) // plan["sv_chunk"] + 1, 8)
    else:
        blocks_per_seq = -(-shape.seq_len // bt) + 1
        max_chunks = 0
    P_loc = B_loc * blocks_per_seq + 1
    return dict(plan=plan, W=W, bt=bt, NB=NB, B_loc=B_loc, groups=g_eff,
                P_loc=P_loc, max_chunks=max_chunks,
                cap=plan.get("cap", 1), MT=NB + 1,
                chunk_blocks=max(1, plan.get("sv_chunk", bt) // bt))


# ---------------------------------------------------------------------------
# input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig, groups: int) -> dict:
    """Returns dict with 'batch' (train/prefill) or decode-cell structures."""
    s = jax.ShapeDtypeStruct
    if shape.kind in ("train", "prefill"):
        B = shape.global_batch
        S = shape.seq_len
        out = {}
        if cfg.family == "encdec":
            out["tokens"] = s((B, S // 2), jnp.int32)
            out["extra_embeds"] = s((B, S // 2, cfg.d_model), jnp.bfloat16)
        elif cfg.frontend == "vision_stub":
            out["tokens"] = s((B, S), jnp.int32)
            out["extra_embeds"] = s((B, min(256, S // 2), cfg.d_model),
                                    jnp.bfloat16)
        else:
            out["tokens"] = s((B, S), jnp.int32)
        return out

    g = decode_geometry(cfg, shape, groups)
    G, B_loc = g["groups"], g["B_loc"]
    tokens = s((G, B_loc), jnp.int32)
    pools = registry.decode_pool_shapes(
        cfg, batch=B_loc, num_blocks=g["P_loc"], block_tokens=g["bt"],
        max_chunks=g["max_chunks"],
        enc_len=4096 if cfg.family == "encdec" else 0)
    pools = jax.tree.map(lambda x: s((G,) + x.shape, x.dtype), pools)
    i32 = lambda *sh: s(sh, jnp.int32)
    descr = FrameDescriptor(
        block_table=i32(G, B_loc, g["NB"]), window_base=i32(G, B_loc),
        seq_lens=i32(G, B_loc), slot_active=i32(G, B_loc),
        write_block=i32(G, B_loc), write_offset=i32(G, B_loc),
        train_start=i32(G, B_loc, g["MT"]), train_len=i32(G, B_loc, g["MT"]),
        train_dst=i32(G, B_loc, g["MT"]),
        far_table=i32(G, B_loc, g["cap"]), far_valid=i32(G, B_loc, g["cap"]),
        far_chunk_blocks=i32(G, B_loc, g["chunk_blocks"]),
        far_chunk_tokens=i32(G, B_loc), far_do_summarize=i32(G, B_loc),
        far_write_idx=i32(G, B_loc), epoch=i32(G))
    return {"tokens": tokens, "pools": pools, "descr": descr, "geom": g}


# ---------------------------------------------------------------------------
# cell builders: (fn, example_args, in_shardings)
# ---------------------------------------------------------------------------

def build_train_cell(cfg, shape, mesh):
    groups = shd.data_shards(mesh)
    ba = shd.batch_axes(mesh)
    bspec = ba if len(ba) > 1 else ba[0]
    moe_ep = cfg.family == "moe"
    ep_axes = (bspec if moe_ep else None)

    rows_per_shard = max(1, shape.global_batch // groups)
    v = VARIANT_OPTS
    if v.get("ep_off"):
        ep_axes = None
    tcfg = TrainConfig(microbatches=v.get("microbatches", rows_per_shard),
                       remat=not v.get("no_remat", False),
                       token_groups=groups, ep_axes=ep_axes,
                       batch_axes=bspec,
                       accum_dtype=v.get("accum_dtype", "float32"),
                       compression="bf16" if "pod" in mesh.axis_names else "none")
    ocfg = OptimizerConfig(
        moment_dtype="bfloat16" if cfg.param_count() > 1e11 else "float32")
    step = make_train_step(cfg, ocfg, tcfg)

    params_sh = jax.eval_shape(lambda k: registry.init_params(k, cfg),
                               jax.random.PRNGKey(0))
    pspecs = shd.param_specs(cfg, params_sh, ep_axes=ep_axes)
    opt_sh = jax.eval_shape(
        lambda p: OptState(step=jnp.zeros((), jnp.int32), mu=p, nu=p), params_sh)
    ospecs = OptState(step=P(), mu=pspecs, nu=pspecs)
    err_sh = params_sh
    especs = pspecs

    ins = input_specs(cfg, shape, groups)
    batch_sh = {k: v for k, v in ins.items()}
    bspecs = {k: P(bspec, *([None] * (len(v.shape) - 1)))
              for k, v in batch_sh.items()}

    def fn(params, opt, err, batch):
        return step(params, opt, err, batch)

    pspecs = shd.sanitize_specs(mesh, params_sh, pspecs)
    ospecs = OptState(step=P(), mu=pspecs, nu=pspecs)
    especs = pspecs
    bspecs = shd.sanitize_specs(mesh, batch_sh, bspecs)
    args = (params_sh, opt_sh, err_sh, batch_sh)
    in_sh = (shd.to_shardings(mesh, pspecs), shd.to_shardings(mesh, ospecs),
             shd.to_shardings(mesh, especs),
             shd.to_shardings(mesh, bspecs))
    return fn, args, in_sh


def build_prefill_cell(cfg, shape, mesh):
    groups = shd.data_shards(mesh)
    ba = shd.batch_axes(mesh)
    bspec = ba if len(ba) > 1 else ba[0]
    moe_ep = cfg.family == "moe" and not VARIANT_OPTS.get("ep_off")
    ep_axes = (bspec if moe_ep else None)

    params_sh = jax.eval_shape(lambda k: registry.init_params(k, cfg),
                               jax.random.PRNGKey(0))
    pspecs = shd.param_specs(cfg, params_sh,
                             ep_axes=(bspec if cfg.family == "moe" else None))
    ins = input_specs(cfg, shape, groups)
    bspecs = {k: P(bspec, *([None] * (len(v.shape) - 1)))
              for k, v in ins.items()}

    kw = {}
    if cfg.family == "moe":
        kw = dict(token_groups=groups, ep_axes=ep_axes)

    def fn(params, batch):
        tokens = batch["tokens"]
        extra = batch.get("extra_embeds")
        if extra is not None:
            logits = registry.forward(params, cfg, tokens, extra_embeds=extra,
                                      remat=True, **kw)
        else:
            out = registry.forward(params, cfg, tokens, remat=True, **kw)
            logits = out[0] if isinstance(out, tuple) else out
        # serving prefill emits the LAST position's logits (first new token)
        return logits[:, -1, :]

    pspecs = shd.sanitize_specs(mesh, params_sh, pspecs)
    bspecs = shd.sanitize_specs(mesh, ins, bspecs)
    return fn, (params_sh, ins), (shd.to_shardings(mesh, pspecs),
                                  shd.to_shardings(mesh, bspecs))


def build_decode_cell(cfg, shape, mesh):
    groups = shd.data_shards(mesh)
    ba = shd.batch_axes(mesh)
    bspec = ba if len(ba) > 1 else ba[0]

    moe_ep = cfg.family == "moe"
    params_sh = jax.eval_shape(lambda k: registry.init_params(k, cfg),
                               jax.random.PRNGKey(0))
    # expert STORAGE stays EP-sharded in decode (memory posture); compute-side
    # EP all-to-all for decode is a §Perf item (see EXPERIMENTS.md)
    pspecs = shd.param_specs(cfg, params_sh, ep_axes=(bspec if moe_ep else None))
    ins = input_specs(cfg, shape, groups)
    geom = ins.pop("geom")
    W = geom["W"]

    sv = ServingConfig(near_window=W, farview_cap=geom["cap"],
                       sv_chunk=geom["plan"].get("sv_chunk", 128),
                       enable_farview=geom["plan"].get("farview", False))
    cfg_dec = cfg.replace(serving=sv)
    kw = {}
    if cfg.family == "moe":
        kw = dict(token_groups=1, ep_axes=None)  # EP-decode: see §Perf

    def one_group(params, tokens_g, pools_g, descr_g):
        logits, pools2, fu = registry.decode_step(params, cfg_dec, tokens_g,
                                                  pools_g, descr_g, **kw)
        return jnp.argmax(logits, axis=-1).astype(jnp.int32), pools2, fu

    def fn(params, tokens, pools, descr):
        return jax.vmap(one_group, in_axes=(None, 0, 0, 0))(
            params, tokens, pools, descr)

    # shardings: leading G over batch axes; pool payload kv-heads over model
    pool_specs = shd.grouped_pool_specs(cfg, ins["pools"], bspec)
    descr_specs = jax.tree.map(
        lambda x: P(bspec, *([None] * (len(x.shape) - 1))), ins["descr"])
    tok_spec = P(bspec, None)
    pspecs = shd.sanitize_specs(mesh, params_sh, pspecs)
    pool_specs = shd.sanitize_specs(mesh, ins["pools"], pool_specs)
    descr_specs = shd.sanitize_specs(mesh, ins["descr"], descr_specs)
    tok_spec = shd.sanitize_specs(mesh, ins["tokens"], tok_spec)
    args = (params_sh, ins["tokens"], ins["pools"], ins["descr"])
    in_sh = (shd.to_shardings(mesh, pspecs),
             NamedSharding(mesh, tok_spec),
             shd.to_shardings(mesh, pool_specs),
             shd.to_shardings(mesh, descr_specs))
    return fn, args, in_sh


BUILDERS = {"train": build_train_cell, "prefill": build_prefill_cell,
            "decode": build_decode_cell}


# ---------------------------------------------------------------------------
# run one cell
# ---------------------------------------------------------------------------

def run_cell(arch: str, shape_name: str, mesh_name: str,
             out_dir: str = OUT_DIR, force: bool = False,
             variant: str = "", cfg_override=None) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    tag = f"{arch}__{shape_name}__{mesh_name}" + (f"__{variant}" if variant else "")
    path = os.path.join(out_dir, tag + ".json")
    if os.path.exists(path) and not force:
        with open(path) as f:
            return json.load(f)

    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_name == "multi"))
    builder = BUILDERS[shape.kind]

    global VARIANT_OPTS
    VARIANT_OPTS = dict(VARIANTS.get(variant, {}))
    import jax.numpy as _jnp
    from repro.models import common as _cm
    from repro.models import moe as _moe
    _cm.set_score_dtype(_jnp.bfloat16 if VARIANT_OPTS.get("score_dtype") ==
                        "bfloat16" else _jnp.float32)
    _cm.set_rope_pairing(VARIANT_OPTS.get("rope_pairing", "half"))
    _moe.CAPACITY_FACTOR = VARIANT_OPTS.get("capacity_factor", 1.25)
    from repro.models import xlstm as _xl
    _xl.set_time_chunk(VARIANT_OPTS.get("time_chunk", 256))

    t0 = time.time()
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_name,
           "variant": variant, "chips": int(mesh.size)}
    try:
        fn, args, in_sh = builder(cfg, shape, mesh)
        donate = {"decode": (2,), "train": (0, 1, 2), "prefill": ()}[shape.kind]
        from repro.distributed.act_sharding import use_batch_axes, use_model_axis
        ba = shd.batch_axes(mesh)
        act_axes = (ba if len(ba) > 1 else ba[0]) \
            if shape.kind in ("train", "prefill") else None
        q_model = ("model" if (VARIANT_OPTS.get("q_model_constraint")
                               and shape.kind == "decode") else None)
        with mesh_context(mesh), use_batch_axes(act_axes), \
                use_model_axis(q_model):
            lowered = jax.jit(fn, in_shardings=in_sh,
                              donate_argnums=donate).lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        ma = compiled.memory_analysis()
        cost = compiled.cost_analysis()
        if isinstance(cost, (list, tuple)):   # jax 0.4.x: list of dicts
            cost = cost[0] if cost else {}
        hlo = compiled.as_text()
        vis = (serving_plan(cfg, shape)["near_window"]
               if shape.kind == "decode" else None)
        if shape.kind == "decode" and serving_plan(cfg, shape).get("farview"):
            plan = serving_plan(cfg, shape)
            vis = plan["near_window"] + plan["cap"] * plan["sv_chunk"]
        roof = analysis.summarize(cost, hlo, cfg, shape, arch, shape_name,
                                  mesh_name, int(mesh.size),
                                  visible_window=vis)
        rec.update({
            "ok": True,
            "lower_s": round(t_lower, 2), "compile_s": round(t_compile, 2),
            "memory": {
                "argument_bytes_per_device": int(ma.argument_size_in_bytes),
                "output_bytes_per_device": int(ma.output_size_in_bytes),
                "temp_bytes_per_device": int(ma.temp_size_in_bytes),
                "alias_bytes_per_device": int(ma.alias_size_in_bytes),
                "total_bytes_per_device": int(ma.argument_size_in_bytes
                                              + ma.output_size_in_bytes
                                              + ma.temp_size_in_bytes
                                              - ma.alias_size_in_bytes),
            },
            "roofline": roof.to_dict(),
            "semantics": (serving_plan(cfg, shape)["semantics"]
                          if shape.kind == "decode" else "dense"),
        })
        print(f"[OK] {tag}: compile {t_compile:.1f}s "
              f"mem/dev {rec['memory']['total_bytes_per_device']/2**30:.2f}GiB "
              f"bottleneck {roof.bottleneck} "
              f"roofline {roof.roofline_fraction:.3f}")
    except Exception as e:
        rec.update({"ok": False, "error": f"{type(e).__name__}: {e}",
                    "traceback": traceback.format_exc()[-2000:]})
        print(f"[FAIL] {tag}: {type(e).__name__}: {str(e)[:200]}")
    with open(path, "w") as f:
        json.dump(rec, f, indent=1, default=str)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=list(ARCH_IDS) + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    archs = list(ARCH_IDS) if (args.all or args.arch is None) else [args.arch]
    shapes = list(SHAPES) if (args.all or args.shape is None) else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    n_ok = n_fail = 0
    for mesh_name in meshes:
        for arch in archs:
            for shape_name in shapes:
                rec = run_cell(arch, shape_name, mesh_name, out_dir=args.out,
                               force=args.force)
                n_ok += bool(rec.get("ok"))
                n_fail += not rec.get("ok")
    print(f"\ndone: {n_ok} ok, {n_fail} failed")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
