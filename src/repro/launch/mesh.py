"""Mesh construction (production dry-run + serving engine). Importing this
module never touches JAX device state — meshes are built only inside the
functions.

Single pod : (data=16, model=16)            = 256 chips
Multi-pod  : (pod=2, data=16, model=16)     = 512 chips
Engine     : (data=d, model=m) from ``--mesh dxm`` (launch/serve.py); each
             data row is one replicated engine lane, the model axis carries
             tensor-parallel decode (DESIGN.md §4).

Version compat: ``jax.sharding.AxisType`` / ``axis_types=`` and
``jax.set_mesh`` only exist on newer jax; this container pins jax 0.4.37.
All mesh construction and mesh-context entry goes through the helpers below
so the rest of the repo stays version-agnostic.
"""
from __future__ import annotations

from contextlib import contextmanager

import jax
import numpy as np
from jax.sharding import Mesh

try:  # jax >= 0.5: explicit/auto axis types
    from jax.sharding import AxisType
    _AXIS_TYPES = True
except ImportError:  # jax 0.4.x: all axes behave as Auto
    AxisType = None
    _AXIS_TYPES = False


def make_mesh(shape, axes) -> Mesh:
    """jax.make_mesh with Auto axis types where the API supports them."""
    if _AXIS_TYPES:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


@contextmanager
def mesh_context(mesh: Mesh):
    """Enter a mesh scope: ``jax.set_mesh`` on new jax, ``with mesh:`` on
    0.4.x (both make the mesh ambient for bare-PartitionSpec constraints)."""
    if hasattr(jax, "set_mesh"):
        with jax.set_mesh(mesh):
            yield mesh
    else:
        with mesh:
            yield mesh


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_debug_mesh(n_devices: int | None = None):
    """Tiny mesh over whatever devices exist (tests on CPU)."""
    n = n_devices or len(jax.devices())
    model = 1
    for cand in (4, 2, 1):
        if n % cand == 0:
            model = cand
            break
    return make_mesh((n // model, model), ("data", "model"))


# ---------------------------------------------------------------------------
# serving-engine meshes (launch/serve.py --mesh dxm; DESIGN.md §4)
# ---------------------------------------------------------------------------

def parse_mesh_spec(spec: str) -> tuple[int, int]:
    """'dxm' -> (data, model), e.g. '1x2' -> (1, 2). '' / 'none' -> (1, 1)."""
    if not spec or spec.lower() == "none":
        return (1, 1)
    parts = spec.lower().split("x")
    if len(parts) != 2:
        raise ValueError(f"--mesh expects 'DxM' (e.g. 2x2), got {spec!r}")
    d, m = int(parts[0]), int(parts[1])
    if d < 1 or m < 1:
        raise ValueError(f"mesh axes must be >= 1, got {spec!r}")
    return d, m


def make_engine_mesh(data: int, model: int) -> Mesh:
    """(data, model) mesh over the first data*model local devices."""
    devs = jax.devices()
    need = data * model
    if len(devs) < need:
        raise ValueError(
            f"mesh {data}x{model} needs {need} devices, have {len(devs)} "
            f"(set XLA_FLAGS=--xla_force_host_platform_device_count={need} "
            f"before jax initializes for CPU testing)")
    arr = np.array(devs[:need]).reshape(data, model)
    return Mesh(arr, ("data", "model"))


def lane_meshes(mesh: Mesh) -> list[Mesh]:
    """One single-axis ('model',) submesh per data row: each lane hosts one
    replicated serving engine whose params/KV pools shard over its row."""
    if "data" not in mesh.axis_names or mesh.shape["data"] == 1:
        devs = np.array(mesh.devices).reshape(-1)
        return [Mesh(devs, ("model",))]
    rows = np.array(mesh.devices).reshape(mesh.shape["data"], -1)
    return [Mesh(rows[i], ("model",)) for i in range(rows.shape[0])]


def lane_meshes_for_spec(spec: str) -> list:
    """Lane meshes for a '--mesh DxM' spec; the 1x1 spec maps to ``[None]``
    (single-device engine, seed-exact placement) so callers — the
    ``serving.build`` factory — need no special case."""
    d, m = parse_mesh_spec(spec)
    if (d, m) == (1, 1):
        return [None]
    return lane_meshes(make_engine_mesh(d, m))
