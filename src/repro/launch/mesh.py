"""Production mesh construction. Importing this module never touches JAX
device state — meshes are built only inside the function.

Single pod : (data=16, model=16)            = 256 chips
Multi-pod  : (pod=2, data=16, model=16)     = 512 chips
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=(AxisType.Auto,) * len(axes))


def make_debug_mesh(n_devices: int | None = None):
    """Tiny mesh over whatever devices exist (tests on CPU)."""
    n = n_devices or len(jax.devices())
    model = 1
    for cand in (4, 2, 1):
        if n % cand == 0:
            model = cand
            break
    return jax.make_mesh((n // model, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)
