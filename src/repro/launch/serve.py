"""Serving launcher: run a workload through the KV-RM engine (or the
static-arena baseline) and print throughput / tail latency / memory /
transport / invariant audits.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-32b \
        --mode paged_merge --workload mixed --requests 32
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro.configs import get_reduced
from repro.core.engine import EngineConfig, KVRMEngine
from repro.data import traces
from repro.models import registry


def build_engine(arch: str, mode: str, batch: int, max_seq: int,
                 near_window=None, seed: int = 0, **kw) -> KVRMEngine:
    cfg = get_reduced(arch)
    params = registry.init_params(jax.random.PRNGKey(seed), cfg)
    ecfg = EngineConfig(mode=mode, batch=batch, max_seq=max_seq,
                        near_window=near_window, block_tokens=8, **kw)
    return KVRMEngine(cfg, params, ecfg)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-32b")
    ap.add_argument("--mode", default="paged_merge",
                    choices=["arena", "paged", "paged_merge", "full"])
    ap.add_argument("--workload", default="mixed",
                    choices=["mixed", "predictable", "replay"])
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--token-scale", type=float, default=0.25)
    ap.add_argument("--json", action="store_true")
    args = ap.parse_args(argv)

    eng = build_engine(args.arch, args.mode, args.batch, args.max_seq)
    tcfg = traces.TraceConfig(n_requests=args.requests,
                              vocab=eng.cfg.vocab_size,
                              token_scale=args.token_scale)
    gen = {"mixed": traces.mixed_length_workload,
           "predictable": traces.predictable_workload,
           "replay": traces.azure_like_replay}[args.workload]
    reqs = gen(tcfg)
    print("workload:", traces.trace_summary(reqs))
    for r in reqs:
        eng.submit(r)

    if args.workload == "replay":
        # virtual-time replay: arrivals gate admission
        t0 = None
        import time as _t
        t0 = _t.perf_counter()
        scale = 0.02  # compress the 60s window for CPU runs
        eng.run(max_steps=100_000,
                now_fn=lambda: (_t.perf_counter() - t0) / scale)
    else:
        eng.run(max_steps=100_000)

    out = {"audit": eng.audit(), "latency": eng.latency_stats(),
           "throughput_tok_s": eng.throughput(),
           "finished": len(eng.sched.finished)}
    if args.json:
        print(json.dumps(out, indent=1, default=float))
    else:
        for k, v in out.items():
            print(f"{k}: {v}")
    return out


if __name__ == "__main__":
    main()
