"""Serving launcher: run a workload through the KV-RM engine (or the
static-arena baseline) and print throughput / tail latency / memory /
transport / invariant audits.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-32b \
        --mode paged_merge --workload mixed --requests 32

SPMD serving (DESIGN.md §4): ``--mesh DxM`` runs D data-parallel engine
lanes, each lane one replicated engine whose params and KV pools shard
M-ways over its row's `model` axis. The trace is striped round-robin over
lanes; lanes are stepped round-robin so their (async) device work overlaps.

Oversubscription (DESIGN.md §8): ``--kv-oversubscribe R`` (R > 1) or
``--host-pool-blocks N`` enables the host KV tier — the device pool may
be smaller than the admitted working set; bursts are absorbed by cold
swap-out and preemption-aware scheduling below the fixed descriptor
interface. ``audit()`` splits admission stalls into compute-bound
(``admit_blocked_no_slot``) vs memory-bound
(``admit_blocked_kv_watermark``) so operators can tell which resource is
gating the queue.

    PYTHONPATH=src python -m repro.launch.serve --workload replay \
        --requests 48 --kv-oversubscribe 1.5

Shared-prefix reuse (DESIGN.md §9): ``--prefix-cache`` indexes committed
prompt blocks in a radix tree and COW-aliases matches at admission —
repeated system prompts skip their prefill entirely, bitwise-identically.
``--workload shared_prefix`` generates the matching multi-tenant trace;
``audit()`` reports ``prefix_hits`` / ``prefix_tokens_reused`` /
``cow_copies``.

    PYTHONPATH=src python -m repro.launch.serve --workload shared_prefix \
        --requests 32 --prefix-cache

Quantized KV tier (DESIGN.md §10): ``--kv-dtype fp8_e4m3 | int8`` stores
KV blocks narrow with per-block per-head scales — ~2x less reserved KV
and half the swap/COW bytes (``quant_bytes_saved``), composable with all
of the above. ``--kv-dtype bf16`` (default) is bitwise-identical to seed.

    PYTHONPATH=src python -m repro.launch.serve --workload mixed \
        --requests 32 --kv-dtype fp8_e4m3

Every flag and every ``audit()`` counter is tabulated with the invariant
it witnesses in docs/OPERATIONS.md.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m repro.launch.serve --mesh 2x2
    (when launched as __main__ the flag is set automatically for CPU runs)
"""
from __future__ import annotations

# --mesh / --xla-profile bootstrap: the forced host-device count and the
# tuned XLA flag profile must be set BEFORE jax initializes, which is
# before this module's own jax import when run as a script. Only touches
# CPU runs that didn't set a device count themselves.
import os
import sys

if __name__ == "__main__":
    _spec = _prof = None
    for _i, _a in enumerate(sys.argv):
        if _a == "--mesh" and _i + 1 < len(sys.argv):
            _spec = sys.argv[_i + 1]
        elif _a.startswith("--mesh="):
            _spec = _a.split("=", 1)[1]
        elif _a == "--xla-profile" and _i + 1 < len(sys.argv):
            _prof = sys.argv[_i + 1]
        elif _a.startswith("--xla-profile="):
            _prof = _a.split("=", 1)[1]
    if _prof is not None:
        from repro.launch import xla_flags as _xf
        if _prof in _xf.PROFILES:      # unknown name -> argparse errors later
            _xf.apply_profile(_prof)
    if _spec is not None:
        try:
            _d, _m = (int(x) for x in _spec.lower().split("x"))
            if "xla_force_host_platform_device_count" not in \
                    os.environ.get("XLA_FLAGS", ""):
                os.environ["XLA_FLAGS"] = (
                    os.environ.get("XLA_FLAGS", "")
                    + f" --xla_force_host_platform_device_count={_d * _m}"
                ).strip()
        except ValueError:
            pass

import argparse
import json
import time

import numpy as np

from repro.configs.base import SamplingConfig
from repro.core.engine import KVRMEngine
from repro.data import traces
from repro.launch import xla_flags


def build_engine(arch: str, mode: str, batch: int, max_seq: int,
                 near_window=None, seed: int = 0, mesh=None,
                 params=None, **kw) -> KVRMEngine:
    """Thin shim over the consolidated ``serving.build`` factory (§14)."""
    from repro.serving.factory import build
    return build(arch, mode=mode, batch=batch, max_seq=max_seq,
                 near_window=near_window, seed=seed, mesh=mesh,
                 params=params, **kw)[0]


def build_lanes(arch: str, mode: str, batch: int, max_seq: int,
                mesh_spec: str, **kw) -> list:
    """One replicated engine per `data` row of the requested mesh; params
    are initialized once (cached) and placed per lane. Delegates to
    ``serving.build`` (§14) — the one construction path for serve,
    benchmarks and the gateway."""
    from repro.serving.factory import build
    return build(arch, mode=mode, batch=batch, max_seq=max_seq,
                 mesh_spec=mesh_spec, seed=kw.pop("seed", 0), **kw)


def run_lanes(engines: list, reqs, *, max_steps: int = 100_000,
              now_fn=None) -> dict:
    """Stripe requests round-robin over lanes, step lanes round-robin (their
    dispatched device work overlaps), and aggregate the lane audits.

    ``aggregate_tok_s`` measures steady state: the clock starts after the
    first round of steps (which pays each lane's one-time executor compile —
    seconds on CPU, and systematically larger for sharded executors), and
    the first round's emissions are excluded from the numerator, matching
    the warmup-skipping convention of ``KVRMEngine.throughput``.
    ``wall_tok_s`` keeps the raw end-to-end figure, compile included."""
    for i, r in enumerate(reqs):
        engines[i % len(engines)].submit(r)
    t0 = time.perf_counter()
    t_warm = t0
    warm_tok = 0
    steps = 0
    while steps < max_steps:
        busy = False
        for eng in engines:
            if eng.sched.waiting or eng.sched.preempted \
                    or eng.sched.active_slots():
                eng.step(now=now_fn() if now_fn else float("inf"))
                busy = True
        if steps == 0:
            t_warm = time.perf_counter()
            warm_tok = sum(m.emitted for e in engines for m in e.metrics)
        steps += 1
        if not busy:
            break
    for eng in engines:
        eng.flush()
    end = time.perf_counter()

    tok = sum(sum(len(r.generated) for r in e.sched.finished) for e in engines)
    emitted = sum(m.emitted for e in engines for m in e.metrics)
    out = {
        "lanes": len(engines),
        "finished": sum(len(e.sched.finished) for e in engines),
        "tokens": tok,
        "aggregate_tok_s": (emitted - warm_tok) / max(end - t_warm, 1e-12),
        "wall_tok_s": tok / max(end - t0, 1e-12),
        "per_lane_tok_s": [e.throughput() for e in engines],
        "audit": engines[0].audit(),
        "latency": engines[0].latency_stats(),
    }
    if len(engines) > 1:
        out["lane_audits"] = [e.audit() for e in engines[1:]]
    return out


def run_gateway(engines: list, reqs, *, slo_class: str = "standard",
                arrival_scale: float = 0.02, tenants: int = 4,
                router=None, admission=None) -> dict:
    """Open-loop serving through the asyncio gateway (DESIGN.md §14): an
    async driver submits each request at its (scaled) trace arrival and
    consumes its token-event stream; rejected/shed submissions surface as
    typed AdmissionRejected backpressure, counted not raised."""
    import asyncio

    from repro import serving

    classes = [serving.SLO_CLASSES[slo_class]] if slo_class != "mixed" \
        else [serving.INTERACTIVE, serving.STANDARD, serving.BATCH]
    jobs = [(float(r.arrival) * arrival_scale,
             serving.GenerationRequest(
                 rid=r.rid, prompt=tuple(int(t) for t in r.prompt),
                 gen_len=r.gen_len, tenant=f"tenant{i % tenants}",
                 slo=classes[i % len(classes)],
                 stop_tokens=tuple(r.stop_tokens)))
            for i, r in enumerate(reqs)]

    gw = serving.Gateway(engines, router=router, admission=admission)
    rejects = []

    async def _one(arrival, greq):
        await asyncio.sleep(max(0.0, arrival - gw.now()))
        try:
            return await gw.generate(greq)
        except serving.AdmissionRejected as e:
            rejects.append((greq.rid, e.reason))
            return None

    async def _drive():
        res = await asyncio.gather(*[_one(a, g) for a, g in jobs])
        await gw.drain()
        gw.close()
        return res

    results = [r for r in asyncio.run(_drive()) if r is not None]
    audit = gw.audit()
    lane_audits = audit.pop("lane_audits")
    ttft = sorted(r.ttft_s for r in results) or [0.0]
    tpot = sorted(r.tpot_s for r in results) or [0.0]
    p99 = lambda xs: xs[min(len(xs) - 1, int(0.99 * len(xs)))]
    return {
        "lanes": len(engines),
        "offered": len(jobs),
        "finished": len(results),
        "rejected": len(rejects),
        "tokens": sum(len(r.tokens) for r in results),
        "ttft_p50_ms": 1e3 * ttft[len(ttft) // 2],
        "ttft_p99_ms": 1e3 * p99(ttft),
        "tpot_p50_ms": 1e3 * tpot[len(tpot) // 2],
        "tpot_p99_ms": 1e3 * p99(tpot),
        "slo": gw.slo_stats(),
        "gateway_audit": audit,
        "audit": lane_audits[0],
        **({"lane_audits": lane_audits[1:]} if len(lane_audits) > 1 else {}),
        "results": {r.rid: list(r.tokens) for r in results},
    }


def build_arg_parser() -> argparse.ArgumentParser:
    """The serve CLI surface. Kept in a named builder so the operator-doc
    regression test (tests/test_docs.py) can diff every flag against
    docs/OPERATIONS.md."""
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-32b")
    ap.add_argument("--mode", default="paged_merge",
                    choices=["arena", "paged", "paged_merge", "full"])
    ap.add_argument("--workload", default="mixed",
                    choices=["mixed", "predictable", "replay",
                             "shared_prefix", "stop_token"])
    ap.add_argument("--requests", type=int, default=32)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=256)
    ap.add_argument("--token-scale", type=float, default=0.25)
    ap.add_argument("--mesh", default="1x1",
                    help="DxM device mesh: D data-parallel engine lanes, "
                         "M-way tensor-parallel decode per lane (DESIGN.md §4)")
    ap.add_argument("--pool-budget", type=float, default=1.0,
                    help="device KV pool size as a fraction of worst case")
    ap.add_argument("--kv-oversubscribe", type=float, default=1.0,
                    help="KV capacity ratio vs the device pool (> 1 enables "
                         "the host tier: host = (R-1) * device blocks, "
                         "DESIGN.md §8)")
    ap.add_argument("--host-pool-blocks", type=int, default=0,
                    help="explicit host KV tier size in blocks "
                         "(overrides --kv-oversubscribe's derivation)")
    ap.add_argument("--prefix-cache", action="store_true",
                    help="automatic shared-prefix KV reuse: index committed "
                         "prompt blocks in a radix tree and COW-alias "
                         "matches at admission (DESIGN.md §9)")
    ap.add_argument("--prefix-cache-blocks", type=int, default=0,
                    help="prefix-cache pin budget in blocks "
                         "(0 = half the device pool)")
    ap.add_argument("--kv-dtype", default="bf16",
                    choices=["bf16", "fp8_e4m3", "int8"],
                    help="KV-block storage width (DESIGN.md §10): narrow "
                         "dtypes store K/V quantized with per-block "
                         "per-head scales, halving reserved/swap/COW KV "
                         "bytes under the same descriptor interface")
    ap.add_argument("--xla-profile", default=None,
                    choices=xla_flags.profile_names(),
                    help="tuned XLA flag profile (launch/xla_flags.py, "
                         "DESIGN.md §11): applied pre-jax-import by the "
                         "__main__ bootstrap; 'latency_hiding' enables the "
                         "latency-hiding scheduler, pipelined collectives, "
                         "and combine-threshold/allocator hygiene")
    ap.add_argument("--no-async-movement", action="store_true",
                    help="disable the async movement engine (DESIGN.md "
                         "§11): swap readbacks block at the pressure event "
                         "instead of deferring behind fences — the A/B "
                         "baseline for the overlap identity gate")
    ap.add_argument("--no-kernel-skip", action="store_true",
                    help="disable active-extent work skipping in the paged "
                         "decode/prefill kernels (DESIGN.md §12): every "
                         "grid step runs its block even when fully masked "
                         "— the always-run A/B baseline for the skip "
                         "identity gate (kernel_blocks_skipped audits 0)")
    ap.add_argument("--continuous-batching", dest="continuous_batching",
                    action="store_true", default=True,
                    help="step-level admission (DESIGN.md §15, default on): "
                         "a slot freed by EOS retirement, cancel or "
                         "preemption is refilled at the very next decode "
                         "step while surviving slots keep stepping; the "
                         "gateway releases arrived requests immediately")
    ap.add_argument("--no-continuous-batching", dest="continuous_batching",
                    action="store_false",
                    help="round-based A/B baseline (DESIGN.md §15): admit "
                         "only once every active slot has drained — the "
                         "head-of-line-blocking baseline for the TTFT gate "
                         "(continuous_admits/slot_idle_steps_saved audit 0)")
    # --- on-device sampling + detected-EOS retirement (DESIGN.md §13).
    # Passing ANY of these switches the engine out of the legacy greedy
    # budget-EOS path (greedy=False); with none of them the run stays
    # bitwise-identical to seed. "Greedy with stop tokens" is
    # --temperature 0 plus --stop-token (the sampler's exact argmax
    # branch, retired at readback on the detected stop).
    ap.add_argument("--temperature", type=float, default=None,
                    help="sampling temperature (0 = exact argmax branch); "
                         "any sampling flag enables sampled decode with "
                         "detected-EOS retirement (DESIGN.md §13)")
    ap.add_argument("--top-k", type=int, default=None,
                    help="keep the k highest logits before sampling "
                         "(0 = disabled; ties at the k-th value included)")
    ap.add_argument("--top-p", type=float, default=None,
                    help="nucleus filter: smallest logit-sorted set with "
                         "mass >= p (top-1 always kept)")
    ap.add_argument("--stop-token", type=int, action="append", default=None,
                    help="token id ending a request (repeatable); stamped "
                         "on every submitted request and detected on the "
                         "readback path, one step late under pipelining")
    ap.add_argument("--seed", type=int, default=None,
                    help="sampler base PRNG key (threefry), folded with "
                         "(rid, position) per slot-step so token streams "
                         "are invariant to slot/batch/depth placement")
    # --- async serving gateway (DESIGN.md §14). Default OFF: without
    # --gateway the closed-loop replay path below is bitwise-identical to
    # seed (the gateway reuses the same engines, so the identity gate in
    # bench_gateway_slo can diff the two token streams).
    ap.add_argument("--gateway", action="store_true",
                    help="serve through the asyncio gateway (DESIGN.md "
                         "§14): typed submit/stream/cancel API, SLO-aware "
                         "admission with typed backpressure, per-tenant "
                         "fairness, prefix-affinity lane routing; off = "
                         "the closed-loop replay driver (seed-exact)")
    ap.add_argument("--arrival", default="trace",
                    choices=["trace", "poisson", "bursty"],
                    help="open-loop arrival process overriding the "
                         "workload's own arrivals (data/traces.py): "
                         "memoryless 'poisson' or Pareto-window 'bursty'; "
                         "'trace' keeps the workload's arrivals")
    ap.add_argument("--slo-class", default="standard",
                    choices=["interactive", "standard", "batch", "mixed"],
                    help="SLO class stamped on gateway requests "
                         "(serving/api.py: TTFT/TPOT targets + shed "
                         "depth); 'mixed' stripes all three classes "
                         "round-robin over the trace")
    ap.add_argument("--json", action="store_true")
    return ap


def main(argv=None):
    ap = build_arg_parser()
    args = ap.parse_args(argv)

    if (args.kv_oversubscribe > 1.0 or args.host_pool_blocks > 0) \
            and args.mesh not in ("1x1", "1X1"):
        ap.error("the host KV tier is single-device for now: "
                 "use --mesh 1x1 with --kv-oversubscribe/--host-pool-blocks")
    if args.prefix_cache and args.mesh not in ("1x1", "1X1"):
        ap.error("the prefix cache is single-device for now: "
                 "use --mesh 1x1 with --prefix-cache")

    # sampled decode (§13): any sampling flag leaves the legacy greedy path
    sampling = SamplingConfig(
        temperature=1.0 if args.temperature is None else args.temperature,
        top_k=args.top_k or 0,
        top_p=1.0 if args.top_p is None else args.top_p,
        seed=args.seed or 0,
        stop_tokens=tuple(args.stop_token or ()),
        legacy=all(v is None for v in (
            args.temperature, args.top_k, args.top_p, args.stop_token,
            args.seed)) and args.workload != "stop_token",
    )
    sample_kw = {}
    if not sampling.greedy():
        sample_kw = dict(greedy=False,
                         temperature=sampling.temperature,
                         top_k=sampling.top_k, top_p=sampling.top_p,
                         sample_seed=sampling.seed)

    engines = build_lanes(args.arch, args.mode, args.batch, args.max_seq,
                          args.mesh, pool_budget_frac=args.pool_budget,
                          **sample_kw,
                          kv_oversubscribe=args.kv_oversubscribe,
                          host_pool_blocks=args.host_pool_blocks,
                          prefix_cache=args.prefix_cache,
                          prefix_cache_blocks=args.prefix_cache_blocks,
                          kv_dtype=args.kv_dtype,
                          async_movement=not args.no_async_movement,
                          kernel_skip_extent=not args.no_kernel_skip,
                          continuous_batching=args.continuous_batching)
    tcfg = traces.TraceConfig(n_requests=args.requests,
                              vocab=engines[0].cfg.vocab_size,
                              token_scale=args.token_scale,
                              stop_tokens=sampling.stop_tokens)
    gen = {"mixed": traces.mixed_length_workload,
           "predictable": traces.predictable_workload,
           "replay": traces.azure_like_replay,
           "shared_prefix": traces.shared_prefix_workload,
           "stop_token": traces.stop_token_workload}[args.workload]
    reqs = gen(tcfg)
    if sampling.stop_tokens and args.workload != "stop_token":
        for r in reqs:
            r.stop_tokens = sampling.stop_tokens
    if args.arrival != "trace":
        # open-loop arrival override (§14): Poisson or bursty process over
        # the TraceConfig window, independent of the length mixture
        traces.assign_arrivals(reqs, args.arrival, tcfg)
    print("workload:", traces.trace_summary(reqs))

    scale = 0.02                # trace window -> wall seconds compression
    if args.gateway:
        out = run_gateway(engines, reqs, slo_class=args.slo_class,
                          arrival_scale=scale)
        out.pop("results")      # per-rid token streams: bench-only payload
    else:
        now_fn = None
        if args.workload == "replay" or args.arrival != "trace":
            # virtual-time replay: arrivals gate admission. The trace
            # window is compressed into wall seconds up front (arrivals and
            # the engine's latency stamps then share one clock; admission
            # timing is equivalent to dividing now by the scale).
            for r in reqs:
                r.arrival *= scale
            t0 = time.perf_counter()
            now_fn = lambda: time.perf_counter() - t0
        out = run_lanes(engines, reqs, now_fn=now_fn)
        out["throughput_tok_s"] = out["aggregate_tok_s"]
    out["xla_profile"] = xla_flags.active_profile()

    if args.json:
        print(json.dumps(out, indent=1, default=float))
    else:
        for k, v in out.items():
            if k == "lane_audits":
                continue
            print(f"{k}: {v}")
    return out


if __name__ == "__main__":
    main()
