"""Synthetic data pipeline: deterministic, seekable token streams.

Batches are a pure function of (seed, step) so training can resume from a
checkpoint bit-exactly after a failure — the data cursor is just the step
index (checkpointed with the optimizer state). Host-side generation uses
numpy (cheap, no device transfer until the step consumes it).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, Optional

import numpy as np


@dataclass(frozen=True)
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    frontend: str = "none"        # vision_stub | audio_stub -> extra_embeds
    d_model: int = 0
    frontend_len: int = 0


class SyntheticLM:
    """Markov-ish synthetic LM data (structured enough that loss decreases)."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        v = cfg.vocab_size
        # sparse bigram structure: each token has a few likely successors
        self._succ = rng.integers(0, v, size=(v, 4)).astype(np.int64)

    def batch(self, step: int) -> Dict[str, np.ndarray]:
        cfg = self.cfg
        rng = np.random.default_rng((cfg.seed, step))
        B, S = cfg.global_batch, cfg.seq_len
        toks = np.empty((B, S), np.int32)
        cur = rng.integers(0, cfg.vocab_size, size=B)
        toks[:, 0] = cur
        for t in range(1, S):
            pick = rng.integers(0, 4, size=B)
            noise = rng.random(B) < 0.1
            nxt = self._succ[cur, pick]
            nxt = np.where(noise, rng.integers(0, cfg.vocab_size, size=B), nxt)
            toks[:, t] = nxt
            cur = nxt
        out = {"tokens": toks}
        if cfg.frontend != "none":
            out["extra_embeds"] = rng.standard_normal(
                (B, cfg.frontend_len, cfg.d_model)).astype(np.float32)
        return out

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        step = 0
        while True:
            yield self.batch(step)
            step += 1
