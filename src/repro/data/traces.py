"""Production-trace-like workload generators (paper Table 1 / §5.1-5.2).

The Azure LLM inference trace itself is not available offline; this module
synthesizes replay windows matching the paper's reported heterogeneity:
  * generated length: heavy-tailed, p50/p90/p99 ~ 96/384/1024
  * bursty arrivals: top-10% windows hold ~31% of arrivals
  * EOS completions arrive in bursts (follows from length mixture + bursts)
Scaling: benches run a scaled-down token budget; the SHAPE of the mixture is
what the workloads preserve (scale knob `token_scale`).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import List

import numpy as np

from repro.core.scheduler import Request


@dataclass(frozen=True)
class TraceConfig:
    n_requests: int = 64
    vocab: int = 256
    prompt_mean: int = 32
    token_scale: float = 1.0       # scales lengths down for CPU benches
    burstiness: float = 3.0        # arrival concentration knob
    window_s: float = 60.0
    seed: int = 0
    shared_prefix_frac: float = 0.0
    shared_prefix_len: int = 16
    n_prefixes: int = 4            # distinct system prompts (shared_prefix_*)
    gen_mean: int = 32             # shared-prefix family: mean decode length
    # stop-token family (DESIGN.md §13): per-request stop sets drawn from
    # the low-id band the sampler actually emits, so detected-EOS
    # retirement fires well before the gen_len budget cap
    stop_tokens: tuple = ()        # explicit stop set (all requests)
    n_stop_tokens: int = 4         # drawn per trace when stop_tokens empty


def _heavy_tail_lengths(rng, n, scale):
    """Lognormal mixture calibrated to p50/p90/p99 ~= 96/384/1024."""
    base = rng.lognormal(mean=np.log(96), sigma=1.05, size=n)
    lens = np.clip(base, 4, 2048) * scale
    return np.maximum(1, lens.astype(np.int64))


# ---------------------------------------------------------------------------
# open-loop arrival processes (serving gateway, DESIGN.md §14)
# ---------------------------------------------------------------------------

def poisson_arrivals(n: int, window_s: float, rng) -> np.ndarray:
    """Memoryless open-loop arrivals: n exponential inter-arrival gaps
    with mean window_s / n (so the window holds the whole trace in
    expectation), cumulatively summed."""
    gaps = rng.exponential(window_s / max(1, n), size=n)
    return np.cumsum(gaps)


def bursty_arrivals(n: int, window_s: float, burstiness: float,
                    rng) -> np.ndarray:
    """Concentrated arrivals matching the Azure-trace heterogeneity (top
    10% of windows hold ~31% of arrivals): Pareto-weighted window counts,
    uniform placement within each window. Factored out of
    ``azure_like_replay`` so the gateway's open-loop driver and the
    closed-loop replay share one arrival process."""
    nw = 20
    w = rng.pareto(burstiness / 2, size=nw) + 0.1
    w = w / w.sum()
    counts = rng.multinomial(n, w)
    arrivals = []
    for wi, c in enumerate(counts):
        lo = window_s * wi / nw
        hi = window_s * (wi + 1) / nw
        arrivals += list(rng.uniform(lo, hi, size=c))
    return np.sort(np.array(arrivals))[:n]


def assign_arrivals(reqs: List[Request], kind: str, cfg: TraceConfig) -> None:
    """Reassign a workload's arrivals in place: ``kind`` is 'poisson' or
    'bursty' (serve.py --arrival); the generator draws from a seed offset
    so arrival randomness is independent of the length mixture."""
    rng = np.random.default_rng(cfg.seed + 7)
    if kind == "poisson":
        arr = poisson_arrivals(len(reqs), cfg.window_s, rng)
    elif kind == "bursty":
        arr = bursty_arrivals(len(reqs), cfg.window_s, cfg.burstiness, rng)
    else:
        raise ValueError(f"unknown arrival process {kind!r}")
    for r, a in zip(reqs, arr):
        r.arrival = float(a)


def mixed_length_workload(cfg: TraceConfig) -> List[Request]:
    """Controlled mixed-length decode (paper Fig. 4c-d): all arrive at t=0."""
    rng = np.random.default_rng(cfg.seed)
    gen = _heavy_tail_lengths(rng, cfg.n_requests, cfg.token_scale)
    plen = np.maximum(1, rng.poisson(cfg.prompt_mean * cfg.token_scale,
                                     cfg.n_requests))
    reqs = []
    for i in range(cfg.n_requests):
        prompt = rng.integers(0, cfg.vocab, size=int(plen[i])).astype(np.int32)
        r = Request(rid=i, prompt=prompt, gen_len=int(gen[i]), arrival=0.0)
        if cfg.shared_prefix_frac and i > 0 and rng.random() < cfg.shared_prefix_frac:
            r.prefix_of = 0
            r.prefix_len = min(cfg.shared_prefix_len, len(reqs[0].prompt))
            r.prompt = np.concatenate([reqs[0].prompt[:r.prefix_len], prompt])
        reqs.append(r)
    return reqs


def predictable_workload(cfg: TraceConfig) -> List[Request]:
    """Homogeneous regime (paper Table 4): narrow spread, steady width."""
    rng = np.random.default_rng(cfg.seed)
    gl = max(2, int(64 * cfg.token_scale))
    pl = max(1, int(cfg.prompt_mean * cfg.token_scale))
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab, size=pl).astype(np.int32),
                    gen_len=gl + int(rng.integers(0, 3)), arrival=0.0)
            for i in range(cfg.n_requests)]


def azure_like_replay(cfg: TraceConfig) -> List[Request]:
    """Bursty replay window (paper Fig. 4a-b, Table 1): heavy-tailed lengths
    + concentrated arrivals."""
    rng = np.random.default_rng(cfg.seed)
    gen = _heavy_tail_lengths(rng, cfg.n_requests, cfg.token_scale)
    plen = np.maximum(1, rng.poisson(cfg.prompt_mean * cfg.token_scale,
                                     cfg.n_requests))
    # bursty arrivals: Pareto-weighted window concentration (shared with
    # the gateway's open-loop driver via bursty_arrivals)
    arrivals = bursty_arrivals(cfg.n_requests, cfg.window_s,
                               cfg.burstiness, rng)
    reqs = []
    for i in range(cfg.n_requests):
        prompt = rng.integers(0, cfg.vocab, size=int(plen[i])).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, gen_len=int(gen[i]),
                            arrival=float(arrivals[i])))
    return reqs


def shared_prefix_workload(cfg: TraceConfig) -> List[Request]:
    """Multi-turn / shared-system-prompt traffic (DESIGN.md §9): requests
    draw one of ``n_prefixes`` distinct system prompts of
    ``shared_prefix_len`` tokens and append a short unique user suffix
    (Poisson around ``prompt_mean``); generation lengths are modest
    (chat turns, Poisson around ``gen_mean``). No ``prefix_of`` hints are
    set — the sharing is implicit in the token streams, exactly what the
    engine's radix prefix cache discovers on its own. Arrivals are spread
    uniformly over ``window_s`` so later requests can hit prefixes
    committed by earlier ones (a t=0 burst would all miss a cold cache)."""
    rng = np.random.default_rng(cfg.seed)
    prefixes = [rng.integers(0, cfg.vocab, size=cfg.shared_prefix_len)
                .astype(np.int32) for _ in range(max(1, cfg.n_prefixes))]
    arrivals = np.sort(rng.uniform(0, cfg.window_s, size=cfg.n_requests))
    reqs = []
    for i in range(cfg.n_requests):
        pfx = prefixes[int(rng.integers(len(prefixes)))]
        suffix = rng.integers(
            0, cfg.vocab,
            size=max(1, int(rng.poisson(cfg.prompt_mean * cfg.token_scale)))
        ).astype(np.int32)
        gen = max(2, int(rng.poisson(cfg.gen_mean * cfg.token_scale)))
        reqs.append(Request(rid=i, prompt=np.concatenate([pfx, suffix]),
                            gen_len=gen, arrival=float(arrivals[i])))
    return reqs


def stop_token_workload(cfg: TraceConfig) -> List[Request]:
    """Variable-length decode driven by detected EOS (DESIGN.md §13): every
    request carries a stop set, and the gen_len budget is only a cap — the
    ACTUAL lengths are decided on-device by the sampled token stream, which
    is exactly the data-dependent heterogeneity the paper's static-graph
    retirement path has to absorb. The stop set is shared across the trace
    (one tokenizer's EOS ids) and drawn from the vocab unless pinned via
    ``cfg.stop_tokens``; budgets are heavy-tailed so budget-capped and
    stop-retired requests mix. Requires sampled decode (greedy=False) —
    the engine rejects stop sets in legacy mode."""
    rng = np.random.default_rng(cfg.seed)
    if cfg.stop_tokens:
        stops = tuple(int(t) for t in cfg.stop_tokens)
    else:
        stops = tuple(sorted(int(t) for t in rng.choice(
            cfg.vocab, size=min(cfg.n_stop_tokens, cfg.vocab),
            replace=False)))
    gen = _heavy_tail_lengths(rng, cfg.n_requests, cfg.token_scale)
    plen = np.maximum(1, rng.poisson(cfg.prompt_mean * cfg.token_scale,
                                     cfg.n_requests))
    reqs = []
    for i in range(cfg.n_requests):
        prompt = rng.integers(0, cfg.vocab, size=int(plen[i])).astype(np.int32)
        reqs.append(Request(rid=i, prompt=prompt, gen_len=int(gen[i]),
                            arrival=0.0, stop_tokens=stops))
    return reqs


def trace_summary(reqs: List[Request]) -> dict:
    """Table-1-style heterogeneity summary."""
    gen = np.array([r.gen_len for r in reqs], float)
    arr = np.array([r.arrival for r in reqs], float)
    qs = np.percentile(gen, [50, 90, 99])
    hist, _ = np.histogram(arr, bins=20)
    top = np.sort(hist)[::-1]
    top10_share = top[:max(1, len(top) // 10)].sum() / max(1, hist.sum())
    return {"gen_p50": qs[0], "gen_p90": qs[1], "gen_p99": qs[2],
            "arrival_top10_share": float(top10_share),
            "n": len(reqs)}
