"""KV pager — RESERVE / ALIAS / TRIM / FRAME over page-aligned blocks (§4.2).

Host-side control plane. Physical KV memory is virtualized as page-aligned
*blocks* (BLOCKALIGN granularity: ``block_tokens`` tokens, sized ~tau bytes so
one block is a burst-friendly transfer quantum). Per-session view descriptors
map logical token ranges onto physical blocks; the device always sees the same
fixed-shape window while the host remaps which logical tokens occupy it.

Verbs:
  * reserve(sid, n_tokens)  — allocate block-aligned spans; O(1) via
    size-partitioned free runs + tail-adjacency placement hints (lookahead
    placement keeps a session's blocks physically contiguous -> long trains).
  * alias(src, dst, n_tok)  — copy-on-write prefix sharing (refcounts; the
    partial tail block is marked for a device-side COW copy).
  * alias_blocks(dst, blocks, n_tok) — alias() from an explicit committed
    block chain (the §9 prefix cache's hit path); both raise the typed
    SwapRefused over a host-resident prefix.
  * retain_block / release_block — external (non-session) references: the
    prefix cache (DESIGN.md §9) keeps committed prompt blocks alive past
    their session's EOS; external refs refuse swap like COW shares.
  * trim(sid, ...)          — reclaim EOS / cold blocks to the free pool.
  * frame()                 — seal all edits for step t into ONE atomic
    descriptor commit (shadow -> active double buffer, epoch counter;
    linearizable + idempotent under retries; O(|delta_t|) per step).
  * swap_out / swap_in      — host-tier residency (DESIGN.md §8): move
    cold or preempted blocks into a host backing pool and back. A
    session's ``blocks`` list encodes per-block residency by sign:
    entry >= 1 is a DEVICE block id, entry <= -1 is host slot
    ``-(entry + 1)``. The compiled executor must never observe a
    host-resident block; ``_window_blocks``/descriptor assembly only read
    window-range entries, which swap_in restores to device first.

Block 0 is scratch (never allocated): inactive slots' writes land there.
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

# session-level residency state machine (DESIGN.md §8, §11):
#   DEVICE --swap_out_session--> [IN_FLIGHT_OUT --swap_out_commit-->] HOST
#      ^                                                               |
#      |                                                       swap_in_begin
#      +------------------swap_in_commit-------------- IN_FLIGHT <-----+
# (swap_out_cold keeps the session DEVICE: only below-window blocks move.
# IN_FLIGHT_OUT is the §11 async-movement fence state: the device->host
# gather was ISSUED but not yet synchronized — the blocks are already
# host-entries in the block list, but the host slots hold no bytes until
# the engine drains the transfer's fence and calls swap_out_commit.
# swap_in_begin refuses the state, so a resume forces the drain first.)
RES_DEVICE = "device"
RES_HOST = "host"
RES_IN_FLIGHT = "in_flight"
RES_IN_FLIGHT_OUT = "in_flight_out"


def host_slot_of(entry: int) -> int:
    """Decode a sign-encoded host-resident block entry."""
    assert entry < 0
    return -(entry + 1)


def host_entry_of(slot: int) -> int:
    return -(slot + 1)


@dataclass
class Session:
    sid: int
    blocks: List[int] = field(default_factory=list)   # logical order
    length: int = 0                                   # tokens written
    shared_prefix_blocks: int = 0                     # aliased (COW) prefix
    cow_pending: Optional[Tuple[int, int]] = None     # (src, dst) tail copy
    trimmed_prefix_blocks: int = 0                    # far-view: summarized+trimmed
    swap_state: str = RES_DEVICE                      # DESIGN.md §8 state machine
    # provenance stack of recent reserves' takes — (newb, [(start, want,
    # length, class_idx)]) — so a lagged-EOS reconcile (§13) can undo each
    # overshoot allocation POSITIONALLY (newest first) and leave the free
    # structure byte-identical to the timeline that never reserved
    reserve_provenance: List[Tuple] = field(default_factory=list)

    def device_blocks(self) -> List[int]:
        return [b for b in self.blocks if b > 0]

    def host_slots(self) -> List[int]:
        return [host_slot_of(b) for b in self.blocks if b < 0]


class FrameError(RuntimeError):
    pass


class SwapError(RuntimeError):
    """Swap refused (COW-shared blocks, wrong residency state)."""


class SwapRefused(SwapError):
    """An operation needed device-resident blocks but found host-resident
    ones (e.g. alias() over a cold-swapped prefix). Callers either pick a
    different source or swap the prefix in first — this is a policy
    decision, not a crash, hence a typed error instead of an assert."""


class BlockPager:
    def __init__(self, num_blocks: int, block_tokens: int,
                 bytes_per_block: int = 0, size_classes=(32, 8, 2, 1),
                 span_blocks: int = 4, host_pool_blocks: int = 0):
        assert num_blocks > 1
        self.num_blocks = num_blocks
        self.block_tokens = block_tokens
        self.bytes_per_block = bytes_per_block
        # host backing tier (DESIGN.md §8): a fixed pool of host block slots
        # that absorbs swapped-out device blocks; 0 disables the tier
        self.host_pool_blocks = host_pool_blocks
        self._host_free: List[int] = list(range(host_pool_blocks))
        self.host_used = 0
        self.host_peak = 0
        self._swap_in_pairs: Dict[int, List[Tuple[int, int]]] = {}
        # lookahead placement granularity: sessions grow in spans of
        # `span_blocks` contiguous blocks so interleaved growth stays
        # burst-friendly (paper: BLOCKALIGN(S_{t+1}) + placement planning)
        self.span_blocks = max(1, span_blocks)
        self.size_classes = tuple(sorted(size_classes, reverse=True))
        # free runs: run_start -> run_len ; reverse index block -> run_start
        self._run_len: Dict[int, int] = {}
        self._run_of: Dict[int, int] = {}
        self._free_by_class: Dict[int, List[int]] = {c: [] for c in self.size_classes}
        self._take_log: Optional[List[Tuple]] = None  # reserve provenance
        self._insert_run(1, num_blocks - 1)           # block 0 = scratch
        self.refcount = np.zeros(num_blocks, np.int32)
        self.sessions: Dict[int, Session] = {}
        # external (non-session) references: the prefix cache retains
        # committed immutable blocks so they survive their session's EOS.
        # refcount counts session owners + external retains; invariants
        # check both (DESIGN.md §9)
        self.external_refs: Dict[int, int] = {}
        # frame double buffer
        self.epoch = 0
        self._edit_log: List[Tuple] = []              # edits staged this frame
        self._committed_edit_count = 0
        self._last_frame: Optional[dict] = None
        # stats
        self.stats = {"reserve_ops": 0, "trim_ops": 0, "alias_ops": 0,
                      "frames": 0, "blocks_allocated": 0, "blocks_freed": 0,
                      "swap_out_blocks": 0, "swap_in_blocks": 0,
                      "swap_out_ops": 0, "swap_in_ops": 0,
                      "swap_refusals": 0}

    # ------------------------------------------------------------------
    # free-run bookkeeping (size-partitioned, O(1) amortized)
    # ------------------------------------------------------------------
    def _class_of(self, n: int) -> int:
        for c in self.size_classes:
            if n >= c:
                return c
        return self.size_classes[-1]

    def _insert_run(self, start: int, length: int) -> None:
        if length <= 0:
            return
        # coalesce with left/right neighbours
        left = self._run_of.get(start - 1)
        if left is not None:
            llen = self._run_len.pop(left)
            self._remove_from_class(left, llen)
            start, length = left, llen + length
        right_start = start + length
        if right_start in self._run_len:
            rlen = self._run_len.pop(right_start)
            self._remove_from_class(right_start, rlen)
            for b in range(right_start, right_start + rlen):
                self._run_of.pop(b, None)
            length += rlen
        self._run_len[start] = length
        for b in range(start, start + length):
            self._run_of[b] = start
        self._free_by_class[self._class_of(length)].append(start)

    def _remove_from_class(self, start: int, length: int) -> None:
        cls = self._class_of(length)
        try:
            self._free_by_class[cls].remove(start)
        except ValueError:
            pass

    def _take_run(self, start: int, want: int) -> List[int]:
        """Take `want` blocks from the head of run `start`."""
        length = self._run_len.pop(start)
        cls = self._class_of(length)
        try:
            idx = self._free_by_class[cls].index(start)
            self._free_by_class[cls].pop(idx)
        except ValueError:
            idx = None
        for b in range(start, start + length):
            self._run_of.pop(b, None)
        taken = list(range(start, start + want))
        if length > want:
            self._insert_run(start + want, length - want)
        if self._take_log is not None:
            self._take_log.append((start, want, length, idx))
        return taken

    def _alloc_blocks(self, n: int, hint: Optional[int] = None) -> List[int]:
        out: List[int] = []
        # placement: extend at hint (tail adjacency) for burst-friendly trains
        if hint is not None and (hint + 1) in self._run_of:
            start = self._run_of[hint + 1]
            if start == hint + 1:
                run = self._run_len[start]
                take = min(run, n)
                out += self._take_run(start, take)
        while len(out) < n:
            need = n - len(out)
            chosen = None
            for c in self.size_classes:          # largest class first
                if self._free_by_class[c]:
                    chosen = self._free_by_class[c][-1]
                    break
            if chosen is None:
                # rollback the partial take: callers may catch MemoryError
                # and retry after relieving pressure (DESIGN.md §8), so the
                # blocks taken so far must return to the free list or the
                # pool bleeds one run per failed reservation
                for b in out:
                    self._insert_run(b, 1)
                raise MemoryError(
                    f"KV pool exhausted: want {need} more blocks, "
                    f"{self.free_blocks()} free")
            run = self._run_len[chosen]
            out += self._take_run(chosen, min(run, need))
        self.refcount[out] += 1
        self.stats["blocks_allocated"] += len(out)
        return out

    def _free_block(self, b: int) -> None:
        self.refcount[b] -= 1
        assert self.refcount[b] >= 0
        if self.refcount[b] == 0:
            self._insert_run(b, 1)
            self.stats["blocks_freed"] += 1

    def _free_entry(self, e: int) -> None:
        """Free one session block entry, device- or host-resident."""
        if e > 0:
            self._free_block(e)
        else:
            self._host_free_slot(host_slot_of(e))

    # ------------------------------------------------------------------
    # host pool slot bookkeeping
    # ------------------------------------------------------------------
    def _host_alloc(self, n: int) -> List[int]:
        """Take n host slots, lowest-first (keeps swap groups mergeable:
        the free list is sorted, so consecutive takes are usually
        physically contiguous host slots)."""
        if n > len(self._host_free):
            raise MemoryError(
                f"host KV pool exhausted: want {n} slots, "
                f"{len(self._host_free)} free of {self.host_pool_blocks}")
        taken, self._host_free = self._host_free[:n], self._host_free[n:]
        self.host_used += n
        self.host_peak = max(self.host_peak, self.host_used)
        return taken

    def _host_free_slot(self, h: int) -> None:
        bisect.insort(self._host_free, h)
        self.host_used -= 1

    def host_free_blocks(self) -> int:
        return len(self._host_free)

    # ------------------------------------------------------------------
    # verbs
    # ------------------------------------------------------------------
    def open_session(self, sid: int) -> Session:
        assert sid not in self.sessions
        s = Session(sid)
        self.sessions[sid] = s
        return s

    def reserve(self, sid: int, n_tokens: int) -> List[int]:
        """Ensure capacity for n_tokens more tokens; BLOCKALIGN'd."""
        s = self.sessions[sid]
        cap = len(s.blocks) * self.block_tokens
        local_len = s.length - s.trimmed_prefix_blocks * self.block_tokens
        need_tokens = local_len + n_tokens - cap
        if need_tokens <= 0:
            return []
        nb = -(-need_tokens // self.block_tokens)
        hint = s.blocks[-1] if s.blocks else None
        # placement: grow in spans when possible; fall back to exact size
        # under memory pressure so spans never cause spurious OOM
        want = max(nb, self.span_blocks)
        if want > nb and self.free_blocks() < want + self.span_blocks:
            want = nb
        self._take_log = []
        try:
            newb = self._alloc_blocks(want, hint=hint)
            s.reserve_provenance.append((tuple(newb), self._take_log))
            del s.reserve_provenance[:-4]    # bounded: > max pipeline depth
        finally:
            self._take_log = None
        s.blocks += newb
        self._edit_log.append(("reserve", sid, tuple(newb)))
        self.stats["reserve_ops"] += 1
        return newb

    def reconcile_overshoot(self, sid: int, newb: List[int],
                            n_tokens: int = 1) -> None:
        """Reverse ONE dispatched-but-scrubbed emission (lagged-EOS
        reconcile, DESIGN.md §13): under pipelining the host learns of a
        detected stop token ``depth`` dispatches late, and the overshot
        steps already ran ``reserve`` + ``append_token`` for tokens that
        will never be read. Roll the session back exactly: undo the length
        accounting and return the blocks that overshoot's reserve took
        (``newb``, possibly [] when capacity already existed — reserve's
        early return increments nothing, so neither does this). Stats are
        reversed rather than double-counted so a depth-d run's pager audit
        is byte-identical to the depth-0 run of the same trace. Tail decode
        blocks are never shared (COW aliases cover prompt prefixes only)
        and never cold-swapped (the append tail stays device-resident), so
        popping them is safe even though a frame already committed them —
        the committed descriptor only ever pointed one WRITE at them, and
        that write is the one being scrubbed."""
        s = self.sessions[sid]
        assert s.swap_state == RES_DEVICE, \
            f"overshoot reconcile on non-resident sid={sid}"
        s.length -= n_tokens
        assert s.length >= 0
        if newb:
            assert s.blocks[-len(newb):] == list(newb), \
                f"overshoot blocks not at tail: sid={sid} {newb}"
            for b in reversed(newb):
                assert b > 0 and self.refcount[b] == 1, \
                    f"overshoot block {b} shared (refcount "\
                    f"{self.refcount[b]})"
                s.blocks.pop()
                self.refcount[b] -= 1
            takes = None
            if s.reserve_provenance and \
                    s.reserve_provenance[-1][0] == tuple(newb):
                takes = s.reserve_provenance.pop()[1]
            if not self._undo_takes(takes, newb):
                # free structure disturbed since the reserve (another slot
                # allocated in between) — positional identity is already
                # gone; return the blocks through the normal coalescing
                # path so the pool stays leak-free
                for b in reversed(newb):
                    self._insert_run(b, 1)
            # exact reversal of the overshoot's reserve: the allocation and
            # op counters net to the timeline that never reserved
            self.stats["blocks_allocated"] -= len(newb)
            self.stats["reserve_ops"] -= 1
        self._edit_log.append(("reconcile", sid, tuple(newb)))

    def _undo_takes(self, takes, newb: List[int]) -> bool:
        """Positionally invert one reserve's ``_take_run`` sequence so the
        free structure (runs AND class-list order — allocation picks
        ``[-1]``, so order decides future placement) ends byte-identical to
        the never-reserved timeline. Returns False without mutating when
        the provenance no longer matches — e.g. a remainder run was
        consumed or coalesced by an interleaved allocation — in which case
        the caller falls back to plain frees (the documented §13 limit:
        placement identity holds for uncontended overshoot windows)."""
        if takes is None or sum(t[1] for t in takes) != len(newb):
            return False
        got = [b for st_, w, _, _ in takes for b in range(st_, st_ + w)]
        if got != list(newb):
            return False
        for st_, w, length, idx in takes:
            if idx is None:
                return False
            if length > w and self._run_len.get(st_ + w) != length - w:
                return False
            if length == w and any(b in self._run_of
                                   for b in range(st_, st_ + w)):
                return False
        for st_, w, length, idx in reversed(takes):
            if length > w:
                rem = st_ + w
                self._run_len.pop(rem)
                self._remove_from_class(rem, length - w)
                for b in range(rem, rem + length - w):
                    self._run_of.pop(b, None)
            self._run_len[st_] = length
            for b in range(st_, st_ + length):
                self._run_of[b] = st_
            self._free_by_class[self._class_of(length)].insert(idx, st_)
        return True

    def alias(self, src_sid: int, dst_sid: int, n_tokens: int) -> None:
        """Share the first n_tokens of src with dst (COW). Raises
        ``SwapRefused`` when the source prefix (including the partial-tail
        copy source) is host-resident — the caller must either swap the
        source in first or forfeit the share and prefill."""
        src = self.sessions[src_sid]
        nb = -(-n_tokens // self.block_tokens)
        self.alias_blocks(dst_sid, src.blocks[:nb], n_tokens)

    def alias_blocks(self, dst_sid: int, blocks: List[int],
                     n_tokens: int) -> None:
        """Share the first n_tokens stored in an explicit committed block
        chain with a fresh session (COW). This is alias() decoupled from a
        source SESSION: the prefix cache (DESIGN.md §9) holds block chains
        of retired sessions via ``retain_block``, and new admissions alias
        straight from the index. ``blocks`` must cover n_tokens (the block
        holding the partial tail included, when n_tokens is unaligned)."""
        dst = self.sessions[dst_sid]
        assert dst.length == 0 and not dst.blocks, "alias onto fresh session"
        nb_full = n_tokens // self.block_tokens
        rem = n_tokens % self.block_tokens
        need = nb_full + (1 if rem else 0)
        assert len(blocks) >= need, \
            f"alias chain too short: {len(blocks)} blocks for {n_tokens} tokens"
        if not all(b > 0 for b in blocks[:need]):
            raise SwapRefused(
                f"cannot alias a host-resident prefix (dst={dst_sid}, "
                f"n_tokens={n_tokens}): swap it in first")
        shared = blocks[:nb_full]
        own = None
        if rem:
            # partial tail: dst gets its own block; device must copy its
            # contents (COW). Allocate BEFORE touching dst so an exhausted
            # pool leaves the fresh session untouched (atomic failure —
            # callers fall back to a plain prefill).
            own = self._alloc_blocks(1, hint=shared[-1] if shared else None)
        self.refcount[shared] += 1
        dst.blocks = list(shared)
        dst.shared_prefix_blocks = nb_full
        dst.length = nb_full * self.block_tokens
        if rem:
            dst.blocks.append(own[0])
            dst.cow_pending = (blocks[nb_full], own[0])
            dst.length = n_tokens
        self._edit_log.append(("alias", dst_sid, tuple(blocks[:need]), n_tokens))
        self.stats["alias_ops"] += 1

    # ------------------------------------------------------------------
    # external block references (prefix cache, DESIGN.md §9)
    # ------------------------------------------------------------------
    def retain_block(self, b: int) -> None:
        """Take an external (non-session) reference on a committed block so
        it survives its owning session's trim/close. External refs make a
        block ineligible for swap exactly like a COW share (refcount > 1)."""
        assert 0 < b < self.num_blocks and self.refcount[b] > 0, \
            f"retain of dead block {b}"
        self.refcount[b] += 1
        self.external_refs[b] = self.external_refs.get(b, 0) + 1

    def release_block(self, b: int) -> None:
        """Drop one external reference; frees the block when it was the
        last owner (session- or cache-side)."""
        n = self.external_refs.get(b, 0)
        assert n > 0, f"release of unretained block {b}"
        if n == 1:
            del self.external_refs[b]
        else:
            self.external_refs[b] = n - 1
        self._free_block(b)

    def trim(self, sid: int, *, close: bool = False,
             prefix_blocks: int = 0) -> List[int]:
        """Reclaim blocks. close=True frees everything (EOS);
        prefix_blocks frees summarized far-history blocks (bounded-budget)."""
        s = self.sessions[sid]
        freed: List[int] = []
        if close:
            for b in s.blocks:
                self._free_entry(b)
            freed = s.blocks
            s.blocks = []
            del self.sessions[sid]
        elif prefix_blocks:
            take = s.blocks[:prefix_blocks]
            for b in take:
                self._free_entry(b)
            freed = take
            s.blocks = s.blocks[prefix_blocks:]
            s.trimmed_prefix_blocks += prefix_blocks
            s.shared_prefix_blocks = max(0, s.shared_prefix_blocks - prefix_blocks)
        if freed:
            self._edit_log.append(("trim", sid, tuple(freed)))
            self.stats["trim_ops"] += 1
        return freed

    def append_token(self, sid: int) -> Tuple[int, int]:
        """Account one token write; returns (physical_block, offset).
        Caller must have reserved capacity."""
        s = self.sessions[sid]
        local = s.length - s.trimmed_prefix_blocks * self.block_tokens
        bi, off = divmod(local, self.block_tokens)
        assert bi < len(s.blocks), f"no capacity: sid={sid} len={s.length}"
        s.length += 1
        blk = s.blocks[bi]
        assert blk > 0, \
            f"write targets host-resident block: sid={sid} entry={blk}"
        return blk, off

    def append_tokens(self, sid: int, n: int) -> Tuple[np.ndarray, np.ndarray]:
        """Account n token writes at once (chunked prefill); returns
        (blocks (n,), offsets (n,)) int32 arrays. Caller must have reserved
        capacity for all n tokens."""
        s = self.sessions[sid]
        bt = self.block_tokens
        local = s.length - s.trimmed_prefix_blocks * bt
        idx = local + np.arange(n)
        bi, off = np.divmod(idx, bt)
        assert n == 0 or bi[-1] < len(s.blocks), \
            f"no capacity: sid={sid} len={s.length} n={n}"
        blocks = np.asarray(s.blocks, np.int32)[bi]
        s.length += n
        assert n == 0 or (blocks > 0).all(), \
            f"write targets host-resident block: sid={sid}"
        return blocks.astype(np.int32), off.astype(np.int32)

    # ------------------------------------------------------------------
    # host-tier swap verbs (DESIGN.md §8)
    # ------------------------------------------------------------------
    def blocks_needed(self, sid: int, n_tokens: int) -> int:
        """Device blocks a reserve(sid, n_tokens) would allocate (exact-fit
        math; span placement may take more when the pool is comfortable)."""
        s = self.sessions[sid]
        cap = len(s.blocks) * self.block_tokens
        local_len = s.length - s.trimmed_prefix_blocks * self.block_tokens
        need_tokens = local_len + n_tokens - cap
        return max(0, -(-need_tokens // self.block_tokens))

    def swap_eligible(self, sid: int) -> bool:
        """A session can move to the host tier only if it shares no block
        with another session: every device block has refcount 1 and no COW
        tail copy is pending. Aliased (COW) blocks are REFUSED — swapping
        one side would either tear the share or need a copy-split; the
        engine must pick another victim."""
        s = self.sessions[sid]
        if s.cow_pending is not None or s.swap_state != RES_DEVICE:
            return False
        dev = s.device_blocks()
        return all(self.refcount[b] == 1 for b in dev)

    def swap_out_cold(self, sid: int, keep_from_local: int
                      ) -> List[Tuple[int, int]]:
        """Move the session's blocks BELOW logical index ``keep_from_local``
        (i.e. strictly below the near window) to the host tier, coldest
        (oldest) first. Shared (refcount > 1) and already-host blocks are
        skipped. The session stays DEVICE-resident: its window never
        references the moved blocks again (the window only advances), so the
        executor-residency invariant holds with no swap-in path needed.
        Returns (device_block, host_slot) copy pairs for the transport."""
        s = self.sessions[sid]
        if s.swap_state != RES_DEVICE:
            raise SwapError(f"sid={sid} not device-resident")
        # never move the append tail: only FULL blocks strictly below the
        # current write position are cold, whatever the caller asked for
        local = s.length - s.trimmed_prefix_blocks * self.block_tokens
        limit = min(keep_from_local, local // self.block_tokens, len(s.blocks))
        pairs: List[Tuple[int, int]] = []
        for i in range(limit):
            b = s.blocks[i]
            if b < 0 or self.refcount[b] != 1:
                continue
            h = self._host_alloc(1)[0]
            pairs.append((b, h))
            s.blocks[i] = host_entry_of(h)
            self._free_block(b)
        if pairs:
            self.stats["swap_out_blocks"] += len(pairs)
            self.stats["swap_out_ops"] += 1
            self._edit_log.append(("swap_out", sid,
                                   tuple(p[0] for p in pairs)))
        return pairs

    def swap_out_session(self, sid: int, *, deferred: bool = False
                         ) -> Optional[List[Tuple[int, int]]]:
        """Preemption swap-out: move ALL the session's device blocks to the
        host tier and mark it HOST-resident — or, with ``deferred=True``
        (async movement, DESIGN.md §11), IN_FLIGHT_OUT: the caller issued
        the device->host gather but has not synchronized it, and must call
        ``swap_out_commit`` once the fence drains. Returns (device_block,
        host_slot) copy pairs, or None if the session is REFUSED (COW-shared
        blocks — the caller must pick another victim)."""
        if not self.swap_eligible(sid):
            self.stats["swap_refusals"] += 1
            return None
        s = self.sessions[sid]
        dev_idx = [i for i, b in enumerate(s.blocks) if b > 0]
        hosts = self._host_alloc(len(dev_idx))
        pairs = []
        for i, h in zip(dev_idx, hosts):
            b = s.blocks[i]
            pairs.append((b, h))
            s.blocks[i] = host_entry_of(h)
            self._free_block(b)
        # a deferred transfer with nothing to move has no fence to wait on
        s.swap_state = RES_IN_FLIGHT_OUT if (deferred and pairs) else RES_HOST
        s.shared_prefix_blocks = 0
        self.stats["swap_out_blocks"] += len(pairs)
        self.stats["swap_out_ops"] += 1
        self._edit_log.append(("swap_out", sid, tuple(p[0] for p in pairs)))
        return pairs

    def swap_out_commit(self, sid: int) -> None:
        """Async-movement fence release (DESIGN.md §11): the deferred
        device->host readback landed — the host slots now hold real bytes,
        so the session becomes plain HOST-resident and swap_in_begin may
        run. Sessions can be closed while IN_FLIGHT_OUT (their data is
        never read); a vanished sid is therefore not an error."""
        s = self.sessions.get(sid)
        if s is None:
            return
        if s.swap_state != RES_IN_FLIGHT_OUT:
            raise SwapError(f"sid={sid} not in-flight-out")
        s.swap_state = RES_HOST

    def swap_in_begin(self, sid: int, from_local: int
                      ) -> List[Tuple[int, int]]:
        """Resume phase 1: allocate device blocks for every host-resident
        entry at logical index >= ``from_local`` (the resumed window + tail)
        and mark the session IN_FLIGHT. Blocks strictly below the window
        stay host-resident (the window never retreats). Returns (host_slot,
        device_block) copy pairs; raises MemoryError when the device pool
        cannot hold the working set (caller must gate admission first)."""
        s = self.sessions[sid]
        if s.swap_state != RES_HOST:
            raise SwapError(f"sid={sid} not host-resident")
        # the append tail must come back whatever the caller asked for:
        # cap from_local at the current write position's block
        local = s.length - s.trimmed_prefix_blocks * self.block_tokens
        from_local = min(from_local, local // self.block_tokens)
        idx = [i for i in range(from_local, len(s.blocks)) if s.blocks[i] < 0]
        pairs: List[Tuple[int, int]] = []
        if idx:
            newb = self._alloc_blocks(len(idx))
            for i, b in zip(idx, newb):
                pairs.append((host_slot_of(s.blocks[i]), b))
                s.blocks[i] = b
        s.swap_state = RES_IN_FLIGHT
        self._swap_in_pairs[sid] = pairs
        return pairs

    def swap_in_commit(self, sid: int) -> None:
        """Resume phase 2: the copies landed on device — release the host
        slots and mark the session DEVICE-resident again."""
        s = self.sessions[sid]
        if s.swap_state != RES_IN_FLIGHT:
            raise SwapError(f"sid={sid} not in-flight")
        pairs = self._swap_in_pairs.pop(sid, [])
        for h, _ in pairs:
            self._host_free_slot(h)
        s.swap_state = RES_DEVICE
        self.stats["swap_in_blocks"] += len(pairs)
        self.stats["swap_in_ops"] += 1
        self._edit_log.append(("swap_in", sid, tuple(p[1] for p in pairs)))

    # ------------------------------------------------------------------
    # frame commit (shadow -> active, epoch, idempotent)
    # ------------------------------------------------------------------
    def frame(self) -> dict:
        """Seal this step's edits into one committed frame. Calling frame()
        again with no new edits returns the SAME committed frame (idempotent
        retry semantics)."""
        if self._last_frame is not None and \
           len(self._edit_log) == self._committed_edit_count:
            return self._last_frame              # retry: identical commit
        # shadow build: snapshot of session views
        shadow = {
            "epoch": self.epoch + 1,
            "edits": list(self._edit_log[self._committed_edit_count:]),
            "views": {sid: (tuple(s.blocks), s.length, s.trimmed_prefix_blocks,
                            s.cow_pending)
                      for sid, s in self.sessions.items()},
        }
        # atomic swap
        self.epoch += 1
        self._committed_edit_count = len(self._edit_log)
        self._last_frame = shadow
        self.stats["frames"] += 1
        for s in self.sessions.values():
            s.cow_pending = None                 # consumed by this frame
        return shadow

    # ------------------------------------------------------------------
    # stats
    # ------------------------------------------------------------------
    def free_blocks(self) -> int:
        return int(sum(self._run_len.values()))

    def reserved_blocks(self) -> int:
        return self.num_blocks - 1 - self.free_blocks()

    def reserved_bytes(self) -> int:
        return self.reserved_blocks() * self.bytes_per_block

    def active_tokens(self) -> int:
        return sum(s.length - s.trimmed_prefix_blocks * self.block_tokens
                   for s in self.sessions.values())

    def check_invariants(self) -> None:
        """Property-test hook: refcounts/ownership/free-list consistency,
        plus host-tier slot accounting (DESIGN.md §8)."""
        owned = {}
        host_owned: List[int] = []
        for sid, s in self.sessions.items():
            for i, b in enumerate(s.blocks):
                if b < 0:
                    host_owned.append(host_slot_of(b))
                    continue
                owned.setdefault(b, []).append(sid)
                assert 0 < b < self.num_blocks
            if s.swap_state in (RES_HOST, RES_IN_FLIGHT_OUT):
                assert not s.device_blocks(), \
                    f"host-resident sid={sid} still owns device blocks"
        for b, ext in self.external_refs.items():
            assert ext > 0 and 0 < b < self.num_blocks
            owned.setdefault(b, [])
        for b, owners in owned.items():
            want = len(owners) + self.external_refs.get(b, 0)
            assert self.refcount[b] == want, \
                f"block {b}: refcount {self.refcount[b]} != owners {owners} " \
                f"+ ext {self.external_refs.get(b, 0)}"
            assert b not in self._run_of, f"block {b} owned AND free"
        total_free = self.free_blocks()
        ref_live = int((self.refcount[1:] > 0).sum())
        assert ref_live + total_free == self.num_blocks - 1, \
            f"leak: live {ref_live} + free {total_free} != {self.num_blocks - 1}"
        # host tier: owned slots are unique, in range, disjoint from the
        # free list, and the used counter matches ownership exactly
        assert len(host_owned) == len(set(host_owned)), \
            f"host slot double-owned: {sorted(host_owned)}"
        assert all(0 <= h < self.host_pool_blocks for h in host_owned)
        assert not set(host_owned) & set(self._host_free), "host slot owned AND free"
        assert self.host_used == len(host_owned), \
            f"host leak: used {self.host_used} != owned {len(host_owned)}"
        assert self.host_used + len(self._host_free) == self.host_pool_blocks
