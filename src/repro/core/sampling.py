"""On-device sampling for the fixed-shape decode step (DESIGN.md §13).

The sampler is a static closure over (temperature, top_k, top_p): the knobs
are trace-time Python constants, so every configuration compiles to its own
minimal program and ``greedy`` mode pays nothing for the machinery. Per-slot
randomness is derived FUNCTIONALLY from the control plane: the key for one
emission is

    fold_in(fold_in(PRNGKey(sample_seed), rid), position)

where ``rid`` rides the flat descriptor commit's rid row and ``position`` is
the descriptor's ``seq_lens`` entry (logical length BEFORE this step's
token). A token therefore depends only on (seed, rid, position) — it is
invariant to slot placement, batch composition, pipeline depth, preemption/
resume, and mesh layout, which is what makes the depth-0 vs depth-1 and
TP-vs-single identity gates possible for sampled decode.

Filter semantics (float32 throughout, mirrored by the numpy reference):
  * temperature <= 0 is an exact argmax branch (no categorical draw), so
    "greedy with stop tokens" is expressible as greedy=False, temperature=0.
  * top-k keeps every logit >= the k-th largest (ties INCLUDED — the
    support may exceed k on ties, never lose probability mass to tie order).
  * top-p keeps the smallest descending-sorted prefix whose mass reaches p
    (the top-1 token is always kept; kept mass is >= p).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def slot_keys(base_key, rids, positions):
    """Per-slot threefry keys for one step: vmapped double fold_in over the
    (B,) rid row and the (B,) seq_lens row of the committed descriptor."""
    def one(rid, pos):
        return jax.random.fold_in(jax.random.fold_in(base_key, rid), pos)
    return jax.vmap(one)(rids, positions)


def make_sampler(temperature: float, top_k: int, top_p: float):
    """Build the jitted-path sampler: (keys (B,2|key), logits (B,V)) ->
    token ids (B,) int32. The knobs are STATIC (baked at trace time)."""
    t = float(temperature)
    k = int(top_k)
    p = float(top_p)

    def sample(keys, logits):
        x = logits.astype(jnp.float32)
        if t <= 0.0:
            return jnp.argmax(x, axis=-1).astype(jnp.int32)
        x = x / t
        if 0 < k < x.shape[-1]:
            kth = jax.lax.top_k(x, k)[0][..., -1:]
            x = jnp.where(x < kth, -jnp.inf, x)
        if p < 1.0:
            xs = jnp.sort(x, axis=-1)[..., ::-1]
            probs = jax.nn.softmax(xs, axis=-1)
            excl = jnp.cumsum(probs, axis=-1) - probs
            thr = jnp.min(jnp.where(excl < p, xs, jnp.inf), axis=-1,
                          keepdims=True)
            x = jnp.where(x < thr, -jnp.inf, x)
        return jax.vmap(
            lambda kk, xx: jax.random.categorical(kk, xx))(keys, x).astype(
                jnp.int32)

    return sample


def ref_support(logits, temperature: float, top_k: int, top_p: float):
    """Numpy reference: the exact set of token ids the sampler can emit for
    one logit row, under the same float32 filter semantics as
    ``make_sampler``. The property suite asserts sampled tokens land in this
    set; it does NOT model the categorical draw itself."""
    x = np.asarray(logits, np.float32)
    n = x.shape[-1]
    if temperature <= 0.0:
        return {int(np.argmax(x))}
    x = (x / np.float32(temperature)).astype(np.float32)
    if 0 < top_k < n:
        kth = np.sort(x)[-top_k]
        x = np.where(x < kth, -np.inf, x).astype(np.float32)
    if top_p < 1.0:
        xs = np.sort(x)[::-1].astype(np.float32)
        m = xs[0]
        e = np.exp((xs - m).astype(np.float32)).astype(np.float32)
        probs = (e / e.sum(dtype=np.float32)).astype(np.float32)
        excl = (np.cumsum(probs, dtype=np.float32) - probs).astype(np.float32)
        thr = np.min(np.where(excl < np.float32(top_p), xs, np.inf))
        x = np.where(x < thr, -np.inf, x)
    return {i for i in range(n) if np.isfinite(x[i])}


def ref_probs(logits, temperature: float) -> np.ndarray:
    """Float64 softmax of logits/temperature — the mass basis the property
    suite uses for the top-p bound (tolerant of float32 cumsum edges)."""
    x = np.asarray(logits, np.float64)
    if temperature > 0:
        x = x / temperature
    e = np.exp(x - x.max())
    return e / e.sum()
