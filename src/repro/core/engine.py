"""KVRMEngine — fixed-shape decode serving under the descriptor transport
interface (paper §4), plus the static-arena baseline on the SAME executor.

Modes (Table 5 attribution rows):
  * arena       — static-graph baseline: worst-case contiguous per-slot
                  reservation, no paging, no merging.
  * paged       — + KV pager (RESERVE/ALIAS/TRIM/FRAME), unmerged transport.
  * paged_merge — + merge-staged descriptor transport (core KV-RM path).
  * full        — + far-view summarization (optional bounded-budget policy).

Invariants audited every run: the decode step is compiled ONCE (no retrace
after warm-up), exactly one Frame commit per step, bounded host control share.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.descriptor import FrameDescriptor, empty_descriptor
from repro.core.farview import FarViewPolicy
from repro.core.pager import BlockPager
from repro.core.scheduler import Request, Scheduler
from repro.core.transport import MergeStagedTransport, StagedDescriptor, merge_runs
from repro.models import registry

MODES = ("arena", "paged", "paged_merge", "full")


@dataclass
class EngineConfig:
    mode: str = "paged_merge"
    batch: int = 8                   # fixed slot width B
    max_seq: int = 512               # worst-case sequence length
    near_window: Optional[int] = None   # W* (kernel width); None = max_seq (dense)
    block_tokens: int = 16           # BLOCKALIGN quantum (tokens)
    pool_budget_frac: float = 1.0    # paged pool size vs worst case
    farview_cap: int = 16
    sv_chunk: int = 64
    span_blocks: int = 4             # placement span (BLOCKALIGN granularity)
    greedy: bool = True
    debug_logits: bool = False       # capture per-step logits (tests only)


@dataclass
class StepMetrics:
    wall: float = 0.0
    host: float = 0.0                # control-plane time (submit+frame)
    frame_commit: float = 0.0
    dma_groups: int = 0
    active: int = 0
    emitted: int = 0


class KVRMEngine:
    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig):
        assert ecfg.mode in MODES
        self.cfg = cfg
        self.params = params
        self.e = ecfg
        self.paged_arch = registry.uses_paged_kv(cfg)

        bt = ecfg.block_tokens
        self.bt = bt
        self.W = ecfg.near_window or ecfg.max_seq
        self.NB = -(-self.W // bt) + 1
        self.MT = self.NB + 1
        self.blocks_per_seq = -(-ecfg.max_seq // bt) + 1
        worst = ecfg.batch * self.blocks_per_seq
        if ecfg.mode == "arena":
            self.num_blocks = worst + 1
        else:
            self.num_blocks = max(self.NB * ecfg.batch,
                                  int(worst * ecfg.pool_budget_frac)) + 1

        # per-layer payload bytes (transport accounting uses the real model)
        self.bytes_per_token = registry.paged_payload_bytes_per_token(cfg)
        self.block_bytes = bt * self.bytes_per_token
        n_layers_paged = max(1, registry.n_paged_layers(cfg))
        self.pool_bytes_total = (self.num_blocks - 1) * self.block_bytes * n_layers_paged

        self.farview = ecfg.mode == "full" and self.paged_arch and cfg.family != "hybrid"
        self.cap = ecfg.farview_cap if self.farview else 1
        self.max_chunks = (-(-max(1, ecfg.max_seq - self.W) // ecfg.sv_chunk) + 1
                           if self.farview else 0)
        self.chunk_blocks = max(1, ecfg.sv_chunk // bt)

        # --- device state ---
        self.pools = registry.init_decode_pools(
            cfg, batch=ecfg.batch, num_blocks=self.num_blocks, block_tokens=bt,
            max_chunks=self.max_chunks,
            enc_len=ecfg.max_seq if cfg.family == "encdec" else 0)
        if cfg.family == "encdec":
            self.pools["enc_len"] = jnp.zeros((ecfg.batch,), jnp.int32)

        # --- host control plane ---
        self.sched = Scheduler(ecfg.batch)
        self.pager = (BlockPager(self.num_blocks, bt, self.block_bytes,
                                 span_blocks=ecfg.span_blocks)
                      if ecfg.mode != "arena" else None)
        self.transport = MergeStagedTransport(
            block_bytes=self.block_bytes,
            merge_threshold_bytes=cfg.serving.merge_threshold_bytes,
            max_hold_steps=cfg.serving.max_hold_steps, max_trains=self.MT)
        self.fv = (FarViewPolicy(ecfg.batch, self.max_chunks, self.cap,
                                 ecfg.sv_chunk, bt) if self.farview else None)

        # arena bookkeeping: slot -> fixed block range
        self._arena_base = [1 + i * self.blocks_per_seq for i in range(ecfg.batch)]
        self._slot_len = np.zeros(ecfg.batch, np.int64)   # tokens in cache
        self._slot_sid = -np.ones(ecfg.batch, np.int64)
        self._last_token = np.zeros(ecfg.batch, np.int64)

        # --- compiled decode step (ONE compilation; invariant audit) ---
        cfg_dec = cfg.replace(serving=cfg.serving.__class__(
            page_size=cfg.serving.page_size, near_window=self.W,
            farview_cap=self.cap, sv_chunk=ecfg.sv_chunk,
            merge_threshold_bytes=cfg.serving.merge_threshold_bytes,
            max_hold_steps=cfg.serving.max_hold_steps,
            enable_farview=self.farview))
        self._cfg_dec = cfg_dec

        dbg = ecfg.debug_logits

        def _step(params, tokens, pools, descr):
            logits, pools, fu = registry.decode_step(params, cfg_dec, tokens,
                                                     pools, descr)
            nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return nxt, pools, fu, (logits if dbg else jnp.zeros((), jnp.int32))

        self._step_fn = jax.jit(_step, donate_argnums=(2,))
        self._compiles = 0
        self.debug_logits: List[np.ndarray] = []

        # metrics
        self.metrics: List[StepMetrics] = []
        self.frames_committed = 0
        self.steps_run = 0
        self.peak_reserved_kv = 0
        self.peak_active_kv = 0
        self.cum_wall = 0.0
        self._rid_to_sid: Dict[int, int] = {}

        # encdec: encoder-side prefill executor (separate from the audited
        # decode path; populates immutable cross-attention KV per admission)
        if cfg.family == "encdec":
            def _encode(params, enc_embeds):
                from repro.models import encdec as ed
                enc_out = ed.encode(params, cfg, enc_embeds)
                return ed.cross_kv(params, cfg, enc_out)
            self._encode_fn = jax.jit(_encode)
            self._set_cross = jax.jit(
                lambda pools, slot_onehot, ck, cv, elen: {
                    **pools,
                    "cross_k": jnp.where(slot_onehot[None, :, None, None, None],
                                         ck, pools["cross_k"]),
                    "cross_v": jnp.where(slot_onehot[None, :, None, None, None],
                                         cv, pools["cross_v"]),
                    "enc_len": jnp.where(slot_onehot, elen, pools["enc_len"]),
                })

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.sched.submit(req)

    # ------------------------------------------------------------------
    def _admit(self, now: float) -> None:
        for slot, req, sid in self.sched.admit(now):
            self._slot_len[slot] = 0
            self._last_token[slot] = int(req.prompt[0]) if len(req.prompt) else 0
            if self.pager is not None:
                self.pager.open_session(sid)
                self._slot_sid[slot] = sid
                if req.prefix_of is not None and req.prefix_len >= self.bt:
                    src_sid = self._rid_to_sid.get(req.prefix_of)
                    if src_sid is not None and src_sid in self.pager.sessions:
                        self.pager.alias(src_sid, sid, req.prefix_len)
                        self._slot_len[slot] = self.pager.sessions[sid].length
                        req.prompt_pos = int(self._slot_len[slot])
                self._rid_to_sid[req.rid] = sid
            if self.fv is not None:
                self.fv.reset_slot(slot)
            if self.cfg.family == "encdec":
                enc = getattr(req, "enc_embeds", None)
                if enc is None:
                    enc = np.random.default_rng(req.rid).normal(
                        size=(1, 8, self.cfg.d_model)).astype(np.float32)
                ck, cv = self._encode_fn(self.params, jnp.asarray(enc))
                se = ck.shape[2]
                pad = self.pools["cross_k"].shape[2] - se
                ck = jnp.pad(ck, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))[:, 0]
                cv = jnp.pad(cv, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))[:, 0]
                onehot = jnp.arange(self.e.batch) == slot
                self.pools = self._set_cross(
                    self.pools, onehot, ck[:, None], cv[:, None],
                    jnp.full((self.e.batch,), se, jnp.int32))

    # ------------------------------------------------------------------
    def _window_blocks(self, slot: int) -> (List[int], int):
        """Physical blocks covering the near window + window_base (tokens)."""
        t = int(self._slot_len[slot])              # position of current token
        lo = max(0, t + 1 - self.W)
        wb = (lo // self.bt) * self.bt
        if self.e.mode == "arena":
            base = self._arena_base[slot]
            first = wb // self.bt
            return [base + first + i for i in range(self.NB)], wb
        sid = int(self._slot_sid[slot])
        s = self.pager.sessions[sid]
        trimmed = s.trimmed_prefix_blocks
        wb = max(wb, trimmed * self.bt)
        first_local = wb // self.bt - trimmed
        blocks = s.blocks[first_local:first_local + self.NB]
        return blocks + [0] * (self.NB - len(blocks)), wb

    # ------------------------------------------------------------------
    def step(self, now: float = float("inf")) -> StepMetrics:
        t0 = time.perf_counter()
        m = StepMetrics()
        self.sched.step_idx = self.steps_run

        # ---- Shift: retire EOS (handled at end of prev step), admit
        self._admit(now)
        active = self.sched.active_slots()
        m.active = len(active)

        B = self.e.batch
        descr = empty_descriptor(B, self.NB, self.cap, self.MT,
                                 chunk_blocks=self.chunk_blocks)
        tokens = np.zeros(B, np.int32)

        for slot in active:
            req = self.sched.request_at(slot)
            tokens[slot] = self.sched.next_token(slot, int(self._last_token[slot]))
            t = int(self._slot_len[slot])
            descr.seq_lens[slot] = t
            descr.slot_active[slot] = 1

            # ---- Stage: BLOCKALIGN reservation (prefetch-1 lookahead)
            if self.e.mode == "arena":
                base = self._arena_base[slot]
                bi, off = divmod(t, self.bt)
                descr.write_block[slot] = base + bi
                descr.write_offset[slot] = off
            else:
                sid = int(self._slot_sid[slot])
                self.pager.reserve(sid, 2)        # this token + lookahead
                blk, off = self.pager.append_token(sid)
                descr.write_block[slot] = blk
                descr.write_offset[slot] = off

            # ---- far-view: chunk completion -> summarize + trim
            if self.fv is not None:
                sid = int(self._slot_sid[slot])
                s = self.pager.sessions[sid]
                n_done = int(self.fv.n_chunks[slot])
                chunk_end = (n_done + 1) * self.e.sv_chunk
                if t + 1 - self.W >= chunk_end:
                    first_local = (n_done * self.e.sv_chunk) // self.bt \
                        - s.trimmed_prefix_blocks
                    cb = s.blocks[first_local:first_local + self.chunk_blocks]
                    descr.far_chunk_blocks[slot, :len(cb)] = cb
                    descr.far_chunk_tokens[slot] = self.e.sv_chunk
                    descr.far_do_summarize[slot] = 1
                    descr.far_write_idx[slot] = self.fv.on_chunk_summarized(slot)
                    # TRIM the summarized blocks (bounded budget)
                    self.pager.trim(sid, prefix_blocks=first_local + self.chunk_blocks)
                tbl, val = self.fv.select(slot)
                descr.far_table[slot] = tbl
                descr.far_valid[slot] = val

            # ---- window table + Reduce (train merging)
            blocks, wb = self._window_blocks(slot)
            descr.block_table[slot, :len(blocks)] = blocks
            descr.window_base[slot] = wb
            merging = self.e.mode in ("paged_merge", "full") or self.e.mode == "arena"
            trains, groups = self.transport.reduce(
                blocks, far_blocks=int(descr.far_valid[slot].sum() > 0),
                merging=merging)
            self.transport.fill_train_arrays(
                trains, descr.train_start, descr.train_len, descr.train_dst, slot)
            m.dma_groups += groups

        # ---- Frame: single atomic commit
        tf0 = time.perf_counter()
        if self.pager is not None:
            frame = self.pager.frame()
            descr = descr._replace(epoch=np.int32(frame["epoch"]))
            self.frames_committed += 1
        else:
            descr = descr._replace(epoch=np.int32(self.steps_run + 1))
        m.frame_commit = time.perf_counter() - tf0

        jdescr = FrameDescriptor(*[jnp.asarray(a) for a in descr])
        m.host = time.perf_counter() - t0

        # ---- device: one engine call, fixed shapes
        nxt, self.pools, fu, lg = self._step_fn(self.params, jnp.asarray(tokens),
                                                self.pools, jdescr)
        nxt = np.asarray(jax.block_until_ready(nxt))
        if self.e.debug_logits:
            self.debug_logits.append(np.asarray(lg, np.float32))

        # ---- post: bookkeeping, EOS retirement (burst-safe)
        for slot in active:
            self._slot_len[slot] += 1
            if self.sched.is_prefilling(slot):
                continue
            self._last_token[slot] = int(nxt[slot])
            req_t = self.sched.request_at(slot)
            if req_t is not None and req_t.first_token_step < 0:
                req_t.ttft_wall = self.cum_wall
            if self.e.debug_logits:
                req = self.sched.request_at(slot)
                if not hasattr(req, "logit_trace"):
                    req.logit_trace = []
                req.logit_trace.append(np.asarray(lg[slot], np.float32))
            if self.sched.record_output(slot, int(nxt[slot])):
                m.emitted += 1
                self.sched.requests[self.sched.slots[slot].rid].finish_wall = \
                    self.cum_wall
                self.sched.retire(slot)
                if self.pager is not None:
                    self.pager.trim(int(self._slot_sid[slot]), close=True)
                    self._slot_sid[slot] = -1
                self._slot_len[slot] = 0
            else:
                m.emitted += 1
        if self.fv is not None:
            self.fv.observe_utility(np.asarray(fu), np.asarray(descr.far_table))

        self.steps_run += 1
        m.wall = time.perf_counter() - t0
        self.cum_wall += m.wall
        self.peak_reserved_kv = max(self.peak_reserved_kv, self.reserved_kv_bytes())
        self.peak_active_kv = max(self.peak_active_kv, self.active_kv_bytes())
        self.metrics.append(m)
        return m

    # ------------------------------------------------------------------
    def run(self, max_steps: int = 10_000, now_fn=None) -> None:
        while (self.sched.waiting or self.sched.active_slots()) \
                and self.steps_run < max_steps:
            self.step(now=now_fn() if now_fn else float("inf"))

    # ------------------------------------------------------------------
    # audits & metrics
    # ------------------------------------------------------------------
    def audit(self) -> dict:
        steps = [m for m in self.metrics if m.active > 0]
        walls = np.array([m.wall for m in steps]) if steps else np.zeros(1)
        hosts = np.array([m.host for m in steps]) if steps else np.zeros(1)
        commits = np.array([m.frame_commit for m in steps]) if steps else np.zeros(1)
        ncomp = getattr(self._step_fn, "_cache_size", lambda: -1)()
        return {
            "mode": self.e.mode,
            "steps": len(steps),
            "compilations": ncomp,
            "single_commit_per_step": (self.pager is None
                                       or self.frames_committed == self.steps_run),
            "frames_committed": self.frames_committed,
            "submit_share": float(hosts.sum() / max(walls.sum(), 1e-12)),
            "frame_commit_us": float(commits.mean() * 1e6),
            "dma_groups_per_step": self.transport.stats.groups_per_step,
            "avg_dma_bytes": self.transport.stats.avg_group_bytes,
            "unmerged_groups_per_step": self.transport.stats.unmerged_groups_per_step,
            "reserved_kv_bytes": self.reserved_kv_bytes(),
            "active_kv_bytes": self.active_kv_bytes(),
            "peak_reserved_kv": self.peak_reserved_kv,
            "peak_active_kv": self.peak_active_kv,
        }

    def reserved_kv_bytes(self) -> int:
        n_layers = max(1, registry.n_paged_layers(self.cfg))
        if self.e.mode == "arena":
            return (self.num_blocks - 1) * self.block_bytes * n_layers
        return self.pager.reserved_bytes() * n_layers

    def active_kv_bytes(self) -> int:
        n_layers = max(1, registry.n_paged_layers(self.cfg))
        if self.e.mode == "arena":
            return int(self._slot_len.sum()) * self.bytes_per_token * n_layers
        return self.pager.active_tokens() * self.bytes_per_token * n_layers

    def latency_stats(self, skip: int = 3) -> dict:
        active = [m for m in self.metrics if m.active > 0]
        walls = np.array([m.wall for m in active[skip:]])
        if walls.size == 0:
            walls = np.array([m.wall for m in active]) if active else np.zeros(1)
        q = lambda p: float(np.percentile(walls * 1e3, p))
        return {"p50_ms": q(50), "p95_ms": q(95), "p99_ms": q(99),
                "p999_ms": q(99.9), "mean_ms": float(walls.mean() * 1e3),
                "max_ms": float(walls.max() * 1e3)}

    def throughput(self, skip: int = 3) -> float:
        steps = [m for m in self.metrics if m.active > 0][skip:]
        if not steps:
            steps = [m for m in self.metrics if m.active > 0]
        tok = sum(m.emitted for m in steps)
        wall = sum(m.wall for m in steps)
        return tok / max(wall, 1e-12)

    def request_latency_stats(self) -> dict:
        """Request-level completion / time-to-first-token (wall seconds,
        relative to engine start; arrival offsets subtracted when present)."""
        fin = self.sched.finished
        if not fin:
            return {}
        comp = np.array([getattr(r, "finish_wall", 0.0) for r in fin])
        ttft = np.array([getattr(r, "ttft_wall", 0.0) for r in fin])
        q = lambda a, p: float(np.percentile(a * 1e3, p))
        return {"completion_p50_ms": q(comp, 50), "completion_p99_ms": q(comp, 99),
                "ttft_p50_ms": q(ttft, 50), "ttft_p99_ms": q(ttft, 99)}
