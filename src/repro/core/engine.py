"""KVRMEngine — fixed-shape decode serving under the descriptor transport
interface (paper §4), plus the static-arena baseline on the SAME executor.

Modes (Table 5 attribution rows):
  * arena       — static-graph baseline: worst-case contiguous per-slot
                  reservation, no paging, no merging.
  * paged       — + KV pager (RESERVE/ALIAS/TRIM/FRAME), unmerged transport.
  * paged_merge — + merge-staged descriptor transport (core KV-RM path).
  * full        — + far-view summarization (optional bounded-budget policy).

Invariants audited every run: the decode step is compiled ONCE (no retrace
after warm-up), exactly one Frame commit per step, bounded host control share.

Hot-path structure (DESIGN.md §3):
  * ``pipeline_depth >= 1`` (default) overlaps host descriptor assembly for
    step t+1 with device execution of step t. Sampled-token feedback flows
    device-side (the compiled step selects between host prompt tokens and the
    previous step's on-device sample), so host readback lags dispatch by one
    step. In legacy greedy mode (``greedy=True``) EOS is the gen_len token
    budget, retirement is host-predictable and happens at dispatch time, and
    the pager/transport timeline is bit-identical to the synchronous path.
  * ``greedy = False`` (DESIGN.md §13) turns on real on-device sampling
    (temperature/top-k/top-p, per-slot threefry keys derived from the
    control plane's rid row + descriptor seq_lens) and data-dependent EOS:
    per-request stop tokens end a request wherever they land. Retirement is
    then DETECTED at readback — under pipelining the host learns of a stop
    ``depth`` dispatches late, scrubs the overshot in-flight emissions, and
    reconciles pager/transport/kernel accounting exactly, so the depth-0
    and depth-d timelines still agree byte-for-byte.
  * ``pipeline_depth = 0`` preserves the exact seed behavior (per-slot
    descriptor assembly, blocking readback each step) for A/B measurement.
  * ``prefill_chunk = C > 0`` ingests prompts through a second fixed-shape
    chunked prefill executor (compiled once) at C tokens per engine step
    instead of one; the final prompt token always goes through the decode
    step so sampled-token semantics are unchanged.
  * ``prefix_cache`` (DESIGN.md §9) indexes committed full prompt blocks
    in a radix tree keyed on token-id block chunks; admissions that match
    COW-alias the cached chain (unaligned tails get an audited device-side
    COW copy) and skip the covered prefill entirely — a cached system
    prompt costs zero prefill steps. Eviction is refcount-aware LRU over
    unpinned leaves, preferring unshared (immediately freeable) blocks.
  * ``mesh`` (DESIGN.md §4) runs the SAME executors SPMD over a device mesh:
    params shard by the name-based TP rules, KV pools shard their kv-head
    axis over ``model``, and both executors compile ONCE with explicit
    in/out shardings (descriptor + token feedback replicated, donated pools
    keep their sharding). The host control plane — scheduler, pager,
    transport, the single flat descriptor commit — is untouched, so every
    audit invariant and the full token stream are identical to the
    single-device engine at every TP degree.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass, field
from typing import Deque, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.descriptor import (FrameDescriptor, active_block_extents,
                                   chunk_flat_size, control_plane_size,
                                   control_plane_views,
                                   descriptor_flat_size,
                                   empty_descriptor, flat_chunk_views,
                                   flat_descriptor_views, refresh_control_row,
                                   unflatten_chunk_descriptor,
                                   unflatten_descriptor)
from repro.core.farview import FarViewPolicy
from repro.core.pager import (RES_DEVICE, RES_HOST, BlockPager, SwapRefused)
from repro.core.prefix_cache import PrefixCache
from repro.core.sampling import make_sampler, slot_keys
from repro.core.scheduler import Request, Scheduler
from repro.core.transport import MergeStagedTransport, StagedDescriptor, merge_runs
from repro.models import registry
from repro.serving.api import AuditReport

MODES = ("arena", "paged", "paged_merge", "full")


@dataclass
class EngineConfig:
    mode: str = "paged_merge"
    batch: int = 8                   # fixed slot width B
    max_seq: int = 512               # worst-case sequence length
    near_window: Optional[int] = None   # W* (kernel width); None = max_seq (dense)
    block_tokens: int = 16           # BLOCKALIGN quantum (tokens)
    pool_budget_frac: float = 1.0    # paged pool size vs worst case
    farview_cap: int = 16
    sv_chunk: int = 64
    span_blocks: int = 4             # placement span (BLOCKALIGN granularity)
    greedy: bool = True              # True = legacy bit-exact argmax decode
    #                                  with pure budget-EOS; False = on-device
    #                                  sampling + detected EOS (DESIGN.md §13)
    # --- sampling knobs (greedy=False only; static at trace time) ---
    temperature: float = 1.0         # <= 0 is an exact argmax branch
    top_k: int = 0                   # 0 = off (full vocab)
    top_p: float = 1.0               # 1.0 = off (no nucleus cut)
    sample_seed: int = 0             # base PRNG seed; per-slot keys are
    #                                  fold_in(fold_in(seed, rid), position)
    debug_logits: bool = False       # capture per-step logits (tests only)
    # --- host/device overlap + chunked prefill (DESIGN.md §3) ---
    pipeline_depth: int = 1          # 0 = seed-exact synchronous loop (A/B)
    prefill_chunk: int = 0           # tokens per prefill-executor call (0 = off)
    # --- SPMD decode (DESIGN.md §4): jax Mesh with a 'model' axis (TP);
    # None = single-device (seed-exact placement) ---
    mesh: Optional[object] = None
    # --- host KV tier + preemption-aware scheduling (DESIGN.md §8) ---
    host_pool_blocks: int = 0        # host backing pool (blocks); 0 = off
    kv_oversubscribe: float = 1.0    # derives host_pool_blocks when > 1.0:
    #                                  host = (ratio - 1) * device pool
    swap_high_watermark: float = 0.92  # device-pool fill that triggers
    swap_low_watermark: float = 0.80   # cold swap-out down to this fill
    admit_watermark: float = 0.85    # admission caps committed KV at
    #                                  admit_wm * device + host blocks
    # --- automatic shared-prefix KV reuse (radix prefix cache, §9) ---
    prefix_cache: bool = False       # index committed prompt blocks and
    #                                  COW-alias matches at admission
    prefix_cache_blocks: int = 0     # cache pin budget (blocks);
    #                                  0 = auto (half the device pool)
    # --- quantized KV-block storage tier (DESIGN.md §10) ---
    kv_dtype: str = "bf16"           # "bf16" | "fp8_e4m3" | "int8": narrow
    #                                  K/V storage + per-block per-head f32
    #                                  scale pools managed by the pager in
    #                                  lockstep with their data blocks
    # --- async movement engine (DESIGN.md §11) ---
    async_movement: bool = True      # double-buffered staging + deferred
    #                                  swap-out readback fences; False =
    #                                  per-event blocking movement (A/B)
    # --- work-skipping kernels (DESIGN.md §12) ---
    kernel_skip_extent: bool = True  # per-slot active-extent predication in
    #                                  the decode/prefill kernels; False =
    #                                  always-run masked baseline (A/B)
    # --- step-level (continuous) batching (DESIGN.md §15) ---
    continuous_batching: bool = True  # admit into freed slots at every
    #                                  decode step; False = round-based
    #                                  baseline (admit only once every
    #                                  active slot has drained) for A/B
    #                                  head-of-line-blocking measurement


@dataclass
class StepMetrics:
    wall: float = 0.0
    host: float = 0.0                # control-plane time (submit+frame)
    frame_commit: float = 0.0
    dma_groups: int = 0
    active: int = 0
    emitted: int = 0


class KVRMEngine:
    def __init__(self, cfg: ModelConfig, params, ecfg: EngineConfig):
        assert ecfg.mode in MODES
        self.cfg = cfg
        self.params = params
        self.e = ecfg
        self.paged_arch = registry.uses_paged_kv(cfg)

        bt = ecfg.block_tokens
        self.bt = bt
        self.W = ecfg.near_window or ecfg.max_seq
        self.NB = -(-self.W // bt) + 1
        self.MT = self.NB + 1
        self.blocks_per_seq = -(-ecfg.max_seq // bt) + 1
        worst = ecfg.batch * self.blocks_per_seq
        # Without a host tier the pool floor keeps every slot's near window
        # device-resident simultaneously (no swap path exists). WITH the
        # host tier (DESIGN.md §8) the floor drops to one window + growth
        # slack: the device pool may be genuinely oversubscribed — admission
        # watermarks and preemption keep the concurrent working set inside
        # it, and the host pool absorbs the rest.
        want_host = ecfg.mode != "arena" and (ecfg.host_pool_blocks > 0
                                              or ecfg.kv_oversubscribe > 1.0)
        if ecfg.mode == "arena":
            self.num_blocks = worst + 1
        else:
            floor = self.NB * ecfg.batch
            if want_host:
                floor = min(floor, self.NB + ecfg.span_blocks + 2)
            self.num_blocks = max(floor,
                                  int(worst * ecfg.pool_budget_frac)) + 1

        # --- quantized KV-block tier (DESIGN.md §10) --------------------
        # Narrow storage halves (or better) every per-block byte figure the
        # transport accounts — window trains, swaps, COW copies — plus the
        # reserved-KV audit; the per-block f32 scale pools are a sibling
        # physical resource whose overhead is accounted per block here.
        self._quant = ecfg.kv_dtype != "bf16"
        if self._quant:
            err = registry.quant_decode_error(cfg, ecfg.kv_dtype)
            if err is not None:
                raise ValueError(err)
            if ecfg.mode == "full":
                raise ValueError("kv_dtype != 'bf16' requires mode != 'full' "
                                 "(far-view summaries are stored full-width)")

        # per-layer payload bytes (transport accounting uses the real model)
        self.bytes_per_token = registry.paged_payload_bytes_per_token(
            cfg, ecfg.kv_dtype)
        # per-(layer, block) scale overhead: one f32 per kv head for each of
        # the k and v scale pools (0 when unquantized)
        self.scale_bytes_per_block = (2 * cfg.n_kv_heads * 4
                                      if self._quant else 0)
        self.block_bytes = bt * self.bytes_per_token + self.scale_bytes_per_block
        # what the same block costs at full bf16 width (quant savings basis)
        self._dense_block_bytes = bt * registry.paged_payload_bytes_per_token(cfg)
        n_layers_paged = max(1, registry.n_paged_layers(cfg))
        self.pool_bytes_total = (self.num_blocks - 1) * self.block_bytes * n_layers_paged

        self.farview = ecfg.mode == "full" and self.paged_arch and cfg.family != "hybrid"
        self.cap = ecfg.farview_cap if self.farview else 1
        self.max_chunks = (-(-max(1, ecfg.max_seq - self.W) // ecfg.sv_chunk) + 1
                           if self.farview else 0)
        self.chunk_blocks = max(1, ecfg.sv_chunk // bt)

        # --- device state ---
        self.pools = registry.init_decode_pools(
            cfg, batch=ecfg.batch, num_blocks=self.num_blocks, block_tokens=bt,
            max_chunks=self.max_chunks,
            enc_len=ecfg.max_seq if cfg.family == "encdec" else 0,
            kv_dtype=ecfg.kv_dtype)
        if cfg.family == "encdec":
            self.pools["enc_len"] = jnp.zeros((ecfg.batch,), jnp.int32)

        # --- host KV tier (DESIGN.md §8) -------------------------------
        hostb = int(ecfg.host_pool_blocks)
        if hostb == 0 and ecfg.kv_oversubscribe > 1.0:
            hostb = int(np.ceil((ecfg.kv_oversubscribe - 1.0)
                                * (self.num_blocks - 1)))
        self.host_pool_blocks = hostb if ecfg.mode != "arena" else 0
        self._host_tier = self.host_pool_blocks > 0
        if self._host_tier:
            # swap moves block-indexed pool payload only; families with
            # slot-indexed decode state (recurrent/conv/cross-KV) or
            # far-view summaries would lose it across a preemption
            if ecfg.mode == "full" or cfg.family not in ("dense", "vlm", "moe"):
                raise ValueError(
                    "host KV tier requires a block-paged family "
                    "(dense/vlm/moe) and mode != 'full'")
            if ecfg.mesh is not None:
                raise ValueError("host KV tier is single-device for now "
                                 "(sharded swap gather/scatter untested)")

        # --- radix prefix cache (DESIGN.md §9): shared-prefix KV reuse --
        # same scope rules as the host tier: block aliasing moves paged KV
        # only, so families with extra slot-indexed decode state (and the
        # far view's summaries) cannot skip prefill by block sharing
        self._prefix_on = ecfg.prefix_cache
        if self._prefix_on:
            if ecfg.mode == "arena" or self.farview \
                    or cfg.family not in ("dense", "vlm", "moe"):
                raise ValueError(
                    "prefix cache requires a paged mode (not 'arena' or "
                    "'full') and a block-paged family (dense/vlm/moe)")
            if ecfg.mesh is not None:
                raise ValueError("prefix cache is single-device for now "
                                 "(sharded COW tail copy untested)")

        # --- host control plane ---
        self.sched = Scheduler(ecfg.batch)
        self.pager = (BlockPager(self.num_blocks, bt, self.block_bytes,
                                 span_blocks=ecfg.span_blocks,
                                 host_pool_blocks=self.host_pool_blocks)
                      if ecfg.mode != "arena" else None)
        self.transport = MergeStagedTransport(
            block_bytes=self.block_bytes,
            merge_threshold_bytes=cfg.serving.merge_threshold_bytes,
            max_hold_steps=cfg.serving.max_hold_steps, max_trains=self.MT,
            dense_block_bytes=self._dense_block_bytes)
        self.fv = (FarViewPolicy(ecfg.batch, self.max_chunks, self.cap,
                                 ecfg.sv_chunk, bt) if self.farview else None)

        # --- prefix cache state (DESIGN.md §9) --------------------------
        self.prefix_cache = None
        if self._prefix_on:
            cap_blocks = ecfg.prefix_cache_blocks or \
                max(self.NB, (self.num_blocks - 1) // 2)
            self.prefix_cache = PrefixCache(self.pager, bt, cap_blocks)
        self._pinned_paths: Dict[int, list] = {}   # rid -> matched path
        self._indexed_rids: set = set()            # prompts already indexed
        self._cow_pairs_step: List = []            # COW tail copies to run
        self._cow_origin: Dict[int, int] = {}      # this round: dst -> src

        # --- SPMD placement (DESIGN.md §4) ------------------------------
        # Params shard by the name-based TP rules; paged KV pools shard the
        # kv-head axis over `model` (n_rep grouping preserved per shard, so
        # attention needs no collective — the one psum per layer is at the
        # output projection). Everything host-committed (descriptor, tokens,
        # feed mask) is replicated. mesh=None keeps seed-exact placement.
        self.mesh = ecfg.mesh
        self.tp_degree = 1
        self._kv_shards = 1
        self._repl = self._param_sh = self._pool_sh = None
        if self.mesh is not None:
            from jax.sharding import NamedSharding, PartitionSpec
            from repro.distributed import sharding as shd
            tp = shd.model_shards(self.mesh)
            err = registry.tp_decode_error(cfg, tp)
            if err is not None:
                raise ValueError(err)
            self.tp_degree = tp
            pspecs = shd.sanitize_specs(self.mesh, self.params,
                                        shd.param_specs(cfg, self.params))
            self._param_sh = shd.to_shardings(self.mesh, pspecs)
            self.params = jax.device_put(self.params, self._param_sh)
            kvspecs = shd.sanitize_specs(
                self.mesh, self.pools,
                registry.decode_pool_partition_specs(cfg, self.pools))
            self._pool_sh = shd.to_shardings(self.mesh, kvspecs)
            self.pools = jax.device_put(self.pools, self._pool_sh)
            self._repl = NamedSharding(self.mesh, PartitionSpec())
            paged_key = "k" if "k" in kvspecs else (
                "lat" if "lat" in kvspecs else None)
            if paged_key is not None and shd.MODEL in tuple(kvspecs[paged_key]):
                self._kv_shards = tp

        # arena bookkeeping: slot -> fixed block range
        self._arena_base = [1 + i * self.blocks_per_seq for i in range(ecfg.batch)]
        self._slot_len = np.zeros(ecfg.batch, np.int64)   # tokens in cache
        self._slot_sid = -np.ones(ecfg.batch, np.int64)
        self._last_token = np.zeros(ecfg.batch, np.int64)

        # --- compiled decode step (ONE compilation; invariant audit) ---
        cfg_dec = cfg.replace(serving=cfg.serving.__class__(
            page_size=cfg.serving.page_size, near_window=self.W,
            farview_cap=self.cap, sv_chunk=ecfg.sv_chunk,
            merge_threshold_bytes=cfg.serving.merge_threshold_bytes,
            max_hold_steps=cfg.serving.max_hold_steps,
            enable_farview=self.farview,
            skip_extent=ecfg.kernel_skip_extent))
        self._cfg_dec = cfg_dec

        dbg = ecfg.debug_logits

        # --- on-device sampling (DESIGN.md §13) -------------------------
        # greedy=True keeps the exact legacy argmax executor; greedy=False
        # builds a static sampler closure (temperature/top-k/top-p baked at
        # trace time) whose per-slot keys derive from the control plane's
        # rid row and the committed seq_lens — tokens depend only on
        # (sample_seed, rid, position), invariant to slot placement, batch
        # composition, pipeline depth, preemption and mesh layout.
        self._sampled = not ecfg.greedy
        self.eos_detected = 0
        self.eos_overshoot_tokens = 0
        self.eos_reconciled_blocks = 0
        # per-token event hook (serving gateway, DESIGN.md §14): called as
        # ``token_hook(req, token, finished)`` wherever a token VALUE lands
        # host-side — the sync step's post-device loop and the pipelined
        # readback. Scrubbed overshoot emissions (§13) never fire it, and a
        # cancel's terminal event is the caller's to emit (no token lands).
        self.token_hook = None
        self.cancelled = 0
        # --- step-level admission audit (DESIGN.md §15) -----------------
        # continuous_admits counts admissions that landed while at least
        # one other slot was mid-round (already decoding) — exactly the
        # admissions a round-based engine would have held at the barrier.
        # slot_idle_steps_saved integrates, per dispatched step, the slots
        # occupied by such a mid-round admission: the idle slot-steps the
        # barrier would have cost. Both are identically 0 when
        # continuous_batching=False — the A/B witness.
        self.continuous_admits = 0
        self.slot_idle_steps_saved = 0
        self._mid_round = np.zeros(ecfg.batch, bool)
        if self._sampled:
            if ecfg.temperature > 0 and not 0.0 < ecfg.top_p <= 1.0:
                raise ValueError(f"top_p must be in (0, 1]: {ecfg.top_p}")
            if ecfg.top_k < 0:
                raise ValueError(f"top_k must be >= 0: {ecfg.top_k}")
            sampler = make_sampler(ecfg.temperature, ecfg.top_k, ecfg.top_p)
            base_key = jax.random.PRNGKey(ecfg.sample_seed)

        # Token selection happens ON DEVICE so the pipelined loop can feed the
        # previous step's sampled tokens without a host readback: host prompt
        # tokens where feed_sampled=0, previous on-device sample where 1. The
        # synchronous path passes feed_sampled=0 everywhere — same semantics,
        # identical numerics for both paths.
        def _step_core(params, host_tokens, feed_sampled, rids, prev_nxt,
                       pools, descr):
            tokens = jnp.where(feed_sampled > 0, prev_nxt, host_tokens)
            logits, pools, fu = registry.decode_step(params, cfg_dec, tokens,
                                                     pools, descr)
            if self._sampled:
                keys = slot_keys(base_key, rids, descr.seq_lens)
                nxt = sampler(keys, logits)
            else:
                nxt = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return nxt, pools, fu, (logits if dbg else jnp.zeros((), jnp.int32))

        self.depth = max(0, int(ecfg.pipeline_depth))
        B, NB, CAP, MT, CB = (ecfg.batch, self.NB, self.cap, self.MT,
                              self.chunk_blocks)
        self._flat_descr_size = descriptor_flat_size(B, NB, CAP, MT, CB)
        D = self._flat_descr_size
        # explicit executor shardings under a mesh: donated pools keep their
        # kv-head sharding, the control plane is replicated — exactly one
        # compilation per executor either way (audited)
        R, PS = self._repl, self._pool_sh
        if self.depth <= 0:
            # seed-exact executor: per-array descriptor operands
            kw = ({} if self.mesh is None else dict(
                in_shardings=(self._param_sh, R, R, R, R, PS, R),
                out_shardings=(R, PS, R, R)))
            self._step_fn = jax.jit(_step_core, donate_argnums=(5,), **kw)
        else:
            # pipelined executor: the whole control plane (descriptor + host
            # tokens + feed mask + rid row) arrives as ONE flat int32
            # operand — one device_put per step instead of ~18 (the
            # dominant host cost)
            def _step_flat(params, flat, prev_nxt, pools):
                descr = unflatten_descriptor(flat[:D], B, NB, CAP, MT, CB)
                host_tokens = flat[D:D + B]
                feed_sampled = flat[D + B:D + 2 * B]
                rids = flat[D + 2 * B:D + 3 * B]
                return _step_core(params, host_tokens, feed_sampled, rids,
                                  prev_nxt, pools, descr)
            kw = ({} if self.mesh is None else dict(
                in_shardings=(self._param_sh, R, R, PS),
                out_shardings=(R, PS, R, R)))
            self._step_fn = jax.jit(_step_flat, donate_argnums=(3,), **kw)
        self._compiles = 0
        self.debug_logits: List[np.ndarray] = []

        # --- chunked prefill executor (second fixed-shape compilation) ---
        self._chunked = (ecfg.prefill_chunk > 0
                         and registry.supports_chunked_prefill(cfg)
                         and not self.farview)
        self.chunk = int(ecfg.prefill_chunk) if self._chunked else 0
        if self._chunked:
            CD = chunk_flat_size(B, self.chunk, self.NB)
            C = self.chunk
            def _chunk_step(params, pools, cflat):
                cdescr = unflatten_chunk_descriptor(cflat, B, C, NB)
                return registry.prefill_chunk(params, cfg_dec, pools, cdescr)
            ckw = ({} if self.mesh is None else dict(
                in_shardings=(self._param_sh, PS, R), out_shardings=PS))
            self._chunk_fn = jax.jit(_chunk_step, donate_argnums=(1,), **ckw)
            self._cflat = np.zeros(CD, np.int32)
            self._cdescr = flat_chunk_views(self._cflat, B, self.chunk, self.NB)
        else:
            self._chunk_fn = None
        # below this many remaining prompt tokens, ingestion rides the decode
        # step instead (zero marginal steps while other slots decode) — a
        # full-width batched chunk call isn't worth it for a tiny remainder.
        # Capped at a few blocks so an oversized C never disables chunking.
        self._chunk_min = (max(self.bt, min(self.chunk // 2, 4 * self.bt))
                           if self._chunked else 0)
        self._chunk_steps = 0
        self._chunk_wait = 0.0

        # --- work-skipping kernel audit (DESIGN.md §12): the fixed decode
        # grid walks NB window blocks per participating slot-step; the
        # descriptor-side extent derivation below mirrors the kernel's
        # scalar-prefetch meta, so `skipped` is exactly the predicated-off
        # share of `total` (0 when kernel_skip_extent is off).
        self._kernel_blocks_total = 0
        self._kernel_blocks_skipped = 0

        # --- pipelined dispatch state (DESIGN.md §3) ---
        self._inflight: Deque[dict] = deque()
        self._prev_nxt = jnp.zeros(ecfg.batch, jnp.int32)
        self._zero_feed = jnp.zeros(ecfg.batch, jnp.int32)
        if self.mesh is not None:
            # commit the device-side feedback chain to the replicated layout
            # up front: the executor's later outputs are committed replicated
            # arrays, and an uncommitted first-step operand would key a
            # second (spurious) compilation of the same executable
            self._prev_nxt = jax.device_put(self._prev_nxt, self._repl)
            self._zero_feed = jax.device_put(self._zero_feed, self._repl)
        # device-side feedback chain validity: True once a slot has emitted in
        # a step dispatched BY THIS ENGINE. A restored checkpoint starts with
        # a broken chain (no _prev_nxt) and re-seeds from host _last_token.
        self._feed_ok = np.zeros(ecfg.batch, bool)

        # --- persistent flat descriptor buffer + window-block cache -------
        # (vectorized assembly: numpy views into one flat buffer, rebuilt
        # incrementally, never reallocated)
        self._flat = np.zeros(D + control_plane_size(ecfg.batch), np.int32)
        self._pdescr = flat_descriptor_views(self._flat[:D], B, NB, CAP, MT, CB)
        self._cp = control_plane_views(self._flat, B, offset=D)
        self._tokens_buf = self._cp.host_tokens
        self._feed_buf = self._cp.feed_sampled
        self._rid_buf = self._cp.rids
        self._win_base_cache = np.full(ecfg.batch, -1, np.int64)
        self._win_dirty = np.ones(ecfg.batch, bool)
        self._win_groups = np.zeros(ecfg.batch, np.int64)
        self._win_nblocks = np.zeros(ecfg.batch, np.int64)
        self._merging = ecfg.mode != "paged"

        # --- host-tier swap machinery (DESIGN.md §8) --------------------
        # Block-indexed pools (block axis 1) are the swap payload; the host
        # backing store is allocated lazily on first swap-out. Gather and
        # scatter are padded to a fixed blocks_per_seq index width so each
        # direction compiles exactly once per pool key (padding targets
        # scratch block 0, whose contents are masked by contract).
        self.preemptions = 0
        self._committed_blocks = 0
        self._resume_pending = 0
        self._step_touched: set = set()
        self._host_kv: Dict[str, np.ndarray] = {}
        # block-indexed pool keys (block axis 1): the payload both the
        # host-tier swaps and the §9 COW tail copies move
        self._block_pool_keys = [k for k, v in self.pools.items()
                                 if getattr(v, "ndim", 0) >= 2
                                 and v.shape[1] == self.num_blocks] \
            if self.pager is not None else []
        self._swap_keys = self._block_pool_keys if self._host_tier else []
        if self._host_tier:
            self._swap_gather_fn = jax.jit(lambda pool, idx: pool[:, idx])
            self._swap_scatter_fn = jax.jit(
                lambda pool, idx, data: pool.at[:, idx].set(data),
                donate_argnums=(0,))
        # COW tail copy executor (§9): one padded block->block copy per
        # pool key, dispatched async on the donated pool chain (like
        # swap-in); padding copies scratch block 0 onto itself. Built for
        # ANY paged single-device engine — the legacy prefix_of hint path
        # needs it too whenever the shared prefix is not block-aligned.
        self._cow_copy_fn = None
        if self.pager is not None and self.mesh is None:
            self._cow_copy_fn = jax.jit(
                lambda pool, src, dst: pool.at[:, dst].set(pool[:, src]),
                donate_argnums=(0,))
        # fixed swap-transfer index width: a session can overshoot its token
        # need by up to a placement span (reserve takes whole spans while the
        # pool is comfortable), so the pad must cover blocks_per_seq + span
        self._swap_pad = self.blocks_per_seq + ecfg.span_blocks
        # async movement engine (DESIGN.md §11): double-buffered host
        # staging for swap-in scatters (one preallocated pair per pool key,
        # alternated across transfers) + cumulative blocking-movement time
        # (the per-step stall the deferred path is hiding)
        self._stage_in: Dict[str, List[np.ndarray]] = {}
        self._stage_sel = 0
        self.swap_stall_s = 0.0

        # metrics
        self.metrics: List[StepMetrics] = []
        self.frames_committed = 0
        self.steps_run = 0
        self.peak_reserved_kv = 0
        self.peak_active_kv = 0
        self.cum_wall = 0.0
        self._rid_to_sid: Dict[int, int] = {}

        # encdec: encoder-side prefill executor (separate from the audited
        # decode path; populates immutable cross-attention KV per admission)
        if cfg.family == "encdec":
            def _encode(params, enc_embeds):
                from repro.models import encdec as ed
                enc_out = ed.encode(params, cfg, enc_embeds)
                return ed.cross_kv(params, cfg, enc_out)
            self._encode_fn = jax.jit(_encode)
            self._set_cross = jax.jit(
                lambda pools, slot_onehot, ck, cv, elen: {
                    **pools,
                    "cross_k": jnp.where(slot_onehot[None, :, None, None, None],
                                         ck, pools["cross_k"]),
                    "cross_v": jnp.where(slot_onehot[None, :, None, None, None],
                                         cv, pools["cross_v"]),
                    "enc_len": jnp.where(slot_onehot, elen, pools["enc_len"]),
                })

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        if getattr(req, "stop_tokens", ()) and not self._sampled:
            raise ValueError(
                "per-request stop_tokens require sampled decode "
                "(greedy=False); legacy greedy mode is budget-EOS only. "
                "For argmax decode WITH stop tokens use greedy=False, "
                "temperature=0.")
        self.sched.submit(req)

    def cancel(self, rid: int) -> bool:
        """Cancel a request wherever it lives (serving gateway, §14):

        * still waiting — drop it from the queue (it holds no resources);
        * preempted (host-resident, §8) — release its admission charge and
          prefix pins, close its swapped-out pager session (``trim`` frees
          host entries too);
        * active in a slot — drain the dispatch pipeline first (in-flight
          steps reference its blocks and still owe token readbacks), then
          retire the slot through the one retirement path, which frees
          device blocks, pins and the session exactly as an EOS would.

        ``finish_reason`` becomes "cancelled"; partial output stays on
        ``req.generated``. Returns False when rid is unknown or already
        finished. The pager's zero-leak invariant holds after any cancel
        (asserted in tests via ``pager.check_invariants()``)."""
        req = self.sched.requests.get(rid)
        if req is None or req.finish_reason:
            return False
        if req in self.sched.waiting:
            self.sched.waiting.remove(req)
            req.finish_reason = "cancelled"
            req.finish_wall = self.cum_wall
            req.finish_step = self.sched.step_idx
            self.sched.finished.append(req)
            self.cancelled += 1
            return True
        if req in self.sched.preempted:
            self.sched.preempted.remove(req)
            if self._host_tier:
                self._committed_blocks -= req.committed_blocks
            self._prefix_release(req)
            self._indexed_rids.discard(rid)
            if self.pager is not None and req.swap_sid >= 0:
                self._drain_out_fences()     # in-flight swap-outs must land
                self.pager.trim(req.swap_sid, close=True)
                req.swap_sid = -1
            req.finish_reason = "cancelled"
            req.finish_wall = self.cum_wall
            req.finish_step = self.sched.step_idx
            self.sched.finished.append(req)
            self.cancelled += 1
            return True
        for slot, st in enumerate(self.sched.slots):
            if st.rid == rid:
                self.flush()
                if self.sched.slots[slot].rid != rid:
                    return False             # the drain already retired it
                req.finish_reason = "cancelled"
                self._retire_slot(slot)
                self.cancelled += 1
                return True
        return False

    # ------------------------------------------------------------------
    def _admit(self, now: float) -> None:
        """Step-level admission gate (DESIGN.md §15). With continuous
        batching (the default) every call falls through to
        ``_admit_into_free_slots``: a slot freed by EOS retirement, cancel
        or preemption is refilled on the very next decode step, while the
        surviving slots keep stepping. The round-based baseline instead
        holds the scheduler at a barrier (``hold=True``, which audits the
        stall) until the current round has fully drained."""
        if not self.e.continuous_batching and self.sched.active_slots():
            self.sched.admit(now, hold=True)
            return
        self._admit_into_free_slots(now)

    def _admit_into_free_slots(self, now: float) -> None:
        kv_ok = self._admission_ok if self._host_tier else None
        self._resume_pending = 0         # per-admit-call swap-in demand
        # an admission is "mid-round" when another slot is already decoding
        # — the case a round-based engine would have left this slot idle
        mid_round = bool(self.sched.active_slots())
        for slot, req, sid in self.sched.admit(now, kv_ok=kv_ok):
            self._win_dirty[slot] = True
            self._win_base_cache[slot] = -1
            self._feed_ok[slot] = False
            refresh_control_row(self._cp, slot, rid=req.rid)  # rng meta §13
            self._step_touched.add(slot)
            if mid_round:
                self.continuous_admits += 1
                self._mid_round[slot] = True
            if req.swap_sid >= 0 and req.swap_sid == sid:
                # resume from the host tier (DESIGN.md §8): swap the window
                # working set back onto device in merged groups and
                # re-attach — generation state rides the Request, so no
                # recompute. Blocks below the window stay host-resident.
                self._drain_out_fences()  # slots must hold real bytes
                s = self.pager.sessions[sid]
                assert s.swap_state == RES_HOST
                first_local = self._first_window_local(s, req.resume_len)
                pairs = self.pager.swap_in_begin(sid, first_local)
                if pairs:
                    self.transport.account_swap(pairs, direction="in")
                    self._swap_copy_in([p[0] for p in pairs],
                                       [p[1] for p in pairs])
                self.pager.swap_in_commit(sid)
                self._slot_sid[slot] = sid
                self._slot_len[slot] = req.resume_len
                self._last_token[slot] = req.resume_last_token
                req.swap_sid = -1
                if self.prefix_cache is not None:
                    # re-index (§9): the preempt dropped this prompt from
                    # the cache (swap eligibility required refcount 1);
                    # its device-resident full prompt blocks are committed
                    # KV again, so future admissions can share them
                    self._prefix_index(slot, req)
                continue
            self._slot_len[slot] = 0
            self._last_token[slot] = int(req.prompt[0]) if len(req.prompt) else 0
            if self.pager is not None:
                self.pager.open_session(sid)
                self._slot_sid[slot] = sid
                aliased = False
                if self.prefix_cache is not None:
                    # §9: automatic reuse — radix match over committed
                    # prompt blocks, COW alias, skip the covered prefill
                    aliased = self._prefix_admit(slot, req, sid)
                if not aliased and req.prefix_of is not None \
                        and req.prefix_len >= self.bt:
                    # legacy explicit hint path (trace-provided prefix_of)
                    src_sid = self._rid_to_sid.get(req.prefix_of)
                    if src_sid is not None and src_sid in self.pager.sessions \
                            and self._alias_src_resident(src_sid,
                                                         req.prefix_len):
                        n_share = req.prefix_len
                        if self._cow_copy_fn is None:
                            # no COW executor (sharded engine): share full
                            # blocks only, prefill the unaligned tail
                            n_share = (n_share // self.bt) * self.bt
                        if n_share >= self.bt:
                            self.pager.alias(src_sid, sid, n_share)
                            self._capture_cow(sid)
                            self._slot_len[slot] = \
                                self.pager.sessions[sid].length
                            req.prompt_pos = int(self._slot_len[slot])
                self._rid_to_sid[req.rid] = sid
            if self.fv is not None:
                self.fv.reset_slot(slot)
            if self.cfg.family == "encdec":
                enc = getattr(req, "enc_embeds", None)
                if enc is None:
                    enc = np.random.default_rng(req.rid).normal(
                        size=(1, 8, self.cfg.d_model)).astype(np.float32)
                ck, cv = self._encode_fn(self.params, jnp.asarray(enc))
                se = ck.shape[2]
                pad = self.pools["cross_k"].shape[2] - se
                ck = jnp.pad(ck, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))[:, 0]
                cv = jnp.pad(cv, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))[:, 0]
                onehot = jnp.arange(self.e.batch) == slot
                self.pools = self._set_cross(
                    self.pools, onehot, ck[:, None], cv[:, None],
                    jnp.full((self.e.batch,), se, jnp.int32))
                if self.mesh is not None:
                    # the (unsharded) encode path hands back single-device
                    # pools; restore the executor's expected placement
                    self.pools = jax.device_put(self.pools, self._pool_sh)
        if self._cow_pairs_step:
            # materialize this admit round's COW tails: ONE batched padded
            # copy per pool key, audited as its own group kind (§9)
            pairs, self._cow_pairs_step = self._cow_pairs_step, []
            self._cow_origin.clear()
            self.transport.account_cow(pairs)
            self._cow_copy(pairs)

    # ------------------------------------------------------------------
    # prefix cache: admission match / prompt indexing / COW copies (§9)
    # ------------------------------------------------------------------
    def _capture_cow(self, sid: int) -> None:
        """Queue a fresh alias's pending COW tail copy for this admit
        round's batched execution (frame() would silently consume it).

        Chained same-round aliases (C aliases B which aliased A in the
        SAME round): C's copy source is B's dst block, which the batched
        scatter has not materialized yet — the gather reads the pre-update
        pool. COW copies are whole-block, so copying from the transitive
        ORIGIN block is exact; resolve the chain host-side."""
        cp = self.pager.sessions[sid].cow_pending
        if cp is not None and self._cow_copy_fn is not None:
            src, dst = cp
            src = self._cow_origin.get(src, src)
            self._cow_origin[dst] = src
            self._cow_pairs_step.append((src, dst))

    def _prefix_admit(self, slot: int, req, sid: int) -> bool:
        """Consult the radix index for req's prompt; on a usable match,
        COW-alias the matched chain and skip the covered prefill. At least
        the LAST prompt token always goes through the decode step (sampled
        -token semantics), so the alias covers min(match, len(prompt)-1)."""
        pc = self.prefix_cache
        m = pc.match(req.prompt)
        n_alias = min(m.tokens, max(0, len(req.prompt) - 1))
        if n_alias < self.bt:
            if len(req.prompt) > self.bt:
                pc.miss()                  # an indexable prompt found nothing
            self._reconcile_commit(req, 0)
            return False
        need = -(-n_alias // self.bt)
        try:
            self.pager.alias_blocks(sid, m.blocks[:need], n_alias)
        except (MemoryError, SwapRefused):
            # pool too tight for the COW tail block (or an impossible
            # host-resident cache block): forfeit the share — the normal
            # prefill path has its own pressure relief
            pc.miss()
            self._reconcile_commit(req, 0)
            return False
        self._capture_cow(sid)
        pc.hit(m.nodes[:need], n_alias)
        self._pinned_paths[req.rid] = m.nodes[:need]
        s = self.pager.sessions[sid]
        self._slot_len[slot] = s.length
        req.prompt_pos = int(s.length)
        self._reconcile_commit(req, (n_alias // self.bt))
        return True

    def _reconcile_commit(self, req, shared_blocks: int) -> None:
        """Re-stamp the §8 admission charge with the share that actually
        happened: the kv_ok gate discounted its own (earlier) cache peek,
        but the alias at admit time can cover fewer blocks — or none, when
        the COW tail allocation fails — and an under-charged request would
        let later bursts overshoot the watermark the host pool was sized
        by."""
        if not self._host_tier:
            return
        want = max(1, self._footprint_blocks(req) - shared_blocks)
        self._committed_blocks += want - req.committed_blocks
        req.committed_blocks = want

    def _prefix_index(self, slot: int, req) -> None:
        """Index a fully-prefilled prompt's committed full blocks. Called
        at the prefill->decode transition and again after a resume (§9
        re-index). Only the device-resident prefix is indexable: blocks
        cold-swapped to the host tier (or left there by a resume) stop the
        chain — the index must stay root-contiguous."""
        if req.rid in self._indexed_rids \
                or req.prompt_pos < len(req.prompt):
            return
        sid = int(self._slot_sid[slot])
        s = self.pager.sessions.get(sid)
        if s is None or s.trimmed_prefix_blocks:
            return
        npb = len(req.prompt) // self.bt
        dev = 0
        while dev < npb and dev < len(s.blocks) and s.blocks[dev] > 0:
            dev += 1
        if dev < 1:
            return
        self._indexed_rids.add(req.rid)
        self.prefix_cache.insert(np.asarray(req.prompt[:dev * self.bt]),
                                 s.blocks[:dev])

    def _prefix_release(self, req) -> None:
        """Unpin the request's matched path (retire/preempt); the cached
        blocks themselves stay indexed for the next match."""
        if self.prefix_cache is None:
            return
        path = self._pinned_paths.pop(req.rid, None)
        if path:
            self.prefix_cache.unpin_path(path)

    def _cow_copy(self, pairs) -> None:
        """Execute COW tail copies: one padded (src -> dst) block copy per
        block-indexed pool key, async on the donated pool chain — the next
        step consuming the pools orders after it, exactly like swap-in."""
        P = max(1, self.e.batch)
        for i0 in range(0, len(pairs), P):
            chunk = pairs[i0:i0 + P]
            src = np.zeros(P, np.int32)
            dst = np.zeros(P, np.int32)
            src[:len(chunk)] = [p[0] for p in chunk]
            dst[:len(chunk)] = [p[1] for p in chunk]
            jsrc, jdst = jnp.asarray(src), jnp.asarray(dst)
            for k in self._block_pool_keys:
                self.pools[k] = self._cow_copy_fn(self.pools[k], jsrc, jdst)

    # ------------------------------------------------------------------
    def _alias_src_resident(self, src_sid: int, prefix_len: int) -> bool:
        """COW aliasing shares PHYSICAL device blocks, so the source must
        have actually COMMITTED the prefix (a source admitted in the same
        step has written nothing yet — sharing its unwritten blocks would
        read uninitialized KV) and the whole shared prefix (including the
        partial-tail copy source) must be device-resident. A too-young,
        cold-swapped or preempted source (§8) simply forfeits the share —
        the new request prefills the prefix itself."""
        s = self.pager.sessions[src_sid]
        nb = prefix_len // self.bt + (1 if prefix_len % self.bt else 0)
        return (s.swap_state == RES_DEVICE
                and s.length >= prefix_len and len(s.blocks) >= nb
                and all(b > 0 for b in s.blocks[:nb]))

    # ------------------------------------------------------------------
    def _window_blocks(self, slot: int) -> (List[int], int):
        """Physical blocks covering the near window + window_base (tokens)."""
        t = int(self._slot_len[slot])              # position of current token
        lo = max(0, t + 1 - self.W)
        wb = (lo // self.bt) * self.bt
        if self.e.mode == "arena":
            base = self._arena_base[slot]
            first = wb // self.bt
            return [base + first + i for i in range(self.NB)], wb
        sid = int(self._slot_sid[slot])
        s = self.pager.sessions[sid]
        first_local = self._first_window_local(s, t)
        wb = (first_local + s.trimmed_prefix_blocks) * self.bt
        blocks = s.blocks[first_local:first_local + self.NB]
        # residency invariant (DESIGN.md §8): the compiled executor must
        # never observe a host-resident (sign-encoded) block
        assert all(b > 0 for b in blocks), \
            f"host-resident block in window: sid={sid} {blocks}"
        return blocks + [0] * (self.NB - len(blocks)), wb

    # ------------------------------------------------------------------
    def _farview_step(self, slot: int, t: int, descr) -> None:
        """Far-view policy for one slot/step: summarize + TRIM a completed
        chunk (sealed in this step's commit) and select the far table.
        Shared verbatim by the sync and pipelined paths so the depth A/B
        can never diverge here."""
        sid = int(self._slot_sid[slot])
        s = self.pager.sessions[sid]
        n_done = int(self.fv.n_chunks[slot])
        chunk_end = (n_done + 1) * self.e.sv_chunk
        if t + 1 - self.W >= chunk_end:
            first_local = (n_done * self.e.sv_chunk) // self.bt \
                - s.trimmed_prefix_blocks
            cb = s.blocks[first_local:first_local + self.chunk_blocks]
            descr.far_chunk_blocks[slot, :len(cb)] = cb
            descr.far_chunk_tokens[slot] = self.e.sv_chunk
            descr.far_do_summarize[slot] = 1
            descr.far_write_idx[slot] = self.fv.on_chunk_summarized(slot)
            # TRIM the summarized blocks (bounded budget)
            self.pager.trim(sid, prefix_blocks=first_local + self.chunk_blocks)
            self._win_dirty[slot] = True
        tbl, val = self.fv.select(slot)
        descr.far_table[slot] = tbl
        descr.far_valid[slot] = val

    # ------------------------------------------------------------------
    def _retire_slot(self, slot: int) -> None:
        """EOS retirement: return the slot + its blocks, clear caches."""
        req = self.sched.requests[self.sched.slots[slot].rid]
        req.finish_wall = self.cum_wall
        if not req.finish_reason:
            req.finish_reason = "budget"     # legacy dispatch-time budget EOS
        if self._host_tier:
            # release exactly what the admission gate charged (§9: the
            # charge was reduced by the aliased prefix at admission time)
            self._committed_blocks -= req.committed_blocks
        self._prefix_release(req)
        self._indexed_rids.discard(req.rid)      # rid never returns
        self.sched.retire(slot)
        if self.pager is not None:
            self.pager.trim(int(self._slot_sid[slot]), close=True)
            self._slot_sid[slot] = -1
        self._slot_len[slot] = 0
        self._feed_ok[slot] = False
        self._mid_round[slot] = False
        refresh_control_row(self._cp, slot, rid=0)
        d = self._pdescr
        d.block_table[slot, :] = 0
        d.train_len[slot, :] = 0
        d.window_base[slot] = 0
        self._win_base_cache[slot] = -1
        self._win_dirty[slot] = True
        self._win_groups[slot] = 0
        self._win_nblocks[slot] = 0

    # ------------------------------------------------------------------
    # host KV tier: swap data movement + preemption policy (DESIGN.md §8)
    # ------------------------------------------------------------------
    def _ensure_host_kv(self) -> None:
        if self._host_kv or not self._swap_keys:
            return
        for k in self._swap_keys:
            arr = self.pools[k]
            shp = (self.host_pool_blocks, arr.shape[0]) + tuple(arr.shape[2:])
            self._host_kv[k] = np.zeros(shp, arr.dtype)

    def _swap_copy_out(self, dev_blocks, host_slots, *, sid: int = -1) -> None:
        """Issue one swap-out transfer: ONE padded gather per pool key
        (device -> host). With ``async_movement`` (default) the host-side
        readback is DEFERRED behind a per-transfer fence (DESIGN.md §11):
        the gathers are dispatched now — XLA orders them before any later
        donated-pool overwrite, so the captured bytes are exact — and the
        host rows land only when something actually reads the host slots
        (resume, audit, or the next swap-in). ``sid >= 0`` marks a
        preemption transfer whose pager session must flip IN_FLIGHT_OUT ->
        HOST when the fence drains. With the flag off this is the PR-5
        blocking readback per pressure event."""
        self._ensure_host_kv()
        n = len(dev_blocks)
        idx = np.zeros(self._swap_pad, np.int32)
        idx[:n] = dev_blocks
        jidx = jnp.asarray(idx)
        gathers = {k: self._swap_gather_fn(self.pools[k], jidx)
                   for k in self._swap_keys}
        if self.e.async_movement:
            self.transport.fence_issue({"gathers": gathers, "n": n,
                                        "host_slots": list(host_slots),
                                        "sid": sid})
            return
        t0 = time.perf_counter()
        self._land_swap_out(gathers, host_slots, n)
        self.swap_stall_s += time.perf_counter() - t0

    def _land_swap_out(self, gathers, host_slots, n: int) -> None:
        """Synchronize one swap-out's gathers into the host backing pool."""
        for k in self._swap_keys:
            got = np.asarray(gathers[k])
            self._host_kv[k][host_slots] = np.moveaxis(got[:, :n], 1, 0)

    def _drain_out_fences(self) -> None:
        """Synchronize every pending deferred swap-out readback, FIFO — a
        host slot freed and reallocated between two transfers must end up
        holding the LATER transfer's bytes, exactly like the synchronous
        schedule. Preemption transfers commit their session's
        IN_FLIGHT_OUT -> HOST edge here (DESIGN.md §11)."""
        pend = self.transport.fence_drain_all()
        if not pend:
            return
        t0 = time.perf_counter()
        for p in pend:
            self._land_swap_out(p["gathers"], p["host_slots"], p["n"])
            if p["sid"] >= 0:
                self.pager.swap_out_commit(p["sid"])
        self.swap_stall_s += time.perf_counter() - t0

    def _stage_buf(self, k: str) -> np.ndarray:
        """Preallocated, double-buffered host staging for one pool key's
        swap-in scatter (DESIGN.md §11): two fixed padded arrays alternated
        across transfers, so the device_put of transfer t can still be
        reading its buffer while transfer t+1 refills the other — and no
        per-event ``np.zeros`` allocation ever happens on the swap path."""
        bufs = self._stage_in.get(k)
        if bufs is None:
            arr = self.pools[k]
            shape = (arr.shape[0], self._swap_pad) + tuple(arr.shape[2:])
            bufs = [np.zeros(shape, self._host_kv[k].dtype) for _ in range(2)]
            self._stage_in[k] = bufs
        else:
            self.transport.account_staging_reuse(bufs[self._stage_sel].nbytes)
        return bufs[self._stage_sel]

    def _swap_copy_in(self, host_slots, dev_blocks) -> None:
        """Execute one swap-in transfer: ONE padded scatter per pool key
        (host -> device). The scatter is dispatched async on the pool chain
        (like token feedback), so it overlaps whatever the device is
        running; the next decode step consuming the pools orders after it.
        Staging rides the reusable double buffers (``_stage_buf``); any
        pending deferred swap-out drains first — these host slots may be
        exactly where its bytes land."""
        self._ensure_host_kv()
        self._drain_out_fences()
        n = len(dev_blocks)
        idx = np.zeros(self._swap_pad, np.int32)
        idx[:n] = dev_blocks
        jidx = jnp.asarray(idx)
        t0 = time.perf_counter()
        for k in self._swap_keys:
            arr = self.pools[k]
            if self.e.async_movement:
                data = self._stage_buf(k)
                data[:, :n] = np.moveaxis(self._host_kv[k][host_slots], 0, 1)
                data[:, n:] = 0      # padding targets scratch block 0
            else:
                # A/B baseline: the PR-5 per-event allocation
                data = np.zeros((arr.shape[0], self._swap_pad)
                                + tuple(arr.shape[2:]),
                                self._host_kv[k].dtype)
                data[:, :n] = np.moveaxis(self._host_kv[k][host_slots], 0, 1)
            self.pools[k] = self._swap_scatter_fn(arr, jidx, jnp.asarray(data))
        self._stage_sel ^= 1
        self.swap_stall_s += time.perf_counter() - t0

    def _first_window_local(self, s, t: int) -> int:
        """Local block index where the near window starts for a session at
        logical length t (same math as _window_blocks)."""
        wb = (max(0, t + 1 - self.W) // self.bt) * self.bt
        wb = max(wb, s.trimmed_prefix_blocks * self.bt)
        return wb // self.bt - s.trimmed_prefix_blocks

    def _footprint_blocks(self, req) -> int:
        """Worst-case device blocks a request can reach. ``gen_len`` is a
        CAP, not a schedule: with sampled decode (§13) a detected stop
        token can retire the request much earlier, so this is an upper
        bound (exact up to span-placement slack only in legacy greedy mode,
        where budget-EOS makes the length deterministic)."""
        tokens = len(req.prompt) + req.gen_len + 1
        return -(-tokens // self.bt) + self.e.span_blocks

    def _admission_ok(self, req, is_resume: bool) -> bool:
        """Watermark admission gate (DESIGN.md §8). Fresh requests are
        admitted only while the committed worst-case footprint of all live
        requests fits in admit_wm * device + host blocks — this is what
        bounds host-tier demand so preemption can always find room. Resumes
        are already committed; they additionally need their window working
        set device-resident right now."""
        margin = self.e.span_blocks + 1
        if is_resume:
            s = self.pager.sessions[req.swap_sid]
            first_local = self._first_window_local(s, req.resume_len)
            need = sum(1 for b in s.blocks[first_local:] if b < 0)
            # reserve on accept: the swap-ins run only after ALL of this
            # admit() call's gate checks, so later resumes in the same call
            # must see earlier ones' demand or they jointly overshoot the
            # pool and swap_in_begin raises an uncatchable MemoryError
            if self.pager.free_blocks() < self._resume_pending + need + margin:
                # §9 pressure ladder, resume edition: prefix-cache pins can
                # hold the pool above the gate forever once nothing is
                # active to trigger reserve-time eviction — reclaim unshared
                # cached blocks before refusing, or the resume livelocks
                if self.prefix_cache is not None:
                    self.prefix_cache.evict(self._resume_pending + need
                                            + margin
                                            - self.pager.free_blocks())
                if self.pager.free_blocks() \
                        < self._resume_pending + need + margin:
                    return False
            self._resume_pending += need
            return True
        total_dev = self.num_blocks - 1
        capacity = (int(total_dev * self.e.admit_watermark)
                    + self.host_pool_blocks)
        # §9: blocks served from the prefix cache are SHARED — they are
        # already resident and charged (once) to the cache, so the gate
        # peeks the radix index and discounts them from both the committed
        # footprint and the immediate device headroom the prompt needs
        shared_tokens = 0
        if self.prefix_cache is not None:
            m = self.prefix_cache.match(req.prompt)
            shared_tokens = (min(m.tokens, max(0, len(req.prompt) - 1))
                             // self.bt) * self.bt
        footprint = max(1, self._footprint_blocks(req)
                        - shared_tokens // self.bt)
        if self._committed_blocks + footprint > capacity:
            return False
        # device headroom NOW: room for the (un-shared part of the) prompt
        # (capped at one window) plus growth slack, so a fresh admission
        # doesn't immediately preempt what it just queued behind
        need = min(-(-(len(req.prompt) + 1 - shared_tokens) // self.bt),
                   self.NB)
        if self.pager.free_blocks() < need + margin:
            return False
        # commit on accept (the scheduler admits immediately after a True):
        # later candidates in the SAME admit() call must see this request's
        # footprint or a burst could collectively overshoot the watermark
        self._committed_blocks += footprint
        req.committed_blocks = footprint
        return True

    def _cold_swap(self, target_free: int) -> None:
        """Swap below-window blocks to the host tier until ``target_free``
        device blocks are free: sessions with the largest cold backlog
        first, oldest (coldest) blocks within a session first. Shared (COW)
        and window blocks are never moved, so the compiled executor never
        observes the difference."""
        cands = []
        for slot in self.sched.active_slots():
            sid = int(self._slot_sid[slot])
            if sid < 0 or sid not in self.pager.sessions:
                continue
            s = self.pager.sessions[sid]
            fl = self._first_window_local(s, int(self._slot_len[slot]))
            cold = sum(1 for b in s.blocks[:fl]
                       if b > 0 and self.pager.refcount[b] == 1)
            if cold:
                cands.append((cold, slot, sid, fl))
        for cold, slot, sid, fl in sorted(cands, reverse=True):
            if self.pager.free_blocks() >= target_free:
                return
            try:
                pairs = self.pager.swap_out_cold(sid, fl)
            except MemoryError:
                return                        # host pool full: nothing to do
            if pairs:
                self.transport.account_swap(pairs, direction="out")
                self._swap_copy_out([p[0] for p in pairs],
                                    [p[1] for p in pairs])

    def _memory_pressure_pass(self) -> None:
        """Step-start watermark check: above the high watermark, cold-swap
        down toward the low watermark so reactive preemption stays rare."""
        if not self._host_tier:
            return
        total = self.num_blocks - 1
        if (total - self.pager.free_blocks()) / total \
                > self.e.swap_high_watermark:
            self._cold_swap(int(np.ceil(
                (1.0 - self.e.swap_low_watermark) * total)))

    def _swap_victim(self) -> Optional[int]:
        """Latest-admitted swap-eligible active slot (protects the oldest
        work, which is closest to completion); slots already assembled into
        THIS step's descriptor are never victims — their rows reference
        blocks the swap would free."""
        cands = []
        for slot in self.sched.active_slots():
            if slot in self._step_touched:
                continue
            sid = int(self._slot_sid[slot])
            if sid >= 0 and self.pager.swap_eligible(sid):
                req = self.sched.request_at(slot)
                cands.append((req.start_step, req.rid, slot))
        return max(cands)[2] if cands else None

    def _preempt_slot(self, slot: int) -> None:
        """Evict a request to the host tier: drain the pipeline (its
        sampled-token values must land before the slot state is captured),
        swap the whole session out, and re-queue the request for resume."""
        self.flush()
        req = self.sched.request_at(slot)
        if req is None:
            # sampled mode (§13): the flush's drained readbacks can detect
            # this victim's stop token and retire it — its blocks are
            # already free, which is exactly what the caller wanted
            return
        sid = int(self._slot_sid[slot])
        deferred = bool(self.e.async_movement)
        pairs = self.pager.swap_out_session(sid, deferred=deferred)
        assert pairs is not None, "victim was not swap-eligible"
        if pairs:
            self.transport.account_swap(pairs, direction="out")
            self._swap_copy_out([p[0] for p in pairs],
                                [p[1] for p in pairs],
                                sid=sid if deferred else -1)
        req.swap_sid = sid
        req.resume_len = int(self._slot_len[slot])
        req.resume_last_token = int(self._last_token[slot])
        # drop the prompt from the prefix index bookkeeping so the resume
        # path re-indexes what comes back device-resident (§9), and
        # re-stamp the admission charge at FULL footprint: swap-out gave
        # the session exclusive ownership of every block (prefix included,
        # now in host slots), so the shared-prefix discount no longer holds
        self._prefix_release(req)
        self._indexed_rids.discard(req.rid)      # resume re-indexes
        self._reconcile_commit(req, 0)
        self.sched.preempt(slot)
        self.preemptions += 1
        self._slot_sid[slot] = -1
        self._slot_len[slot] = 0
        self._feed_ok[slot] = False
        self._mid_round[slot] = False
        refresh_control_row(self._cp, slot, rid=0)
        d = self._pdescr
        d.block_table[slot, :] = 0
        d.train_len[slot, :] = 0
        d.window_base[slot] = 0
        self._win_base_cache[slot] = -1
        self._win_dirty[slot] = True
        self._win_groups[slot] = 0
        self._win_nblocks[slot] = 0

    def _ensure_step_capacity(self) -> None:
        """Preemption-aware scheduling pass (DESIGN.md §8), run BEFORE any
        token is consumed or descriptor row assembled: total up the device
        blocks this step's reservations will need (decode lookahead + prompt
        chunks) and, if the pool can't cover them, cold-swap then preempt
        latest-admitted victims until it can. Running it up front means a
        victim can be ANY active slot — once assembly starts, assembled
        slots are pinned (their descriptor rows reference their blocks)."""
        if not self._host_tier:
            return
        while True:
            need = 0
            for slot in self.sched.active_slots():
                sid = int(self._slot_sid[slot])
                if sid < 0:
                    continue
                if self._chunked and \
                        self.sched.chunk_remaining(slot) >= self._chunk_min:
                    n_tok = min(self.chunk, self.sched.chunk_remaining(slot))
                else:
                    n_tok = 2                  # this token + lookahead
                need += self.pager.blocks_needed(sid, n_tok)
            if self.pager.free_blocks() >= need:
                return
            self._cold_swap(need)
            if self.pager.free_blocks() >= need:
                return
            # §9 pressure ladder: before preempting live work, reclaim
            # prefix-cache blocks — unpinned unshared cold leaves free
            # device blocks outright
            if self.prefix_cache is not None:
                self.prefix_cache.evict(need - self.pager.free_blocks())
                if self.pager.free_blocks() >= need:
                    return
            victim = self._swap_victim()
            if victim is None:
                # no swap-eligible victim: cached shares may be what holds
                # every session's refcounts above 1 — flush the index
                # (sessions keep their own refs; only reuse is lost) and
                # retry the whole ladder once more
                if self.prefix_cache is not None \
                        and self.prefix_cache.flush_for_pressure():
                    continue
                return                         # backstop: _reserve raises
            self._preempt_slot(victim)         # loop: recompute without it

    def _reserve(self, slot: int, sid: int, n_tokens: int):
        """pager.reserve with preemption-aware pressure relief: on device
        exhaustion, cold-swap first, then preempt latest-admitted eligible
        victims until the reservation fits (MemoryError only when neither
        can free enough — e.g. host pool exhausted too). The step-start
        capacity pass makes this a rare backstop."""
        if not self._host_tier and self.prefix_cache is None:
            return self.pager.reserve(sid, n_tokens)
        try:
            return self.pager.reserve(sid, n_tokens)
        except MemoryError:
            need = self.pager.blocks_needed(sid, n_tokens)
            if self._host_tier:
                self._cold_swap(need)
            if self.prefix_cache is not None \
                    and self.pager.free_blocks() < need:
                self.prefix_cache.evict(need - self.pager.free_blocks())
            while self.pager.free_blocks() < need:
                victim = self._swap_victim() if self._host_tier else None
                if victim is None or victim == slot:
                    if self.prefix_cache is not None \
                            and self.prefix_cache.flush_for_pressure():
                        continue             # un-shared: retry victims/free
                    raise
                self._preempt_slot(victim)   # may raise: host pool full
            return self.pager.reserve(sid, n_tokens)

    # ------------------------------------------------------------------
    def _prefill_chunks(self) -> None:
        """Ingest up to ``prefill_chunk`` prompt tokens per prefilling slot
        through the batched chunked prefill executor: ONE dispatch per engine
        step covering every slot with chunk work (idle slot rows are masked
        by n_valid=0, same fixed-shape discipline as the decode step).
        Reservations are sealed by THIS step's single frame commit."""
        C = self.chunk
        cd = self._cdescr
        self._chunk_wait = 0.0
        any_chunk = False
        for slot in self.sched.active_slots():
            if self.sched.chunk_remaining(slot) < self._chunk_min:
                continue
            if not any_chunk:
                cd.n_valid[:] = 0
                any_chunk = True
            self._step_touched.add(slot)
            toks = self.sched.consume_prompt_chunk(slot, C)
            n = len(toks)
            t0 = int(self._slot_len[slot])
            if self.e.mode == "arena":
                base = self._arena_base[slot]
                idx = t0 + np.arange(n)
                wblk = (base + idx // self.bt).astype(np.int32)
                woff = (idx % self.bt).astype(np.int32)
            else:
                sid = int(self._slot_sid[slot])
                self._reserve(slot, sid, n)
                wblk, woff = self.pager.append_tokens(sid, n)
            # context = the near window as seen by the chunk's FIRST query;
            # later queries only need a suffix of it (masked in-kernel)
            blocks, wb = self._window_blocks(slot)
            cd.tokens[slot, :n] = toks
            cd.tokens[slot, n:] = 0
            cd.start_pos[slot] = t0
            cd.n_valid[slot] = n
            cd.block_table[slot] = blocks
            cd.window_base[slot] = wb
            cd.write_block[slot, :n] = wblk
            cd.write_block[slot, n:] = 0
            cd.write_offset[slot, :n] = woff
            cd.write_offset[slot, n:] = 0
            self._slot_len[slot] += n
            self._win_dirty[slot] = True
            self._chunk_steps += 1
        if any_chunk:
            td = time.perf_counter()
            self.pools = self._chunk_fn(self.params, self.pools,
                                        jnp.asarray(self._cflat))
            # dispatch can block on the runtime's in-flight queue while the
            # PREVIOUS step still executes — that wait is device occupancy,
            # not host control work; the pipelined path subtracts it from
            # m.host so submit_share keeps measuring the control plane
            self._chunk_wait = time.perf_counter() - td

    # ------------------------------------------------------------------
    def step(self, now: float = float("inf")) -> StepMetrics:
        if self.depth <= 0:
            return self._step_sync(now)
        return self._step_pipelined(now)

    # ------------------------------------------------------------------
    def _account_kernel_blocks(self, window_base, seq_lens, slot_active):
        """Integrate the decode kernel's padded-vs-active block counts over
        this step's participating slots (descriptor-side host math — the
        same derivation the kernel receives as scalar-prefetch meta).
        Returns the per-slot skipped counts (aligned with the input rows)
        when skip predication is on, else None — the pipelined sampled
        path records them per dispatch so a lagged-EOS scrub (§13) can
        reverse this step's share exactly."""
        n = len(window_base)
        if n == 0:
            return None
        self._kernel_blocks_total += self.NB * n
        if self.e.kernel_skip_extent:
            lo, hi = active_block_extents(
                window_base, seq_lens, slot_active,
                near_window=self.W, nb=self.NB, bt=self.bt)
            skipped = self.NB - (hi - lo)
            self._kernel_blocks_skipped += int(skipped.sum())
            return skipped
        return None

    # ------------------------------------------------------------------
    def _step_sync(self, now: float) -> StepMetrics:
        """Seed-exact synchronous step: per-slot descriptor assembly, one
        blocking readback per step (pipeline_depth=0 A/B baseline)."""
        t0 = time.perf_counter()
        m = StepMetrics()
        self.sched.step_idx = self.steps_run

        # ---- Shift: retire EOS (handled at end of prev step), admit
        self._step_touched = set()
        self._memory_pressure_pass()
        self._admit(now)
        self._ensure_step_capacity()
        if self._chunked:
            self._prefill_chunks()
        active = self.sched.active_slots()
        m.active = len(active)

        B = self.e.batch
        descr = empty_descriptor(B, self.NB, self.cap, self.MT,
                                 chunk_blocks=self.chunk_blocks)
        tokens = np.zeros(B, np.int32)

        parts = []                       # slots participating in this step
        for slot in active:
            req = self.sched.request_at(slot)
            if req is None:
                continue                 # preempted mid-step by a neighbour
            if self._chunked and \
                    self.sched.chunk_remaining(slot) >= self._chunk_min:
                continue                 # still mid-chunk: no decode this step
            parts.append(slot)
            self._step_touched.add(slot)
            was_prefilling = self.sched.is_prefilling(slot)
            tokens[slot] = self.sched.next_token(slot, int(self._last_token[slot]))
            if self.prefix_cache is not None and was_prefilling \
                    and req.prompt_pos >= len(req.prompt):
                self._prefix_index(slot, req)    # prompt committed: index
            t = int(self._slot_len[slot])
            descr.seq_lens[slot] = t
            descr.slot_active[slot] = 1

            # ---- Stage: BLOCKALIGN reservation (prefetch-1 lookahead)
            if self.e.mode == "arena":
                base = self._arena_base[slot]
                bi, off = divmod(t, self.bt)
                descr.write_block[slot] = base + bi
                descr.write_offset[slot] = off
            else:
                sid = int(self._slot_sid[slot])
                self._reserve(slot, sid, 2)       # this token + lookahead
                blk, off = self.pager.append_token(sid)
                descr.write_block[slot] = blk
                descr.write_offset[slot] = off

            # ---- far-view: chunk completion -> summarize + trim
            if self.fv is not None:
                self._farview_step(slot, t, descr)

            # ---- window table + Reduce (train merging)
            blocks, wb = self._window_blocks(slot)
            descr.block_table[slot, :len(blocks)] = blocks
            descr.window_base[slot] = wb
            trains, groups = self.transport.reduce(
                blocks, far_blocks=int(descr.far_valid[slot].sum() > 0),
                merging=self._merging)
            self.transport.fill_train_arrays(
                trains, descr.train_start, descr.train_len, descr.train_dst, slot)
            m.dma_groups += groups

        if parts:
            self._account_kernel_blocks(descr.window_base[parts],
                                        descr.seq_lens[parts],
                                        descr.slot_active[parts])
            # §15: each participating mid-round-admitted slot is one
            # slot-step a round barrier would have left idle
            self.slot_idle_steps_saved += int(self._mid_round[parts].sum())

        # ---- Frame: single atomic commit
        tf0 = time.perf_counter()
        if self.pager is not None:
            frame = self.pager.frame()
            descr = descr._replace(epoch=np.int32(frame["epoch"]))
            self.frames_committed += 1
        else:
            descr = descr._replace(epoch=np.int32(self.steps_run + 1))
        m.frame_commit = time.perf_counter() - tf0

        jdescr = FrameDescriptor(*[jnp.asarray(a) for a in descr])
        # chunk-dispatch queue wait is device occupancy, not control work
        # (zero when prefill_chunk=0, keeping the seed path bit-exact)
        m.host = max(0.0, time.perf_counter() - t0 - self._chunk_wait)

        # ---- device: one engine call, fixed shapes
        self.transport.note_dispatch_overlap()
        nxt, self.pools, fu, lg = self._step_fn(
            self.params, jnp.asarray(tokens), self._zero_feed,
            jnp.asarray(self._rid_buf), self._prev_nxt, self.pools, jdescr)
        self._prev_nxt = nxt
        nxt = np.asarray(jax.block_until_ready(nxt))
        if self.e.debug_logits:
            self.debug_logits.append(np.asarray(lg, np.float32))

        # ---- post: bookkeeping, EOS retirement (burst-safe)
        for slot in parts:
            self._slot_len[slot] += 1
            if self.sched.is_prefilling(slot):
                continue
            self._last_token[slot] = int(nxt[slot])
            req_t = self.sched.request_at(slot)
            if req_t is not None and req_t.first_token_step < 0:
                req_t.ttft_wall = self.cum_wall
            if self.e.debug_logits:
                req = self.sched.request_at(slot)
                if not hasattr(req, "logit_trace"):
                    req.logit_trace = []
                req.logit_trace.append(np.asarray(lg[slot], np.float32))
            req_s = self.sched.request_at(slot)
            done = self.sched.record_output(slot, int(nxt[slot]))
            m.emitted += 1
            if done:
                if req_s is not None and req_s.eos_hit:
                    self.eos_detected += 1
                self._retire_slot(slot)
            if self.token_hook is not None and req_s is not None:
                self.token_hook(req_s, int(nxt[slot]), done)
        if self.fv is not None:
            self.fv.observe_utility(np.asarray(fu), np.asarray(descr.far_table))

        self.steps_run += 1
        m.wall = time.perf_counter() - t0
        self.cum_wall += m.wall
        self.peak_reserved_kv = max(self.peak_reserved_kv, self.reserved_kv_bytes())
        self.peak_active_kv = max(self.peak_active_kv, self.active_kv_bytes())
        self.metrics.append(m)
        return m

    # ------------------------------------------------------------------
    def _step_pipelined(self, now: float) -> StepMetrics:
        """Overlapped step: assemble + dispatch step t, then read back step
        t-depth while the device runs. Descriptor assembly is vectorized over
        slots with an incrementally maintained window-block/train cache —
        per-slot Python work happens only on admit/trim/alias/reserve or a
        window slide, not every step."""
        t0 = time.perf_counter()
        m = StepMetrics()
        self.sched.step_idx = self.steps_run

        self._step_touched = set()
        self._memory_pressure_pass()
        self._admit(now)
        self._ensure_step_capacity()
        if self._chunked:
            self._prefill_chunks()
        active = self.sched.active_slots()
        m.active = len(active)

        d = self._pdescr
        tokens = self._tokens_buf
        feed = self._feed_buf
        tokens[:] = 0
        feed[:] = 0
        d.slot_active[:] = 0
        if self.fv is not None:
            d.far_chunk_blocks[:] = 0
            d.far_chunk_tokens[:] = 0
            d.far_do_summarize[:] = 0
            d.far_write_idx[:] = 0

        parts: List[int] = []
        emits: List[tuple] = []          # (slot, req) emitting this step
        resv: Dict[int, list] = {}       # slot -> blocks THIS step reserved
        kskip = None                     # per-slot kernel blocks predicated
        far_flags = None
        for slot in active:
            req = self.sched.request_at(slot)
            if req is None:
                continue                 # preempted mid-step by a neighbour
            if self._chunked and \
                    self.sched.chunk_remaining(slot) >= self._chunk_min:
                continue                 # still mid-chunk: no decode this step
            self._step_touched.add(slot)
            was_prefilling = req.prompt_pos < len(req.prompt)
            tokens[slot] = self.sched.next_token(slot, int(self._last_token[slot]))
            if not was_prefilling and req.emitted > 0 and self._feed_ok[slot]:
                # decode continuation: token comes from the device-side argmax
                # of the previous dispatched step (one-step lag, no readback).
                # _feed_ok is False right after checkpoint restore: the chain
                # re-seeds from the host _last_token mirror for one step.
                feed[slot] = 1
            d.slot_active[slot] = 1
            parts.append(slot)
            if req.prompt_pos >= len(req.prompt):
                emits.append((slot, req))
                if self.prefix_cache is not None and was_prefilling:
                    self._prefix_index(slot, req)    # prompt committed

            t = int(self._slot_len[slot])
            if self.e.mode == "arena":
                base = self._arena_base[slot]
                bi, off = divmod(t, self.bt)
                d.write_block[slot] = base + bi
                d.write_offset[slot] = off
            else:
                sid = int(self._slot_sid[slot])
                newb = self._reserve(slot, sid, 2)  # this token + lookahead
                if newb:
                    self._win_dirty[slot] = True  # new tail block in window
                    if self._sampled:
                        resv[slot] = newb         # §13 overshoot reconcile
                blk, off = self.pager.append_token(sid)
                d.write_block[slot] = blk
                d.write_offset[slot] = off

            if self.fv is not None:
                self._farview_step(slot, t, d)

        # ---- vectorized window/train maintenance (dirty rows only)
        d.seq_lens[:] = self._slot_len
        if parts:
            pa = np.asarray(parts)
            lo = np.maximum(0, self._slot_len[pa] + 1 - self.W)
            wb_vec = (lo // self.bt) * self.bt
            # dirty when the window ADVANCES past the cached base; far-view
            # trims clamp the real base above wb_vec (those set _win_dirty
            # explicitly), so `>` avoids perpetual recomputes after a trim
            dirty = self._win_dirty[pa] | (wb_vec > self._win_base_cache[pa])
            dirty_slots = [int(s) for s in pa[dirty]]
            if dirty_slots:
                blocks_rows = []
                for slot in dirty_slots:
                    blocks, wb_s = self._window_blocks(slot)
                    d.block_table[slot, :] = blocks
                    d.window_base[slot] = wb_s
                    blocks_rows.append([b for b in blocks if b > 0])
                    self._win_base_cache[slot] = wb_s
                    self._win_dirty[slot] = False
                trains_rows = self.transport.reduce_batch(
                    blocks_rows, merging=self._merging)
                self.transport.fill_train_arrays_batch(
                    trains_rows, d.train_start, d.train_len, d.train_dst,
                    dirty_slots)
                for slot, nz, trains in zip(dirty_slots, blocks_rows,
                                            trains_rows):
                    self._win_groups[slot] = len(trains)
                    self._win_nblocks[slot] = len(nz)
            far_flags = ((d.far_valid[pa].sum(axis=1) > 0).astype(np.int64)
                         if self.fv is not None else np.zeros(len(pa), np.int64))
            self.transport.account_batch(self._win_nblocks[pa],
                                         self._win_groups[pa], far_flags)
            m.dma_groups = int(self._win_groups[pa].sum() + far_flags.sum())
            kskip = self._account_kernel_blocks(d.window_base[pa],
                                                d.seq_lens[pa],
                                                d.slot_active[pa])
            # §15: each participating mid-round-admitted slot is one
            # slot-step a round barrier would have left idle
            self.slot_idle_steps_saved += int(self._mid_round[pa].sum())

        # sampled decode (§13): snapshot each emitting slot's share of THIS
        # step's pager/transport/kernel accounting so a lagged detected-EOS
        # readback can reverse the overshoot dispatches exactly
        eos_meta = None
        if self._sampled and emits:
            idx = {slot: i for i, slot in enumerate(parts)}
            eos_meta = {}
            for slot, _req in emits:
                i = idx[slot]
                eos_meta[slot] = {
                    # ownership stamp (§15): a slot re-admitted inside the
                    # pipeline-lag window must never be scrubbed by its
                    # PREDECESSOR's overshoot — _scrub_overshoot checks it
                    "rid": _req.rid,
                    "sid": (int(self._slot_sid[slot])
                            if self.e.mode != "arena" else -1),
                    "newb": resv.get(slot, []),
                    "nblocks": int(self._win_nblocks[slot]),
                    "groups": int(self._win_groups[slot]),
                    "far": int(far_flags[i]) if far_flags is not None else 0,
                    "kskip": int(kskip[i]) if kskip is not None else 0,
                }

        # ---- Frame: single atomic commit
        tf0 = time.perf_counter()
        if self.pager is not None:
            frame = self.pager.frame()
            d.epoch[...] = frame["epoch"]
            self.frames_committed += 1
        else:
            d.epoch[...] = self.steps_run + 1
        m.frame_commit = time.perf_counter() - tf0

        jflat = jnp.asarray(self._flat)      # ONE host->device transfer
        # chunk-dispatch queue wait is device occupancy, not control work
        m.host = max(0.0, time.perf_counter() - t0 - self._chunk_wait)

        # ---- device: dispatch step t (async), keep host moving
        self.transport.note_dispatch_overlap()
        nxt, self.pools, fu, lg = self._step_fn(
            self.params, jflat, self._prev_nxt, self.pools)
        self._prev_nxt = nxt

        # ---- structural bookkeeping at DISPATCH time. Legacy greedy: EOS
        # is the fixed gen_len budget, so retirement is host-predictable
        # here and pager/transport timelines stay bit-identical to the
        # synchronous path. Sampled (§13): EOS is data-dependent, so NOTHING
        # retires at dispatch — stop AND budget retirement both happen at
        # readback, where overshot dispatches are scrubbed via ``eos_meta``.
        # Token VALUES land at readback either way, ``depth`` steps later.
        m.emitted = len(emits)
        for slot in parts:
            self._slot_len[slot] += 1
        for slot, req in emits:
            self._feed_ok[slot] = True
            if self.sched.note_emit(slot) and not self._sampled:
                self._retire_slot(slot)

        self._inflight.append({
            "nxt": nxt, "lg": lg, "fu": fu, "emits": emits,
            "m": m, "eos": eos_meta,
            "far_table": d.far_table.copy() if self.fv is not None else None,
        })
        while len(self._inflight) > self.depth:
            self._readback(self._inflight.popleft())

        self.steps_run += 1
        m.wall = time.perf_counter() - t0
        self.cum_wall += m.wall
        self.peak_reserved_kv = max(self.peak_reserved_kv, self.reserved_kv_bytes())
        self.peak_active_kv = max(self.peak_active_kv, self.active_kv_bytes())
        self.metrics.append(m)
        return m

    # ------------------------------------------------------------------
    def _readback(self, rec: dict) -> None:
        """Value bookkeeping for one in-flight step: sampled tokens, logit
        traces, far-view utility feedback (one step of lag under pipelining)."""
        nxt = np.asarray(jax.block_until_ready(rec["nxt"]))
        lg = None
        if self.e.debug_logits:
            lg = np.asarray(rec["lg"], np.float32)
            self.debug_logits.append(lg)
        for slot, req in rec["emits"]:
            tok = int(nxt[slot])
            req.generated.append(tok)
            # wall-clock latencies stamp when the VALUE is known (readback),
            # not at dispatch — comparable with the synchronous path and
            # never flattered by the one-step pipeline lag
            if len(req.generated) == 1:
                req.ttft_wall = self.cum_wall
            fin = False
            if not self._sampled and req.emitted >= req.gen_len \
                    and len(req.generated) >= req.gen_len:
                req.finish_wall = self.cum_wall
                fin = True
            if lg is not None:
                if not hasattr(req, "logit_trace"):
                    req.logit_trace = []
                req.logit_trace.append(lg[slot])
            if self.sched.slots[slot].rid == req.rid:
                self._last_token[slot] = tok
            if self._sampled:
                # sampled decode (§13): ALL retirement is readback-side.
                # The host learns of a stop ``depth`` steps late — scrub
                # the overshoot dispatches still in flight, then retire.
                stop = bool(req.stop_tokens) and tok in req.stop_tokens
                if stop or len(req.generated) >= req.gen_len:
                    req.eos_hit = stop
                    req.finish_reason = "stop" if stop else "budget"
                    if stop:
                        self.eos_detected += 1
                    assert self.sched.slots[slot].rid == req.rid, \
                        "sampled mode never retires at dispatch"
                    self._scrub_overshoot(slot, req)
                    self._retire_slot(slot)
                    fin = True
            if self.token_hook is not None:
                self.token_hook(req, tok, fin)
        if self.fv is not None:
            self.fv.observe_utility(np.asarray(rec["fu"]), rec["far_table"])

    def _scrub_overshoot(self, slot: int, req) -> None:
        """Reverse every in-flight dispatch issued for ``req`` AFTER its
        finishing token (DESIGN.md §13). Each scrubbed emit undoes exactly
        what its dispatch accounted: the scheduler's structural emission,
        the pager's append (and any tail block that step's reserve
        committed), the transport's per-slot window traffic, and the
        kernel-block integrals. Newest-first so tail-block pops at
        depth > 1 unwind in LIFO order. Freeing a tail block that an
        in-flight device step still references is safe: donated-pool
        chaining serializes device steps, and a decode tail block is never
        shared or cold-swapped (refcount 1) — asserted by the pager.
        Known limit: pressure-relief side effects (cold-swap, preemption)
        triggered BY an overshoot step's reserve are not reversed."""
        for rec in reversed(self._inflight):
            hit = next((p for p in rec["emits"] if p[1] is req), None)
            if hit is None:
                continue
            rec["emits"].remove(hit)
            meta = rec["eos"][slot]
            # §15 slot-reuse-inside-lag-window guard: emits matched by
            # ``req`` identity above, so a successor admitted into this
            # slot while the overshoot was still in flight can never be
            # scrubbed here — the rid stamp makes that contract checkable
            assert meta["rid"] == req.rid, \
                (f"§15 scrub ownership violated: slot {slot} eos_meta "
                 f"stamped rid={meta['rid']} but scrubbing rid={req.rid}")
            req.emitted -= 1
            self._slot_len[slot] -= 1
            self.eos_overshoot_tokens += 1
            rec["m"].emitted -= 1
            rec["m"].dma_groups -= meta["groups"] + meta["far"]
            if self.pager is not None:
                self.pager.reconcile_overshoot(meta["sid"], meta["newb"])
                self.eos_reconciled_blocks += len(meta["newb"])
            self.transport.unaccount_slot(meta["nblocks"], meta["groups"],
                                          meta["far"])
            self._kernel_blocks_total -= self.NB
            self._kernel_blocks_skipped -= meta["kskip"]

    def flush(self) -> None:
        """Drain the dispatch pipeline (blocks on outstanding device steps).
        Drain time counts toward the wall so throughput/latency sums include
        the tail steps' device execution."""
        while self._inflight:
            t0 = time.perf_counter()
            self._readback(self._inflight.popleft())
            dt = time.perf_counter() - t0
            self.cum_wall += dt
            if self.metrics:
                self.metrics[-1].wall += dt

    # ------------------------------------------------------------------
    def run(self, max_steps: int = 10_000, now_fn=None) -> None:
        while (self.sched.waiting or self.sched.preempted
               or self.sched.active_slots()) and self.steps_run < max_steps:
            self.step(now=now_fn() if now_fn else float("inf"))
        self.flush()

    # ------------------------------------------------------------------
    # audits & metrics
    # ------------------------------------------------------------------
    def audit(self) -> dict:
        """Legacy dict view of :meth:`audit_report` — every pre-§14
        ``audit()[key]`` call site keeps working unchanged."""
        return self.audit_report().as_dict()

    def audit_report(self) -> AuditReport:
        # audit reads host-slot state: deferred swap-out bytes must land
        # first (DESIGN.md §11) so the figures match the sync schedule
        self._drain_out_fences()
        steps = [m for m in self.metrics if m.active > 0]
        walls = np.array([m.wall for m in steps]) if steps else np.zeros(1)
        hosts = np.array([m.host for m in steps]) if steps else np.zeros(1)
        commits = np.array([m.frame_commit for m in steps]) if steps else np.zeros(1)
        ncomp = getattr(self._step_fn, "_cache_size", lambda: -1)()
        nc_prefill = (getattr(self._chunk_fn, "_cache_size", lambda: -1)()
                      if self._chunk_fn is not None else 0)
        # field-per-counter typed report (serving/api.py, §14): a counter
        # added here without an AuditReport field — or vice versa — raises
        # TypeError on every audit call, so the contract cannot drift
        return AuditReport(**{
            "mode": self.e.mode,
            "steps": len(steps),
            "compilations": ncomp,
            "prefill_compilations": nc_prefill,
            "pipeline_depth": self.depth,
            "prefill_chunk": self.chunk,
            "prefill_chunks_run": self._chunk_steps,
            "single_commit_per_step": (self.pager is None
                                       or self.frames_committed == self.steps_run),
            "frames_committed": self.frames_committed,
            "submit_share": float(hosts.sum() / max(walls.sum(), 1e-12)),
            "frame_commit_us": float(commits.mean() * 1e6),
            "dma_groups_per_step": self.transport.stats.groups_per_step,
            "avg_dma_bytes": self.transport.stats.avg_group_bytes,
            "unmerged_groups_per_step": self.transport.stats.unmerged_groups_per_step,
            "train_overflows": self.transport.stats.train_overflows,
            "reserved_kv_bytes": self.reserved_kv_bytes(),
            "active_kv_bytes": self.active_kv_bytes(),
            "peak_reserved_kv": self.peak_reserved_kv,
            "peak_active_kv": self.peak_active_kv,
            # --- SPMD decode (DESIGN.md §4): per-DEVICE memory pressure.
            # The logical totals above count the whole pool; with the kv-head
            # axis sharded over `model`, each device holds 1/kv_shards of it —
            # reporting the total as per-device overstates pressure by the TP
            # degree.
            # --- host KV tier + preemption (DESIGN.md §8). Byte figures are
            # per paged layer (same basis as the window-DMA transport stats);
            # admission-stall counters split compute-bound (no_slot) from
            # memory-bound (kv_watermark) queueing for operators.
            "host_pool_blocks": self.host_pool_blocks,
            "host_blocks_used": (self.pager.host_used if self.pager else 0),
            "host_blocks_peak": (self.pager.host_peak if self.pager else 0),
            "preemptions": self.preemptions,
            "swap_out_blocks": (self.pager.stats["swap_out_blocks"]
                                if self.pager else 0),
            "swap_in_blocks": (self.pager.stats["swap_in_blocks"]
                               if self.pager else 0),
            "swap_refusals": (self.pager.stats["swap_refusals"]
                              if self.pager else 0),
            "swap_groups": self.transport.stats.swap_groups,
            "swap_bytes": self.transport.stats.swap_bytes,
            "swap_out_bytes": self.transport.stats.swap_out_bytes,
            "swap_in_bytes": self.transport.stats.swap_in_bytes,
            "avg_swap_group_blocks": self.transport.stats.avg_swap_group_blocks,
            # --- async movement engine (DESIGN.md §11): overlap witnesses.
            # All three counters are zero with async_movement off — the A/B
            # identity gate checks exactly that invariance of everything
            # ABOVE this block while these move.
            # --- work-skipping decode kernel (DESIGN.md §12): padded grid
            # blocks walked vs blocks predicated off by the per-slot active
            # extent. total is the descriptor-side padded count (NB per
            # participating slot-step); skipped is 0 with the flag off.
            "kernel_skip_extent": bool(self.e.kernel_skip_extent),
            "kernel_blocks_total": self._kernel_blocks_total,
            "kernel_blocks_skipped": self._kernel_blocks_skipped,
            # --- sampled decode + detected-EOS retirement (DESIGN.md §13).
            # All three counters are zero in legacy greedy mode — the A/B
            # identity gates check exactly that.
            "greedy": bool(self.e.greedy),
            "eos_detected": self.eos_detected,
            "eos_overshoot_tokens": self.eos_overshoot_tokens,
            "eos_reconciled_blocks": self.eos_reconciled_blocks,
            "async_movement": bool(self.e.async_movement),
            "overlap_steps": self.transport.stats.overlap_steps,
            "deferred_readbacks": self.transport.stats.deferred_readbacks,
            "staging_reuse_bytes": self.transport.stats.staging_reuse_bytes,
            "swap_stall_ms": self.swap_stall_s * 1e3,
            "admit_blocked_no_slot": self.sched.admit_blocked["no_slot"],
            "admit_blocked_kv_watermark":
                self.sched.admit_blocked["kv_watermark"],
            "cancelled": self.cancelled,
            # --- step-level (continuous) batching (DESIGN.md §15).
            # continuous_admits / slot_idle_steps_saved count what a round
            # barrier would have cost; admit_blocked_round_barrier counts
            # what the barrier DID cost. Each triple's zero side is the
            # A/B witness for the opposite mode.
            "continuous_batching": bool(self.e.continuous_batching),
            "continuous_admits": self.continuous_admits,
            "slot_idle_steps_saved": self.slot_idle_steps_saved,
            "admit_blocked_round_barrier":
                self.sched.admit_blocked["round_barrier"],
            # --- radix prefix cache (DESIGN.md §9): shared-prefix reuse.
            # COW tail copies are their own transport group kind so prefix
            # traffic is auditable apart from window trains and swaps.
            "prefix_cache": self._prefix_on,
            "prefix_hits": (self.prefix_cache.stats["hits"]
                            if self.prefix_cache else 0),
            "prefix_misses": (self.prefix_cache.stats["misses"]
                              if self.prefix_cache else 0),
            "prefix_tokens_reused": (self.prefix_cache.stats["tokens_reused"]
                                     if self.prefix_cache else 0),
            "prefix_cached_blocks": (self.prefix_cache.blocks_cached
                                     if self.prefix_cache else 0),
            "prefix_evicted_blocks": (self.prefix_cache.stats["evicted_blocks"]
                                      if self.prefix_cache else 0),
            "cow_copies": self.transport.stats.cow_blocks,
            "cow_groups": self.transport.stats.cow_groups,
            "cow_bytes": self.transport.stats.cow_bytes,
            # --- quantized KV-block tier (DESIGN.md §10): narrow storage
            # width, scale-pool overhead inside the reserved figures, and
            # the bytes every accounted transfer saved vs bf16 width ---
            "kv_dtype": self.e.kv_dtype,
            "quant_bytes_saved": self.transport.stats.quant_bytes_saved,
            "quant_scale_bytes": ((self.num_blocks - 1)
                                  * self.scale_bytes_per_block
                                  * max(1, registry.n_paged_layers(self.cfg))),
            "mesh": (None if self.mesh is None
                     else "x".join(str(self.mesh.shape[a])
                                   for a in self.mesh.axis_names)),
            "tp_degree": self.tp_degree,
            "kv_shards": self._kv_shards,
            "per_device_reserved_kv": self.reserved_kv_bytes() // self._kv_shards,
            "per_device_active_kv": self.active_kv_bytes() // self._kv_shards,
            "per_device_peak_reserved_kv": self.peak_reserved_kv // self._kv_shards,
        })

    def reserved_kv_bytes(self) -> int:
        n_layers = max(1, registry.n_paged_layers(self.cfg))
        if self.e.mode == "arena":
            return (self.num_blocks - 1) * self.block_bytes * n_layers
        return self.pager.reserved_bytes() * n_layers

    def active_kv_bytes(self) -> int:
        n_layers = max(1, registry.n_paged_layers(self.cfg))
        if self.e.mode == "arena":
            return int(self._slot_len.sum()) * self.bytes_per_token * n_layers
        return self.pager.active_tokens() * self.bytes_per_token * n_layers

    def latency_stats(self, skip: int = 3) -> dict:
        active = [m for m in self.metrics if m.active > 0]
        walls = np.array([m.wall for m in active[skip:]])
        if walls.size == 0:
            walls = np.array([m.wall for m in active]) if active else np.zeros(1)
        q = lambda p: float(np.percentile(walls * 1e3, p))
        return {"p50_ms": q(50), "p95_ms": q(95), "p99_ms": q(99),
                "p999_ms": q(99.9), "mean_ms": float(walls.mean() * 1e3),
                "max_ms": float(walls.max() * 1e3)}

    def throughput(self, skip: int = 3) -> float:
        steps = [m for m in self.metrics if m.active > 0][skip:]
        if not steps:
            steps = [m for m in self.metrics if m.active > 0]
        tok = sum(m.emitted for m in steps)
        wall = sum(m.wall for m in steps)
        return tok / max(wall, 1e-12)

    def request_latency_stats(self) -> dict:
        """Request-level completion / time-to-first-token (wall seconds,
        relative to each request's ARRIVAL when present, engine start
        otherwise). Raw ``finish_wall``/``ttft_wall`` stamps are engine-start
        relative, so trace replay (arrivals gate admission) must subtract the
        arrival offset or late requests inflate the percentiles by their own
        arrival time; clamped at 0 for in-flight edge stamps.

        TTFT and TPOT are reported SEPARATELY: TPOT is the mean inter-token
        gap (finish - first token) / (n - 1), so the first-token wait —
        queueing + prefill — no longer folds into the per-token figure."""
        fin = self.sched.finished
        if not fin:
            return {}
        arr = np.array([getattr(r, "arrival", 0.0) or 0.0 for r in fin])
        finw = np.array([getattr(r, "finish_wall", 0.0) for r in fin])
        ttftw = np.array([getattr(r, "ttft_wall", 0.0) for r in fin])
        ngen = np.array([len(r.generated) for r in fin])
        comp = np.maximum(finw - arr, 0.0)
        ttft = np.maximum(ttftw - arr, 0.0)
        tpot = np.where(ngen > 1,
                        np.maximum(finw - ttftw, 0.0) / np.maximum(ngen - 1, 1),
                        0.0)
        q = lambda a, p: float(np.percentile(a * 1e3, p))
        return {"completion_p50_ms": q(comp, 50), "completion_p99_ms": q(comp, 99),
                "ttft_p50_ms": q(ttft, 50), "ttft_p99_ms": q(ttft, 99),
                "tpot_p50_ms": q(tpot, 50), "tpot_p99_ms": q(tpot, 99)}
