"""FrameDescriptor — the single per-step committed descriptor (paper §4.2).

The device consumes exactly one committed descriptor per decode step. The host
expresses all runtime variability (EOS churn, admission, window slide, far-view
selection) as *mapping edits* that the pager seals with one ``Frame`` commit;
the result is this fixed-shape pytree. Executable shape never changes.

Granularity (paper's BLOCKALIGN): the pager allocates in *page blocks* of
``block_pages`` contiguous pages. The kernel-visible near-window table is a
block table, so each grid step moves one burst-friendly block (~tau bytes)
instead of a fragmented page — this is the merge-staged transport contract
realized as an HBM->VMEM copy schedule (DESIGN.md §2).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np


class FrameDescriptor(NamedTuple):
    """Fixed-shape, device-consumed view of one decode step.

    B = engine batch width (fixed), NB = near-window blocks (fixed),
    CAP = far-view cap (fixed), MT = max transport trains (fixed).
    All integer arrays are int32.
    """
    # --- near window (block granularity) ---
    block_table: jnp.ndarray     # (B, NB)  physical block ids, oldest->newest
    window_base: jnp.ndarray     # (B,)     absolute pos of block_table[:,0] token 0
    seq_lens: jnp.ndarray        # (B,)     logical length BEFORE this step's token
    slot_active: jnp.ndarray     # (B,)     1 if slot serves a live request
    # --- this step's KV write ---
    write_block: jnp.ndarray     # (B,)     physical block receiving the new K/V
    write_offset: jnp.ndarray    # (B,)     token offset within that block
    # --- merged transport trains (stats + Pallas copy schedule) ---
    train_start: jnp.ndarray     # (B, MT)  physical start block of each train
    train_len: jnp.ndarray       # (B, MT)  blocks per train (0 = unused)
    train_dst: jnp.ndarray       # (B, MT)  destination block offset in window
    # --- far view (optional policy; zero-filled when disabled) ---
    far_table: jnp.ndarray       # (B, CAP) chunk indices into per-slot far pool
    far_valid: jnp.ndarray       # (B, CAP) 1 if entry holds a real summary
    # far-view chunk summarization for THIS step (sealed in the same commit)
    far_chunk_blocks: jnp.ndarray  # (B, CB) blocks of the just-completed chunk
    far_chunk_tokens: jnp.ndarray  # (B,)    valid tokens in that chunk
    far_do_summarize: jnp.ndarray  # (B,)    1 if a chunk completed this step
    far_write_idx: jnp.ndarray     # (B,)    far-pool slot receiving the summary
    # --- commit metadata ---
    epoch: jnp.ndarray           # ()       frame epoch counter (single commit audit)

    @property
    def batch(self) -> int:
        return self.block_table.shape[0]

    @property
    def n_blocks(self) -> int:
        return self.block_table.shape[1]


def empty_descriptor(batch: int, n_blocks: int, cap: int, max_trains: int,
                     chunk_blocks: int = 1, np_mod=np) -> FrameDescriptor:
    """Host-side zeroed descriptor (numpy for cheap in-place edits)."""
    z = lambda *s: np_mod.zeros(s, np_mod.int32)
    return FrameDescriptor(
        block_table=z(batch, n_blocks),
        window_base=z(batch),
        seq_lens=z(batch),
        slot_active=z(batch),
        write_block=z(batch),
        write_offset=z(batch),
        train_start=z(batch, max_trains),
        train_len=z(batch, max_trains),
        train_dst=z(batch, max_trains),
        far_table=z(batch, cap),
        far_valid=z(batch, cap),
        far_chunk_blocks=z(batch, chunk_blocks),
        far_chunk_tokens=z(batch),
        far_do_summarize=z(batch),
        far_write_idx=z(batch),
        epoch=np_mod.zeros((), np_mod.int32),
    )


def descriptor_geometry(serving, max_seq: int):
    """Static shape parameters implied by a ServingConfig."""
    page, near = serving.page_size, serving.near_window
    # block_pages chosen so one block ~ tau bytes is decided by the engine per
    # model (depends on kv_width); geometry here is token-level.
    return {
        "page_size": page,
        "near_window": near,
        "max_pages": max_seq // page + 1,
    }
