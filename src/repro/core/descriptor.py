"""FrameDescriptor — the single per-step committed descriptor (paper §4.2).

The device consumes exactly one committed descriptor per decode step. The host
expresses all runtime variability (EOS churn, admission, window slide, far-view
selection) as *mapping edits* that the pager seals with one ``Frame`` commit;
the result is this fixed-shape pytree. Executable shape never changes.

Granularity (paper's BLOCKALIGN): the pager allocates in *page blocks* of
``block_pages`` contiguous pages. The kernel-visible near-window table is a
block table, so each grid step moves one burst-friendly block (~tau bytes)
instead of a fragmented page — this is the merge-staged transport contract
realized as an HBM->VMEM copy schedule (DESIGN.md §2).
"""
from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp
import numpy as np


class FrameDescriptor(NamedTuple):
    """Fixed-shape, device-consumed view of one decode step.

    B = engine batch width (fixed), NB = near-window blocks (fixed),
    CAP = far-view cap (fixed), MT = max transport trains (fixed).
    All integer arrays are int32.
    """
    # --- near window (block granularity) ---
    block_table: jnp.ndarray     # (B, NB)  physical block ids, oldest->newest
    window_base: jnp.ndarray     # (B,)     absolute pos of block_table[:,0] token 0
    seq_lens: jnp.ndarray        # (B,)     logical length BEFORE this step's token
    slot_active: jnp.ndarray     # (B,)     1 if slot serves a live request
    # --- this step's KV write ---
    write_block: jnp.ndarray     # (B,)     physical block receiving the new K/V
    write_offset: jnp.ndarray    # (B,)     token offset within that block
    # --- merged transport trains (stats + Pallas copy schedule) ---
    train_start: jnp.ndarray     # (B, MT)  physical start block of each train
    train_len: jnp.ndarray       # (B, MT)  blocks per train (0 = unused)
    train_dst: jnp.ndarray       # (B, MT)  destination block offset in window
    # --- far view (optional policy; zero-filled when disabled) ---
    far_table: jnp.ndarray       # (B, CAP) chunk indices into per-slot far pool
    far_valid: jnp.ndarray       # (B, CAP) 1 if entry holds a real summary
    # far-view chunk summarization for THIS step (sealed in the same commit)
    far_chunk_blocks: jnp.ndarray  # (B, CB) blocks of the just-completed chunk
    far_chunk_tokens: jnp.ndarray  # (B,)    valid tokens in that chunk
    far_do_summarize: jnp.ndarray  # (B,)    1 if a chunk completed this step
    far_write_idx: jnp.ndarray     # (B,)    far-pool slot receiving the summary
    # --- commit metadata ---
    epoch: jnp.ndarray           # ()       frame epoch counter (single commit audit)

    @property
    def batch(self) -> int:
        return self.block_table.shape[0]

    @property
    def n_blocks(self) -> int:
        return self.block_table.shape[1]


def empty_descriptor(batch: int, n_blocks: int, cap: int, max_trains: int,
                     chunk_blocks: int = 1, np_mod=np) -> FrameDescriptor:
    """Host-side zeroed descriptor (numpy for cheap in-place edits)."""
    z = lambda *s: np_mod.zeros(s, np_mod.int32)
    return FrameDescriptor(
        block_table=z(batch, n_blocks),
        window_base=z(batch),
        seq_lens=z(batch),
        slot_active=z(batch),
        write_block=z(batch),
        write_offset=z(batch),
        train_start=z(batch, max_trains),
        train_len=z(batch, max_trains),
        train_dst=z(batch, max_trains),
        far_table=z(batch, cap),
        far_valid=z(batch, cap),
        far_chunk_blocks=z(batch, chunk_blocks),
        far_chunk_tokens=z(batch),
        far_do_summarize=z(batch),
        far_write_idx=z(batch),
        epoch=np_mod.zeros((), np_mod.int32),
    )


# ---------------------------------------------------------------------------
# flat descriptor packing (pipelined hot path; DESIGN.md §3)
# ---------------------------------------------------------------------------
# The pipelined engine assembles the descriptor in ONE persistent flat int32
# buffer: every FrameDescriptor field is a numpy VIEW into it, so per-slot
# edits land in the flat buffer directly and the per-step host->device
# transfer is a single device_put instead of ~16 (measured ~2.2ms -> ~0.15ms
# per step on the CPU container). The compiled step unpacks it with static
# slices (free under XLA fusion). Field order is the NamedTuple order;
# ``epoch`` is a (1,) view host-side and a scalar slice device-side.

def _descriptor_layout(batch: int, n_blocks: int, cap: int, max_trains: int,
                       chunk_blocks: int):
    B = batch
    shapes = [
        ("block_table", (B, n_blocks)), ("window_base", (B,)),
        ("seq_lens", (B,)), ("slot_active", (B,)),
        ("write_block", (B,)), ("write_offset", (B,)),
        ("train_start", (B, max_trains)), ("train_len", (B, max_trains)),
        ("train_dst", (B, max_trains)),
        ("far_table", (B, cap)), ("far_valid", (B, cap)),
        ("far_chunk_blocks", (B, chunk_blocks)), ("far_chunk_tokens", (B,)),
        ("far_do_summarize", (B,)), ("far_write_idx", (B,)),
        ("epoch", ()),
    ]
    layout = []
    off = 0
    for name, shp in shapes:
        n = int(np.prod(shp)) if shp else 1
        layout.append((name, shp, off, off + n))
        off += n
    return layout, off


def descriptor_flat_size(batch: int, n_blocks: int, cap: int, max_trains: int,
                         chunk_blocks: int = 1) -> int:
    return _descriptor_layout(batch, n_blocks, cap, max_trains,
                              chunk_blocks)[1]


# host->device control plane appended AFTER the flat descriptor words in the
# engine's single per-step commit buffer (DESIGN.md §3/§13): three (B,) int32
# rows — host prompt tokens, the feed_sampled mask selecting device-side
# token feedback, and the per-slot request id the sampler folds into its
# per-step PRNG keys (rng meta: key = fold_in(fold_in(seed, rid), seq_len)).
# ONE device_put moves descriptor + control rows together.
N_CONTROL_ROWS = 3


def control_plane_size(batch: int) -> int:
    """Flat int32 words the engine appends after the descriptor."""
    return N_CONTROL_ROWS * batch


class ControlPlane(NamedTuple):
    """Numpy views of the three per-slot control rows inside the engine's
    flat commit buffer (host assembly side). Unpacks positionally in the
    row order the compiled step slices them back out."""
    host_tokens: np.ndarray      # (B,) prompt token fed where feed == 0
    feed_sampled: np.ndarray     # (B,) 1 = take the device-side feedback
    rids: np.ndarray             # (B,) request id (sampler PRNG meta, §13)


def control_plane_views(flat: np.ndarray, batch: int, *,
                        offset: int) -> ControlPlane:
    """ControlPlane of numpy VIEWS into ``flat`` starting at ``offset``
    (the descriptor words precede the control rows in the commit buffer)."""
    B = batch
    assert flat.dtype == np.int32 and flat.size >= offset + N_CONTROL_ROWS * B
    return ControlPlane(
        host_tokens=flat[offset:offset + B],
        feed_sampled=flat[offset + B:offset + 2 * B],
        rids=flat[offset + 2 * B:offset + 3 * B])


def refresh_control_row(cp: ControlPlane, slot: int, *, rid: int = 0) -> None:
    """Incremental control-row refresh for ONE slot that changes owner
    mid-pipeline (step-level admission, DESIGN.md §15).

    A slot freed by EOS retirement / cancel / preemption and refilled on
    the very next step flips exactly these three words: the rid row must
    carry the NEW owner before its first dispatch (the sampler folds it
    into every per-step PRNG key, so a stale rid would silently decode
    the predecessor's stream), and the token/feed words reset so the
    first step re-seeds from the host prompt rather than the
    predecessor's device-side feedback chain. Everything else in the
    committed descriptor is rebuilt per step or owned by the pager's
    frame edits — slot ownership changes never touch it."""
    cp.host_tokens[slot] = 0
    cp.feed_sampled[slot] = 0
    cp.rids[slot] = rid


def flat_descriptor_views(flat: np.ndarray, batch: int, n_blocks: int,
                          cap: int, max_trains: int,
                          chunk_blocks: int = 1) -> "FrameDescriptor":
    """FrameDescriptor of numpy VIEWS into ``flat`` (host assembly side)."""
    layout, total = _descriptor_layout(batch, n_blocks, cap, max_trains,
                                       chunk_blocks)
    assert flat.shape == (total,) and flat.dtype == np.int32
    fields = {}
    for name, shp, lo, hi in layout:
        v = flat[lo:hi]
        fields[name] = v.reshape(shp) if shp else v   # epoch: (1,) view
    return FrameDescriptor(**fields)


def unflatten_descriptor(flat: jnp.ndarray, batch: int, n_blocks: int,
                         cap: int, max_trains: int,
                         chunk_blocks: int = 1) -> "FrameDescriptor":
    """Device-side unpack (called INSIDE the compiled step; static slices)."""
    layout, _ = _descriptor_layout(batch, n_blocks, cap, max_trains,
                                   chunk_blocks)
    fields = {}
    for name, shp, lo, hi in layout:
        v = flat[lo:hi]
        fields[name] = v.reshape(shp) if shp else v[0]
    return FrameDescriptor(**fields)


class PrefillChunkDescriptor(NamedTuple):
    """Fixed-shape view of one batched prompt-ingestion step (§3).

    B = engine batch width, C = chunk width, NB = near-window blocks — all
    fixed, same table geometry as the decode descriptor. Every slot row is
    processed every call (ONE dispatch per engine step, like the decode
    step); slots with nothing to ingest carry ``n_valid = 0`` and are fully
    masked. A P-token prompt is ingested in ceil((P-1)/C) chunks — the
    final prompt token always goes through the decode step so sampled-token
    semantics match the token-at-a-time path exactly. Chunks need not be
    block-aligned (aliased prefixes start mid-block): each chunk token
    carries its own (write_block, write_offset) pair; invalid (padded)
    tokens point at the scratch block 0. All integer arrays are int32.
    """
    tokens: jnp.ndarray          # (B, C)  prompt token ids (zero-padded)
    start_pos: jnp.ndarray       # (B,)    absolute position of tokens[b, 0]
    n_valid: jnp.ndarray         # (B,)    valid tokens in this chunk (<= C)
    block_table: jnp.ndarray     # (B, NB) window blocks covering [wb, start)
    window_base: jnp.ndarray     # (B,)    absolute pos of table[b,0] token 0
    write_block: jnp.ndarray     # (B, C)  physical block receiving token KV
    write_offset: jnp.ndarray    # (B, C)  token offset within that block


def _chunk_layout(batch: int, chunk: int, n_blocks: int):
    B = batch
    shapes = [("tokens", (B, chunk)), ("start_pos", (B,)), ("n_valid", (B,)),
              ("block_table", (B, n_blocks)), ("window_base", (B,)),
              ("write_block", (B, chunk)), ("write_offset", (B, chunk))]
    layout = []
    off = 0
    for name, shp in shapes:
        n = int(np.prod(shp))
        layout.append((name, shp, off, off + n))
        off += n
    return layout, off


def chunk_flat_size(batch: int, chunk: int, n_blocks: int) -> int:
    return _chunk_layout(batch, chunk, n_blocks)[1]


def flat_chunk_views(flat: np.ndarray, batch: int, chunk: int,
                     n_blocks: int) -> PrefillChunkDescriptor:
    """PrefillChunkDescriptor of numpy views into ``flat`` (host side)."""
    layout, total = _chunk_layout(batch, chunk, n_blocks)
    assert flat.shape == (total,) and flat.dtype == np.int32
    return PrefillChunkDescriptor(**{
        name: flat[lo:hi].reshape(shp) for name, shp, lo, hi in layout})


def unflatten_chunk_descriptor(flat: jnp.ndarray, batch: int, chunk: int,
                               n_blocks: int) -> PrefillChunkDescriptor:
    layout, _ = _chunk_layout(batch, chunk, n_blocks)
    return PrefillChunkDescriptor(**{
        name: flat[lo:hi].reshape(shp) for name, shp, lo, hi in layout})


def active_block_extents(window_base, seq_lens, slot_active, *,
                         near_window: int, nb: int, bt: int):
    """Host-side (numpy) per-slot active window-block extents [lo, hi).

    The canonical descriptor-side derivation of the work-skipping kernel's
    trip counts (DESIGN.md §12): block i of slot b holds positions
    ``wb + i*bt .. wb + (i+1)*bt - 1``; only blocks intersecting
    ``(t - near_window, t] ∩ [0, inf)`` carry unmasked work, and retired
    slots (``slot_active == 0``) carry none. Must stay in lockstep with
    ``kernels/ref.py active_block_extent`` (the jnp twin fed to the kernels
    as scalar-prefetch meta) — tests/test_kernel_skip.py asserts agreement.
    The engine's ``kernel_blocks_{total,skipped}`` audit counters integrate
    ``nb - (hi - lo)`` over participating slot-steps.

    Inputs are (B,) int arrays (descriptor views); returns int32 (lo, hi).
    """
    window_base = np.asarray(window_base, np.int64)
    seq_lens = np.asarray(seq_lens, np.int64)
    act = np.asarray(slot_active) > 0
    lo_pos = np.maximum(0, seq_lens + 1 - near_window)
    lo = (lo_pos - window_base) // bt
    hi = (seq_lens - window_base) // bt + 1
    lo = np.clip(np.where(act, lo, 0), 0, nb).astype(np.int32)
    hi = np.clip(np.where(act, hi, 0), 0, nb).astype(np.int32)
    return lo, np.maximum(hi, lo)


def descriptor_geometry(serving, max_seq: int):
    """Static shape parameters implied by a ServingConfig."""
    page, near = serving.page_size, serving.near_window
    # block_pages chosen so one block ~ tau bytes is decided by the engine per
    # model (depends on kv_width); geometry here is token-level.
    return {
        "page_size": page,
        "near_window": near,
        "max_pages": max_seq // page + 1,
    }
