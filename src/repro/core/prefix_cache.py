"""Radix prefix cache — automatic shared-prefix KV reuse (DESIGN.md §9).

Index over COMMITTED, IMMUTABLE KV blocks: the key space is the token-id
content of full blocks (``block_tokens`` tokens per edge), arranged as a
radix tree so prompts sharing a prefix share index nodes exactly as they
share physical blocks. One node = one block; a root-to-node path spells
the token prefix whose KV the node's block holds.

Interaction with the pager:
  * The cache takes an EXTERNAL reference (``BlockPager.retain_block``) on
    every indexed block, so a cached prefix survives its originating
    session's EOS. External refs behave like COW shares everywhere else:
    refcount > 1 makes a block ineligible for host-tier swap, so cached
    (and therefore aliased) blocks are never swap candidates.
  * On a match the engine aliases the matched chain into the fresh session
    via ``BlockPager.alias_blocks`` (COW): full blocks are shared, an
    unaligned tail gets a device-side copy-on-write block copy, accounted
    by the transport as its own group kind (``account_cow``).
  * Blocks held only by the cache (refcount 1) are DEVICE-resident by
    construction — the swap verbs only walk sessions — so a hit can never
    trip ``SwapRefused``.

Eviction is refcount-aware LRU over LEAVES (interior nodes anchor longer
cached prefixes and are only exposed once their subtree drains):
  * ``pins`` — pin-on-match: every node on a matched path is pinned for
    the lifetime of the matching request; pinned nodes are skipped unless
    the engine explicitly flushes for memory pressure (a flush only loses
    reuse — sessions hold their own block references).
  * Unshared leaves first (refcount 1: only the cache holds the block, so
    dropping it returns a device block NOW), then coldest ``last_use``.
    Shared leaves free nothing immediately but un-share their block,
    re-enabling host-tier swap of the owning session.
"""
from __future__ import annotations

from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.pager import BlockPager


class _Node:
    __slots__ = ("key", "block", "parent", "children", "pins", "last_use")

    def __init__(self, key: Tuple[int, ...], block: int,
                 parent: Optional["_Node"]):
        self.key = key                      # block_tokens token ids (edge)
        self.block = block                  # retained device block id
        self.parent = parent
        self.children: Dict[Tuple[int, ...], "_Node"] = {}
        self.pins = 0
        self.last_use = 0


class PrefixMatch:
    """Result of a (pure) longest-prefix lookup: the matched node path,
    their physical blocks, and the covered token count (block-aligned)."""
    __slots__ = ("nodes", "blocks", "tokens")

    def __init__(self, nodes: List[_Node], block_tokens: int):
        self.nodes = nodes
        self.blocks = [n.block for n in nodes]
        self.tokens = len(nodes) * block_tokens


class PrefixCache:
    def __init__(self, pager: BlockPager, block_tokens: int, max_blocks: int):
        assert max_blocks >= 1
        self.pager = pager
        self.bt = block_tokens
        self.max_blocks = max_blocks
        self._root = _Node((), 0, None)
        self._clock = 0
        self.blocks_cached = 0
        self.stats = {"hits": 0, "misses": 0, "tokens_reused": 0,
                      "insertions": 0, "inserted_blocks": 0,
                      "evicted_blocks": 0, "pressure_flushes": 0}

    # ------------------------------------------------------------------
    def _chunks(self, tokens: Sequence[int], n_blocks: int
                ) -> List[Tuple[int, ...]]:
        t = np.asarray(tokens)
        return [tuple(int(x) for x in t[i * self.bt:(i + 1) * self.bt])
                for i in range(n_blocks)]

    def _touch(self, node: _Node) -> None:
        self._clock += 1
        node.last_use = self._clock

    # ------------------------------------------------------------------
    # lookup / pin
    # ------------------------------------------------------------------
    def match(self, prompt: Sequence[int]) -> PrefixMatch:
        """Longest indexed prefix of ``prompt`` in full blocks. Pure: no
        stats, no pins, no LRU touch — safe for the admission watermark
        gate to peek before the request is actually placed. Chunks are
        keyed lazily so a root miss on a long queued prompt (re-gated
        every step while blocked) costs one chunk, not the whole prompt."""
        nodes: List[_Node] = []
        node = self._root
        t = np.asarray(prompt)
        for i in range(len(t) // self.bt):
            key = tuple(int(x) for x in t[i * self.bt:(i + 1) * self.bt])
            child = node.children.get(key)
            if child is None:
                break
            nodes.append(child)
            node = child
        return PrefixMatch(nodes, self.bt)

    def hit(self, nodes: List[_Node], tokens_reused: int) -> None:
        """Account a served match and pin its path for the lifetime of the
        matching request (release with ``unpin_path`` at retire/preempt)."""
        self.stats["hits"] += 1
        self.stats["tokens_reused"] += tokens_reused
        self.pin_path(nodes)

    def miss(self) -> None:
        self.stats["misses"] += 1

    def pin_path(self, nodes: List[_Node]) -> None:
        for n in nodes:
            n.pins += 1
            self._touch(n)

    def unpin_path(self, nodes: List[_Node]) -> None:
        for n in nodes:                     # resilient to flushed nodes
            n.pins = max(0, n.pins - 1)

    # ------------------------------------------------------------------
    # insert
    # ------------------------------------------------------------------
    def insert(self, tokens: Sequence[int], blocks: Sequence[int]) -> int:
        """Index a committed full-block prefix: ``blocks[i]`` holds the KV
        of ``tokens[i*bt:(i+1)*bt]``. Shared (already-indexed) chunks are
        deduplicated — the EXISTING block stays canonical and the new
        duplicate is not retained. Returns the number of newly retained
        blocks (may stop early when the cap cannot be freed)."""
        n_blocks = min(len(blocks), len(tokens) // self.bt)
        if n_blocks < 1:
            return 0
        path: List[_Node] = []
        node = self._root
        added = 0
        try:
            for key, b in zip(self._chunks(tokens, n_blocks),
                              blocks[:n_blocks]):
                child = node.children.get(key)
                if child is None:
                    if self.blocks_cached >= self.max_blocks and \
                            self.evict(self.blocks_cached
                                       - self.max_blocks + 1) == 0:
                        break               # cap reached, nothing evictable
                    self.pager.retain_block(b)
                    child = _Node(key, b, node)
                    node.children[key] = child
                    self.blocks_cached += 1
                    added += 1
                self._touch(child)
                path.append(child)
                child.pins += 1             # shield the in-progress path
                node = child
        finally:
            for n in path:
                n.pins -= 1
        if added:
            self.stats["insertions"] += 1
            self.stats["inserted_blocks"] += added
        return added

    # ------------------------------------------------------------------
    # eviction (refcount-aware LRU over leaves)
    # ------------------------------------------------------------------
    def _leaves(self, include_pinned: bool) -> List[_Node]:
        out: List[_Node] = []
        stack = list(self._root.children.values())
        while stack:
            n = stack.pop()
            if n.children:
                stack.extend(n.children.values())
            elif include_pinned or n.pins == 0:
                out.append(n)
        return out

    def _drop(self, node: _Node) -> None:
        node.parent.children.pop(node.key)
        node.parent = None                  # detached (unpin stays safe)
        self.pager.release_block(node.block)
        self.blocks_cached -= 1
        self.stats["evicted_blocks"] += 1

    def evict(self, max_drop: int, *, include_pinned: bool = False) -> int:
        """Drop up to ``max_drop`` leaf blocks, unshared-coldest-first.
        Dropping an unshared (refcount-1) leaf frees a device block
        immediately; dropping a shared leaf un-shares it (host-tier swap
        eligibility) and releases cache budget. Returns blocks dropped."""
        dropped = 0
        while dropped < max_drop:
            # batch per tree level: drop the whole sorted leaf set before
            # re-collecting (re-collection only exposes parents), keeping
            # a full flush O(nodes * depth) instead of O(nodes^2 log n)
            leaves = self._leaves(include_pinned)
            if not leaves:
                break
            leaves.sort(key=lambda n: (bool(self.pager.refcount[n.block] > 1),
                                       n.last_use))
            for n in leaves[:max_drop - dropped]:
                self._drop(n)
                dropped += 1
        return dropped

    def flush_for_pressure(self) -> int:
        """Memory-pressure backstop: drop EVERYTHING, pinned paths included
        (live sessions keep their own block references — only future reuse
        is lost). Un-shares every cached block so the engine's preemption
        victim search can run unobstructed. Returns blocks dropped."""
        dropped = self.evict(1 << 30, include_pinned=True)
        if dropped:
            self.stats["pressure_flushes"] += 1
        return dropped

    # ------------------------------------------------------------------
    def check_invariants(self) -> None:
        """Property-test hook: tree block accounting matches the pager's
        external-ref table; every cached block is device-resident & live."""
        seen: List[int] = []
        stack = list(self._root.children.values())
        while stack:
            n = stack.pop()
            seen.append(n.block)
            assert n.pins >= 0
            assert 0 < n.block < self.pager.num_blocks
            assert self.pager.refcount[n.block] >= 1, \
                f"cached block {n.block} is dead"
            assert self.pager.external_refs.get(n.block, 0) >= 1, \
                f"cached block {n.block} lost its external ref"
            stack.extend(n.children.values())
        assert len(seen) == len(set(seen)), "block double-indexed"
        assert self.blocks_cached == len(seen)
