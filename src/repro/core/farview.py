"""Far-view summarization policy (paper §4.4) — optional bounded-budget view.

Host-side policy state: per-slot EMA of aggregated attention utility per far
chunk (fed back from the device's far_util output each step), used to select
up to ``cap`` representative chunks for the next frame. Chunk summaries are
built on-device by uniform aggregation (kernels farview_summarize) when the
near window slides past a chunk boundary; the underlying blocks are then
TRIMmed, so reserved memory stays O(W* + cap) per session.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Optional

import numpy as np


@dataclass
class FarViewState:
    max_chunks: int
    cap: int
    ema_decay: float = 0.9
    n_chunks: np.ndarray = None          # (B,) summaries written per slot
    ema: np.ndarray = None               # (B, max_chunks) utility scores

    def __post_init__(self):
        pass


class FarViewPolicy:
    def __init__(self, batch: int, max_chunks: int, cap: int,
                 sv_chunk: int, block_tokens: int, ema_decay: float = 0.9):
        assert sv_chunk % block_tokens == 0, "sv_chunk must be BLOCKALIGN'd"
        self.batch = batch
        self.max_chunks = max_chunks
        self.cap = cap
        self.sv_chunk = sv_chunk
        self.block_tokens = block_tokens
        self.chunk_blocks = sv_chunk // block_tokens
        self.ema_decay = ema_decay
        self.n_chunks = np.zeros(batch, np.int32)
        self.ema = np.zeros((batch, max_chunks), np.float32)

    def reset_slot(self, row: int) -> None:
        self.n_chunks[row] = 0
        self.ema[row] = 0.0

    def observe_utility(self, far_util: np.ndarray, far_table: np.ndarray) -> None:
        """far_util: (B, cap) attention mass per SELECTED entry from the
        device; scatter back into per-chunk EMA scores."""
        d = self.ema_decay
        for b in range(self.batch):
            sel = far_table[b]
            self.ema[b] *= d
            np.add.at(self.ema[b], sel, (1 - d) * far_util[b])

    def select(self, row: int) -> np.ndarray:
        """Top-cap chunks by EMA for one slot -> (cap,) indices (+valid via
        n_chunks). Falls back to most-recent-first for unscored chunks."""
        n = int(self.n_chunks[row])
        cap = self.cap
        table = np.zeros(cap, np.int32)
        valid = np.zeros(cap, np.int32)
        if n == 0:
            return table, valid
        if n <= cap:
            table[:n] = np.arange(n)
            valid[:n] = 1
            return table, valid
        scores = self.ema[row, :n].copy()
        # recency prior: never starve recent chunks that haven't been scored
        scores += 1e-6 * np.arange(n)
        top = np.argpartition(scores, -cap)[-cap:]
        top.sort()
        table[:] = top
        valid[:] = 1
        return table, valid

    def on_chunk_summarized(self, row: int) -> int:
        """Account a new summary; returns the far-pool slot it was written to."""
        idx = int(self.n_chunks[row])
        if idx >= self.max_chunks:
            # budget exhausted: recycle the lowest-utility slot
            idx = int(np.argmin(self.ema[row]))
            self.ema[row, idx] = 0.0
            return idx
        self.n_chunks[row] += 1
        return idx
