"""Merge-staged descriptor transport (paper §4.3, Algorithm 1).

Three phases per step:
  Shift  — advance the near-window view, apply alias/COW/EOS edits (pager).
  Stage  — BLOCKALIGN the lookahead set S_{t+1}, materialize page descriptors,
           prefetch-1 (next block reserved adjacent to the tail).
  Reduce — greedily merge adjacent descriptors into trains until the size
           threshold tau (~128 KiB) or the age cutoff delta, then emit a
           near-window train (and, when enabled, one far-view train).

On TPU the emitted trains are the HBM->VMEM copy schedule consumed by the
Pallas kernel (train_start/train_len/train_dst in the FrameDescriptor); the
same structures give the DMA statistics the paper audits (groups per step,
average merged transfer size).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import List, Sequence, Tuple

import numpy as np


@dataclass
class TransportStats:
    steps: int = 0
    total_groups: int = 0
    total_bytes: int = 0
    max_groups: int = 0
    unmerged_groups: int = 0      # what the group count would be w/o merging
    held_descriptors: int = 0     # staged but deferred (age < delta)
    train_overflows: int = 0      # slots whose trains exceeded MT (stress)
    # --- host-tier swap traffic (DESIGN.md §8): swaps ride the same
    # large-copy discipline as window trains — coalesced into groups
    # contiguous in BOTH source and destination coordinates ---
    swap_groups: int = 0          # merged host<->device copy groups
    swap_unmerged: int = 0        # blocks moved (group count w/o merging)
    swap_out_bytes: int = 0       # device -> host
    swap_in_bytes: int = 0        # host -> device
    # --- COW tail copies (prefix aliasing, DESIGN.md §9): device-side
    # block copies materializing the partial tail of an aliased prefix —
    # their own group kind so prefix-reuse traffic is auditable apart
    # from window trains and swaps ---
    cow_groups: int = 0           # merged copy groups executed
    cow_blocks: int = 0           # blocks copied (1 per unaligned alias)
    cow_bytes: int = 0
    # --- quantized KV tier (DESIGN.md §10): bytes every accounted block
    # movement (window trains, swaps, COW copies) saved vs full bf16
    # width; 0 when the pools store bf16 ---
    quant_bytes_saved: int = 0
    # --- async movement engine (DESIGN.md §11): deferred swap-out
    # readbacks ride a per-transfer fence table; these witness that the
    # overlap actually happened (all zero when async_movement is off) ---
    overlap_steps: int = 0        # steps dispatched with >= 1 fence pending
    deferred_readbacks: int = 0   # swap-out transfers synchronized lazily
    staging_reuse_bytes: int = 0  # bytes staged through reused host buffers

    @property
    def groups_per_step(self) -> float:
        return self.total_groups / max(1, self.steps)

    @property
    def avg_group_bytes(self) -> float:
        return self.total_bytes / max(1, self.total_groups)

    @property
    def unmerged_groups_per_step(self) -> float:
        return self.unmerged_groups / max(1, self.steps)

    @property
    def swap_bytes(self) -> int:
        return self.swap_out_bytes + self.swap_in_bytes

    @property
    def avg_swap_group_blocks(self) -> float:
        return self.swap_unmerged / max(1, self.swap_groups)


@dataclass
class StagedDescriptor:
    block: int
    dst: int          # destination window slot (block index in window)
    age: int = 0      # steps held


def merge_swap_pairs(pairs: Sequence[Tuple[int, int]]
                     ) -> List[Tuple[int, int, int]]:
    """Coalesce (src_block, dst_block) swap copy pairs into maximal
    (src_start, dst_start, len) groups contiguous in BOTH coordinates —
    the same large-copy discipline as window trains (§2), applied to
    host<->device swap traffic (DESIGN.md §8). Pair order is preserved:
    the pager emits swap pairs oldest-block-first and allocates host slots
    lowest-first, so both sides are usually long runs."""
    groups: List[Tuple[int, int, int]] = []
    i, n = 0, len(pairs)
    while i < n:
        s0, d0 = pairs[i]
        ln = 1
        while (i + ln < n and pairs[i + ln][0] == s0 + ln
               and pairs[i + ln][1] == d0 + ln):
            ln += 1
        groups.append((s0, d0, ln))
        i += ln
    return groups


def merge_runs(blocks: Sequence[int]) -> List[Tuple[int, int, int]]:
    """Greedy merge of a window block list into (start, len, dst) trains.
    A train is a maximal physically-contiguous run in window order."""
    trains: List[Tuple[int, int, int]] = []
    i = 0
    n = len(blocks)
    while i < n:
        start = blocks[i]
        dst = i
        ln = 1
        while i + ln < n and blocks[i + ln] == start + ln:
            ln += 1
        trains.append((start, ln, dst))
        i += ln
    return trains


class MergeStagedTransport:
    def __init__(self, *, block_bytes: int, merge_threshold_bytes: int,
                 max_hold_steps: int, max_trains: int,
                 dense_block_bytes: int = 0):
        self.block_bytes = block_bytes
        # bf16-width cost of the same block (quantized tier, DESIGN.md §10):
        # every accounted block movement adds the difference to
        # ``quant_bytes_saved``; defaults to block_bytes (no savings)
        self.dense_block_bytes = max(dense_block_bytes, block_bytes)
        self.tau = merge_threshold_bytes
        self.delta = max_hold_steps
        self.max_trains = max_trains
        self.stats = TransportStats()
        self._staged: List[StagedDescriptor] = []
        # per-transfer fence table (async movement, DESIGN.md §11):
        # fence id -> opaque payload (the engine parks its un-synchronized
        # device gathers here). Insertion-ordered: drains are FIFO so a
        # host slot freed and reallocated between two transfers takes the
        # LATER transfer's bytes, exactly like the synchronous schedule.
        self._fences: dict = {}
        self._next_fence = 0

    def _account_quant_saving(self, n_blocks: int) -> None:
        self.stats.quant_bytes_saved += (
            n_blocks * (self.dense_block_bytes - self.block_bytes))

    # -- Stage -----------------------------------------------------------
    def stage(self, descriptors: List[StagedDescriptor]) -> None:
        for d in descriptors:
            self._staged.append(d)
        self.stats.held_descriptors += len(descriptors)

    # -- per-transfer fences (async movement engine, DESIGN.md §11) ------
    def fence_issue(self, payload) -> int:
        """Park one issued-but-unsynchronized transfer. The payload is
        engine-owned (device gather handles + destination host slots);
        the transport only tracks ordering and the audit counters."""
        fid = self._next_fence
        self._next_fence += 1
        self._fences[fid] = payload
        return fid

    def fence_drain_all(self) -> List:
        """Take every pending transfer, FIFO. Each drained fence is by
        construction a readback that happened LATER than its issue point,
        so the count lands in ``deferred_readbacks``."""
        if not self._fences:
            return []
        payloads = list(self._fences.values())
        self._fences.clear()
        self.stats.deferred_readbacks += len(payloads)
        return payloads

    def fences_pending(self) -> int:
        return len(self._fences)

    def note_dispatch_overlap(self) -> None:
        """Engine hook at device-dispatch time: a step issued while >= 1
        swap-out fence is still pending means the transfer is genuinely
        overlapping compute (the latency-hiding audit)."""
        if self._fences:
            self.stats.overlap_steps += 1

    def account_staging_reuse(self, nbytes: int) -> None:
        self.stats.staging_reuse_bytes += int(nbytes)

    # -- swap groups (host tier, DESIGN.md §8) ---------------------------
    def account_swap(self, pairs: Sequence[Tuple[int, int]], *,
                     direction: str) -> List[Tuple[int, int, int]]:
        """Coalesce one swap transfer's copy pairs into merged groups and
        fold them into the transport audit. ``direction`` is 'out'
        (device -> host) or 'in' (host -> device). Returns the merged
        (src_start, dst_start, len) groups — the copy program the engine
        executes as ONE gather/scatter per swap event."""
        assert direction in ("out", "in")
        groups = merge_swap_pairs(pairs)
        nbytes = len(pairs) * self.block_bytes
        self.stats.swap_groups += len(groups)
        self.stats.swap_unmerged += len(pairs)
        if direction == "out":
            self.stats.swap_out_bytes += nbytes
        else:
            self.stats.swap_in_bytes += nbytes
        self._account_quant_saving(len(pairs))
        return groups

    # -- COW tail copies (prefix cache, DESIGN.md §9) --------------------
    def account_cow(self, pairs: Sequence[Tuple[int, int]]) -> None:
        """Fold one admit round's COW tail copies ((src_block, dst_block),
        device -> device) into the audit as their own group kind. The
        engine executes the pairs as ONE batched padded copy per pool key;
        ``cow_groups`` additionally records how many contiguous-in-both-
        coordinates runs the pairs form (same layout-quality audit basis
        as ``swap_groups``), not a separately executed schedule."""
        self.stats.cow_groups += len(merge_swap_pairs(list(pairs)))
        self.stats.cow_blocks += len(pairs)
        self.stats.cow_bytes += len(pairs) * self.block_bytes
        self._account_quant_saving(len(pairs))

    # -- Reduce ----------------------------------------------------------
    def reduce(self, window_blocks: Sequence[int], *,
               far_blocks: int = 0, merging: bool = True
               ) -> Tuple[List[Tuple[int, int, int]], int]:
        """Merge one slot's window into trains. Returns (trains, n_groups).

        merging=False models the unmerged path (one group per block) for the
        paper's with/without-descriptor-merging comparison.
        """
        blocks = [b for b in window_blocks if b > 0]
        # fold staged descriptors whose age exceeded delta or that are
        # adjacent to the window tail (merge into the tail train)
        ready = []
        still = []
        for d in self._staged:
            d.age += 1
            if d.age >= self.delta or (blocks and d.block == blocks[-1] + 1):
                ready.append(d)
            else:
                still.append(d)
        self._staged = still
        self.stats.held_descriptors -= len(ready)
        blocks = blocks + [d.block for d in ready]

        trains = self.merge_slot(blocks, merging=merging)

        groups = len(trains) + (1 if far_blocks else 0)
        self.stats.steps += 1
        self.stats.total_groups += groups
        self.stats.max_groups = max(self.stats.max_groups, groups)
        self.stats.total_bytes += (len(blocks) * self.block_bytes
                                   + far_blocks * self.block_bytes)
        self.stats.unmerged_groups += len(blocks) + far_blocks
        self._account_quant_saving(len(blocks) + far_blocks)
        return trains, groups

    def merge_slot(self, blocks: Sequence[int], *, merging: bool = True
                   ) -> List[Tuple[int, int, int]]:
        """Pure train merge for one slot's window blocks — no stats, no staged
        descriptor aging. The engine's window-block cache calls this only when
        a slot's window actually changed (admit/trim/alias/reserve/slide) and
        accounts the cached result each step via ``account_batch``."""
        if merging:
            trains = merge_runs(blocks)
            # split over-tau trains so each group stays a burst-sized DMA;
            # tau is a threshold, not a cap — modest overshoot is expected
            # (paper: ~132 KiB average vs 128 KiB threshold)
            max_blocks = max(1, (2 * self.tau) // self.block_bytes)
            out = []
            for s, ln, dst in trains:
                while ln > max_blocks:
                    out.append((s, max_blocks, dst))
                    s, ln, dst = s + max_blocks, ln - max_blocks, dst + max_blocks
                out.append((s, ln, dst))
            return out
        return [(b, 1, i) for i, b in enumerate(blocks)]

    # -- batched Reduce (vectorized descriptor assembly) -----------------
    def reduce_batch(self, blocks_per_row: List[Sequence[int]], *,
                     merging: bool = True) -> List[List[Tuple[int, int, int]]]:
        """Merge many slots' windows at once (no stats side effects).

        Staged descriptors are a per-slot aging mechanism and are not folded
        here; callers that stage() must use the per-slot reduce() path."""
        return [self.merge_slot(b, merging=merging) for b in blocks_per_row]

    def account_batch(self, n_blocks, n_groups, far_flags) -> None:
        """Accumulate one engine step's per-slot DMA stats (numpy vectors over
        the ACTIVE slots). Matches reduce()'s accounting exactly: one stats
        'step' per active slot per engine step."""
        n_blocks = np.asarray(n_blocks, np.int64)
        n_groups = np.asarray(n_groups, np.int64)
        far_flags = np.asarray(far_flags, np.int64)
        if n_blocks.size == 0:
            return
        groups = n_groups + far_flags
        self.stats.steps += int(n_blocks.size)
        self.stats.total_groups += int(groups.sum())
        self.stats.max_groups = max(self.stats.max_groups, int(groups.max()))
        self.stats.total_bytes += int((n_blocks + far_flags).sum()) * self.block_bytes
        self.stats.unmerged_groups += int((n_blocks + far_flags).sum())
        self._account_quant_saving(int((n_blocks + far_flags).sum()))

    def unaccount_slot(self, n_blocks: int, n_groups: int,
                       far_flag: int = 0) -> None:
        """Reverse ONE slot-step of ``account_batch`` (lagged-EOS overshoot
        reconcile, DESIGN.md §13): a pipelined dispatch accounted this
        slot's window DMA before the readback revealed the request had
        already stopped, so subtract exactly what that dispatch added.
        ``max_groups`` is a monotone high-water mark and is left alone."""
        blocks = int(n_blocks) + int(far_flag)
        self.stats.steps -= 1
        self.stats.total_groups -= int(n_groups) + int(far_flag)
        self.stats.total_bytes -= blocks * self.block_bytes
        self.stats.unmerged_groups -= blocks
        self._account_quant_saving(-blocks)

    def fill_train_arrays(self, trains: List[Tuple[int, int, int]],
                          train_start: np.ndarray, train_len: np.ndarray,
                          train_dst: np.ndarray, row: int) -> None:
        """Write one slot's trains into the descriptor arrays (fixed MT).

        Overflow (more trains than MT — only possible under stress, e.g. many
        staged folds or adversarial fragmentation): the first MT-1 trains are
        emitted normally and the last slot becomes an explicit DEGENERATE
        sentinel ``train_start = -1`` whose ``train_len`` is the total block
        count of the folded remainder. The remainder trains are generally not
        physically contiguous, so no single (start, len) copy describes them;
        the sentinel tells the device to fall back to per-block gather via
        ``block_table`` for those window positions (``train_dst`` marks the
        first such position). Coverage accounting (sum of train_len) is
        preserved and the event is counted in ``TransportStats``."""
        mt = train_start.shape[1]
        train_len[row, :] = 0
        if len(trains) <= mt:
            for j, (s, ln, dst) in enumerate(trains):
                train_start[row, j] = s
                train_len[row, j] = ln
                train_dst[row, j] = dst
            return
        for j, (s, ln, dst) in enumerate(trains[:mt - 1]):
            train_start[row, j] = s
            train_len[row, j] = ln
            train_dst[row, j] = dst
        rest = trains[mt - 1:]
        train_start[row, mt - 1] = -1            # degenerate-schedule sentinel
        train_len[row, mt - 1] = sum(t[1] for t in rest)
        train_dst[row, mt - 1] = rest[0][2]
        self.stats.train_overflows += 1

    def fill_train_arrays_batch(self, trains_per_row, train_start, train_len,
                                train_dst, rows) -> None:
        """Write several slots' trains at once (rows aligned with trains)."""
        for row, trains in zip(rows, trains_per_row):
            self.fill_train_arrays(trains, train_start, train_len, train_dst,
                                   row)
