"""Continuous-batching scheduler for the fixed-width decode engine.

Slots are the fixed batch rows of the compiled decode step. The scheduler
admits waiting requests into free slots, retires EOS bursts, and proposes the
lookahead set S_{t+1} (slots whose next token crosses a block boundary) that
the pager BLOCKALIGNs and reserves with tail-adjacent placement (prefetch-1).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


@dataclass
class Request:
    rid: int
    prompt: np.ndarray               # int32 token ids
    gen_len: int                     # max tokens to generate (budget cap)
    arrival: float = 0.0             # for trace replay
    prefix_of: Optional[int] = None  # rid whose prompt prefix this shares
    prefix_len: int = 0
    # data-dependent EOS (DESIGN.md §13): any generated token in this set
    # ends the request. Only meaningful with sampled decode (greedy=False);
    # an empty set keeps the legacy pure-budget semantics bit-exact.
    stop_tokens: tuple = ()
    # runtime
    generated: List[int] = field(default_factory=list)
    prompt_pos: int = 0              # tokens of prompt already consumed
    start_step: int = -1
    finish_step: int = -1
    first_token_step: int = -1
    # structural emission count, stamped at DISPATCH time. In legacy greedy
    # mode EOS is the gen_len budget, so retirement is host-predictable from
    # this counter alone; in sampled mode EOS is data-dependent and ALL
    # retirement happens at readback, where overshot dispatches are scrubbed
    # back out of this counter (DESIGN.md §13). Token VALUES land in
    # ``generated`` at readback, ``pipeline_depth`` steps later (DESIGN.md §3)
    emitted: int = 0
    eos_hit: bool = False            # a stop token ended this request
    finish_reason: str = ""          # "stop" | "budget" (set at retirement)
    # --- preemption / host-tier resume (DESIGN.md §8) ---
    swap_sid: int = -1               # pager session holding swapped-out KV
    resume_len: int = 0              # tokens in cache at preemption
    resume_last_token: int = 0       # host token mirror for the resume step
    preempt_count: int = 0
    # worst-case device blocks the admission watermark charged for this
    # request (DESIGN.md §8/§9). Stamped by the engine's kv_ok gate so
    # retirement releases EXACTLY what admission committed — with prefix
    # aliasing (§9) the charge is reduced by the shared blocks, which a
    # recompute at retire time could no longer reproduce (the cache may
    # have changed since).
    committed_blocks: int = 0


@dataclass
class SlotState:
    rid: int = -1                    # -1 = free
    sid: int = -1                    # pager session


class AdmissionPolicy:
    """Pluggable admission ORDERING (DESIGN.md §14): ``order`` returns the
    sequence in which the waiting queue is considered this admit round —
    head-of-line blocking then applies in that order. The default identity
    policy preserves the seed FIFO semantics bit-for-bit; the serving
    gateway installs an SLO-priority policy. Only the fresh-admission
    queue is reordered: preempted resumes keep their no-overtaking FIFO
    (a resume's working set shrinks only when others finish)."""

    def order(self, waiting: List["Request"], now: float) -> List["Request"]:
        return waiting


class Scheduler:
    def __init__(self, n_slots: int, policy: Optional[AdmissionPolicy] = None):
        self.n_slots = n_slots
        self.slots = [SlotState() for _ in range(n_slots)]
        self.waiting: List[Request] = []
        self.preempted: List[Request] = []   # resume-priority queue (§8)
        self.requests: Dict[int, Request] = {}
        self.finished: List[Request] = []
        self.policy = policy
        self._next_sid = 0
        self.step_idx = 0
        # admission-stall counters: one count per admit() call whose queue
        # head was arrived but could not be placed, keyed by why — lets
        # operators split compute-bound (no_slot) from memory-bound
        # (kv_watermark) queueing in serve.py's audit. "round_barrier"
        # counts admit() calls held by round-based batching (an arrived
        # request existed but the engine's --no-continuous-batching
        # barrier kept every free slot idle, DESIGN.md §15) — identically
        # 0 under step-level (continuous) admission.
        self.admit_blocked = {"no_slot": 0, "kv_watermark": 0,
                              "round_barrier": 0}

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.requests[req.rid] = req
        self.waiting.append(req)

    def free_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.rid < 0]

    def active_slots(self) -> List[int]:
        return [i for i, s in enumerate(self.slots) if s.rid >= 0]

    def admit(self, now: float = float("inf"), kv_ok=None,
              hold: bool = False) -> List[tuple]:
        """Admit waiting requests (arrival <= now) into free slots.
        Returns [(slot, request, sid)] admissions.

        This is the STEP-LEVEL admission primitive (DESIGN.md §15): the
        engine calls it at the top of every decode step, so a slot freed
        by EOS retirement, cancel or preemption is refilled on the very
        next step. ``hold=True`` is the round-based baseline's barrier:
        nothing is admitted, but an arrived request held back by the
        barrier counts one ``admit_blocked['round_barrier']`` stall so
        the A/B cost is auditable.

        Preempted requests resume FIRST (FIFO within the preempted queue)
        and reuse their swapped-out pager session (``req.swap_sid``); fresh
        requests behind a blocked resume wait with it (no overtaking — a
        resume's working set shrinks only when others finish, so letting
        fresh admissions in front would starve it).

        ``kv_ok(req, is_resume)``, when given, is the KV watermark gate
        (DESIGN.md §8): a request that has a slot available but fails the
        gate is counted in ``admit_blocked['kv_watermark']``; a request
        with no free slot counts in ``admit_blocked['no_slot']``.

        An installed ``self.policy`` (§14) reorders the FRESH queue's
        consideration order; with the default identity policy the walk —
        and every counter — is bit-identical to the seed FIFO."""
        if hold:
            if any(r.arrival <= now for r in self.preempted) \
                    or any(r.arrival <= now for r in self.waiting):
                self.admit_blocked["round_barrier"] += 1
            return []
        out = []
        free = self.free_slots()
        blocked = False
        for queue, is_resume in ((self.preempted, True), (self.waiting, False)):
            view = queue if (is_resume or self.policy is None) \
                else self.policy.order(queue, now)
            taken = set()
            for req in view:
                if blocked or req.arrival > now:
                    continue
                if not free:
                    self.admit_blocked["no_slot"] += 1
                    blocked = True
                    continue
                if kv_ok is not None and not kv_ok(req, is_resume):
                    self.admit_blocked["kv_watermark"] += 1
                    blocked = True
                    continue
                slot = free.pop(0)
                if is_resume:
                    sid = req.swap_sid
                else:
                    sid = self._next_sid
                    self._next_sid += 1
                    req.start_step = self.step_idx
                self.slots[slot] = SlotState(rid=req.rid, sid=sid)
                out.append((slot, req, sid))
                taken.add(id(req))
            if taken:
                queue[:] = [r for r in queue if id(r) not in taken]
        return out

    def preempt(self, slot: int) -> Request:
        """Evict a live request from its slot into the resume queue
        (DESIGN.md §8). The caller (engine) swaps its KV to the host tier
        first and stamps ``swap_sid`` / ``resume_len`` /
        ``resume_last_token``; generation state (prompt_pos, emitted,
        generated) rides on the Request itself, so resume needs no
        recompute."""
        st = self.slots[slot]
        req = self.requests[st.rid]
        req.preempt_count += 1
        self.preempted.append(req)
        self.slots[slot] = SlotState()
        return req

    def retire(self, slot: int) -> Request:
        st = self.slots[slot]
        req = self.requests[st.rid]
        req.finish_step = self.step_idx
        self.finished.append(req)
        self.slots[slot] = SlotState()
        return req

    def request_at(self, slot: int) -> Optional[Request]:
        st = self.slots[slot]
        return self.requests.get(st.rid) if st.rid >= 0 else None

    def next_token(self, slot: int, last_sampled: int) -> int:
        """Token to feed this step: prompt token while prefilling, else the
        previously sampled token."""
        req = self.request_at(slot)
        if req.prompt_pos < len(req.prompt):
            tok = int(req.prompt[req.prompt_pos])
            req.prompt_pos += 1
            return tok
        return last_sampled

    def is_prefilling(self, slot: int) -> bool:
        req = self.request_at(slot)
        return req is not None and req.prompt_pos < len(req.prompt)

    def chunk_remaining(self, slot: int) -> int:
        """Prompt tokens available for chunked ingestion — everything except
        the LAST prompt token, which always goes through the decode step."""
        req = self.request_at(slot)
        if req is None:
            return 0
        return max(0, len(req.prompt) - 1 - req.prompt_pos)

    def consume_prompt_chunk(self, slot: int, max_tokens: int) -> np.ndarray:
        """Take up to max_tokens prompt tokens for the prefill executor."""
        req = self.request_at(slot)
        n = min(max_tokens, self.chunk_remaining(slot))
        toks = np.asarray(req.prompt[req.prompt_pos:req.prompt_pos + n],
                          np.int32)
        req.prompt_pos += n
        return toks

    def note_emit(self, slot: int) -> bool:
        """Account one decode emission structurally (at dispatch time); True
        if the request hits its gen_len budget with this token. The token
        value itself is appended to ``generated`` at readback. Sampled mode
        ignores the return value — detected-EOS retirement is readback-side
        (DESIGN.md §13) and the engine scrubs any budget overshoot there."""
        req = self.request_at(slot)
        if req.first_token_step < 0:
            req.first_token_step = self.step_idx
        req.emitted += 1
        return req.emitted >= req.gen_len

    def record_output(self, slot: int, token: int) -> bool:
        """Record a generated token; True if the request hit EOS — a
        per-request stop token (data-dependent, DESIGN.md §13) or the
        gen_len budget cap."""
        req = self.request_at(slot)
        if req.first_token_step < 0:
            req.first_token_step = self.step_idx
        req.generated.append(token)
        req.emitted = len(req.generated)
        if req.stop_tokens and token in req.stop_tokens:
            req.eos_hit = True
            req.finish_reason = "stop"
            return True
        if len(req.generated) >= req.gen_len:
            req.finish_reason = "budget"
            return True
        return False
