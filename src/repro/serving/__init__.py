"""Serving layer (DESIGN.md §14): typed API + asyncio gateway over the
data-parallel engine lanes.

``repro.serving.api`` is imported eagerly (pure dataclasses — the engine
itself imports ``AuditReport`` from there); the gateway / router /
admission / build modules import the engine, so they load lazily via
PEP 562 to keep ``core.engine -> serving.api`` acyclic.
"""
from repro.serving.api import (BATCH, INTERACTIVE, REJECT_QUEUE_FULL,
                               REJECT_REASONS, REJECT_SLO_SHED, SLO_CLASSES,
                               STANDARD, AdmissionRejected, AuditReport,
                               GenerationRequest, RequestResult, SLOClass,
                               TokenEvent)

_LAZY = {
    "Gateway": ("repro.serving.gateway", "Gateway"),
    "AdmissionController": ("repro.serving.admission", "AdmissionController"),
    "SLOOrderPolicy": ("repro.serving.admission", "SLOOrderPolicy"),
    "AffinityRouter": ("repro.serving.router", "AffinityRouter"),
    "RoundRobinRouter": ("repro.serving.router", "RoundRobinRouter"),
    "build": ("repro.serving.factory", "build"),
}

__all__ = ["AdmissionRejected", "AuditReport", "GenerationRequest",
           "RequestResult", "SLOClass", "TokenEvent", "SLO_CLASSES",
           "INTERACTIVE", "STANDARD", "BATCH", "REJECT_QUEUE_FULL",
           "REJECT_SLO_SHED", "REJECT_REASONS"] + list(_LAZY)


def __getattr__(name):
    if name in _LAZY:
        import importlib
        modname, attr = _LAZY[name]
        return getattr(importlib.import_module(modname), attr)
    raise AttributeError(f"module 'repro.serving' has no attribute {name!r}")
