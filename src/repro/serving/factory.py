"""One engine-construction factory for the whole repo (DESIGN.md §14).

``serve.build_engine`` / ``serve.build_lanes`` / ``benchmarks.common
.engine`` used to each carry their own copy of the config -> params ->
EngineConfig -> KVRMEngine plumbing; all three now delegate here.

``build(...)`` returns a list of engine lanes (or a :class:`Gateway`
over them with ``gateway=True``):

  * ``mesh_spec='DxM'`` — D device-backed lanes, M-way tensor-parallel
    each (DESIGN.md §4), params initialized once and placed per lane;
  * ``lanes=N`` — N logical single-device lanes sharing one param set
    (the gateway's data-parallel shape on a single device; composes with
    ``prefix_cache=True`` for affinity routing, unlike sharded lanes);
  * default — one single-device engine, seed-exact.

Params are cached per (arch, seed): ``init_params`` from the same
PRNGKey is deterministic, so sharing the cache across engines keeps
memory flat and every construction site bitwise-identical.
"""
from __future__ import annotations

from typing import List, Optional

import jax

from repro.configs import get_reduced
from repro.core.engine import EngineConfig, KVRMEngine
from repro.launch import mesh as mesh_mod
from repro.models import registry

_PARAM_CACHE = {}


def cached_params(arch: str, seed: int = 0):
    key = (arch, seed)
    if key not in _PARAM_CACHE:
        cfg = get_reduced(arch)
        _PARAM_CACHE[key] = registry.init_params(jax.random.PRNGKey(seed), cfg)
    return _PARAM_CACHE[key]


def build(arch: str = "qwen2.5-32b", *, mode: str = "paged_merge",
          batch: int = 8, max_seq: int = 256, near_window: Optional[int] = None,
          block_tokens: int = 8, pool_budget: float = 1.0, seed: int = 0,
          mesh_spec: str = "1x1", lanes: int = 0, mesh=None, params=None,
          gateway: bool = False, gateway_kw: Optional[dict] = None,
          **engine_kw):
    """Build engine lanes (list) or a Gateway over them.

    ``mesh`` (a jax Mesh or None) overrides ``mesh_spec`` for a single
    explicitly-placed lane; ``lanes=N > 0`` replicates the single-device
    lane N times (mutually exclusive with a multi-lane mesh_spec).
    Remaining ``engine_kw`` pass through to :class:`EngineConfig`.
    """
    cfg = get_reduced(arch)
    # legacy spelling from pre-§14 call sites
    pool_budget = engine_kw.pop("pool_budget_frac", pool_budget)
    if params is None:
        params = cached_params(arch, seed)
    if mesh is not None:
        meshes: List = [mesh]
    else:
        meshes = mesh_mod.lane_meshes_for_spec(mesh_spec)
    if lanes:
        if len(meshes) != 1:
            raise ValueError(
                f"lanes={lanes} needs a single-lane mesh_spec, got "
                f"{mesh_spec!r} ({len(meshes)} lanes)")
        meshes = meshes * lanes
    engines = [KVRMEngine(cfg, params, EngineConfig(
        mode=mode, batch=batch, max_seq=max_seq, near_window=near_window,
        block_tokens=block_tokens, pool_budget_frac=pool_budget,
        mesh=lane_mesh, **engine_kw)) for lane_mesh in meshes]
    if gateway:
        from repro.serving.gateway import Gateway
        return Gateway(engines, **(gateway_kw or {}))
    return engines
