"""SLO-aware admission control + per-tenant fairness (DESIGN.md §14).

The gateway consults :class:`AdmissionController` at SUBMIT time — before
a request touches any engine queue — so backpressure is typed and
immediate (:class:`~repro.serving.api.AdmissionRejected`), extending the
engine's §8 ``admit_blocked_*`` stall taxonomy with the two gateway-level
outcomes:

  * ``queue_full`` — a tenant's queue bound or the gateway's global
    outstanding bound is exhausted (fairness backpressure);
  * ``slo_shed``   — the request's SLO class already has
    ``max_queue_depth`` requests queued-or-running per lane, so queueing
    deeper provably blows its TTFT target; shedding it NOW keeps the
    admitted population's tail inside the SLO (the paper's goodput
    argument applied above the engines).

:class:`SLOOrderPolicy` is the matching scheduler plug-in: once requests
reach an engine's waiting queue, admission considers them in
(SLO priority, arrival) order instead of raw FIFO.
"""
from __future__ import annotations

from typing import Dict

from repro.core.scheduler import AdmissionPolicy
from repro.serving.api import (REJECT_QUEUE_FULL, REJECT_SLO_SHED,
                               AdmissionRejected, GenerationRequest)


class SLOOrderPolicy(AdmissionPolicy):
    """Engine-side admission ordering: (SLO priority, arrival, rid).
    Requests without a gateway-attached SLO sort as standard priority."""

    def order(self, waiting, now):
        return sorted(waiting,
                      key=lambda r: (getattr(r, "slo_priority", 1),
                                     r.arrival, r.rid))


class AdmissionController:
    """Bounded-queue admission with per-class accounting.

    ``check`` raises :class:`AdmissionRejected` or records the admit; the
    per-class admitted / rejected / shed counters feed the gateway audit.
    ``unbounded=True`` disables every bound (the naive admit-everything
    A/B baseline in bench_gateway_slo).
    """

    def __init__(self, *, tenant_queue_max: int = 64,
                 max_outstanding: int = 0, unbounded: bool = False):
        self.tenant_queue_max = tenant_queue_max
        self.max_outstanding = max_outstanding   # 0 = lanes*batch*4 at check
        self.unbounded = unbounded
        self.admitted_per_class: Dict[str, int] = {}
        self.rejected_per_class: Dict[str, int] = {}
        self.shed_per_class: Dict[str, int] = {}

    # ------------------------------------------------------------------
    def check(self, greq: GenerationRequest, gw) -> None:
        cls = greq.slo.name
        if not self.unbounded:
            cap = self.max_outstanding or 4 * sum(
                e.e.batch for e in gw.engines)
            if gw.outstanding() >= cap:
                self.rejected_per_class[cls] = \
                    self.rejected_per_class.get(cls, 0) + 1
                raise AdmissionRejected(
                    REJECT_QUEUE_FULL,
                    f"gateway outstanding {gw.outstanding()} >= {cap}")
            if gw.tenant_queued(greq.tenant) >= self.tenant_queue_max:
                self.rejected_per_class[cls] = \
                    self.rejected_per_class.get(cls, 0) + 1
                raise AdmissionRejected(
                    REJECT_QUEUE_FULL,
                    f"tenant '{greq.tenant}' queue >= {self.tenant_queue_max}")
            depth_cap = greq.slo.max_queue_depth * len(gw.engines)
            if depth_cap and gw.outstanding_in_class(cls) >= depth_cap:
                self.shed_per_class[cls] = self.shed_per_class.get(cls, 0) + 1
                raise AdmissionRejected(
                    REJECT_SLO_SHED,
                    f"class '{cls}' depth >= {depth_cap}: TTFT target "
                    f"{greq.slo.ttft_target_ms}ms unmeetable from this deep")
        self.admitted_per_class[cls] = self.admitted_per_class.get(cls, 0) + 1

    # ------------------------------------------------------------------
    def stats(self) -> dict:
        tot = lambda d: sum(d.values())
        return {
            "admitted": tot(self.admitted_per_class),
            "admit_rejected_queue_full": tot(self.rejected_per_class),
            "admit_shed_slo": tot(self.shed_per_class),
            "admitted_per_class": dict(self.admitted_per_class),
            "rejected_per_class": dict(self.rejected_per_class),
            "shed_per_class": dict(self.shed_per_class),
        }
