"""Asyncio serving gateway over the data-parallel engine lanes (§14).

``Gateway.submit(GenerationRequest)`` returns an async iterator of
:class:`~repro.serving.api.TokenEvent` — per-token streaming fed by the
engine's readback-side token hook, so an event fires exactly when the
token VALUE becomes host-visible (never flattered by pipeline lag, §3,
and never for a scrubbed overshoot emission, §13). Admission is checked
synchronously at submit (typed :class:`AdmissionRejected` backpressure);
accepted requests flow through per-(lane, tenant) FIFO queues that a
single background pump task releases round-robin across tenants, then
steps every busy lane — the open-system analogue of the closed-loop
``run_lanes`` replay driver, over the very same engines.

The pump is cooperative: one engine step per lane per cycle with an
``await asyncio.sleep(0)`` between cycles, so streams and submitters
interleave with decode. All timestamps are on one gateway clock
(``perf_counter`` - t0, overridable for tests).
"""
from __future__ import annotations

import asyncio
import time
from collections import deque
from typing import AsyncIterator, Deque, Dict, List, Optional, Tuple

from repro.serving.admission import AdmissionController, SLOOrderPolicy
from repro.serving.api import (GenerationRequest, RequestResult, TokenEvent)
from repro.serving.router import AffinityRouter, RoundRobinRouter  # noqa: F401


class Gateway:
    def __init__(self, engines: List, *, router=None, admission=None,
                 now_fn=None, slo_order: bool = True):
        assert engines, "gateway needs at least one engine lane"
        self.engines = list(engines)
        self.router = router if router is not None else AffinityRouter()
        self.admission = admission if admission is not None \
            else AdmissionController()
        self._t0 = time.perf_counter()
        self._now = now_fn or (lambda: time.perf_counter() - self._t0)
        # per-(lane, tenant) FIFO queues + a round-robin tenant cursor:
        # release order interleaves tenants so one chatty tenant cannot
        # starve the rest of a lane (fairness, §14)
        self._queues: List[Dict[str, Deque]] = [dict() for _ in self.engines]
        self._rr: List[int] = [0 for _ in self.engines]
        self._events: Dict[int, asyncio.Queue] = {}
        self._greqs: Dict[int, Tuple[GenerationRequest, object, int]] = {}
        self._meta: Dict[int, dict] = {}
        self._results: Dict[int, RequestResult] = {}
        self._out_class: Dict[str, int] = {}
        self.cancelled = 0
        self._wake = asyncio.Event()
        self._task: Optional[asyncio.Task] = None
        self._closed = False
        for lane, eng in enumerate(self.engines):
            eng.token_hook = self._hook_for(lane)
            if slo_order and eng.sched.policy is None:
                eng.sched.policy = SLOOrderPolicy()

    # ------------------------------------------------------------------
    # public API: submit / stream / cancel / drain
    # ------------------------------------------------------------------
    def now(self) -> float:
        return self._now()

    def submit(self, greq: GenerationRequest) -> AsyncIterator[TokenEvent]:
        """Admit (or raise :class:`AdmissionRejected`) and return the
        request's token-event stream. Admission is decided HERE, at submit
        time — a returned iterator is a promise the request will run."""
        assert greq.rid not in self._greqs, f"rid {greq.rid} reused"
        self._ensure_pump()
        self.admission.check(greq, self)     # raises AdmissionRejected
        now = self._now()
        arrival = now if greq.arrival is None else float(greq.arrival)
        req = greq.to_request(arrival=arrival)
        req.slo_priority = greq.slo.priority     # for SLOOrderPolicy
        depths = [self._lane_depth(i) for i in range(len(self.engines))]
        lane = self.router.route(greq, self.engines, depths)
        self._events[greq.rid] = asyncio.Queue()
        self._greqs[greq.rid] = (greq, req, lane)
        self._meta[greq.rid] = {"arrival": arrival, "first_t": None,
                                "last_t": None, "n": 0}
        self._out_class[greq.slo.name] = \
            self._out_class.get(greq.slo.name, 0) + 1
        self._queues[lane].setdefault(greq.tenant, deque()).append(req)
        self._wake.set()
        return self._stream(greq.rid)

    async def generate(self, greq: GenerationRequest) -> RequestResult:
        """Submit and consume the whole stream; returns the terminal
        :class:`RequestResult`."""
        async for _ev in self.submit(greq):
            pass
        return self._results[greq.rid]

    def cancel(self, rid: int) -> bool:
        """Cancel a submitted request: dequeue it if the gateway still
        holds it, else hand off to ``engine.cancel`` (which drains the
        dispatch pipeline and retires through the one EOS path, freeing
        every pager block). A synthetic terminal TokenEvent closes the
        stream either way. False if unknown or already finished."""
        info = self._greqs.get(rid)
        if info is None or rid in self._results:
            return False
        greq, req, lane = info
        q = self._queues[lane].get(greq.tenant)
        if q is not None and req in q:
            q.remove(req)
            req.finish_reason = "cancelled"
        elif not self.engines[lane].cancel(rid):
            return False
        self.cancelled += 1
        self._finish(rid, req, synthetic=True)
        return True

    async def drain(self) -> None:
        """Wait until every accepted request has finished (the pump keeps
        stepping; this just parks until the outstanding count hits 0)."""
        self._ensure_pump()
        while self.outstanding() > 0:
            self._wake.set()
            await asyncio.sleep(0)

    def result(self, rid: int) -> Optional[RequestResult]:
        return self._results.get(rid)

    def close(self) -> None:
        self._closed = True
        self._wake.set()
        for eng in self.engines:
            eng.token_hook = None

    # ------------------------------------------------------------------
    # admission introspection (AdmissionController reads these)
    # ------------------------------------------------------------------
    def outstanding(self) -> int:
        return len(self._greqs) - len(self._results)

    def outstanding_in_class(self, cls: str) -> int:
        return self._out_class.get(cls, 0)

    def tenant_queued(self, tenant: str) -> int:
        """Requests a tenant has pending BEFORE a decode slot: gateway
        tenant queues plus the engines' own waiting queues (§15 —
        continuous release hands arrived requests to the engine
        immediately, so the engine-side queue must count toward the
        per-tenant admission bound or it would never trip)."""
        gw_q = sum(len(qs[tenant]) for qs in self._queues if tenant in qs)
        eng_rids = set()
        for eng in self.engines:
            eng_rids.update(r.rid for r in eng.sched.waiting)
        eng_q = sum(1 for rid, (greq, _r, _l) in self._greqs.items()
                    if greq.tenant == tenant and rid in eng_rids)
        return gw_q + eng_q

    def _lane_depth(self, lane: int) -> int:
        eng = self.engines[lane]
        return (sum(len(q) for q in self._queues[lane].values())
                + len(eng.sched.waiting) + len(eng.sched.preempted)
                + len(eng.sched.active_slots()))

    # ------------------------------------------------------------------
    # event plumbing
    # ------------------------------------------------------------------
    def _hook_for(self, lane: int):
        def hook(req, tok: int, fin: bool):
            rid = req.rid
            meta = self._meta.get(rid)
            if meta is None:
                return                   # not a gateway request (replay path)
            t = self._now()
            if meta["first_t"] is None:
                meta["first_t"] = t
            meta["last_t"] = t
            meta["n"] += 1
            ev = TokenEvent(rid=rid, token=tok, index=len(req.generated) - 1,
                            t=t, finished=fin,
                            finish_reason=req.finish_reason if fin else "")
            q = self._events.get(rid)
            if q is not None:
                q.put_nowait(ev)
            if fin:
                self._finish(rid, req)
        return hook

    def _finish(self, rid: int, req, synthetic: bool = False) -> None:
        if rid in self._results:
            return
        greq, _req, _lane = self._greqs[rid]
        meta = self._meta[rid]
        first, last, n = meta["first_t"], meta["last_t"], meta["n"]
        ttft = (first - meta["arrival"]) if first is not None else float("inf")
        tpot = ((last - first) / (n - 1)) if n and n > 1 else 0.0
        self._results[rid] = RequestResult(
            rid=rid, tokens=tuple(req.generated),
            finish_reason=req.finish_reason or "cancelled",
            slo=greq.slo, tenant=greq.tenant, arrival=meta["arrival"],
            ttft_s=max(0.0, ttft) if ttft != float("inf") else ttft,
            tpot_s=max(0.0, tpot),
            finish_t=last if last is not None else self._now())
        self._out_class[greq.slo.name] -= 1
        if synthetic:
            q = self._events.get(rid)
            if q is not None:
                q.put_nowait(TokenEvent(
                    rid=rid, token=-1, index=len(req.generated), t=self._now(),
                    finished=True, finish_reason="cancelled"))

    async def _stream(self, rid: int) -> AsyncIterator[TokenEvent]:
        q = self._events[rid]
        while True:
            ev = await q.get()
            yield ev
            if ev.finished:
                break
        self._events.pop(rid, None)

    # ------------------------------------------------------------------
    # the pump: release fairly, step busy lanes, flush idle tails
    # ------------------------------------------------------------------
    def _ensure_pump(self) -> None:
        if self._task is None or self._task.done():
            self._closed = False
            self._task = asyncio.get_running_loop().create_task(self._pump())

    def _release(self, lane: int, now: float) -> None:
        """Move arrived requests from this lane's tenant queues into the
        engine, one per tenant per pass (round-robin). With a
        continuous-batching engine (§15) every arrived request is released
        immediately — the engine refills freed slots at each step, so
        holding requests at the gateway would only re-introduce the round
        barrier one layer up; RR order still decides WHO goes first. With
        the round-based baseline the release keeps the engine's waiting
        queue shallower than its slot width — deep enough to keep slots
        fed, shallow enough that gateway fairness ordering (not engine
        FIFO) decides who goes next."""
        eng = self.engines[lane]
        qs = self._queues[lane]
        tenants = sorted(qs)
        cap = float("inf") if eng.e.continuous_batching else eng.e.batch
        while tenants and len(eng.sched.waiting) < cap:
            released = False
            for k in range(len(tenants)):
                t = tenants[(self._rr[lane] + k) % len(tenants)]
                q = qs[t]
                if q and q[0].arrival <= now:
                    eng.submit(q.popleft())
                    self._rr[lane] = (self._rr[lane] + k + 1) % len(tenants)
                    released = True
                    break
            if not released:
                break

    def _pending(self) -> int:
        return sum(len(q) for qs in self._queues for q in qs.values())

    async def _pump(self) -> None:
        while not self._closed:
            now = self._now()
            busy = False
            for lane, eng in enumerate(self.engines):
                self._release(lane, now)
                if eng.sched.waiting or eng.sched.preempted \
                        or eng.sched.active_slots():
                    eng.step(now=now)
                    busy = True
            if busy:
                await asyncio.sleep(0)   # let streams/submitters run
                continue
            for eng in self.engines:
                eng.flush()              # tail of the pipeline -> last events
            if self._pending():
                await asyncio.sleep(0.002)   # queued, not yet arrived
                continue
            self._wake.clear()
            if self._closed:
                break
            await self._wake.wait()

    # ------------------------------------------------------------------
    # audit
    # ------------------------------------------------------------------
    def audit(self) -> dict:
        """Gateway-level counters + per-lane engine audits. Keys extend
        the operator taxonomy documented in docs/OPERATIONS.md §14."""
        out = {"lanes": len(self.engines), **self.admission.stats(),
               "cancelled": self.cancelled,
               "affinity_hits": getattr(self.router, "affinity_hits", 0),
               "affinity_misses": getattr(self.router, "affinity_misses", 0),
               "completed": len(self._results),
               "lane_audits": [e.audit() for e in self.engines]}
        return out

    def slo_stats(self) -> dict:
        """Per-class SLO attainment over finished requests: goodput is
        attained completions / offered (admitted + rejected + shed), the
        headline gateway metric."""
        per = {}
        for r in self._results.values():
            d = per.setdefault(r.slo.name, {"served": 0, "attained": 0,
                                            "cancelled": 0})
            if r.finish_reason == "cancelled":
                d["cancelled"] += 1
                continue
            d["served"] += 1
            d["attained"] += int(r.slo_attained)
        adm = self.admission
        out = {}
        for cls, d in per.items():
            offered = (adm.admitted_per_class.get(cls, 0)
                       + adm.rejected_per_class.get(cls, 0)
                       + adm.shed_per_class.get(cls, 0))
            out[cls] = {**d, "offered": offered,
                        "goodput": d["attained"] / max(1, offered)}
        return out
