"""Typed public serving API (DESIGN.md §14).

Everything a client (or another subsystem) exchanges with the serving
layer is a frozen dataclass defined here — this module is the contract:

  * ``SLOClass``          — named latency class with TTFT/TPOT targets and
                            an admission queue-depth bound (the shed knob).
  * ``GenerationRequest`` — what a client submits (tenant + SLO attached).
  * ``TokenEvent``        — one streamed token, stamped on the gateway
                            clock at READBACK time (value known, §3/§13).
  * ``RequestResult``     — terminal summary: token stream + TTFT/TPOT.
  * ``AdmissionRejected`` — typed backpressure, extending the §8
                            ``admit_blocked_*`` taxonomy with the
                            gateway-level reasons (queue_full / slo_shed).
  * ``AuditReport``       — the engine audit as a frozen field-per-counter
                            dataclass; ``engine.audit()`` returns
                            ``audit_report().as_dict()`` so every legacy
                            dict call site keeps working while the FIELD
                            LIST is the single documented source of truth
                            (tests/test_docs.py diffs it against
                            docs/OPERATIONS.md).

Import discipline: this module may import ``core.scheduler`` (for the
``Request`` conversion) but never ``core.engine`` — the engine imports
``AuditReport`` from here, and the serving package keeps its heavier
modules (gateway/build) lazy in ``__init__`` to stay acyclic.
"""
from __future__ import annotations

from dataclasses import asdict, dataclass, fields
from typing import Optional, Tuple

import numpy as np

from repro.core.scheduler import Request

# ---------------------------------------------------------------------------
# SLO classes
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class SLOClass:
    """A named latency class. ``ttft_target_ms`` / ``tpot_target_ms`` define
    SLO attainment (goodput counts a request iff BOTH hold);
    ``max_queue_depth`` bounds how many requests of this class may be
    queued-or-running per lane before admission sheds new ones — the
    deterministic stand-in for "queueing deeper than this cannot meet the
    TTFT target" (0 = never shed on depth)."""
    name: str
    ttft_target_ms: float
    tpot_target_ms: float
    max_queue_depth: int = 0
    priority: int = 1                # lower = admitted/ordered first


INTERACTIVE = SLOClass("interactive", ttft_target_ms=500.0,
                       tpot_target_ms=100.0, max_queue_depth=8, priority=0)
STANDARD = SLOClass("standard", ttft_target_ms=2_000.0,
                    tpot_target_ms=200.0, max_queue_depth=0, priority=1)
BATCH = SLOClass("batch", ttft_target_ms=60_000.0,
                 tpot_target_ms=1_000.0, max_queue_depth=0, priority=2)

SLO_CLASSES = {c.name: c for c in (INTERACTIVE, STANDARD, BATCH)}


# ---------------------------------------------------------------------------
# request / event / result
# ---------------------------------------------------------------------------


@dataclass(frozen=True, eq=False)
class GenerationRequest:
    """A client-side generation request. ``arrival`` is optional trace time
    on the gateway clock (None = stamped at submit); ``stop_tokens`` needs
    sampled decode, exactly as on the engine ``Request``."""
    rid: int
    prompt: Tuple[int, ...]
    gen_len: int
    tenant: str = "default"
    slo: SLOClass = STANDARD
    arrival: Optional[float] = None
    stop_tokens: Tuple[int, ...] = ()

    def to_request(self, arrival: float) -> Request:
        return Request(rid=self.rid,
                       prompt=np.asarray(self.prompt, np.int32),
                       gen_len=int(self.gen_len), arrival=float(arrival),
                       stop_tokens=tuple(self.stop_tokens))


@dataclass(frozen=True)
class TokenEvent:
    """One streamed token. ``t`` is the gateway clock at readback (the
    moment the token VALUE is host-visible — never flattered by pipeline
    lag, DESIGN.md §3); ``index`` is the token's position in the stream.
    The terminal event has ``finished=True`` and a ``finish_reason``
    ("stop" | "budget" | "cancelled"); a cancel emits a synthetic terminal
    event with ``token = -1`` and ``index`` of the next unproduced token."""
    rid: int
    token: int
    index: int
    t: float
    finished: bool = False
    finish_reason: str = ""


@dataclass(frozen=True)
class RequestResult:
    """Terminal request summary, built by the gateway from the event
    stream. TTFT is first-token time minus arrival; TPOT is the mean
    inter-token gap (first token excluded — satellite fix: first-token
    wait no longer folds into per-token latency)."""
    rid: int
    tokens: Tuple[int, ...]
    finish_reason: str
    slo: SLOClass
    tenant: str
    arrival: float
    ttft_s: float
    tpot_s: float
    finish_t: float

    @property
    def slo_attained(self) -> bool:
        if self.finish_reason == "cancelled":
            return False
        return (self.ttft_s * 1e3 <= self.slo.ttft_target_ms
                and self.tpot_s * 1e3 <= self.slo.tpot_target_ms)


# ---------------------------------------------------------------------------
# typed backpressure
# ---------------------------------------------------------------------------

# gateway-level extension of the engine's §8 admission-stall taxonomy
# (admit_blocked_no_slot / admit_blocked_kv_watermark): rejects happen at
# SUBMIT time, before a request ever reaches an engine queue
REJECT_QUEUE_FULL = "queue_full"     # tenant or gateway bound hit
REJECT_SLO_SHED = "slo_shed"         # class queue depth says TTFT unmeetable
REJECT_REASONS = (REJECT_QUEUE_FULL, REJECT_SLO_SHED)


class AdmissionRejected(Exception):
    """Typed admission backpressure: ``reason`` is one of
    ``REJECT_REASONS``; ``detail`` names the exhausted bound."""

    def __init__(self, reason: str, detail: str = ""):
        assert reason in REJECT_REASONS, reason
        self.reason = reason
        self.detail = detail
        super().__init__(f"{reason}: {detail}" if detail else reason)


# ---------------------------------------------------------------------------
# audit report
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AuditReport:
    """``engine.audit()`` as a typed, frozen, field-per-counter report.

    The field list IS the audit contract: ``KVRMEngine.audit_report()``
    constructs this from its counter dict, so a counter added engine-side
    without a field here raises ``TypeError`` in every audit call
    (self-checking both ways), and tests/test_docs.py diffs these field
    names against the docs/OPERATIONS.md counter tables. Grouping mirrors
    the DESIGN.md sections each block of counters witnesses."""
    # --- executor / step invariants (§3) ---
    mode: str
    steps: int
    compilations: int
    prefill_compilations: int
    pipeline_depth: int
    prefill_chunk: int
    prefill_chunks_run: int
    single_commit_per_step: bool
    frames_committed: int
    submit_share: float
    frame_commit_us: float
    # --- descriptor transport (§2) ---
    dma_groups_per_step: float
    avg_dma_bytes: float
    unmerged_groups_per_step: float
    train_overflows: int
    # --- KV memory ---
    reserved_kv_bytes: int
    active_kv_bytes: int
    peak_reserved_kv: int
    peak_active_kv: int
    # --- host KV tier + preemption (§8) ---
    host_pool_blocks: int
    host_blocks_used: int
    host_blocks_peak: int
    preemptions: int
    swap_out_blocks: int
    swap_in_blocks: int
    swap_refusals: int
    swap_groups: int
    swap_bytes: int
    swap_out_bytes: int
    swap_in_bytes: int
    avg_swap_group_blocks: float
    # --- work-skipping kernels (§12) ---
    kernel_skip_extent: bool
    kernel_blocks_total: int
    kernel_blocks_skipped: int
    # --- sampled decode + detected EOS (§13) ---
    greedy: bool
    eos_detected: int
    eos_overshoot_tokens: int
    eos_reconciled_blocks: int
    # --- async movement engine (§11) ---
    async_movement: bool
    overlap_steps: int
    deferred_readbacks: int
    staging_reuse_bytes: int
    swap_stall_ms: float
    # --- admission stalls (§8) + gateway cancel (§14) ---
    admit_blocked_no_slot: int
    admit_blocked_kv_watermark: int
    cancelled: int
    # --- step-level (continuous) batching (§15) ---
    continuous_batching: bool
    continuous_admits: int
    slot_idle_steps_saved: int
    admit_blocked_round_barrier: int
    # --- radix prefix cache (§9) ---
    prefix_cache: bool
    prefix_hits: int
    prefix_misses: int
    prefix_tokens_reused: int
    prefix_cached_blocks: int
    prefix_evicted_blocks: int
    cow_copies: int
    cow_groups: int
    cow_bytes: int
    # --- quantized KV tier (§10) ---
    kv_dtype: str
    quant_bytes_saved: int
    quant_scale_bytes: int
    # --- SPMD decode (§4) ---
    mesh: Optional[str]
    tp_degree: int
    kv_shards: int
    per_device_reserved_kv: int
    per_device_active_kv: int
    per_device_peak_reserved_kv: int

    def as_dict(self) -> dict:
        """Legacy dict view — every pre-§14 ``eng.audit()[key]`` call site
        keeps working unchanged."""
        return asdict(self)

    @classmethod
    def field_names(cls) -> tuple:
        return tuple(f.name for f in fields(cls))
