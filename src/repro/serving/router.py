"""Lane routing policies for the serving gateway (DESIGN.md §14).

A router picks which data-parallel engine lane serves a request. The
load-bearing policy is :class:`AffinityRouter`: it checks the request's
block-aligned prompt prefix against each lane's radix prefix index (§9)
— ``PrefixCache.match`` is a pure longest-prefix lookup over committed
block chunks, so peeking is free and side-effect-less — and routes to the
lane already holding the longest hit. Shared-system-prompt tenants
therefore concentrate on the lane whose cache is warm instead of
round-robin smearing every prefix into every lane's cache.
"""
from __future__ import annotations

from typing import List

import numpy as np


class RoundRobinRouter:
    """Stripe requests over lanes in submit order — the naive baseline
    (and the exact lane placement of the closed-loop replay path)."""

    def __init__(self):
        self._i = 0

    def route(self, greq, engines: List, depths: List[int]) -> int:
        lane = self._i % len(engines)
        self._i += 1
        return lane


class LeastLoadedRouter:
    """Route to the lane with the fewest queued-or-running requests;
    ties break to the lowest lane index (deterministic)."""

    def route(self, greq, engines: List, depths: List[int]) -> int:
        return int(np.argmin(depths))


class AffinityRouter:
    """Prefix-cache-affinity routing: peek every lane's radix index with
    the prompt's block-aligned prefix chunks and route to the deepest
    match (>= one block); cold prompts fall back to least-loaded.
    ``affinity_hits`` / ``affinity_misses`` count routed-by-match vs
    fallback decisions (surfaced in the gateway audit)."""

    def __init__(self):
        self.affinity_hits = 0
        self.affinity_misses = 0
        self._fallback = LeastLoadedRouter()

    def route(self, greq, engines: List, depths: List[int]) -> int:
        prompt = np.asarray(greq.prompt, np.int32)
        best_lane, best_tok = -1, 0
        for lane, eng in enumerate(engines):
            pc = getattr(eng, "prefix_cache", None)
            if pc is None:
                continue
            tok = pc.match(prompt).tokens
            if tok > best_tok:
                best_lane, best_tok = lane, tok
        if best_lane >= 0 and best_tok >= engines[best_lane].bt:
            self.affinity_hits += 1
            return best_lane
        self.affinity_misses += 1
        return self._fallback.route(greq, engines, depths)
