"""Mamba2 (SSD) mixer + Zamba2 hybrid (mamba backbone, shared attention block).

Training/prefill uses the chunked SSD scan (O(S*Q) memory, exact); decode is a
single-step state recurrence. Zamba2 structure: a single SHARED attention
block (one weight set) applied every ``shared_attn_every`` layers; each
application site has its own KV cache, paged by KV-RM like any attention
layer. SSM/conv states are O(1) per session and live in engine state slots.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import common as cm

CHUNK = 128


# ---------------------------------------------------------------------------
# Mamba2 mixer
# ---------------------------------------------------------------------------

def mamba2_init(key, cfg: ModelConfig):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    nheads = di // cfg.ssm_headdim
    convw = cfg.ssm_conv
    conv_ch = di + 2 * n
    ks = jax.random.split(key, 4)
    return {
        "ln": cm.norm_init(d),
        # in_proj -> [z (di), x (di), B (n), C (n), dt (nheads)]
        "in_proj": cm.dense_init(ks[0], d, 2 * di + 2 * n + nheads),
        "conv_w": (jax.random.normal(ks[1], (convw, conv_ch), jnp.float32)
                   / math.sqrt(convw)).astype(cm.DTYPE),
        "conv_b": jnp.zeros((conv_ch,), cm.DTYPE),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, nheads)).astype(jnp.float32),
        "D": jnp.ones((nheads,), jnp.float32),
        "dt_bias": jnp.zeros((nheads,), jnp.float32),
        "out_norm": cm.norm_init(di),
        "out_proj": cm.dense_init(ks[2], di, d),
    }


def _split_in_proj(cfg, zxbcdt):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    nheads = di // cfg.ssm_headdim
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di:di + di + 2 * n]
    dt = zxbcdt[..., di + di + 2 * n:]
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv over time. xbc: (B,S,C), w: (W,C)."""
    W = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (W - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i][None, None, :] for i in range(W))
    return jax.nn.silu(out + b)


def _ssd_chunked(x, dt, A, B_in, C_in):
    """Chunked SSD scan. x:(B,S,H,P) dt:(B,S,H) A:(H,) B_in/C_in:(B,S,N).
    Returns y:(B,S,H,P), final state (B,H,P,N)."""
    Bb, S, H, P = x.shape
    N = B_in.shape[-1]
    Q = CHUNK if S % CHUNK == 0 else (S if S <= CHUNK else 1)
    nc = S // Q
    xc = x.reshape(Bb, nc, Q, H, P)
    dtc = dt.reshape(Bb, nc, Q, H)
    Bc = B_in.reshape(Bb, nc, Q, N)
    Cc = C_in.reshape(Bb, nc, Q, N)

    la = -jnp.exp(A)[None, None, None, :] * dtc                 # (B,nc,Q,H) log decay
    S_cum = jnp.cumsum(la, axis=2)                              # inclusive

    def chunk_step(h, inp):
        xq, dtq, bq, cq, sq, laq = inp                          # per chunk
        # intra: M[t,s] = (C_t . B_s) exp(S_t - S_s) [s<=t]
        cb = jnp.einsum("btn,bsn->bts", cq, bq)                 # (B,Q,Q)
        dec = sq[:, :, None, :] - sq[:, None, :, :]             # (B,Q,Q,H) S_t - S_s
        mask = jnp.tril(jnp.ones((Q, Q), bool))[None, :, :, None]
        # clamp BEFORE exp so masked-out positions don't leak NaN grads
        dec = jnp.where(mask, dec, 0.0)
        m = jnp.where(mask, jnp.exp(dec), 0.0) * cb[..., None]
        y_intra = jnp.einsum("btsh,bshp->bthp", m, xq * dtq[..., None])
        # inter: y_t += exp(S_t) C_t . h
        y_inter = jnp.einsum("btn,bhpn,bth->bthp", cq, h, jnp.exp(sq))
        # state update: h' = exp(S_Q) h + sum_s exp(S_Q - S_s) dt_s x_s B_s^T
        w = jnp.exp(sq[:, -1:, :] - sq)                         # (B,Q,H)
        dx = xq * (dtq * w)[..., None]                          # (B,Q,H,P)
        h = (jnp.exp(sq[:, -1, :])[:, :, None, None] * h
             + jnp.einsum("bqhp,bqn->bhpn", dx, bq))
        return h, y_intra + y_inter

    h0 = jnp.zeros((Bb, H, P, N), jnp.float32)
    xs = tuple(t.transpose(1, 0, *range(2, t.ndim)) for t in
               (xc.astype(jnp.float32), dtc, Bc.astype(jnp.float32),
                Cc.astype(jnp.float32), S_cum, la))
    h, ys = jax.lax.scan(chunk_step, h0, xs)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(Bb, S, H, P)
    return y, h


def mamba2_forward(p, cfg: ModelConfig, x):
    """Full-sequence mixer. x: (B,S,d) -> (B,S,d)."""
    Bb, S, d = x.shape
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    H = di // cfg.ssm_headdim
    P = cfg.ssm_headdim
    h = cm.rmsnorm(p["ln"], x, cfg.norm_eps)
    z, xbc, dt = _split_in_proj(cfg, cm.dense(p["in_proj"], h))
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    xin = xbc[..., :di].reshape(Bb, S, H, P)
    B_in, C_in = xbc[..., di:di + n], xbc[..., di + n:]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    y, _ = _ssd_chunked(xin.astype(jnp.float32), dt, p["A_log"], B_in, C_in)
    y = y + p["D"][None, None, :, None] * xin.astype(jnp.float32)
    y = y.reshape(Bb, S, di).astype(x.dtype) * jax.nn.silu(z)
    y = cm.rmsnorm(p["out_norm"], y, cfg.norm_eps)
    return x + cm.dense(p["out_proj"], y)


def mamba2_decode(p, cfg: ModelConfig, x, conv_state, ssd_state):
    """Single-token decode. x: (B,d); conv_state: (B, W-1, C); ssd_state:
    (B,H,P,N). Returns (out (B,d), conv_state, ssd_state)."""
    Bb, d = x.shape
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    H, P = di // cfg.ssm_headdim, cfg.ssm_headdim
    h = cm.rmsnorm(p["ln"], x, cfg.norm_eps)
    z, xbc, dt = _split_in_proj(cfg, cm.dense(p["in_proj"], h))
    # conv over [state, current]
    seq = jnp.concatenate([conv_state, xbc[:, None, :]], axis=1)  # (B,W,C)
    conv = jax.nn.silu((seq * p["conv_w"][None]).sum(axis=1) + p["conv_b"])
    new_conv_state = seq[:, 1:, :]
    xin = conv[..., :di].reshape(Bb, H, P).astype(jnp.float32)
    B_in = conv[..., di:di + n].astype(jnp.float32)
    C_in = conv[..., di + n:].astype(jnp.float32)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,H)
    a = jnp.exp(-jnp.exp(p["A_log"])[None] * dt)                  # (B,H)
    ssd_state = (a[:, :, None, None] * ssd_state
                 + jnp.einsum("bhp,bn->bhpn", xin * dt[..., None], B_in))
    y = jnp.einsum("bhpn,bn->bhp", ssd_state, C_in)
    y = y + p["D"][None, :, None] * xin
    y = y.reshape(Bb, di).astype(x.dtype) * jax.nn.silu(z)
    y = cm.rmsnorm(p["out_norm"], y, cfg.norm_eps)
    return x + cm.dense(p["out_proj"], y), new_conv_state, ssd_state


# ---------------------------------------------------------------------------
# Zamba2 hybrid model
# ---------------------------------------------------------------------------

def _shared_attn_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    return {
        "ln1": cm.norm_init(cfg.d_model), "attn": cm.gqa_init(ks[0], cfg),
        "ln2": cm.norm_init(cfg.d_model),
        "mlp": cm.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_act),
    }


def n_attn_sites(cfg: ModelConfig) -> int:
    return cfg.n_layers // cfg.shared_attn_every


def init_params(key, cfg: ModelConfig):
    every = cfg.shared_attn_every
    sites = n_attn_sites(cfg)
    rem = cfg.n_layers - sites * every
    k_emb, k_m, k_r, k_a, k_out = jax.random.split(key, 5)
    params = {
        "embed": (jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model), jnp.float32)
                  * 0.02).astype(cm.DTYPE),
        "shared_attn": _shared_attn_init(k_a, cfg),
        # (sites, every, ...) stacked mamba params
        "mamba": jax.vmap(lambda k: cm.stack_layers(
            partial(mamba2_init, cfg=cfg), k, every))(jax.random.split(k_m, sites)),
        "ln_f": cm.norm_init(cfg.d_model),
        "lm_head": cm.dense_init(k_out, cfg.d_model, cfg.vocab_size),
    }
    if rem:
        params["mamba_tail"] = cm.stack_layers(
            partial(mamba2_init, cfg=cfg), k_r, rem)
    return params


def _attn_full(shared, cfg, x, positions, window=None):
    h = cm.rmsnorm(shared["ln1"], x, cfg.norm_eps)
    x = x + cm.gqa_full(shared["attn"], cfg, h, positions, window=window)
    h = cm.rmsnorm(shared["ln2"], x, cfg.norm_eps)
    return x + cm.mlp_apply(shared["mlp"], h, cfg.mlp_act)


def forward(params, cfg: ModelConfig, tokens, *, remat: bool = False,
            attn_window: int | None = None, extra_embeds=None):
    """tokens (B,S) -> logits. attn_window bounds the shared-attention width
    (KV-RM near-window semantics for long context)."""
    B, S = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def site(x, site_params):
        x = cm.constrain_batch(x)
        x = _attn_full(params["shared_attn"], cfg, x, positions, window=attn_window)
        def inner(x, mp):
            return mamba2_forward(mp, cfg, x), None
        body = jax.checkpoint(inner) if remat else inner
        x, _ = jax.lax.scan(body, x, site_params)
        return x, None

    body = jax.checkpoint(site) if remat else site
    x, _ = jax.lax.scan(body, x, params["mamba"])
    if "mamba_tail" in params:
        def inner(x, mp):
            return mamba2_forward(mp, cfg, x), None
        x, _ = jax.lax.scan(inner, x, params["mamba_tail"])
    x = cm.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return cm.dense(params["lm_head"], x)


def decode_step(params, cfg: ModelConfig, tokens, pools, descr):
    """pools: k/v (SITES,P,BT,KV,hd) paged per attention site; conv_state
    (L,B,W-1,C); ssd_state (L,B,H,P,N). States are engine-slot-resident."""
    B = tokens.shape[0]
    sv = cfg.serving
    every = cfg.shared_attn_every
    sites = n_attn_sites(cfg)
    x = params["embed"][tokens]
    fu0 = jnp.zeros((B, descr.far_table.shape[1]), jnp.float32)

    def attn_decode(x, pk, pv, fu):
        # site pools are READ-ONLY in the scan (deltas scattered after)
        h = cm.rmsnorm(params["shared_attn"]["ln1"], x, cfg.norm_eps)
        q, k, v = cm.gqa_qkv(params["shared_attn"]["attn"], cfg, h[:, None, :],
                             descr.seq_lens[:, None])
        q, k, v = q[:, 0], k[:, 0], v[:, 0]
        o, futil = ops.paged_decode_attention(
            q, pk, pv, descr.block_table, descr.window_base, descr.seq_lens,
            descr.slot_active, near_window=sv.near_window, cur_k=k, cur_v=v,
            skip_extent=sv.skip_extent)
        x = x + cm.dense(params["shared_attn"]["attn"]["wo"], o.reshape(B, -1))
        h = cm.rmsnorm(params["shared_attn"]["ln2"], x, cfg.norm_eps)
        x = x + cm.mlp_apply(params["shared_attn"]["mlp"], h, cfg.mlp_act)
        return x, k, v, fu + futil

    def site_block(carry, xs):
        x, fu = carry
        site_params, pk, pv, conv_s, ssd_s = xs
        x, k_new, v_new, fu = attn_decode(x, pk, pv, fu)
        def inner(carry2, mp_states):
            x = carry2
            mp, cs, ss = mp_states
            x, cs, ss = mamba2_decode(mp, cfg, x, cs, ss)
            return x, (cs, ss)
        x, (conv_s, ssd_s) = jax.lax.scan(inner, x, (site_params, conv_s, ssd_s))
        return (x, fu), (k_new, v_new, conv_s, ssd_s)

    L = cfg.n_layers
    conv = pools["conv_state"]
    ssd = pools["ssd_state"]
    body_n = sites * every
    conv_sites = conv[:body_n].reshape(sites, every, *conv.shape[1:])
    ssd_sites = ssd[:body_n].reshape(sites, every, *ssd.shape[1:])
    (x, fu), ys = jax.lax.scan(
        site_block, (x, fu0),
        (params["mamba"], pools["k"], pools["v"], conv_sites, ssd_sites))
    k_new, v_new, conv_out, ssd_out = ys
    pk = ops.pool_write_stacked(pools["k"], k_new, descr.write_block,
                                descr.write_offset, descr.slot_active)
    pv = ops.pool_write_stacked(pools["v"], v_new, descr.write_block,
                                descr.write_offset, descr.slot_active)
    conv_out = conv_out.reshape(body_n, *conv.shape[1:])
    ssd_out = ssd_out.reshape(body_n, *ssd.shape[1:])
    if "mamba_tail" in params:
        def inner(carry2, mp_states):
            x = carry2
            mp, cs, ss = mp_states
            x, cs, ss = mamba2_decode(mp, cfg, x, cs, ss)
            return x, (cs, ss)
        x, (ct, st) = jax.lax.scan(inner, x,
                                   (params["mamba_tail"], conv[body_n:], ssd[body_n:]))
        conv_out = jnp.concatenate([conv_out, ct], axis=0)
        ssd_out = jnp.concatenate([ssd_out, st], axis=0)
    x = cm.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = cm.dense(params["lm_head"], x)
    new_pools = {"k": pk, "v": pv, "conv_state": conv_out, "ssd_state": ssd_out}
    return logits, new_pools, fu / max(1, sites)
