"""Model registry: uniform (init_params / forward / decode_step) API per
family, plus decode-pool geometry shared by the engine and the dry-run.
"""
from __future__ import annotations

from types import SimpleNamespace
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models import encdec, mamba2, moe, transformer, xlstm

_FAMILY = {
    "dense": transformer,
    "vlm": transformer,
    "moe": moe,
    "hybrid": mamba2,
    "ssm": xlstm,
    "encdec": encdec,
}


def get_module(cfg: ModelConfig):
    return _FAMILY[cfg.family]


def init_params(key, cfg: ModelConfig):
    return get_module(cfg).init_params(key, cfg)


def forward(params, cfg: ModelConfig, tokens, **kw):
    return get_module(cfg).forward(params, cfg, tokens, **kw)


def decode_step(params, cfg: ModelConfig, tokens, pools, descr, **kw):
    return get_module(cfg).decode_step(params, cfg, tokens, pools, descr, **kw)


def supports_chunked_prefill(cfg: ModelConfig) -> bool:
    """Families with a fixed-shape chunked prompt-ingestion executor
    (DESIGN.md §3). Others fall back to token-at-a-time prefill through the
    decode step (sequential-state families need per-token recurrences; encdec
    and MLA chunk executors are future work)."""
    return cfg.family in ("dense", "vlm") and hasattr(get_module(cfg),
                                                      "prefill_chunk")


def prefill_chunk(params, cfg: ModelConfig, pools, descr, **kw):
    """Ingest one prompt chunk for one slot (see transformer.prefill_chunk)."""
    return get_module(cfg).prefill_chunk(params, cfg, pools, descr, **kw)


# ---------------------------------------------------------------------------
# decode pool geometry
# ---------------------------------------------------------------------------

def decode_pool_shapes(cfg: ModelConfig, *, batch: int, num_blocks: int,
                       block_tokens: int, max_chunks: int = 0,
                       enc_len: int = 0, dtype=cm.DTYPE) -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for every decode-state buffer (dry-run + engine).

    num_blocks = physical blocks in the (per-shard) pool; block 0 is scratch.
    max_chunks > 0 enables far-view buffers.
    """
    L, KV, HD = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    s = jax.ShapeDtypeStruct
    fam = cfg.family
    if fam in ("dense", "vlm"):
        pools = {"k": s((L, num_blocks, block_tokens, KV, HD), dtype),
                 "v": s((L, num_blocks, block_tokens, KV, HD), dtype)}
        if max_chunks:
            pools["far_k"] = s((L, batch, max_chunks, KV, HD), dtype)
            pools["far_v"] = s((L, batch, max_chunks, KV, HD), dtype)
    elif fam == "moe":
        if cfg.use_mla:
            R = cfg.kv_lora_rank + cfg.qk_rope_dim
            pools = {"lat": s((L, num_blocks, block_tokens, R), dtype)}
            if max_chunks:
                pools["far_lat"] = s((L, batch, max_chunks, R), dtype)
        else:
            pools = {"k": s((L, num_blocks, block_tokens, KV, HD), dtype),
                     "v": s((L, num_blocks, block_tokens, KV, HD), dtype)}
            if max_chunks:
                pools["far_k"] = s((L, batch, max_chunks, KV, HD), dtype)
                pools["far_v"] = s((L, batch, max_chunks, KV, HD), dtype)
    elif fam == "hybrid":
        sites = mamba2.n_attn_sites(cfg)
        di = cfg.ssm_expand * cfg.d_model
        H, P, N = di // cfg.ssm_headdim, cfg.ssm_headdim, cfg.ssm_state
        conv_ch = di + 2 * N
        pools = {
            "k": s((sites, num_blocks, block_tokens, KV, HD), dtype),
            "v": s((sites, num_blocks, block_tokens, KV, HD), dtype),
            "conv_state": s((L, batch, cfg.ssm_conv - 1, conv_ch), dtype),
            "ssd_state": s((L, batch, H, P, N), jnp.float32),
        }
    elif fam == "ssm":
        d, di, H = cfg.d_model, cfg.ssm_expand * cfg.d_model, cfg.n_heads
        hd_m, hd_s = di // H, d // H
        pairs = xlstm.n_pairs(cfg)
        pools = {
            "m": {"C": s((pairs, batch, H, hd_m, hd_m), jnp.float32),
                  "n": s((pairs, batch, H, hd_m), jnp.float32),
                  "m": s((pairs, batch, H), jnp.float32),
                  "conv": s((pairs, batch, cfg.ssm_conv - 1, di), dtype)},
            "s": {"h": s((pairs, batch, H, hd_s), jnp.float32),
                  "c": s((pairs, batch, H, hd_s), jnp.float32),
                  "n": s((pairs, batch, H, hd_s), jnp.float32),
                  "m": s((pairs, batch, H, hd_s), jnp.float32)},
        }
    elif fam == "encdec":
        Ld = cfg.dec_layers
        pools = {"k": s((Ld, num_blocks, block_tokens, KV, HD), dtype),
                 "v": s((Ld, num_blocks, block_tokens, KV, HD), dtype),
                 "cross_k": s((Ld, batch, enc_len, KV, HD), dtype),
                 "cross_v": s((Ld, batch, enc_len, KV, HD), dtype),
                 "enc_len": s((batch,), jnp.int32)}
    else:
        raise ValueError(fam)
    return pools


def init_decode_pools(cfg: ModelConfig, **kw):
    shapes = decode_pool_shapes(cfg, **kw)
    return jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype), shapes)


def uses_paged_kv(cfg: ModelConfig) -> bool:
    return cfg.family != "ssm"


# ---------------------------------------------------------------------------
# tensor-parallel decode (DESIGN.md §4)
# ---------------------------------------------------------------------------

def decode_pool_partition_specs(cfg: ModelConfig, pools):
    """PartitionSpecs sharding each decode pool's kv-head axis over `model`
    (replicated where the family has no head-sharded paged payload — MLA
    latents are shared by all heads, sequential states stay local)."""
    from repro.distributed import sharding as shd
    return shd.engine_pool_specs(cfg, pools)


def tp_decode_error(cfg: ModelConfig, tp: int) -> str | None:
    """Why this config can NOT shard decode tp-ways (None = compatible).

    GQA-paged families need kv-heads (and q heads, to preserve the per-shard
    n_rep grouping) divisible by the TP degree; MLA pages a head-shared
    latent, so the pool itself stays replicated and only head projections
    shard (n_heads divisibility enforced by spec sanitation instead)."""
    if tp <= 1:
        return None
    if cfg.family == "ssm":
        return None                     # recurrent states; specs sanitize
    if cfg.use_mla:
        return None
    if cfg.n_kv_heads % tp:
        return (f"TP degree {tp} must divide n_kv_heads={cfg.n_kv_heads} "
                f"for kv-head-sharded decode")
    if cfg.n_heads % tp:
        return (f"TP degree {tp} must divide n_heads={cfg.n_heads} "
                f"(per-shard GQA n_rep grouping)")
    return None


def paged_payload_bytes_per_token(cfg: ModelConfig) -> int:
    """Bytes/token/layer moved through the paged pool (bf16)."""
    return cfg.kv_width * 2


def n_paged_layers(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return mamba2.n_attn_sites(cfg)
    if cfg.family == "encdec":
        return cfg.dec_layers
    if cfg.family == "ssm":
        return 0
    return cfg.n_layers
