"""Model registry: uniform (init_params / forward / decode_step) API per
family, plus decode-pool geometry shared by the engine and the dry-run.
"""
from __future__ import annotations

from types import SimpleNamespace
from typing import Dict

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as cm
from repro.models import encdec, mamba2, moe, transformer, xlstm

_FAMILY = {
    "dense": transformer,
    "vlm": transformer,
    "moe": moe,
    "hybrid": mamba2,
    "ssm": xlstm,
    "encdec": encdec,
}


def get_module(cfg: ModelConfig):
    return _FAMILY[cfg.family]


def init_params(key, cfg: ModelConfig):
    return get_module(cfg).init_params(key, cfg)


def forward(params, cfg: ModelConfig, tokens, **kw):
    return get_module(cfg).forward(params, cfg, tokens, **kw)


def decode_step(params, cfg: ModelConfig, tokens, pools, descr, **kw):
    return get_module(cfg).decode_step(params, cfg, tokens, pools, descr, **kw)


def supports_chunked_prefill(cfg: ModelConfig) -> bool:
    """Families with a fixed-shape chunked prompt-ingestion executor
    (DESIGN.md §3). Others fall back to token-at-a-time prefill through the
    decode step (sequential-state families need per-token recurrences; encdec
    and MLA chunk executors are future work)."""
    return cfg.family in ("dense", "vlm") and hasattr(get_module(cfg),
                                                      "prefill_chunk")


def prefill_chunk(params, cfg: ModelConfig, pools, descr, **kw):
    """Ingest one prompt chunk for one slot (see transformer.prefill_chunk)."""
    return get_module(cfg).prefill_chunk(params, cfg, pools, descr, **kw)


# ---------------------------------------------------------------------------
# decode pool geometry
# ---------------------------------------------------------------------------

# quantized KV-block storage tier (DESIGN.md §10): kv_dtype -> storage dtype.
# Narrow dtypes add sibling per-(layer, block, kv-head) f32 scale pools that
# the pager moves in lockstep with their data blocks (same block index).
KV_DTYPES = {"bf16": cm.DTYPE,
             "fp8_e4m3": jnp.float8_e4m3fn,
             "int8": jnp.int8}


def kv_storage_dtype(kv_dtype: str):
    if kv_dtype not in KV_DTYPES:
        raise ValueError(f"unknown kv_dtype {kv_dtype!r}; "
                         f"one of {sorted(KV_DTYPES)}")
    return KV_DTYPES[kv_dtype]


def quant_decode_error(cfg: ModelConfig, kv_dtype: str) -> str | None:
    """Why this config can NOT store KV quantized (None = compatible).
    Only the GQA-paged dense/vlm families have the quantizing write path
    and the dequantizing attention epilogue (DESIGN.md §10)."""
    if kv_dtype == "bf16":
        return None
    if kv_dtype not in KV_DTYPES:
        return f"unknown kv_dtype {kv_dtype!r}; one of {sorted(KV_DTYPES)}"
    if cfg.family not in ("dense", "vlm"):
        return (f"kv_dtype={kv_dtype!r} requires a GQA-paged family "
                f"(dense/vlm), not {cfg.family!r}")
    return None


def decode_pool_shapes(cfg: ModelConfig, *, batch: int, num_blocks: int,
                       block_tokens: int, max_chunks: int = 0,
                       enc_len: int = 0, dtype=cm.DTYPE,
                       kv_dtype: str = "bf16") -> Dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStructs for every decode-state buffer (dry-run + engine).

    num_blocks = physical blocks in the (per-shard) pool; block 0 is scratch.
    max_chunks > 0 enables far-view buffers. kv_dtype != 'bf16' stores k/v
    in a narrow dtype plus per-block per-head f32 scale pools (§10).
    """
    L, KV, HD = cfg.n_layers, cfg.n_kv_heads, cfg.head_dim
    s = jax.ShapeDtypeStruct
    fam = cfg.family
    err = quant_decode_error(cfg, kv_dtype)
    if err is not None:
        raise ValueError(err)
    if fam in ("dense", "vlm"):
        kv_store = dtype if kv_dtype == "bf16" else kv_storage_dtype(kv_dtype)
        pools = {"k": s((L, num_blocks, block_tokens, KV, HD), kv_store),
                 "v": s((L, num_blocks, block_tokens, KV, HD), kv_store)}
        if kv_dtype != "bf16":
            # sibling physical resource: indexed by the same block id, so
            # alias/COW/swap move data + scale chains atomically (§10)
            pools["k_scale"] = s((L, num_blocks, KV), jnp.float32)
            pools["v_scale"] = s((L, num_blocks, KV), jnp.float32)
        if max_chunks:
            pools["far_k"] = s((L, batch, max_chunks, KV, HD), dtype)
            pools["far_v"] = s((L, batch, max_chunks, KV, HD), dtype)
    elif fam == "moe":
        if cfg.use_mla:
            R = cfg.kv_lora_rank + cfg.qk_rope_dim
            pools = {"lat": s((L, num_blocks, block_tokens, R), dtype)}
            if max_chunks:
                pools["far_lat"] = s((L, batch, max_chunks, R), dtype)
        else:
            pools = {"k": s((L, num_blocks, block_tokens, KV, HD), dtype),
                     "v": s((L, num_blocks, block_tokens, KV, HD), dtype)}
            if max_chunks:
                pools["far_k"] = s((L, batch, max_chunks, KV, HD), dtype)
                pools["far_v"] = s((L, batch, max_chunks, KV, HD), dtype)
    elif fam == "hybrid":
        sites = mamba2.n_attn_sites(cfg)
        di = cfg.ssm_expand * cfg.d_model
        H, P, N = di // cfg.ssm_headdim, cfg.ssm_headdim, cfg.ssm_state
        conv_ch = di + 2 * N
        pools = {
            "k": s((sites, num_blocks, block_tokens, KV, HD), dtype),
            "v": s((sites, num_blocks, block_tokens, KV, HD), dtype),
            "conv_state": s((L, batch, cfg.ssm_conv - 1, conv_ch), dtype),
            "ssd_state": s((L, batch, H, P, N), jnp.float32),
        }
    elif fam == "ssm":
        d, di, H = cfg.d_model, cfg.ssm_expand * cfg.d_model, cfg.n_heads
        hd_m, hd_s = di // H, d // H
        pairs = xlstm.n_pairs(cfg)
        pools = {
            "m": {"C": s((pairs, batch, H, hd_m, hd_m), jnp.float32),
                  "n": s((pairs, batch, H, hd_m), jnp.float32),
                  "m": s((pairs, batch, H), jnp.float32),
                  "conv": s((pairs, batch, cfg.ssm_conv - 1, di), dtype)},
            "s": {"h": s((pairs, batch, H, hd_s), jnp.float32),
                  "c": s((pairs, batch, H, hd_s), jnp.float32),
                  "n": s((pairs, batch, H, hd_s), jnp.float32),
                  "m": s((pairs, batch, H, hd_s), jnp.float32)},
        }
    elif fam == "encdec":
        Ld = cfg.dec_layers
        pools = {"k": s((Ld, num_blocks, block_tokens, KV, HD), dtype),
                 "v": s((Ld, num_blocks, block_tokens, KV, HD), dtype),
                 "cross_k": s((Ld, batch, enc_len, KV, HD), dtype),
                 "cross_v": s((Ld, batch, enc_len, KV, HD), dtype),
                 "enc_len": s((batch,), jnp.int32)}
    else:
        raise ValueError(fam)
    return pools


def init_decode_pools(cfg: ModelConfig, **kw):
    shapes = decode_pool_shapes(cfg, **kw)
    return jax.tree.map(lambda sd: jnp.zeros(sd.shape, sd.dtype), shapes)


def uses_paged_kv(cfg: ModelConfig) -> bool:
    return cfg.family != "ssm"


# ---------------------------------------------------------------------------
# tensor-parallel decode (DESIGN.md §4)
# ---------------------------------------------------------------------------

def decode_pool_partition_specs(cfg: ModelConfig, pools):
    """PartitionSpecs sharding each decode pool's kv-head axis over `model`
    (replicated where the family has no head-sharded paged payload — MLA
    latents are shared by all heads, sequential states stay local)."""
    from repro.distributed import sharding as shd
    return shd.engine_pool_specs(cfg, pools)


def tp_decode_error(cfg: ModelConfig, tp: int) -> str | None:
    """Why this config can NOT shard decode tp-ways (None = compatible).

    GQA-paged families need kv-heads (and q heads, to preserve the per-shard
    n_rep grouping) divisible by the TP degree; MLA pages a head-shared
    latent, so the pool itself stays replicated and only head projections
    shard (n_heads divisibility enforced by spec sanitation instead)."""
    if tp <= 1:
        return None
    if cfg.family == "ssm":
        return None                     # recurrent states; specs sanitize
    if cfg.use_mla:
        return None
    if cfg.n_kv_heads % tp:
        return (f"TP degree {tp} must divide n_kv_heads={cfg.n_kv_heads} "
                f"for kv-head-sharded decode")
    if cfg.n_heads % tp:
        return (f"TP degree {tp} must divide n_heads={cfg.n_heads} "
                f"(per-shard GQA n_rep grouping)")
    return None


def paged_payload_bytes_per_token(cfg: ModelConfig,
                                  kv_dtype: str = "bf16") -> int:
    """Bytes/token/layer moved through the paged pool (storage width of
    ``kv_dtype``; per-block scale overhead is accounted separately —
    ``KVRMEngine.scale_bytes_per_block``, DESIGN.md §10)."""
    return cfg.kv_width * jnp.dtype(kv_storage_dtype(kv_dtype)).itemsize


def n_paged_layers(cfg: ModelConfig) -> int:
    if cfg.family == "hybrid":
        return mamba2.n_attn_sites(cfg)
    if cfg.family == "encdec":
        return cfg.dec_layers
    if cfg.family == "ssm":
        return 0
    return cfg.n_layers
