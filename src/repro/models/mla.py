"""Multi-head Latent Attention (DeepSeek-V3). The paged payload is the
compressed latent c_kv (kv_lora_rank) + decoupled rope key (qk_rope_dim), so
KV-RM pages ~576 elements/token instead of 2*H*hd = 32768 (DESIGN.md §4).

Decode uses the absorbed-matmul formulation (attention scored in latent
space); tests/test_kernels.py verifies absorbed == naive.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import common as cm


def mla_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 7)
    d, H = cfg.d_model, cfg.n_heads
    dn, dr, dv = cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    rq, rkv = cfg.q_lora_rank, cfg.kv_lora_rank
    return {
        "wq_a": cm.dense_init(ks[0], d, rq),
        "q_norm": cm.norm_init(rq),
        "wq_b": cm.dense_init(ks[1], rq, H * (dn + dr)),
        "wkv_a": cm.dense_init(ks[2], d, rkv + dr),
        "kv_norm": cm.norm_init(rkv),
        "wk_b": cm.dense_init(ks[3], rkv, H * dn),
        "wv_b": cm.dense_init(ks[4], rkv, H * dv),
        "wo": cm.dense_init(ks[5], H * dv, d),
    }


def _project_q(p, cfg, x, positions):
    """x: (B,S,d) -> q_nope (B,S,H,dn), q_rope (B,S,H,dr) roped."""
    B, S, _ = x.shape
    H, dn, dr = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim
    qa = cm.rmsnorm(p["q_norm"], cm.dense(p["wq_a"], x), cfg.norm_eps)
    q = cm.dense(p["wq_b"], qa).reshape(B, S, H, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    q_rope = cm.apply_rope(q_rope, positions, cfg.rope_theta)
    return q_nope, q_rope


def _project_latent(p, cfg, x, positions):
    """x: (B,S,d) -> latent (B,S,R) with R = kv_lora_rank + dr (rope applied)."""
    B, S, _ = x.shape
    rkv, dr = cfg.kv_lora_rank, cfg.qk_rope_dim
    kv = cm.dense(p["wkv_a"], x)
    c_kv = cm.rmsnorm(p["kv_norm"], kv[..., :rkv], cfg.norm_eps)
    k_rope = kv[..., rkv:].reshape(B, S, 1, dr)
    k_rope = cm.apply_rope(k_rope, positions, cfg.rope_theta).reshape(B, S, dr)
    return jnp.concatenate([c_kv, k_rope.astype(c_kv.dtype)], axis=-1)


def mla_full(p, cfg: ModelConfig, x, positions, *, causal=True):
    """Full-sequence MLA attention (train / prefill)."""
    B, S, _ = x.shape
    H, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    rkv = cfg.kv_lora_rank
    q_nope, q_rope = _project_q(p, cfg, x, positions)
    lat = _project_latent(p, cfg, x, positions)
    c_kv, k_rope = lat[..., :rkv], lat[..., rkv:]
    k_nope = cm.dense(p["wk_b"], c_kv).reshape(B, S, H, dn)
    v = cm.dense(p["wv_b"], c_kv).reshape(B, S, H, dv)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope[:, :, None, :], (B, S, H, dr)).astype(k_nope.dtype)],
        axis=-1)
    if S > 1024:
        o = cm.attention_blocked(q, k, v, causal=causal)
    else:
        o = cm.attention_dense(q, k, v, causal=causal)
    return cm.dense(p["wo"], o.reshape(B, S, H * dv))


def mla_decode(p, cfg: ModelConfig, x, pool_lat, descr, far_lat=None):
    """One-token MLA decode against the (read-only) paged latent pool.

    x: (B, d). Returns (attn_out (B,d), lat_delta (B,R), far_util); the
    caller scatters lat_delta into the pool after the layer scan
    (EXPERIMENTS.md §Perf iteration 8).
    """
    B, _ = x.shape
    H, dn, dr, dv = cfg.n_heads, cfg.qk_nope_dim, cfg.qk_rope_dim, cfg.v_head_dim
    rkv = cfg.kv_lora_rank
    positions = descr.seq_lens[:, None]
    q_nope, q_rope = _project_q(p, cfg, x[:, None, :], positions)
    q_nope, q_rope = q_nope[:, 0], q_rope[:, 0]
    lat = _project_latent(p, cfg, x[:, None, :], positions)[:, 0]   # (B, R)
    w_k_b = p["wk_b"]["w"].reshape(rkv, H, dn).transpose(1, 0, 2)   # (H, rkv, dn)
    w_v_b = p["wv_b"]["w"].reshape(rkv, H, dv).transpose(1, 0, 2)
    farview = far_lat is not None
    o, futil = ops.mla_decode_attention(
        q_nope, q_rope, pool_lat, w_k_b, w_v_b, descr.block_table,
        descr.window_base, descr.seq_lens, descr.slot_active,
        near_window=cfg.serving.near_window, kv_lora_rank=rkv,
        far_lat=far_lat,
        far_table=descr.far_table if farview else None,
        far_valid=descr.far_valid if farview else None,
        cur_lat=lat)
    out = cm.dense(p["wo"], o.reshape(B, H * dv))
    return out, lat, futil
