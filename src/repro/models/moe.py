"""MoE transformer (kimi-k2, deepseek-v3). Gather-based capacity dispatch.

Distribution strategy (DESIGN.md §5): tokens stay data-shard-local; dispatch
runs per token-group (one group per data shard, ``token_groups`` arg), expert
weights are sharded over the 'model' axis on the FFN hidden dim, so the only
collective is the same psum a dense TP MLP needs — no all-to-all at this mesh
size. An EP all-to-all variant is evaluated in EXPERIMENTS.md §Perf.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import common as cm
from repro.models import mla as mla_mod

CAPACITY_FACTOR = 1.25


# ---------------------------------------------------------------------------
# MoE FFN
# ---------------------------------------------------------------------------

def moe_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 5)
    d, f, E = cfg.d_model, cfg.d_ff, cfg.n_experts
    scale = 1.0 / jnp.sqrt(d)
    p = {
        "router": (jax.random.normal(ks[0], (d, E), jnp.float32) * 0.02).astype(jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (E, d, f), jnp.float32) * scale).astype(cm.DTYPE),
        "w_up": (jax.random.normal(ks[2], (E, d, f), jnp.float32) * scale).astype(cm.DTYPE),
        "w_down": (jax.random.normal(ks[3], (E, f, d), jnp.float32) / jnp.sqrt(f)).astype(cm.DTYPE),
    }
    if cfg.n_shared_experts:
        p["shared"] = cm.mlp_init(ks[4], d, f * cfg.n_shared_experts, cfg.mlp_act)
    return p


def _dispatch_one_group(x, router_logits, top_k: int, capacity: int):
    """x: (T, d); router_logits: (T, E). Returns (xe (E,C,d), combine info)."""
    T, d = x.shape
    E = router_logits.shape[1]
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    gates, idx = jax.lax.top_k(probs, top_k)                 # (T, k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)

    flat_e = idx.reshape(-1)                                 # (T*k,)
    onehot = jax.nn.one_hot(flat_e, E, dtype=jnp.int32)      # (T*k, E)
    pos = jnp.cumsum(onehot, axis=0) - 1
    slot = (pos * onehot).sum(-1)                            # (T*k,)
    keep = slot < capacity
    slot_w = jnp.where(keep, slot, capacity)                 # OOB -> dropped

    tok_ids = jnp.repeat(jnp.arange(T), top_k)
    idx_table = jnp.full((E, capacity), T, jnp.int32)        # T = zero-row sentinel
    idx_table = idx_table.at[flat_e, slot_w].set(tok_ids, mode="drop")

    xp = jnp.concatenate([x, jnp.zeros((1, d), x.dtype)], axis=0)
    xe = xp[idx_table]                                       # (E, C, d)
    # load-balance aux (switch-style): mean prob * mean assignment per expert
    me = probs.mean(axis=0)
    ce = onehot.astype(jnp.float32).mean(axis=0) * top_k
    aux = (me * ce).sum() * E
    return xe, (flat_e, slot_w, keep, tok_ids, gates.reshape(-1)), aux


def _combine_one_group(h, info, T: int):
    """h: (E, C, d) expert outputs -> (T, d) weighted scatter-add."""
    flat_e, slot_w, keep, tok_ids, gates_flat = info
    d = h.shape[-1]
    hp = jnp.concatenate([h, jnp.zeros((h.shape[0], 1, d), h.dtype)], axis=1)
    h_tok = hp[flat_e, slot_w]                               # (T*k, d)
    w = jnp.where(keep, gates_flat, 0.0).astype(jnp.float32)
    out = jnp.zeros((T, d), jnp.float32)
    out = out.at[tok_ids].add(h_tok.astype(jnp.float32) * w[:, None])
    return out


def moe_apply(p, cfg: ModelConfig, x, *, token_groups: int = 1,
              ep_axes=None):
    """x: (B, S, d) -> (out, aux_loss). Dispatch is per token group (one group
    per data shard, routing stays shard-local).

    ep_axes: mesh axis name(s) carrying expert parallelism. When set, the
    dispatched tensor xe is resharding-constrained from token-group-sharded to
    expert-sharded (XLA inserts the all-to-all), expert FFNs run on their
    owning shard, and the combine constraint moves results back — real EP
    with expert weights stored E-over-data x f-over-model (DESIGN.md §5).
    """
    B, S, d = x.shape
    orig = (B, S, d)
    xt = x.reshape(token_groups, (B * S) // token_groups, d)
    Tg = xt.shape[1]
    E, k = cfg.n_experts, cfg.top_k
    C = max(1, int(-(-Tg * k // E) * CAPACITY_FACTOR))

    def dispatch_group(xg):
        logits = xg.astype(jnp.float32) @ p["router"]
        return _dispatch_one_group(xg, logits, k, C)

    # dispatch per group (vmap), then reshard OUTSIDE the vmap: sharding
    # constraints under vmap bind the batched leading dim, so the EP
    # constraint must see the full (G, E, C, d) tensor — G (token-sharded)
    # -> E (expert-sharded); XLA inserts the all-to-all. Constraining inside
    # the vmap silently re-pins the G dim instead, and XLA then all-gathers
    # ~2.1 GB of expert weights per layer (EXPERIMENTS.md §Perf iter. 2).
    xe, info, aux = jax.vmap(dispatch_group)(xt)          # (G, E, C, d)
    if ep_axes is not None:
        P_ = jax.sharding.PartitionSpec
        xe = jax.lax.with_sharding_constraint(xe, P_(None, ep_axes, None, None))
    h = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["w_gate"]))
    h = h * jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    h = jnp.einsum("gecf,efd->gecd", h, p["w_down"])
    if ep_axes is not None:
        # expert-sharded -> token-sharded (all-to-all back)
        h = jax.lax.with_sharding_constraint(h, P_(ep_axes, None, None, None))
    out = jax.vmap(_combine_one_group, in_axes=(0, 0, None))(h, info, Tg)
    out = out.reshape(orig).astype(x.dtype)
    if cfg.n_shared_experts:
        out = out + cm.mlp_apply(p["shared"], x, cfg.mlp_act)
    return out, aux.mean()


# ---------------------------------------------------------------------------
# full MoE transformer
# ---------------------------------------------------------------------------

def _attn_init(key, cfg):
    return mla_mod.mla_init(key, cfg) if cfg.use_mla else cm.gqa_init(key, cfg)


def _dense_layer_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    dff = cfg.dense_d_ff or cfg.d_ff
    return {
        "ln1": cm.norm_init(cfg.d_model), "attn": _attn_init(ks[0], cfg),
        "ln2": cm.norm_init(cfg.d_model),
        "mlp": cm.mlp_init(ks[1], cfg.d_model, dff, cfg.mlp_act),
    }


def _moe_layer_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 2)
    return {
        "ln1": cm.norm_init(cfg.d_model), "attn": _attn_init(ks[0], cfg),
        "ln2": cm.norm_init(cfg.d_model), "moe": moe_init(ks[1], cfg),
    }


def init_params(key, cfg: ModelConfig):
    k_emb, k_d, k_m, k_out = jax.random.split(key, 4)
    params = {
        "embed": (jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model), jnp.float32)
                  * 0.02).astype(cm.DTYPE),
        "ln_f": cm.norm_init(cfg.d_model),
        "lm_head": cm.dense_init(k_out, cfg.d_model, cfg.vocab_size),
    }
    if cfg.first_k_dense:
        params["dense_layers"] = cm.stack_layers(
            partial(_dense_layer_init, cfg=cfg), k_d, cfg.first_k_dense)
    params["moe_layers"] = cm.stack_layers(
        partial(_moe_layer_init, cfg=cfg), k_m, cfg.n_layers - cfg.first_k_dense)
    return params


def _attn_full(p, cfg, x, positions):
    if cfg.use_mla:
        return mla_mod.mla_full(p, cfg, x, positions)
    return cm.gqa_full(p, cfg, x, positions)


def forward(params, cfg: ModelConfig, tokens, *, token_groups: int = 1,
            extra_embeds=None, remat: bool = False, return_aux: bool = False,
            ep_axes=None):
    B, S = tokens.shape
    x = params["embed"][tokens]
    if extra_embeds is not None:
        x = jax.lax.dynamic_update_slice(x, extra_embeds.astype(x.dtype), (0, 0, 0))
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def dense_block(x, layer):
        x = cm.constrain_batch(x)
        h = cm.rmsnorm(layer["ln1"], x, cfg.norm_eps)
        x = x + _attn_full(layer["attn"], cfg, h, positions)
        h = cm.rmsnorm(layer["ln2"], x, cfg.norm_eps)
        x = x + cm.mlp_apply(layer["mlp"], h, cfg.mlp_act)
        return x, None

    def moe_block(carry, layer):
        x, aux = carry
        x = cm.constrain_batch(x)
        h = cm.rmsnorm(layer["ln1"], x, cfg.norm_eps)
        x = x + _attn_full(layer["attn"], cfg, h, positions)
        h = cm.rmsnorm(layer["ln2"], x, cfg.norm_eps)
        mo, a = moe_apply(layer["moe"], cfg, h, token_groups=token_groups,
                          ep_axes=ep_axes)
        return (x + mo, aux + a), None

    if cfg.first_k_dense:
        body = jax.checkpoint(dense_block) if remat else dense_block
        x, _ = jax.lax.scan(body, x, params["dense_layers"])
    body = jax.checkpoint(moe_block) if remat else moe_block
    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                               params["moe_layers"])
    x = cm.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = cm.dense(params["lm_head"], x)
    if return_aux:
        return logits, aux / max(1, cfg.n_layers - cfg.first_k_dense)
    return logits


# ---------------------------------------------------------------------------
# paged decode step
# ---------------------------------------------------------------------------

def decode_step(params, cfg: ModelConfig, tokens, pools, descr, *,
                token_groups: int = 1, ep_axes=None):
    """pools: MLA -> {'lat': (L,P,BT,R), optional 'far_lat': (L,B,MAXC,R)};
    GQA -> {'k','v', optional 'far_k','far_v'}. Layer axis spans
    dense layers first, then MoE layers (same order as forward)."""
    B = tokens.shape[0]
    sv = cfg.serving
    x = params["embed"][tokens]
    farview = ("far_lat" in pools) or ("far_k" in pools)
    nd = cfg.first_k_dense

    def attn_decode(layer, x, pool_slices, fu):
        # pools are READ-ONLY here; deltas returned for the post-scan scatter
        if cfg.use_mla:
            (pl_,) = pool_slices[:1]
            far = pool_slices[1] if farview else None
            o, lat, futil = mla_mod.mla_decode(layer["attn"], cfg, x, pl_, descr,
                                               far_lat=far)
            return o, (lat,) + ((far,) if farview else ()), fu + futil
        pk, pv = pool_slices[:2]
        fk = pool_slices[2] if farview else None
        fv = pool_slices[3] if farview else None
        h = x[:, None, :]
        q, k, v = cm.gqa_qkv(layer["attn"], cfg, h, descr.seq_lens[:, None])
        q, k, v = q[:, 0], k[:, 0], v[:, 0]
        if farview:
            sk = ops.farview_summarize(pk, descr.far_chunk_blocks,
                                       descr.far_chunk_tokens, descr.far_do_summarize)
            svv = ops.farview_summarize(pv, descr.far_chunk_blocks,
                                        descr.far_chunk_tokens, descr.far_do_summarize)
            bidx = jnp.arange(B)
            gate = (descr.far_do_summarize > 0)[:, None, None]
            fk = fk.at[bidx, descr.far_write_idx].set(
                jnp.where(gate, sk, fk[bidx, descr.far_write_idx]))
            fv = fv.at[bidx, descr.far_write_idx].set(
                jnp.where(gate, svv, fv[bidx, descr.far_write_idx]))
        o, futil = ops.paged_decode_attention(
            q, pk, pv, descr.block_table, descr.window_base, descr.seq_lens,
            descr.slot_active, near_window=sv.near_window,
            far_k=fk, far_v=fv,
            far_table=descr.far_table if farview else None,
            far_valid=descr.far_valid if farview else None,
            cur_k=k, cur_v=v, skip_extent=sv.skip_extent)
        o = cm.dense(layer["attn"]["wo"], o.reshape(B, -1))
        return o, ((k, v) + ((fk, fv) if farview else ())), fu + futil

    def dense_block(carry, layer_xs):
        x, fu = carry
        layer, pool_slices = layer_xs[0], layer_xs[1:]
        h = cm.rmsnorm(layer["ln1"], x, cfg.norm_eps)
        o, new_pools, fu = attn_decode(layer, h, pool_slices, fu)
        x = x + o
        h = cm.rmsnorm(layer["ln2"], x, cfg.norm_eps)
        x = x + cm.mlp_apply(layer["mlp"], h, cfg.mlp_act)
        return (x, fu), new_pools

    def moe_block(carry, layer_xs):
        x, fu = carry
        layer, pool_slices = layer_xs[0], layer_xs[1:]
        h = cm.rmsnorm(layer["ln1"], x, cfg.norm_eps)
        o, new_pools, fu = attn_decode(layer, h, pool_slices, fu)
        x = x + o
        h = cm.rmsnorm(layer["ln2"], x, cfg.norm_eps)
        mo, _ = moe_apply(layer["moe"], cfg, h[:, None, :], token_groups=token_groups,
                          ep_axes=ep_axes)
        x = x + mo[:, 0]
        return (x, fu), new_pools

    pool_keys = (("lat",) + (("far_lat",) if farview else ())) if cfg.use_mla \
        else (("k", "v") + (("far_k", "far_v") if farview else ()))
    fu0 = jnp.zeros((B, descr.far_table.shape[1]), jnp.float32)

    new_pools = {k: [] for k in pool_keys}
    carry = (x, fu0)
    if nd:
        xs = (params["dense_layers"],) + tuple(pools[k][:nd] for k in pool_keys)
        carry, ys = jax.lax.scan(dense_block, carry, xs)
        for k_, y in zip(pool_keys, ys):
            new_pools[k_].append(y)
    xs = (params["moe_layers"],) + tuple(pools[k][nd:] for k in pool_keys)
    carry, ys = jax.lax.scan(moe_block, carry, xs)
    for k_, y in zip(pool_keys, ys):
        new_pools[k_].append(y)
    (x, fu) = carry
    deltas = {k: jnp.concatenate(v, axis=0) if len(v) > 1 else v[0]
              for k, v in new_pools.items()}
    out_pools = {}
    for key in pool_keys:
        if key.startswith("far_"):
            out_pools[key] = deltas[key]
        else:
            out_pools[key] = ops.pool_write_stacked(
                pools[key], deltas[key], descr.write_block,
                descr.write_offset, descr.slot_active)
    x = cm.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = cm.dense(params["lm_head"], x)
    return logits, out_pools, fu / cfg.n_layers
