"""Shared model building blocks (functional JAX, no framework deps).

Conventions:
  * params are nested dicts of jnp arrays; layer stacks carry a leading L dim
    and are consumed with ``jax.lax.scan`` so HLO stays compact for 60-80 layer
    models (essential for dry-run compile times).
  * compute dtype bf16, fp32 for softmax/norm accumulation.
  * attention over long sequences uses blocked (flash-style) online softmax so
    compile-time memory analysis reflects O(S * block) temps, not O(S^2).
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.act_sharding import constrain_batch

DTYPE = jnp.bfloat16
# attention softmax/score accumulation dtype — f32 default; the bf16 variant
# halves score-tensor HBM traffic (EXPERIMENTS.md §Perf)
SCORE_DTYPE = jnp.float32


def set_score_dtype(dt):
    global SCORE_DTYPE
    SCORE_DTYPE = dt


# ---------------------------------------------------------------------------
# param init
# ---------------------------------------------------------------------------

def dense_init(key, d_in: int, d_out: int, bias: bool = False, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / math.sqrt(d_in)
    p = {"w": (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(DTYPE)}
    if bias:
        p["b"] = jnp.zeros((d_out,), DTYPE)
    return p


def dense(p, x):
    # f32 accumulation with one rounding at the end. Under tensor-parallel
    # decode (DESIGN.md §4) a contraction-sharded projection (wo, mlp down,
    # lm_head) becomes per-shard partial dots + one psum; keeping the partials
    # and the all-reduce in f32 makes the sharded result match the
    # single-device result bitwise on every tested degree — a bf16-output dot
    # would round each partial before a bf16 all-reduce and drift ~1e-2,
    # flipping greedy argmax at bf16 logit ties.
    y = jax.lax.dot_general(x, p["w"], (((x.ndim - 1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32).astype(x.dtype)
    if "b" in p:
        y = y + p["b"]
    return y


def norm_init(dim: int):
    return {"scale": jnp.ones((dim,), DTYPE)}


def rmsnorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


def stack_layers(init_fn, key, n_layers: int):
    """vmap a per-layer init over split keys -> params with leading L dim."""
    keys = jax.random.split(key, n_layers)
    return jax.vmap(init_fn)(keys)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


# rope rotation pairing:
#   'half'        — (i, i+hd/2) pairs (llama convention; faithful default)
#   'interleaved' — (2i, 2i+1) pairs. Numerically a fixed permutation of the
#     'half' layout (weights permute accordingly when loading checkpoints);
#     crucially the pairs stay WITHIN a model-axis shard when head_dim is
#     sharded for TP decode, so rope doesn't force a resharding of K/Q and
#     the partial-score psum stays viable (EXPERIMENTS.md §Perf iter. 3).
ROPE_PAIRING = "half"


def set_rope_pairing(mode: str):
    global ROPE_PAIRING
    assert mode in ("half", "interleaved")
    ROPE_PAIRING = mode


def apply_rope(x, positions, theta: float):
    """x: (..., S, H, hd); positions: broadcastable to (..., S)."""
    hd = x.shape[-1]
    inv = rope_freqs(hd, theta)                       # (hd/2,)
    ang = positions[..., None].astype(jnp.float32) * inv   # (..., S, hd/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]  # (..., S, 1, hd/2)
    xf = x.astype(jnp.float32)
    if ROPE_PAIRING == "interleaved":
        x1, x2 = xf[..., 0::2], xf[..., 1::2]
        r1 = x1 * cos - x2 * sin
        r2 = x1 * sin + x2 * cos
        out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    else:
        x1, x2 = jnp.split(xf, 2, axis=-1)
        out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# attention (blocked flash-style for long sequences)
# ---------------------------------------------------------------------------

def repeat_kv(x, n_rep: int):
    """(B, S, KV, hd) -> (B, S, KV*n_rep, hd)."""
    if n_rep == 1:
        return x
    b, s, kv, hd = x.shape
    return jnp.broadcast_to(x[:, :, :, None, :], (b, s, kv, n_rep, hd)).reshape(b, s, kv * n_rep, hd)


def attention_dense(q, k, v, *, causal: bool, window: Optional[int] = None,
                    q_offset: int = 0):
    """Unblocked reference attention. q:(B,Sq,H,hd) k/v:(B,Sk,KV,hd)."""
    b, sq, h, hd = q.shape
    kvh = k.shape[2]
    k = repeat_kv(k, h // kvh)
    v = repeat_kv(v, h // kvh)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) / math.sqrt(hd)
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(k.shape[1])
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask &= kpos[None, :] <= qpos[:, None]
    if window is not None:
        mask &= kpos[None, :] > qpos[:, None] - window
    scores = jnp.where(mask[None, None], scores, -jnp.inf)
    p = jax.nn.softmax(scores, axis=-1)
    vd = v.shape[-1]
    return jnp.einsum("bhqk,bkhd->bqhd", p.astype(v.dtype), v).reshape(b, sq, h, vd)


def attention_blocked(q, k, v, *, causal: bool = True, window: Optional[int] = None,
                      q_block: int = 512, kv_block: int = 512):
    """Flash-style blocked attention with online softmax.

    q: (B, Sq, H, hd); k, v: (B, Sk, KV, hd). Memory O(Sq * kv_block) instead of
    O(Sq * Sk); compiled cost still counts the full causal einsum FLOPs.
    """
    b, sq, h, hd = q.shape
    sk, kvh = k.shape[1], k.shape[2]
    vd = v.shape[-1]
    n_rep = h // kvh
    if sq % q_block or sk % kv_block:
        return attention_dense(q, k, v, causal=causal, window=window)
    nq, nk = sq // q_block, sk // kv_block
    scale = 1.0 / math.sqrt(hd)

    qr = q.reshape(b, nq, q_block, h, hd).transpose(1, 0, 3, 2, 4)       # (nq,B,H,qb,hd)
    kr = k.reshape(b, nk, kv_block, kvh, hd).transpose(1, 0, 3, 2, 4)    # (nk,B,KV,kb,hd)
    vr = v.reshape(b, nk, kv_block, kvh, vd).transpose(1, 0, 3, 2, 4)

    def q_step(_, qi_and_idx):
        qi, iq = qi_and_idx
        qpos = iq * q_block + jnp.arange(q_block)

        def kv_step(carry, ki_vi_idx):
            acc, m, l = carry
            ki, vi, ik = ki_vi_idx
            kpos = ik * kv_block + jnp.arange(kv_block)
            # broadcast kv heads to q heads: group query heads per kv head
            qg = qi.reshape(b, kvh, n_rep, q_block, hd)
            s = jnp.einsum("bknqd,bkcd->bknqc", qg, ki).astype(SCORE_DTYPE) * scale
            mask = jnp.ones((q_block, kv_block), bool)
            if causal:
                mask &= kpos[None, :] <= qpos[:, None]
            if window is not None:
                mask &= kpos[None, :] > qpos[:, None] - window
            s = jnp.where(mask[None, None, None], s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # guard fully-masked rows
            m_safe = jnp.where(jnp.isinf(m_new), 0.0, m_new)
            p = jnp.exp((s - m_safe[..., None]).astype(SCORE_DTYPE))
            p = jnp.where(jnp.isinf(m_new)[..., None], 0.0, p)
            corr = jnp.exp(jnp.where(jnp.isinf(m), 0.0, m) - m_safe)
            corr = jnp.where(jnp.isinf(m), 0.0, corr)
            l = l * corr + p.sum(axis=-1)
            pv = jnp.einsum("bknqc,bkcd->bknqd", p.astype(vi.dtype), vi).astype(jnp.float32)
            acc = acc * corr[..., None] + pv
            return (acc, m_new, l), None

        acc0 = jnp.zeros((b, kvh, n_rep, q_block, vd), jnp.float32)
        m0 = jnp.full((b, kvh, n_rep, q_block), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((b, kvh, n_rep, q_block), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0),
                                      (kr, vr, jnp.arange(nk)))
        out = acc / jnp.maximum(l[..., None], 1e-20)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_step, None, (qr, jnp.arange(nq)))
    # outs: (nq, B, KV, n_rep, qb, vd) -> (B, Sq, H, vd)
    outs = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, sq, h, vd)
    return outs


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def mlp_init(key, d_model: int, d_ff: int, act: str):
    ks = jax.random.split(key, 3)
    if act == "swiglu":
        return {"gate": dense_init(ks[0], d_model, d_ff),
                "up": dense_init(ks[1], d_model, d_ff),
                "down": dense_init(ks[2], d_ff, d_model)}
    return {"up": dense_init(ks[0], d_model, d_ff),
            "down": dense_init(ks[1], d_ff, d_model)}


def mlp_apply(p, x, act: str):
    if act == "swiglu":
        return dense(p["down"], jax.nn.silu(dense(p["gate"], x)) * dense(p["up"], x))
    h = dense(p["up"], x)
    if act == "sq_relu":
        h = jnp.square(jax.nn.relu(h))
    else:
        h = jax.nn.gelu(h)
    return dense(p["down"], h)


# ---------------------------------------------------------------------------
# GQA attention layer (params + apply for full-sequence mode)
# ---------------------------------------------------------------------------

def gqa_init(key, cfg):
    ks = jax.random.split(key, 6)
    d, h, kv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    p = {
        "wq": dense_init(ks[0], d, h * hd, bias=cfg.qkv_bias),
        "wk": dense_init(ks[1], d, kv * hd, bias=cfg.qkv_bias),
        "wv": dense_init(ks[2], d, kv * hd, bias=cfg.qkv_bias),
        "wo": dense_init(ks[3], h * hd, d),
    }
    if cfg.qk_norm:
        p["q_norm"] = norm_init(hd)
        p["k_norm"] = norm_init(hd)
    return p


def gqa_qkv(p, cfg, x, positions):
    """Project + rope. x: (B, S, d) -> q:(B,S,H,hd), k/v:(B,S,KV,hd)."""
    b, s, _ = x.shape
    h, kv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = dense(p["wq"], x).reshape(b, s, h, hd)
    k = dense(p["wk"], x).reshape(b, s, kv, hd)
    v = dense(p["wv"], x).reshape(b, s, kv, hd)
    if cfg.qk_norm:
        q = rmsnorm(p["q_norm"], q, cfg.norm_eps)
        k = rmsnorm(p["k_norm"], k, cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_full(p, cfg, x, positions, *, causal=True, window=None):
    """Full-sequence GQA attention (train / prefill)."""
    b, s, _ = x.shape
    q, k, v = gqa_qkv(p, cfg, x, positions)
    q, k, v = (constrain_batch(t) for t in (q, k, v))
    if s > 1024:
        o = attention_blocked(q, k, v, causal=causal, window=window)
    else:
        o = attention_dense(q, k, v, causal=causal, window=window)
    return dense(p["wo"], o.reshape(b, s, cfg.n_heads * cfg.head_dim))
