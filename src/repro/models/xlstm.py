"""xLSTM (sLSTM + mLSTM blocks) — attention-free; recurrent state is O(1)
per session, so KV-RM's paging/transport path is inapplicable (DESIGN.md §4).
The serving engine still manages per-session state slots through the pager's
RESERVE/TRIM verbs so the serving interface stays uniform.

Layers alternate (m, s) pairs and are scanned pairwise for compact HLO.
"""
from __future__ import annotations

import math
from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import common as cm

# time-chunked remat for the recurrent scans: without it, backward saves the
# per-STEP matrix memory (C is H*hd^2 floats -> ~1 PB of saved residuals for
# train_4k); chunking checkpoints only chunk-boundary carries and recomputes
# within the chunk (EXPERIMENTS.md §Perf iteration 4).
TIME_CHUNK = 256


def set_time_chunk(n: int):
    global TIME_CHUNK
    TIME_CHUNK = n


def _time_scan(step, carry0, xs):
    """lax.scan over time with chunk-boundary gradient checkpointing."""
    T = xs[0].shape[0]
    ch = TIME_CHUNK
    if not ch or T <= ch or T % ch:
        return jax.lax.scan(step, carry0, xs)
    nc = T // ch
    xs_c = tuple(a.reshape(nc, ch, *a.shape[1:]) for a in xs)

    @jax.checkpoint
    def chunk_body(carry, xc):
        return jax.lax.scan(step, carry, xc)

    carry, ys = jax.lax.scan(chunk_body, carry0, xs_c)
    ys = jax.tree.map(lambda a: a.reshape(T, *a.shape[2:]), ys)
    return carry, ys


# ---------------------------------------------------------------------------
# mLSTM block (matrix memory, exponential gating)
# ---------------------------------------------------------------------------

def mlstm_init(key, cfg: ModelConfig):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    H = cfg.n_heads
    hd = di // H
    ks = jax.random.split(key, 8)
    return {
        "ln": cm.norm_init(d),
        "up": cm.dense_init(ks[0], d, 2 * di),
        "conv_w": (jax.random.normal(ks[1], (cfg.ssm_conv, di), jnp.float32)
                   / math.sqrt(cfg.ssm_conv)).astype(cm.DTYPE),
        "conv_b": jnp.zeros((di,), cm.DTYPE),
        "wq": cm.dense_init(ks[2], di, di),
        "wk": cm.dense_init(ks[3], di, di),
        "wv": cm.dense_init(ks[4], di, di),
        "w_if": cm.dense_init(ks[5], di, 2 * H),    # per-head input/forget gates
        "w_o": cm.dense_init(ks[6], di, di),        # elementwise output gate
        "down": cm.dense_init(ks[7], di, d),
    }


def _mlstm_scan(q, k, v, ig, fg):
    """q,k,v: (B,S,H,hd); ig,fg: (B,S,H) raw gate pre-activations.
    Returns y: (B,S,H,hd). Stabilized exponential gating (xLSTM eq. 19-27)."""
    B, S, H, hd = q.shape
    logf = -jax.nn.softplus(-fg.astype(jnp.float32))        # log sigmoid(f)
    logi = ig.astype(jnp.float32)

    def step(carry, xs):
        C, n, m = carry                                     # (B,H,hd,hd),(B,H,hd),(B,H)
        qt, kt, vt, lf, li = xs
        m_new = jnp.maximum(lf + m, li)
        fprime = jnp.exp(lf + m - m_new)
        iprime = jnp.exp(li - m_new)
        C = fprime[..., None, None] * C + iprime[..., None, None] * (
            vt[..., :, None] * kt[..., None, :])            # v k^T
        n = fprime[..., None] * n + iprime[..., None] * kt
        num = jnp.einsum("bhvk,bhk->bhv", C, qt)
        den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qt)), 1.0)
        return (C, n, m_new), num / den[..., None]

    C0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    n0 = jnp.zeros((B, H, hd), jnp.float32)
    m0 = jnp.zeros((B, H), jnp.float32)
    xs = tuple(t.transpose(1, 0, *range(2, t.ndim)).astype(jnp.float32)
               for t in (q, k, v, logf, logi))
    _, ys = _time_scan(step, (C0, n0, m0), xs)
    return ys.transpose(1, 0, 2, 3)


def mlstm_forward(p, cfg, x):
    B, S, d = x.shape
    di = cfg.ssm_expand * d
    H = cfg.n_heads
    hd = di // H
    h = cm.rmsnorm(p["ln"], x, cfg.norm_eps)
    u = cm.dense(p["up"], h)
    xi, z = u[..., :di], u[..., di:]
    # causal depthwise conv
    W = p["conv_w"].shape[0]
    pad = jnp.pad(xi, ((0, 0), (W - 1, 0), (0, 0)))
    xc = jax.nn.silu(sum(pad[:, i:i + S, :] * p["conv_w"][i][None, None]
                         for i in range(W)) + p["conv_b"])
    q = cm.dense(p["wq"], xc).reshape(B, S, H, hd) / math.sqrt(hd)
    k = cm.dense(p["wk"], xc).reshape(B, S, H, hd) / math.sqrt(hd)
    v = cm.dense(p["wv"], xi).reshape(B, S, H, hd)
    gates = cm.dense(p["w_if"], xc).reshape(B, S, H, 2)
    y = _mlstm_scan(q, k, v, gates[..., 0], gates[..., 1])
    o = jax.nn.sigmoid(cm.dense(p["w_o"], xi).astype(jnp.float32))
    y = (y.reshape(B, S, di) * o).astype(x.dtype) * jax.nn.silu(z)
    return x + cm.dense(p["down"], y)


def mlstm_decode(p, cfg, x, state):
    """x: (B,d); state: dict(C (B,H,hd,hd), n (B,H,hd), m (B,H), conv (B,W-1,di))."""
    B, d = x.shape
    di = cfg.ssm_expand * d
    H = cfg.n_heads
    hd = di // H
    h = cm.rmsnorm(p["ln"], x, cfg.norm_eps)
    u = cm.dense(p["up"], h)
    xi, z = u[..., :di], u[..., di:]
    seq = jnp.concatenate([state["conv"], xi[:, None, :]], axis=1)
    xc = jax.nn.silu((seq * p["conv_w"][None]).sum(1) + p["conv_b"])
    q = cm.dense(p["wq"], xc).reshape(B, H, hd) / math.sqrt(hd)
    k = cm.dense(p["wk"], xc).reshape(B, H, hd) / math.sqrt(hd)
    v = cm.dense(p["wv"], xi).reshape(B, H, hd)
    gates = cm.dense(p["w_if"], xc).reshape(B, H, 2)
    lf = -jax.nn.softplus(-gates[..., 1].astype(jnp.float32))
    li = gates[..., 0].astype(jnp.float32)
    m_new = jnp.maximum(lf + state["m"], li)
    fprime = jnp.exp(lf + state["m"] - m_new)
    iprime = jnp.exp(li - m_new)
    qf, kf, vf = (t.astype(jnp.float32) for t in (q, k, v))
    C = fprime[..., None, None] * state["C"] + iprime[..., None, None] * (
        vf[..., :, None] * kf[..., None, :])
    n = fprime[..., None] * state["n"] + iprime[..., None] * kf
    num = jnp.einsum("bhvk,bhk->bhv", C, qf)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhk,bhk->bh", n, qf)), 1.0)
    y = num / den[..., None]
    o = jax.nn.sigmoid(cm.dense(p["w_o"], xi).astype(jnp.float32))
    y = (y.reshape(B, di) * o).astype(x.dtype) * jax.nn.silu(z)
    new_state = {"C": C, "n": n, "m": m_new, "conv": seq[:, 1:, :]}
    return x + cm.dense(p["down"], y), new_state


# ---------------------------------------------------------------------------
# sLSTM block (scalar memory, recurrent gating) + post-FFN
# ---------------------------------------------------------------------------

def slstm_init(key, cfg: ModelConfig):
    d = cfg.d_model
    H = cfg.n_heads
    hd = d // H
    ks = jax.random.split(key, 4)
    return {
        "ln": cm.norm_init(d),
        "w_gates": cm.dense_init(ks[0], d, 4 * d),     # i,f,z,o pre-acts
        "r_gates": (jax.random.normal(ks[1], (H, hd, 4 * hd), jnp.float32)
                    / math.sqrt(hd)).astype(cm.DTYPE),  # recurrent, block-diag per head
        "ln2": cm.norm_init(d),
        "ffn": cm.mlp_init(ks[2], d, int(d * 4 / 3), "swiglu"),
    }


def _slstm_step(p, cfg, wx, h_prev, c_prev, n_prev, m_prev):
    """wx: (B,4d) input pre-acts; states: (B,H,hd)."""
    B = wx.shape[0]
    d = cfg.d_model
    H = cfg.n_heads
    hd = d // H
    rh = jnp.einsum("bhk,hkg->bhg", h_prev.astype(cm.DTYPE), p["r_gates"])
    pre = wx.reshape(B, H, 4 * hd).astype(jnp.float32) + rh.astype(jnp.float32)
    i_, f_, z_, o_ = jnp.split(pre, 4, axis=-1)
    lf = -jax.nn.softplus(-f_)
    m_new = jnp.maximum(lf + m_prev, i_)
    iprime = jnp.exp(i_ - m_new)
    fprime = jnp.exp(lf + m_prev - m_new)
    c = fprime * c_prev + iprime * jnp.tanh(z_)
    n = fprime * n_prev + iprime
    h = jax.nn.sigmoid(o_) * c / jnp.maximum(n, 1e-6)
    return h, c, n, m_new


def slstm_forward(p, cfg, x):
    B, S, d = x.shape
    H, hd = cfg.n_heads, d // cfg.n_heads
    hn = cm.rmsnorm(p["ln"], x, cfg.norm_eps)
    wx = cm.dense(p["w_gates"], hn)                     # (B,S,4d)

    def step(carry, xs_):
        (wxt,) = xs_
        h, c, n, m = carry
        h, c, n, m = _slstm_step(p, cfg, wxt, h, c, n, m)
        return (h, c, n, m), h

    z0 = jnp.zeros((B, H, hd), jnp.float32)
    (_, _, _, _), ys = _time_scan(step, (z0, z0, z0, z0), (wx.transpose(1, 0, 2),))
    y = ys.transpose(1, 0, 2, 3).reshape(B, S, d).astype(x.dtype)
    x = x + y
    hn = cm.rmsnorm(p["ln2"], x, cfg.norm_eps)
    return x + cm.mlp_apply(p["ffn"], hn, "swiglu")


def slstm_decode(p, cfg, x, state):
    hn = cm.rmsnorm(p["ln"], x, cfg.norm_eps)
    wx = cm.dense(p["w_gates"], hn)
    h, c, n, m = _slstm_step(p, cfg, wx, state["h"], state["c"], state["n"], state["m"])
    d = cfg.d_model
    y = h.reshape(x.shape[0], d).astype(x.dtype)
    x = x + y
    hn = cm.rmsnorm(p["ln2"], x, cfg.norm_eps)
    x = x + cm.mlp_apply(p["ffn"], hn, "swiglu")
    return x, {"h": h, "c": c, "n": n, "m": m}


# ---------------------------------------------------------------------------
# full model — scanned (m, s) pairs
# ---------------------------------------------------------------------------

def n_pairs(cfg: ModelConfig) -> int:
    assert cfg.xlstm_pattern and len(cfg.xlstm_pattern) % 2 == 0, \
        "xlstm pattern must be (m,s) pairs"
    return len(cfg.xlstm_pattern) // 2


def init_params(key, cfg: ModelConfig):
    k_emb, k_l, k_out = jax.random.split(key, 3)
    pairs = n_pairs(cfg)
    def pair_init(k):
        k1, k2 = jax.random.split(k)
        return {"m": mlstm_init(k1, cfg), "s": slstm_init(k2, cfg)}
    return {
        "embed": (jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model), jnp.float32)
                  * 0.02).astype(cm.DTYPE),
        "pairs": cm.stack_layers(pair_init, k_l, pairs),
        "ln_f": cm.norm_init(cfg.d_model),
        "lm_head": cm.dense_init(k_out, cfg.d_model, cfg.vocab_size),
    }


def forward(params, cfg: ModelConfig, tokens, *, remat: bool = False,
            extra_embeds=None):
    x = params["embed"][tokens]

    def pair_block(x, pp):
        x = cm.constrain_batch(x)
        x = mlstm_forward(pp["m"], cfg, x)
        x = slstm_forward(pp["s"], cfg, x)
        return x, None

    body = jax.checkpoint(pair_block) if remat else pair_block
    x, _ = jax.lax.scan(body, x, params["pairs"])
    x = cm.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return cm.dense(params["lm_head"], x)


def init_decode_state(cfg: ModelConfig, batch: int):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    H = cfg.n_heads
    hd_m, hd_s = di // H, d // H
    pairs = n_pairs(cfg)
    zf = lambda *s: jnp.zeros(s, jnp.float32)
    return {
        "m": {"C": zf(pairs, batch, H, hd_m, hd_m), "n": zf(pairs, batch, H, hd_m),
              "m": zf(pairs, batch, H), "conv": jnp.zeros((pairs, batch, cfg.ssm_conv - 1, di), cm.DTYPE)},
        "s": {"h": zf(pairs, batch, H, hd_s), "c": zf(pairs, batch, H, hd_s),
              "n": zf(pairs, batch, H, hd_s), "m": zf(pairs, batch, H, hd_s)},
    }


def decode_step(params, cfg: ModelConfig, tokens, pools, descr):
    """pools = init_decode_state-shaped state stacks. descr is consumed only
    for slot_active masking (no KV pool — attention-free)."""
    x = params["embed"][tokens]
    fu = jnp.zeros((tokens.shape[0], descr.far_table.shape[1]), jnp.float32)

    def pair_block(x, xs):
        pp, ms, ss = xs
        x, ms = mlstm_decode(pp["m"], cfg, x, ms)
        x, ss = slstm_decode(pp["s"], cfg, x, ss)
        return x, (ms, ss)

    x, (ms, ss) = jax.lax.scan(pair_block, x, (params["pairs"], pools["m"], pools["s"]))
    x = cm.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = cm.dense(params["lm_head"], x)
    return logits, {"m": ms, "s": ss}, fu
