"""Encoder-decoder transformer backbone (seamless-m4t-medium).

The audio frontend is a stub: the encoder consumes precomputed frame
embeddings (B, S_enc, d). The decoder self-attention KV is paged by KV-RM;
the cross-attention KV is computed once at encode time and is immutable —
the pager's RESERVE/ALIAS prefix-sharing case (DESIGN.md §4).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import common as cm


def _enc_layer_init(key, cfg):
    ks = jax.random.split(key, 2)
    return {
        "ln1": cm.norm_init(cfg.d_model), "attn": cm.gqa_init(ks[0], cfg),
        "ln2": cm.norm_init(cfg.d_model),
        "mlp": cm.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_act),
    }


def _dec_layer_init(key, cfg):
    ks = jax.random.split(key, 3)
    return {
        "ln1": cm.norm_init(cfg.d_model), "self_attn": cm.gqa_init(ks[0], cfg),
        "ln_x": cm.norm_init(cfg.d_model), "cross_attn": cm.gqa_init(ks[1], cfg),
        "ln2": cm.norm_init(cfg.d_model),
        "mlp": cm.mlp_init(ks[2], cfg.d_model, cfg.d_ff, cfg.mlp_act),
    }


def init_params(key, cfg: ModelConfig):
    k_emb, k_e, k_d, k_out = jax.random.split(key, 4)
    return {
        "embed": (jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model), jnp.float32)
                  * 0.02).astype(cm.DTYPE),
        "enc_layers": cm.stack_layers(partial(_enc_layer_init, cfg=cfg), k_e, cfg.enc_layers),
        "enc_ln": cm.norm_init(cfg.d_model),
        "dec_layers": cm.stack_layers(partial(_dec_layer_init, cfg=cfg), k_d, cfg.dec_layers),
        "ln_f": cm.norm_init(cfg.d_model),
        "lm_head": cm.dense_init(k_out, cfg.d_model, cfg.vocab_size),
    }


def encode(params, cfg: ModelConfig, enc_embeds, *, remat: bool = False):
    """enc_embeds: (B, S_enc, d) precomputed frame embeddings -> (B, S_enc, d)."""
    B, S, _ = enc_embeds.shape
    x = enc_embeds.astype(cm.DTYPE)
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def block(x, layer):
        x = cm.constrain_batch(x)
        h = cm.rmsnorm(layer["ln1"], x, cfg.norm_eps)
        x = x + cm.gqa_full(layer["attn"], cfg, h, positions, causal=False)
        h = cm.rmsnorm(layer["ln2"], x, cfg.norm_eps)
        x = x + cm.mlp_apply(layer["mlp"], h, cfg.mlp_act)
        return x, None

    body = jax.checkpoint(block) if remat else block
    x, _ = jax.lax.scan(body, x, params["enc_layers"])
    return cm.rmsnorm(params["enc_ln"], x, cfg.norm_eps)


def cross_kv(params, cfg: ModelConfig, enc_out):
    """Precompute per-decoder-layer cross K/V: (L, B, S_enc, KV, hd)."""
    B, S, _ = enc_out.shape
    kv, hd = cfg.n_kv_heads, cfg.head_dim

    def one(layer):
        k = cm.dense(layer["cross_attn"]["wk"], enc_out).reshape(B, S, kv, hd)
        v = cm.dense(layer["cross_attn"]["wv"], enc_out).reshape(B, S, kv, hd)
        return k, v

    ks, vs = jax.vmap(one)(params["dec_layers"])
    return ks, vs


def decode_full(params, cfg: ModelConfig, dec_tokens, enc_out, *,
                remat: bool = False):
    """Teacher-forced decoder pass (train / prefill). -> logits (B, Sd, V)."""
    B, Sd = dec_tokens.shape
    Se = enc_out.shape[1]
    x = params["embed"][dec_tokens]
    dpos = jnp.broadcast_to(jnp.arange(Sd)[None], (B, Sd))
    epos = jnp.broadcast_to(jnp.arange(Se)[None], (B, Se))

    def block(x, layer):
        h = cm.rmsnorm(layer["ln1"], x, cfg.norm_eps)
        x = x + cm.gqa_full(layer["self_attn"], cfg, h, dpos)
        # cross attention: q from decoder, kv from encoder output
        h = cm.rmsnorm(layer["ln_x"], x, cfg.norm_eps)
        q = cm.dense(layer["cross_attn"]["wq"], h).reshape(B, Sd, cfg.n_heads, cfg.head_dim)
        k = cm.dense(layer["cross_attn"]["wk"], enc_out).reshape(B, Se, cfg.n_kv_heads, cfg.head_dim)
        v = cm.dense(layer["cross_attn"]["wv"], enc_out).reshape(B, Se, cfg.n_kv_heads, cfg.head_dim)
        if Sd > 1024:
            o = cm.attention_blocked(q, k, v, causal=False)
        else:
            o = cm.attention_dense(q, k, v, causal=False)
        x = x + cm.dense(layer["cross_attn"]["wo"], o.reshape(B, Sd, -1))
        h = cm.rmsnorm(layer["ln2"], x, cfg.norm_eps)
        x = x + cm.mlp_apply(layer["mlp"], h, cfg.mlp_act)
        return x, None

    body = jax.checkpoint(block) if remat else block
    x, _ = jax.lax.scan(body, x, params["dec_layers"])
    x = cm.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return cm.dense(params["lm_head"], x)


def forward(params, cfg: ModelConfig, tokens, *, extra_embeds=None,
            remat: bool = False):
    """Uniform train entry: extra_embeds = encoder frame embeddings
    (B, S_enc, d); tokens = decoder tokens (B, S_dec)."""
    assert extra_embeds is not None, "encdec requires encoder embeddings"
    enc_out = encode(params, cfg, extra_embeds, remat=remat)
    return decode_full(params, cfg, tokens, enc_out, remat=remat)


def decode_step(params, cfg: ModelConfig, tokens, pools, descr):
    """pools: k/v (L,P,BT,KV,hd) paged decoder self-attn; cross_k/cross_v
    (L,B,Se,KV,hd) immutable; enc_len (B,) valid encoder length."""
    B = tokens.shape[0]
    sv = cfg.serving
    x = params["embed"][tokens]
    enc_len = pools["enc_len"]
    fu0 = jnp.zeros((B, descr.far_table.shape[1]), jnp.float32)

    def block(carry, xs):
        x, fu = carry
        layer, pk, pv, ck, cv = xs
        h = cm.rmsnorm(layer["ln1"], x, cfg.norm_eps)
        q, k, v = cm.gqa_qkv(layer["self_attn"], cfg, h[:, None, :],
                             descr.seq_lens[:, None])
        q, k, v = q[:, 0], k[:, 0], v[:, 0]
        o, futil = ops.paged_decode_attention(
            q, pk, pv, descr.block_table, descr.window_base, descr.seq_lens,
            descr.slot_active, near_window=sv.near_window, cur_k=k, cur_v=v,
            skip_extent=sv.skip_extent)
        x = x + cm.dense(layer["self_attn"]["wo"], o.reshape(B, -1))
        # cross attention over immutable encoder KV
        h = cm.rmsnorm(layer["ln_x"], x, cfg.norm_eps)
        qx = cm.dense(layer["cross_attn"]["wq"], h).reshape(B, 1, cfg.n_heads, cfg.head_dim)
        Se = ck.shape[1]
        mask_len = jnp.arange(Se)[None, :] < enc_len[:, None]
        kx = jnp.where(mask_len[:, :, None, None], ck, 0)
        sc = jnp.einsum("bqhd,bkhd->bhqk", qx,
                        cm.repeat_kv(kx, cfg.n_heads // cfg.n_kv_heads)
                        ).astype(jnp.float32) * (cfg.head_dim ** -0.5)
        sc = jnp.where(mask_len[:, None, None, :], sc, -jnp.inf)
        # safe softmax: slots with no encoder output yet attend to nothing
        mx = jnp.max(sc, axis=-1, keepdims=True)
        mx = jnp.where(jnp.isinf(mx), 0.0, mx)
        pe = jnp.where(jnp.isinf(sc), 0.0, jnp.exp(sc - mx))
        pr = pe / jnp.maximum(pe.sum(-1, keepdims=True), 1e-20)
        ox = jnp.einsum("bhqk,bkhd->bqhd", pr.astype(cv.dtype),
                        cm.repeat_kv(cv, cfg.n_heads // cfg.n_kv_heads))
        x = x + cm.dense(layer["cross_attn"]["wo"], ox.reshape(B, -1))
        h = cm.rmsnorm(layer["ln2"], x, cfg.norm_eps)
        x = x + cm.mlp_apply(layer["mlp"], h, cfg.mlp_act)
        return (x, fu + futil), (k, v)

    xs = (params["dec_layers"], pools["k"], pools["v"],
          pools["cross_k"], pools["cross_v"])
    (x, fu), (ks, vs) = jax.lax.scan(block, (x, fu0), xs)
    x = cm.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = cm.dense(params["lm_head"], x)
    new_pools = dict(pools)
    new_pools.update({
        "k": ops.pool_write_stacked(pools["k"], ks, descr.write_block,
                                    descr.write_offset, descr.slot_active),
        "v": ops.pool_write_stacked(pools["v"], vs, descr.write_block,
                                    descr.write_offset, descr.slot_active),
    })
    return logits, new_pools, fu / cfg.dec_layers
