"""Dense decoder-only transformer (qwen2.5 / qwen3 / yi / nemotron / internvl2
backbone). Layers are stacked and consumed with lax.scan for compact HLO.
"""
from __future__ import annotations

from functools import partial
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.kernels import ops
from repro.models import common as cm


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def _layer_init(key, cfg: ModelConfig):
    ks = jax.random.split(key, 4)
    return {
        "ln1": cm.norm_init(cfg.d_model),
        "attn": cm.gqa_init(ks[0], cfg),
        "ln2": cm.norm_init(cfg.d_model),
        "mlp": cm.mlp_init(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_act),
    }


def init_params(key, cfg: ModelConfig):
    k_emb, k_layers, k_out = jax.random.split(key, 3)
    params = {
        "embed": (jax.random.normal(k_emb, (cfg.vocab_size, cfg.d_model), jnp.float32)
                  * 0.02).astype(cm.DTYPE),
        "layers": cm.stack_layers(partial(_layer_init, cfg=cfg), k_layers, cfg.n_layers),
        "ln_f": cm.norm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = cm.dense_init(k_out, cfg.d_model, cfg.vocab_size)
    return params


def logits_head(params, cfg: ModelConfig, x):
    if cfg.tie_embeddings:
        return x @ params["embed"].T
    return cm.dense(params["lm_head"], x)


# ---------------------------------------------------------------------------
# full-sequence forward (train / prefill)
# ---------------------------------------------------------------------------

def forward(params, cfg: ModelConfig, tokens, *, extra_embeds=None,
            remat: bool = False):
    """tokens: (B, S) int32 -> logits (B, S, V).

    extra_embeds: optional (B, S_front, d) precomputed modality embeddings
    (vision/audio stubs) overwriting the first S_front positions.
    """
    B, S = tokens.shape
    x = params["embed"][tokens]
    if extra_embeds is not None:
        sf = extra_embeds.shape[1]
        x = jax.lax.dynamic_update_slice(x, extra_embeds.astype(x.dtype), (0, 0, 0))
    positions = jnp.broadcast_to(jnp.arange(S)[None], (B, S))

    def block(x, layer):
        x = cm.constrain_batch(x)
        h = cm.rmsnorm(layer["ln1"], x, cfg.norm_eps)
        x = x + cm.gqa_full(layer["attn"], cfg, h, positions)
        h = cm.rmsnorm(layer["ln2"], x, cfg.norm_eps)
        x = x + cm.mlp_apply(layer["mlp"], h, cfg.mlp_act)
        return x, None

    body = jax.checkpoint(block) if remat else block
    x, _ = jax.lax.scan(body, x, params["layers"])
    x = cm.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    return logits_head(params, cfg, x)


# ---------------------------------------------------------------------------
# chunked prefill step (prompt ingestion; DESIGN.md §3)
# ---------------------------------------------------------------------------

def prefill_chunk(params, cfg: ModelConfig, pools, descr):
    """Ingest one C-token prompt chunk PER SLOT under the KV-RM contract.

    descr: PrefillChunkDescriptor (fixed B / C / NB — compiled once, like
    the decode step; ONE dispatch per engine step with idle slots masked by
    n_valid=0). Writes each chunk's K/V into the paged pool and returns the
    updated pools. No logits: the final prompt token always goes through the
    decode step, so sampled-token semantics are unchanged.
    """
    sv = cfg.serving
    B, C = descr.tokens.shape
    x = params["embed"][descr.tokens]                 # (B, C, d)
    positions = descr.start_pos[:, None] + jnp.arange(C)[None]  # (B, C)
    quant = "k_scale" in pools                        # narrow KV tier (§10)

    attend = jax.vmap(
        lambda q, pk, pv, k, v, tbl, wb, sp, nv, ks, vs:
        ops.chunked_prefill_attention(
            q, pk, pv, k, v, tbl, wb, sp, nv, near_window=sv.near_window,
            k_scale=ks, v_scale=vs, skip_extent=sv.skip_extent),
        in_axes=(0, None, None, 0, 0, 0, 0, 0, 0, None, None))

    # Same read-only pool discipline as decode_step: each layer's chunk K/V
    # attends explicitly and is emitted as a delta, scattered once post-scan.
    def block(x, layer_xs):
        if quant:
            layer, pk, pv, psk, psv = layer_xs
        else:
            layer, pk, pv = layer_xs
            psk = psv = None
        h = cm.rmsnorm(layer["ln1"], x, cfg.norm_eps)
        q, k, v = cm.gqa_qkv(layer["attn"], cfg, h, positions)
        o = attend(q, pk, pv, k, v, descr.block_table, descr.window_base,
                   descr.start_pos, descr.n_valid, psk, psv)  # (B, C, H, hd)
        x = x + cm.dense(layer["attn"]["wo"], o.reshape(B, C, -1))
        h = cm.rmsnorm(layer["ln2"], x, cfg.norm_eps)
        x = x + cm.mlp_apply(layer["mlp"], h, cfg.mlp_act)
        return x, (k, v)

    xs = ((params["layers"], pools["k"], pools["v"], pools["k_scale"],
           pools["v_scale"]) if quant
          else (params["layers"], pools["k"], pools["v"]))
    _, ys = jax.lax.scan(block, x, xs)
    new_pools = dict(pools)
    if quant:
        new_pools["k"], new_pools["k_scale"] = ops.quant_pool_write_chunk(
            pools["k"], pools["k_scale"], ys[0], descr.write_block,
            descr.write_offset, descr.n_valid)
        new_pools["v"], new_pools["v_scale"] = ops.quant_pool_write_chunk(
            pools["v"], pools["v_scale"], ys[1], descr.write_block,
            descr.write_offset, descr.n_valid)
        return new_pools
    new_pools["k"] = ops.pool_write_chunk(pools["k"], ys[0], descr.write_block,
                                          descr.write_offset, descr.n_valid)
    new_pools["v"] = ops.pool_write_chunk(pools["v"], ys[1], descr.write_block,
                                          descr.write_offset, descr.n_valid)
    return new_pools


# ---------------------------------------------------------------------------
# paged decode step (KV-RM path)
# ---------------------------------------------------------------------------

def decode_step(params, cfg: ModelConfig, tokens, pools, descr):
    """One fixed-shape decode step under the KV-RM contract.

    tokens: (B,) int32 current tokens. pools: dict with
      k, v: (L, P, BT, KV, hd); optionally far_k, far_v: (L, B, MAXC, KV, hd).
    descr: FrameDescriptor. Returns (logits (B,V), pools, far_util (B,CAP)).
    """
    B = tokens.shape[0]
    sv = cfg.serving
    x = params["embed"][tokens]                      # (B, d)
    pos = descr.seq_lens.astype(jnp.float32)[:, None]  # rope position = t

    farview = "far_k" in pools
    quant = "k_scale" in pools                       # narrow KV tier (§10)
    assert not (farview and quant), \
        "far view and the quantized KV tier are exclusive (DESIGN.md §10)"

    # The KV pools are READ-ONLY inside the layer scan; each layer's new K/V
    # attends explicitly (cur_k/cur_v) and is emitted as a per-layer delta,
    # scattered into the pool ONCE after the scan. Carrying the pool through
    # scan ys makes XLA copy (and on some backends convert) the full stacked
    # pool every layer (§Perf iteration 8: 850ms -> ~30ms memory term).
    def block(carry, layer_xs):
        x, fu = carry
        psk = psv = None
        if farview:
            layer, pk, pv, fk, fv = layer_xs
        elif quant:
            layer, pk, pv, psk, psv = layer_xs
            fk = fv = None
        else:
            layer, pk, pv = layer_xs
            fk = fv = None
        h = cm.rmsnorm(layer["ln1"], x, cfg.norm_eps)
        hq = h[:, None, :]                            # (B,1,d)
        q, k, v = cm.gqa_qkv(layer["attn"], cfg, hq, descr.seq_lens[:, None])
        q, k, v = q[:, 0], k[:, 0], v[:, 0]           # (B,H,hd)/(B,KV,hd)

        if farview:
            # summarize the just-completed chunk (predicated, fixed shape)
            sk = ops.farview_summarize(pk, descr.far_chunk_blocks,
                                       descr.far_chunk_tokens, descr.far_do_summarize)
            svv = ops.farview_summarize(pv, descr.far_chunk_blocks,
                                        descr.far_chunk_tokens, descr.far_do_summarize)
            bidx = jnp.arange(B)
            gate = (descr.far_do_summarize > 0)[:, None, None]
            fk = fk.at[bidx, descr.far_write_idx].set(
                jnp.where(gate, sk, fk[bidx, descr.far_write_idx]))
            fv = fv.at[bidx, descr.far_write_idx].set(
                jnp.where(gate, svv, fv[bidx, descr.far_write_idx]))

        o, futil = ops.paged_decode_attention(
            q, pk, pv, descr.block_table, descr.window_base, descr.seq_lens,
            descr.slot_active, near_window=sv.near_window,
            far_k=fk, far_v=fv,
            far_table=descr.far_table if farview else None,
            far_valid=descr.far_valid if farview else None,
            cur_k=k, cur_v=v, k_scale=psk, v_scale=psv,
            skip_extent=sv.skip_extent)
        x = x + cm.dense(layer["attn"]["wo"], o.reshape(B, -1))
        h = cm.rmsnorm(layer["ln2"], x, cfg.norm_eps)
        x = x + cm.mlp_apply(layer["mlp"], h, cfg.mlp_act)
        ys = (k, v, fk, fv) if farview else (k, v)
        return (x, fu + futil), ys

    fu0 = jnp.zeros((B, descr.far_table.shape[1]), jnp.float32)
    if farview:
        xs = (params["layers"], pools["k"], pools["v"], pools["far_k"],
              pools["far_v"])
    elif quant:
        xs = (params["layers"], pools["k"], pools["v"], pools["k_scale"],
              pools["v_scale"])
    else:
        xs = (params["layers"], pools["k"], pools["v"])
    (x, fu), ys = jax.lax.scan(block, (x, fu0), xs)
    if quant:
        # quantize-at-commit (§10): data + scale pools updated together
        new_k, new_ks = ops.quant_pool_write_stacked(
            pools["k"], pools["k_scale"], ys[0], descr.write_block,
            descr.write_offset, descr.slot_active)
        new_v, new_vs = ops.quant_pool_write_stacked(
            pools["v"], pools["v_scale"], ys[1], descr.write_block,
            descr.write_offset, descr.slot_active)
        new_pools = {"k": new_k, "v": new_v,
                     "k_scale": new_ks, "v_scale": new_vs}
    else:
        new_pools = {
            "k": ops.pool_write_stacked(pools["k"], ys[0], descr.write_block,
                                        descr.write_offset, descr.slot_active),
            "v": ops.pool_write_stacked(pools["v"], ys[1], descr.write_block,
                                        descr.write_offset, descr.slot_active),
        }
    if farview:
        new_pools["far_k"], new_pools["far_v"] = ys[2], ys[3]
    x = cm.rmsnorm(params["ln_f"], x, cfg.norm_eps)
    logits = logits_head(params, cfg, x)
    return logits, new_pools, fu / cfg.n_layers
