"""seamless-m4t-medium — enc-dec multimodal backbone [arXiv:2308.11596].

The audio frontend is a STUB per assignment: ``input_specs()`` provides
precomputed frame embeddings for the encoder; only the transformer
backbone (12L encoder + 12L decoder) is modeled.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="seamless-m4t-medium",
    family="encdec",
    n_layers=12,             # reported depth; realized as 12 enc + 12 dec
    d_model=1024,
    n_heads=16,
    n_kv_heads=16,
    head_dim=64,             # 1024 / 16
    d_ff=4096,
    vocab_size=256206,
    enc_layers=12,
    dec_layers=12,
    cross_attention=True,
    frontend="audio_stub",
    mlp_act="gelu",
    rope_theta=10000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256, enc_layers=2, dec_layers=2,
    )
