"""kimi-k2-1t-a32b — trillion-param MoE, 384 experts top-8 [arXiv:2501.kimi2]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="kimi-k2-1t-a32b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=2048,               # per-expert FFN width
    vocab_size=163840,
    n_experts=384,
    top_k=8,
    n_shared_experts=1,
    first_k_dense=1,
    dense_d_ff=18432,
    rope_theta=50000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=32, vocab_size=256, n_experts=8, top_k=2, n_shared_experts=1,
        first_k_dense=1, dense_d_ff=128,
    )
