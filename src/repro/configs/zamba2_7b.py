"""zamba2-7b — hybrid Mamba2 + shared attention blocks [arXiv:2411.15242]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="zamba2-7b",
    family="hybrid",
    n_layers=81,
    d_model=3584,
    n_heads=32,
    n_kv_heads=32,
    head_dim=112,            # 3584 / 32
    d_ff=14336,
    vocab_size=32000,
    ssm_state=64,
    ssm_expand=2,
    ssm_conv=4,
    ssm_headdim=64,
    shared_attn_every=6,     # one shared attention block applied every 6 mamba layers
    rope_theta=10000.0,
    sub_quadratic=True,      # Mamba2 state + KV-RM near-window shared attention
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=6, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=128, vocab_size=256, ssm_state=8, ssm_headdim=16,
        shared_attn_every=3,
    )
