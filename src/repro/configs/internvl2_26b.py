"""internvl2-26b — InternViT + InternLM2 backbone [arXiv:2404.16821].

The ViT frontend is a STUB per assignment: ``input_specs()`` provides
precomputed patch embeddings; only the LM backbone is modeled.
"""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="internvl2-26b",
    family="vlm",
    n_layers=48,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=92553,
    frontend="vision_stub",
    rope_theta=1000000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256,
    )
