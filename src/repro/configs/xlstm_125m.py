"""xlstm-125m — alternating sLSTM + mLSTM blocks [arXiv:2405.04517].

Attention-free: no KV cache exists, recurrent state is O(1) per session.
KV-RM's pager manages per-session state slots but the window/far-view/
transport-merging machinery is inapplicable (see DESIGN.md §4).
"""
from repro.configs.base import ModelConfig

_PATTERN = tuple("m" if i % 2 == 0 else "s" for i in range(12))

CONFIG = ModelConfig(
    arch_id="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    head_dim=192,            # 768 / 4
    d_ff=0,                  # xLSTM blocks integrate their own projections
    vocab_size=50304,
    ssm_expand=2,
    ssm_conv=4,
    ssm_headdim=192,
    xlstm_pattern=_PATTERN,
    sub_quadratic=True,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=4, d_model=64, n_heads=2, n_kv_heads=2, head_dim=32,
        vocab_size=256, ssm_headdim=32,
        xlstm_pattern=("m", "s", "m", "s"),
    )
