"""qwen3-32b — dense GQA with qk_norm [hf:Qwen/Qwen3 family]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="qwen3-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=64,
    n_kv_heads=8,
    head_dim=128,
    d_ff=25600,
    vocab_size=151936,
    qk_norm=True,
    rope_theta=1000000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256,
    )
