"""yi-34b — llama-arch dense GQA [arXiv:2403.04652]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="yi-34b",
    family="dense",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=20480,
    vocab_size=64000,
    rope_theta=5000000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256,
    )
