"""Base configuration dataclasses for all assigned architectures.

Every architecture in the assigned pool is expressed as a ``ModelConfig``.
Family-specific extensions (MoE, MLA, SSM, enc-dec) are optional fields so a
single registry / model builder can serve all ten architectures.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ServingConfig:
    """KV-RM serving-side configuration (paper defaults: Table 3)."""
    page_size: int = 16          # tokens per KV page
    near_window: int = 512       # W* — fixed near-window width
    farview_cap: int = 64        # cap — max far-view summary blocks
    sv_chunk: int = 128          # far-view summarization chunk size
    merge_threshold_bytes: int = 128 * 1024   # tau ~ 128 KiB
    max_hold_steps: int = 2      # delta — age cutoff for staged descriptors
    lookahead_pages: int = 1     # prefetch-1
    enable_farview: bool = False # optional policy, off by default (core path)
    skip_extent: bool = True     # work-skipping decode/prefill kernels: mask
                                 # whole out-of-extent window blocks off
                                 # (bitwise no-op; DESIGN.md §12)


@dataclass(frozen=True)
class SamplingConfig:
    """On-device sampling + data-dependent EOS (DESIGN.md §13).

    Bundles the per-run sampling policy the launcher hands to the engine
    (EngineConfig fields) and the per-request stop set it stamps on every
    submitted Request. ``greedy()`` mirrors the engine's legacy switch: the
    exact dispatch-retired budget-EOS path is kept bit-identical whenever no
    sampling knob is touched. "Greedy with stop tokens" is expressed as
    ``temperature=0`` with ``legacy=False`` — the argmax branch of the
    sampler, retired at readback like any sampled run.
    """
    temperature: float = 1.0     # <= 0 selects the exact argmax branch
    top_k: int = 0               # 0 disables the top-k filter
    top_p: float = 1.0           # 1.0 disables the nucleus filter
    seed: int = 0                # base PRNG key (threefry; folded per slot)
    stop_tokens: Tuple[int, ...] = ()   # any generated id in this set ends
                                        # the request ("stop" finish reason)
    legacy: bool = True          # True = legacy greedy budget-EOS path

    def greedy(self) -> bool:
        return self.legacy


@dataclass(frozen=True)
class ModelConfig:
    # --- identity ---
    arch_id: str
    family: str                  # dense | moe | hybrid | ssm | encdec | vlm
    # --- common transformer dims ---
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 128
    # --- attention details ---
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0
    # --- MLP ---
    mlp_act: str = "swiglu"      # swiglu | sq_relu | gelu
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    first_k_dense: int = 0       # leading dense layers (deepseek-v3 style)
    dense_d_ff: int = 0          # d_ff of those dense layers
    # --- MLA (deepseek-v3) ---
    use_mla: bool = False
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_rope_dim: int = 64
    qk_nope_dim: int = 128
    v_head_dim: int = 128
    # --- SSM / hybrid (zamba2 / xlstm) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_conv: int = 4
    ssm_headdim: int = 64
    shared_attn_every: int = 0   # zamba2: shared attention block period
    xlstm_pattern: Tuple[str, ...] = ()   # e.g. ('m','s','m','s',...)
    # --- encoder-decoder (seamless) ---
    enc_layers: int = 0
    dec_layers: int = 0
    cross_attention: bool = False
    # --- modality frontend (stubbed; input_specs provides embeddings) ---
    frontend: str = "none"       # none | vision_stub | audio_stub
    # --- norm ---
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # --- serving ---
    serving: ServingConfig = field(default_factory=ServingConfig)
    # --- attention semantics for long-context decode ---
    # 'dense'            : full attention over history (quadratic prefill, O(T) decode reads)
    # 'native_subquad'   : SSM/hybrid — O(1) state or bounded window natively
    sub_quadratic: bool = False

    # ------------------------------------------------------------------
    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    @property
    def kv_width(self) -> int:
        """Per-token K+V width in elements for one layer (paged payload)."""
        if self.use_mla:
            # MLA pages the compressed latent: c_kv (kv_lora_rank) + decoupled rope key
            return self.kv_lora_rank + self.qk_rope_dim
        return 2 * self.n_kv_heads * self.head_dim

    @property
    def n_attn_layers(self) -> int:
        if self.family == "hybrid":
            return max(1, self.n_layers // max(1, self.shared_attn_every))
        if self.family == "ssm":
            return 0
        if self.family == "encdec":
            return self.dec_layers
        return self.n_layers

    def param_count(self) -> int:
        """Approximate total parameter count (used for MODEL_FLOPS)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        emb = v * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":        # xlstm
            per = 0
            for kind in (self.xlstm_pattern or ("m",) * self.n_layers):
                if kind == "m":
                    di = self.ssm_expand * d
                    per += 2 * d * di + di * d + 3 * di * self.ssm_headdim  # up/gate/down + qkv-ish
                else:
                    per += 4 * d * d + d * (self.d_ff or 4 * d) * 2
            return per + emb
        if self.family == "hybrid":
            di = self.ssm_expand * d
            mamba_per = d * (2 * di + 2 * self.ssm_state) + di * d + di * (self.ssm_conv + 3)
            attn_per = 2 * d * self.n_heads * self.head_dim + 2 * d * self.n_kv_heads * self.head_dim
            mlp_per = 3 * d * f
            n_attn = self.n_attn_layers
            return self.n_layers * mamba_per + n_attn * (attn_per + mlp_per) // max(1, n_attn) + emb
        # attention dims
        if self.use_mla:
            attn = (d * self.q_lora_rank
                    + self.q_lora_rank * self.n_heads * (self.qk_nope_dim + self.qk_rope_dim)
                    + d * (self.kv_lora_rank + self.qk_rope_dim)
                    + self.kv_lora_rank * self.n_heads * (self.qk_nope_dim + self.v_head_dim)
                    + self.n_heads * self.v_head_dim * d)
        else:
            attn = (d * self.n_heads * self.head_dim          # Q
                    + 2 * d * self.n_kv_heads * self.head_dim # K,V
                    + self.n_heads * self.head_dim * d)       # O
        gate = 3 if self.mlp_act == "swiglu" else 2
        if self.family == "moe":
            n_layers_moe = self.n_layers - self.first_k_dense
            mlp_moe = gate * d * f * (self.n_experts + self.n_shared_experts)
            mlp_dense = gate * d * (self.dense_d_ff or f)
            router = d * self.n_experts
            layers = (n_layers_moe * (attn + mlp_moe + router)
                      + self.first_k_dense * (attn + mlp_dense))
        elif self.family == "encdec":
            enc = self.enc_layers * (attn + gate * d * f)
            dec = self.dec_layers * (2 * attn + gate * d * f)  # self + cross
            layers = enc + dec
        else:
            layers = self.n_layers * (attn + gate * d * f)
        return layers + emb

    def active_param_count(self) -> int:
        """Activated params per token (MoE: routed top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        full = self.param_count()
        gate = 3 if self.mlp_act == "swiglu" else 2
        n_layers_moe = self.n_layers - self.first_k_dense
        all_experts = gate * self.d_model * self.d_ff * self.n_experts * n_layers_moe
        active_experts = gate * self.d_model * self.d_ff * self.top_k * n_layers_moe
        return full - all_experts + active_experts


@dataclass(frozen=True)
class ShapeConfig:
    """One assigned input-shape cell."""
    name: str               # train_4k | prefill_32k | decode_32k | long_500k
    seq_len: int
    global_batch: int
    kind: str               # train | prefill | decode


SHAPES = {
    "train_4k":    ShapeConfig("train_4k",    4_096,   256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768,  32,  "prefill"),
    "decode_32k":  ShapeConfig("decode_32k",  32_768,  128, "decode"),
    "long_500k":   ShapeConfig("long_500k",   524_288, 1,   "decode"),
}
