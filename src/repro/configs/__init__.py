"""Architecture config registry: ``get_config(arch_id)`` / ``get_reduced(arch_id)``."""
from __future__ import annotations

from repro.configs.base import ModelConfig, ServingConfig, ShapeConfig, SHAPES
from repro.configs import (  # noqa: F401
    zamba2_7b, kimi_k2_1t_a32b, deepseek_v3_671b, qwen2_5_32b, qwen3_32b,
    yi_34b, nemotron_4_15b, internvl2_26b, xlstm_125m, seamless_m4t_medium,
)

_MODULES = {
    "zamba2-7b": zamba2_7b,
    "kimi-k2-1t-a32b": kimi_k2_1t_a32b,
    "deepseek-v3-671b": deepseek_v3_671b,
    "qwen2.5-32b": qwen2_5_32b,
    "qwen3-32b": qwen3_32b,
    "yi-34b": yi_34b,
    "nemotron-4-15b": nemotron_4_15b,
    "internvl2-26b": internvl2_26b,
    "xlstm-125m": xlstm_125m,
    "seamless-m4t-medium": seamless_m4t_medium,
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch_id: str) -> ModelConfig:
    if arch_id not in _MODULES:
        raise KeyError(f"unknown arch {arch_id!r}; known: {sorted(_MODULES)}")
    return _MODULES[arch_id].CONFIG


def get_reduced(arch_id: str) -> ModelConfig:
    return _MODULES[arch_id].reduced()


__all__ = [
    "ModelConfig", "ServingConfig", "ShapeConfig", "SHAPES",
    "ARCH_IDS", "get_config", "get_reduced",
]
