"""deepseek-v3-671b — MLA, 1 shared + 256 routed top-8 experts [arXiv:2412.19437]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,          # MLA: logical kv heads == heads; paged payload is the latent
    head_dim=128,
    d_ff=2048,               # per-expert FFN width
    vocab_size=129280,
    n_experts=256,
    top_k=8,
    n_shared_experts=1,
    first_k_dense=3,
    dense_d_ff=18432,
    use_mla=True,
    kv_lora_rank=512,
    q_lora_rank=1536,
    qk_rope_dim=64,
    qk_nope_dim=128,
    v_head_dim=128,
    rope_theta=10000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, head_dim=16,
        d_ff=32, vocab_size=256, n_experts=8, top_k=2, n_shared_experts=1,
        first_k_dense=1, dense_d_ff=128,
        kv_lora_rank=32, q_lora_rank=48, qk_rope_dim=8, qk_nope_dim=16,
        v_head_dim=16,
    )
