"""nemotron-4-15b — dense GQA with squared-ReLU MLP [arXiv:2402.16819]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    arch_id="nemotron-4-15b",
    family="dense",
    n_layers=32,
    d_model=6144,
    n_heads=48,
    n_kv_heads=8,
    head_dim=128,
    d_ff=24576,
    vocab_size=256000,
    mlp_act="sq_relu",
    rope_theta=10000.0,
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
        d_ff=128, vocab_size=256,
    )
