"""Fault-tolerant checkpointing: atomic on-disk snapshots of the full
training state (params, optimizer, error-feedback, data cursor, pager state
for serving), async background writes, retention, and deterministic resume.

Format: one .npz per snapshot (flattened pytree with path-encoded keys) plus
a JSON manifest written LAST via atomic rename — a torn write can never be
mistaken for a complete checkpoint (node-failure safety).
"""
from __future__ import annotations

import json
import os
import pickle
import shutil
import threading
import time
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten(tree, prefix=""):
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)) and not hasattr(tree, "_fields"):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}{i}/"))
    elif hasattr(tree, "_fields"):              # NamedTuple
        for k in tree._fields:
            out.update(_flatten(getattr(tree, k), f"{prefix}{k}/"))
    else:
        out[prefix.rstrip("/")] = np.asarray(tree)
    return out


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        os.makedirs(directory, exist_ok=True)
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()
        self.write_count = 0

    # ------------------------------------------------------------------
    def save(self, step: int, state: Dict[str, Any], block: bool = False) -> None:
        """state: dict of pytrees + picklable host objects under 'host'."""
        # snapshot to host memory synchronously (device buffers may be donated
        # by the next step), then write in the background
        arrays = {k: v for k, v in state.items() if k != "host"}
        flat = _flatten(arrays)
        flat = {k: np.asarray(v) for k, v in flat.items()}
        # npz cannot represent ml_dtypes (bf16 etc.) — store raw bits + dtype
        dtype_map = {}
        for k, v in list(flat.items()):
            if v.dtype.kind not in "biufc":     # already numpy-native
                dtype_map[k] = str(v.dtype)
                flat[k] = v.view(np.uint16 if v.dtype.itemsize == 2 else np.uint8)
            elif str(v.dtype) not in ("float64",) and v.dtype.num > 23:
                dtype_map[k] = str(v.dtype)
                flat[k] = v.view(f"u{v.dtype.itemsize}")
        host_blob = pickle.dumps(state.get("host", {}))

        def _write():
            path = os.path.join(self.dir, f"ckpt_{step:08d}")
            tmp = path + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, "arrays.npz"), **flat)
            with open(os.path.join(tmp, "host.pkl"), "wb") as f:
                f.write(host_blob)
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump({"step": step, "keys": sorted(flat),
                           "dtypes": dtype_map, "time": time.time()}, f)
            if os.path.exists(path):
                shutil.rmtree(path)
            os.rename(tmp, path)                 # atomic publish
            with self._lock:
                self.write_count += 1
            self._gc()

        if self.async_write and not block:
            self.wait()                          # one writer at a time
            self._thread = threading.Thread(target=_write, daemon=True)
            self._thread.start()
        else:
            _write()

    def wait(self) -> None:
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # ------------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = []
        for name in os.listdir(self.dir):
            if name.startswith("ckpt_") and not name.endswith(".tmp") and \
               os.path.exists(os.path.join(self.dir, name, "manifest.json")):
                steps.append(int(name.split("_")[1]))
        return max(steps) if steps else None

    def restore(self, template: Dict[str, Any],
                step: Optional[int] = None) -> Dict[str, Any]:
        """Restore into the structure of `template` (same pytree shape)."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoint in {self.dir}")
        path = os.path.join(self.dir, f"ckpt_{step:08d}")
        raw = np.load(os.path.join(path, "arrays.npz"))
        with open(os.path.join(path, "manifest.json")) as f:
            manifest = json.load(f)
        import ml_dtypes  # noqa: F401  (registers bf16 etc. with numpy)
        data = {}
        for k in raw.files:
            v = raw[k]
            if k in manifest.get("dtypes", {}):
                v = v.view(np.dtype(manifest["dtypes"][k]))
            data[k] = v
        with open(os.path.join(path, "host.pkl"), "rb") as f:
            host = pickle.load(f)

        arrays = {k: v for k, v in template.items() if k != "host"}
        flat_t = _flatten(arrays)
        missing = set(flat_t) - set(data)
        if missing:
            raise ValueError(f"checkpoint missing keys: {sorted(missing)[:5]}")

        leaves, treedef = jax.tree.flatten(arrays)
        # rebuild by re-flattening with the same deterministic order
        keys = list(_flatten(arrays).keys())
        new_leaves = [jnp.asarray(data[k]) for k in keys]
        restored = jax.tree.unflatten(treedef, new_leaves)
        out = dict(restored)
        out["host"] = host
        out["step"] = step
        return out

    # ------------------------------------------------------------------
    def _gc(self) -> None:
        steps = sorted(
            int(n.split("_")[1]) for n in os.listdir(self.dir)
            if n.startswith("ckpt_") and not n.endswith(".tmp"))
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"ckpt_{s:08d}"),
                          ignore_errors=True)
