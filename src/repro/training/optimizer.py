"""AdamW with pytree state, optional bf16 moments (memory posture for the
>=671B archs), global-norm clipping, and warmup+cosine schedule. No external
optimizer deps.
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class OptimizerConfig:
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    moment_dtype: str = "float32"     # 'bfloat16' for the 671B/1T archs


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any


def init_opt_state(params, cfg: OptimizerConfig) -> OptState:
    mdt = jnp.bfloat16 if cfg.moment_dtype == "bfloat16" else jnp.float32
    z = lambda p: jnp.zeros(p.shape, mdt)
    return OptState(step=jnp.zeros((), jnp.int32),
                    mu=jax.tree.map(z, params),
                    nu=jax.tree.map(z, params))


def schedule(cfg: OptimizerConfig, step):
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * (0.1 + 0.9 * cos)


def global_norm(tree):
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree.reduce(lambda a, b: a + b, sq))


def apply_updates(params, grads, state: OptState, cfg: OptimizerConfig):
    """One AdamW step. Returns (new_params, new_state, metrics)."""
    step = state.step + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12))
    b1, b2 = cfg.betas
    lr = schedule(cfg, state.step)
    c1 = 1.0 - b1 ** step.astype(jnp.float32)
    c2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m32 = m.astype(jnp.float32) * b1 + (1 - b1) * g
        v32 = v.astype(jnp.float32) * b2 + (1 - b2) * g * g
        mh = m32 / c1
        vh = v32 / c2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        newp = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return newp, m32.astype(m.dtype), v32.astype(v.dtype)

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, OptState(step, new_m, new_v), {"grad_norm": gnorm, "lr": lr}
