"""Training step factory: causal-LM loss, remat, gradient accumulation
(microbatch scan), optional gradient compression with error feedback, MoE aux
loss. The returned step is pure and jit/pjit-friendly; sharding is applied by
the launcher (launch/train.py, launch/dryrun.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import compression
from repro.models import registry
from repro.training.optimizer import OptimizerConfig, OptState, apply_updates


@dataclass(frozen=True)
class TrainConfig:
    microbatches: int = 1             # gradient accumulation
    remat: bool = True
    aux_loss_weight: float = 0.01     # MoE load balance
    compression: str = "none"         # none | bf16 | int8
    token_groups: int = 1             # MoE dispatch groups (= data shards)
    ep_axes: tuple = None             # mesh axes carrying expert parallelism
    batch_axes: tuple = None          # mesh axes sharding batch rows (for the
                                      # microbatch reshape constraint)
    accum_dtype: str = "float32"      # gradient accumulator dtype


def lm_loss(params, cfg: ModelConfig, tokens, extra_embeds=None, *,
            remat: bool = True, aux_w: float = 0.01, token_groups: int = 1,
            ep_axes=None):
    """Next-token cross-entropy (ignores the last position's prediction)."""
    kw = {}
    if cfg.family == "moe":
        logits, aux = registry.forward(params, cfg, tokens, remat=remat,
                                       token_groups=token_groups,
                                       return_aux=True, ep_axes=ep_axes,
                                       extra_embeds=extra_embeds)
    else:
        if extra_embeds is not None:
            kw["extra_embeds"] = extra_embeds
        logits = registry.forward(params, cfg, tokens, remat=remat, **kw)
        aux = jnp.zeros((), jnp.float32)
    logits = logits.astype(jnp.float32)
    tgt = tokens[:, 1:]
    lg = logits[:, :-1]
    ll = jax.nn.log_softmax(lg, axis=-1)
    nll = -jnp.take_along_axis(ll, tgt[..., None], axis=-1)[..., 0]
    loss = nll.mean()
    return loss + aux_w * aux, {"loss": loss, "aux": aux}


def make_train_step(cfg: ModelConfig, ocfg: OptimizerConfig,
                    tcfg: TrainConfig):
    """Returns train_step(params, opt_state, err_fb, batch) ->
    (params, opt_state, err_fb, metrics). batch: dict(tokens (B,S),
    optional extra_embeds)."""

    def grads_of(params, tokens, extra):
        (l, m), g = jax.value_and_grad(
            lambda p: lm_loss(p, cfg, tokens, extra, remat=tcfg.remat,
                              aux_w=tcfg.aux_loss_weight,
                              token_groups=tcfg.token_groups,
                              ep_axes=tcfg.ep_axes),
            has_aux=True)(params)
        return l, m, g

    def train_step(params, opt_state: OptState, err_fb, batch):
        tokens = batch["tokens"]
        extra = batch.get("extra_embeds")
        mb = tcfg.microbatches
        if mb > 1:
            B = tokens.shape[0]
            # keep ROWS data-sharded after the microbatch split — without the
            # constraint XLA shards the scan dim and replicates each
            # microbatch across the data axis (16x overwork; see §Perf log)
            tk = tokens.reshape(B // mb, mb, -1).swapaxes(0, 1)
            ex = (extra.reshape(B // mb, mb, *extra.shape[1:]).swapaxes(0, 1)
                  if extra is not None else None)
            if tcfg.batch_axes:
                from jax.sharding import PartitionSpec as _P
                wsc = jax.lax.with_sharding_constraint
                tk = wsc(tk, _P(None, tcfg.batch_axes, None))
                if ex is not None:
                    ex = wsc(ex, _P(None, tcfg.batch_axes,
                                    *([None] * (ex.ndim - 2))))

            def acc_step(carry, xs):
                gacc, lacc = carry
                tkn = xs[0]
                exn = xs[1] if extra is not None else None
                l, m, g = grads_of(params, tkn, exn)
                gacc = jax.tree.map(
                    lambda a, b: (a.astype(jnp.float32)
                                  + b.astype(jnp.float32) / mb).astype(a.dtype),
                    gacc, g)
                return (gacc, lacc + l / mb), None

            adt = jnp.bfloat16 if tcfg.accum_dtype == "bfloat16" else jnp.float32
            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, adt), params)
            xs = (tk, ex) if extra is not None else (tk,)
            (grads, loss), _ = jax.lax.scan(acc_step, (g0, 0.0), xs)
            metrics = {"loss": loss, "aux": jnp.zeros((), jnp.float32)}
        else:
            loss, metrics, grads = grads_of(params, tokens, extra)

        # gradient compression across the pod axis (error feedback keeps the
        # optimizer unbiased); the actual reduce is XLA-inserted under pjit —
        # the dtype of `grads` at this boundary is what crosses the wire.
        if tcfg.compression == "bf16":
            grads, err_fb = compression.compress_bf16(grads, err_fb)
            grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        elif tcfg.compression == "int8":
            (wire, scales), err_fb = compression.compress_int8(grads, err_fb)
            grads = compression.decompress_int8(wire, scales)

        params, opt_state, om = apply_updates(params, grads, opt_state, ocfg)
        metrics.update(om)
        return params, opt_state, err_fb, metrics

    return train_step
