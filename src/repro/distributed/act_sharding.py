"""Activation sharding hook. Model code is mesh-agnostic; the launcher sets
the batch axes here and models call ``constrain_batch(x)`` at block
boundaries so XLA's propagation never re-shards the batch dim onto the wrong
axis (observed: auto-SPMD re-sharding attention activations 8x fat).

No-op when unset (CPU tests, engine).
"""
from __future__ import annotations

from contextlib import contextmanager
from typing import Optional, Tuple

import jax
from jax.sharding import PartitionSpec as P

_BATCH_AXES: Optional[Tuple[str, ...]] = None
_MODEL_AXIS: Optional[str] = None


def set_batch_axes(axes) -> None:
    global _BATCH_AXES
    _BATCH_AXES = axes


@contextmanager
def use_batch_axes(axes):
    global _BATCH_AXES
    prev = _BATCH_AXES
    _BATCH_AXES = axes
    try:
        yield
    finally:
        _BATCH_AXES = prev


@contextmanager
def use_model_axis(axis):
    global _MODEL_AXIS
    prev = _MODEL_AXIS
    _MODEL_AXIS = axis
    try:
        yield
    finally:
        _MODEL_AXIS = prev


def constrain_model_dim(x, dim: int = -1):
    """Pin dim (default last) to the model axis — used on decode q so the
    paged-attention contraction stays a partial-score psum instead of an
    all-gather of the hd-sharded KV window (EXPERIMENTS.md §Perf iter. 3)."""
    if _MODEL_AXIS is None:
        return x
    spec = [None] * x.ndim
    spec[dim] = _MODEL_AXIS
    return jax.lax.with_sharding_constraint(x, P(*spec))


def constrain_batch(x, batch_dim: int = 0):
    """Pin x's batch dim to the configured axes; other dims unconstrained."""
    if _BATCH_AXES is None:
        return x
    spec = [None] * x.ndim
    spec[batch_dim] = _BATCH_AXES
    return jax.lax.with_sharding_constraint(x, P(*spec))
