"""Per-architecture sharding rules (DP/TP/EP + pod axis), name-based.

Parameters are matched by their pytree path; layer-stacked params (leading L
dim from stack_layers) get a None prepended automatically by matching on
trailing dimensions. The `model` axis carries TP (heads / FFN hidden / vocab);
`data` (+`pod`) carries batch, token groups, and — for the giant MoE archs —
expert storage (EP via resharding constraints inside moe_apply).
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

MODEL = "model"


def _spec_for(path: str, shape: tuple, cfg: ModelConfig, ep_axes) -> P:
    """TP spec by parameter name. `path` is '/'-joined pytree keys."""
    name = path.lower()
    nd = len(shape)

    def last2(spec_in, spec_out):
        """Spec for a 2D weight, padded with None for leading stack dims."""
        return P(*([None] * (nd - 2) + [spec_in, spec_out]))

    # ---- embeddings / heads -------------------------------------------
    if name.endswith("embed"):
        return P(MODEL, None)                      # vocab-sharded
    if "lm_head" in name and name.endswith("/w"):
        return last2(None, MODEL)
    # ---- MoE experts (EP: E over data/pod+data, f over model) ----------
    if "w_gate" in name or "w_up" in name:         # (E, d, f)
        return P(*([None] * (nd - 3) + [ep_axes, None, MODEL]))
    if "w_down" in name:                           # (E, f, d)
        return P(*([None] * (nd - 3) + [ep_axes, MODEL, None]))
    if "router" in name:
        return P(*([None] * nd))
    # ---- attention / MLA ----------------------------------------------
    if any(k in name for k in ("wq", "wk", "wv", "wq_b", "wk_b", "wv_b",
                               "w_if", "w_o/", "up/", "gate/", "w_gates",
                               "in_proj")) and name.endswith("/w"):
        return last2(None, MODEL)
    if any(k in name for k in ("wo", "down", "out_proj")) and name.endswith("/w"):
        return last2(MODEL, None)
    if "wq_a" in name or "wkv_a" in name:
        return last2(None, None)                   # small latent projections
    if "r_gates" in name and nd >= 3:              # (H, hd, 4hd)
        return P(*([None] * (nd - 3) + [MODEL, None, None]))
    # ---- biases of sharded projections ---------------------------------
    if name.endswith("/b") and nd >= 1:
        return P(*([None] * (nd - 1) + [MODEL]))
    # ---- everything else (norms, convs, scalars): replicated -----------
    return P(*([None] * nd))


def param_specs(cfg: ModelConfig, params_shapes, ep_axes=None):
    """PartitionSpec pytree matching params (shapes from jax.eval_shape).
    ep_axes: axis (or tuple) to shard MoE expert storage over (EP)."""
    def walk(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: walk(v, f"{prefix}{k}/") for k, v in tree.items()}
        return _spec_for(prefix[:-1], tree.shape, cfg, ep_axes)
    return walk(params_shapes)


def to_shardings(mesh: Mesh, specs):
    return jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                        is_leaf=lambda x: isinstance(x, P))


def sanitize_specs(mesh: Mesh, shapes, specs):
    """Drop shardings on dims not divisible by their mesh axes (pjit requires
    exact divisibility; small tensors fall back to replication)."""
    def axis_size(ax) -> int:
        if ax is None:
            return 1
        if isinstance(ax, (tuple, list)):
            n = 1
            for a in ax:
                n *= mesh.shape[a]
            return n
        return mesh.shape[ax]

    def fix(sd, spec):
        dims = sd.shape
        new = []
        for i in range(len(dims)):
            ax = spec[i] if i < len(spec) else None
            new.append(ax if (ax is None or dims[i] % axis_size(ax) == 0)
                       else None)
        return P(*new)

    return jax.tree.map(fix, shapes, specs,
                        is_leaf=lambda x: hasattr(x, "shape"))


def batch_axes(mesh: Mesh) -> tuple:
    """Axes that shard the batch/token dimension (pod composes with data)."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def data_shards(mesh: Mesh) -> int:
    n = 1
    for a in batch_axes(mesh):
        n *= mesh.shape[a]
    return n


def model_shards(mesh: Mesh) -> int:
    return mesh.shape.get(MODEL, 1) if mesh is not None else 1


# ---------------------------------------------------------------------------
# engine pool shardings (serving-engine layout: NO leading group dim)
# ---------------------------------------------------------------------------

def engine_pool_specs(cfg: ModelConfig, pools_shapes):
    """PartitionSpecs for KVRMEngine decode pools (DESIGN.md §4).

    Tensor-parallel decode shards the *kv-head* axis over `model`: each shard
    owns KV/tp kv heads with their full head_dim, so the GQA `n_rep` grouping
    (H/KV query heads per kv head) is preserved per shard and the attention
    softmax needs no collective — the single psum per layer happens at the
    output projection. This differs from `grouped_pool_specs` (dry-run
    grouped layout), which shards head_dim for head-count-agnostic analysis.

    Replicated: MLA latent pools (the compressed c_kv is shared by ALL heads —
    that is the point of MLA; head parallelism lives in w_k_b/w_v_b instead),
    sequential-state buffers (conv/ssd/xlstm), and scalar per-slot metadata.
    """

    def spec(path: str, shape):
        nd = len(shape)
        name = path.split("/")[-1].lower()
        full = path.lower()
        if (full.startswith("m/") or full.startswith("s/")) and name != "conv":
            # xlstm recurrent states (pairs, B, H, ...): heads over model
            return P(None, None, MODEL, *([None] * (nd - 3)))
        if name in ("k", "v"):          # (L, P, BT, KV, hd)
            return P(None, None, None, MODEL, None)
        if name in ("k_scale", "v_scale"):   # (L, P, KV) — quant tier §10:
            # scales shard with their kv heads, lockstep with the data pool
            return P(None, None, MODEL)
        if name.startswith("far_") and name != "far_lat":
            return P(*([None] * (nd - 2)), MODEL, None)   # (L,B,MAXC,KV,hd)
        if name.startswith("cross_"):   # (L, B, Se, KV, hd)
            return P(None, None, None, MODEL, None)
        return P(*([None] * nd))        # lat / far_lat / states / enc_len

    def walk(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: walk(v, f"{prefix}{k}/") for k, v in tree.items()}
        return spec(prefix[:-1], tree.shape)

    return walk(pools_shapes)


# ---------------------------------------------------------------------------
# decode pool shardings (grouped layout: leading G dim = serving groups)
# ---------------------------------------------------------------------------

def grouped_pool_specs(cfg: ModelConfig, pools_shapes, bspec):
    """Pools carry a leading group dim G sharded over the batch axes (each
    serving group owns its shard-local pool; gathers stay local — verified
    collective-free). Payload kv-head dims shard over `model`."""

    def spec(path: str, shape):
        nd = len(shape)
        name = path.split("/")[-1].lower()
        full = path.lower()
        if name == "enc_len":
            return P(bspec, None)
        if full.startswith("m/") or full.startswith("s/"):
            # xlstm states (G, pairs, B, H, ...): heads over model
            return P(bspec, None, None, MODEL, *([None] * (nd - 4)))
        if "conv_state" in name or "ssd_state" in name:
            return P(bspec, *([None] * (nd - 1)))
        # payload dims: shard head_dim (or the MLA latent) over `model` —
        # kv-head counts (8) don't divide model=16, head_dim does for every
        # assigned arch (128/112/64; MLA latent 576). Decode attention then
        # psums partial scores over `model` (standard TP decode contraction).
        if name.startswith("cross_"):   # (G, L, B, Se, KV, hd)
            return P(bspec, None, None, None, None, MODEL)
        if name.startswith("far_"):     # (G, L, B, MAXC, [KV, hd] | [R])
            return P(bspec, *([None] * (nd - 2) + [MODEL]))
        if name in ("k", "v"):          # (G, L, P, BT, KV, hd)
            return P(bspec, None, None, None, None, MODEL)
        if name == "lat":               # (G, L, P, BT, R)
            return P(bspec, None, None, None, MODEL)
        return P(bspec, *([None] * (nd - 1)))

    def walk(tree, prefix=""):
        if isinstance(tree, dict):
            return {k: walk(v, f"{prefix}{k}/") for k, v in tree.items()}
        return spec(prefix[:-1], tree.shape)

    return walk(pools_shapes)
