"""Fault-tolerance substrate for 1000+-node posture (DESIGN.md §5).

Three cooperating pieces, all host-side and simulation-testable:

  * HeartbeatMonitor — per-worker liveness with grace windows; emits
    `on_failure(worker)` exactly once per incident. In production the
    callback triggers checkpoint-restore on a replacement slice; in tests it
    drives the same CheckpointManager.restore path the resume drill uses.

  * StragglerMitigator — per-step latency EWMA; steps exceeding
    ``threshold x EWMA`` are flagged. For serving, the mitigation is a hedged
    decode step (re-issue the step on the standby group: decode steps are
    idempotent — the frame descriptor is committed once and replaying the
    same epoch is a no-op by pager idempotency). For training, the policy is
    step-skip quorum: proceed when >= quorum of workers reported.

  * ElasticPlan — pager/session state is device-count-agnostic (logical
    blocks), so growing or shrinking the data axis is a re-shard of pool
    contents plus a slot re-assignment; plan_resize computes the minimal
    session-move plan.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple


class HeartbeatMonitor:
    def __init__(self, workers: List[str], timeout: float,
                 on_failure: Optional[Callable[[str], None]] = None):
        self.timeout = timeout
        self.on_failure = on_failure
        self.last_seen: Dict[str, float] = {w: 0.0 for w in workers}
        self.failed: Dict[str, float] = {}

    def beat(self, worker: str, now: float) -> None:
        if worker in self.failed:
            # worker came back: treat as a fresh join (caller re-admits)
            del self.failed[worker]
        self.last_seen[worker] = now

    def check(self, now: float) -> List[str]:
        """Returns newly-failed workers (each reported once)."""
        newly = []
        for w, t in self.last_seen.items():
            if w not in self.failed and now - t > self.timeout:
                self.failed[w] = now
                newly.append(w)
                if self.on_failure:
                    self.on_failure(w)
        return newly

    def alive(self) -> List[str]:
        return [w for w in self.last_seen if w not in self.failed]


class StragglerMitigator:
    def __init__(self, threshold: float = 3.0, decay: float = 0.9,
                 min_samples: int = 8):
        self.threshold = threshold
        self.decay = decay
        self.min_samples = min_samples
        self.ewma: Optional[float] = None
        self.n = 0
        self.hedged_steps: List[int] = []

    def observe(self, step: int, wall: float) -> bool:
        """Record a step time; True if this step should be hedged."""
        self.n += 1
        if self.ewma is None:
            self.ewma = wall
            return False
        is_straggler = (self.n > self.min_samples
                        and wall > self.threshold * self.ewma)
        if is_straggler:
            self.hedged_steps.append(step)
        else:
            # stragglers don't poison the baseline
            self.ewma = self.decay * self.ewma + (1 - self.decay) * wall
        return is_straggler


@dataclass
class ElasticPlan:
    old_groups: int
    new_groups: int
    session_moves: List[Tuple[int, int, int]]   # (sid, old_group, new_group)
    pool_reshard: bool

    @property
    def moved_sessions(self) -> int:
        return len(self.session_moves)


def plan_resize(session_groups: Dict[int, int], old_groups: int,
                new_groups: int) -> ElasticPlan:
    """Minimal-move session re-assignment when the data axis resizes.

    Sessions on surviving groups stay; sessions on removed groups (or excess
    load when growing) move to the least-loaded new group. Pager state moves
    with the session (logical block lists are device-agnostic; physical pool
    contents are re-sharded by the runtime copy plan)."""
    assert new_groups >= 1
    load = {g: 0 for g in range(new_groups)}
    moves: List[Tuple[int, int, int]] = []
    for sid, g in sorted(session_groups.items()):
        if g < new_groups:
            load[g] += 1
    for sid, g in sorted(session_groups.items()):
        if g >= new_groups:
            tgt = min(load, key=load.get)
            moves.append((sid, g, tgt))
            load[tgt] += 1
    return ElasticPlan(old_groups=old_groups, new_groups=new_groups,
                       session_moves=moves,
                       pool_reshard=new_groups != old_groups)
