"""Gradient compression for cross-pod all-reduce (distributed-optimization
trick; DESIGN.md §5).

Two schemes, both with error feedback so compression noise doesn't bias the
optimizer:
  * 'bf16'  — cast fp32 grads to bf16 before the reduce (2x wire bytes).
  * 'int8'  — per-tensor symmetric int8 with an fp32 scale (4x wire bytes);
              the scale itself is max-reduced first so all ranks dequantize
              identically.

Under pjit the reduce itself is XLA-inserted; these transforms change the
dtype (and therefore bytes) of what crosses the pod axis. Error feedback
state lives next to the optimizer state and is checkpointed with it.
"""
from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def init_error_feedback(params) -> Any:
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.bfloat16), params)


def compress_bf16(grads, err):
    """Returns (wire_grads bf16, new_err). decompress = astype(fp32)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e.astype(jnp.float32)
        wire = g32.astype(jnp.bfloat16)
        new_e = (g32 - wire.astype(jnp.float32)).astype(jnp.bfloat16)
        return wire, new_e
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    return tdef.unflatten([o[0] for o in out]), tdef.unflatten([o[1] for o in out])


def compress_int8(grads, err):
    """Returns ((wire int8, scales fp32), new_err)."""
    def one(g, e):
        g32 = g.astype(jnp.float32) + e.astype(jnp.float32)
        scale = jnp.maximum(jnp.max(jnp.abs(g32)), 1e-12) / 127.0
        q = jnp.clip(jnp.round(g32 / scale), -127, 127).astype(jnp.int8)
        deq = q.astype(jnp.float32) * scale
        new_e = (g32 - deq).astype(jnp.bfloat16)
        return (q, scale), new_e
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = tdef.flatten_up_to(err)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    wires = tdef.unflatten([o[0][0] for o in out])
    scales = tdef.unflatten([o[0][1] for o in out])
    return (wires, scales), tdef.unflatten([o[1] for o in out])


def decompress_int8(wires, scales):
    return jax.tree.map(lambda q, s: q.astype(jnp.float32) * s, wires, scales)


def wire_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))
