"""Backend-aware kernel runtime policy (shared by all Pallas entry points).

Two decisions every kernel wrapper needs, made once here:

* ``resolve_interpret`` — whether ``pl.pallas_call`` should run in interpret
  mode. Historically every entry point defaulted ``interpret=True`` and every
  non-CPU caller had to remember to flip it; now the default (``None``)
  resolves from the active jax backend: CPU -> interpret (there is no Mosaic
  lowering to run), TPU/GPU -> compiled. An explicit bool always wins, and
  ``REPRO_PALLAS_INTERPRET=0/1`` force-overrides for debugging a compiled
  backend with the interpreter.

* ``interpret_dma_supported`` — whether this jax's interpret mode implements
  the ``pltpu.make_async_copy`` / DMA-semaphore primitives the
  double-buffered decode path uses. Probed once with a tiny pallas_call and
  cached; the double-buffered kernel falls back to direct ANY-space reads
  (same buffering structure, no semaphores) when the probe fails, so the CPU
  suite still exercises the staging logic on older jax.
"""
from __future__ import annotations

import functools
import os

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def resolve_interpret(interpret=None) -> bool:
    """Resolve the interpret flag for a Pallas kernel launch.

    Explicit ``True``/``False`` is honored as-is; ``None`` (the new entry
    point default) means "interpret iff the backend has no kernel compiler"
    — i.e. CPU. ``REPRO_PALLAS_INTERPRET`` overrides the backend resolution
    (but not an explicit argument).
    """
    if interpret is not None:
        return bool(interpret)
    env = os.environ.get("REPRO_PALLAS_INTERPRET")
    if env is not None and env != "":
        return env not in ("0", "false", "False")
    return jax.default_backend() == "cpu"


@functools.lru_cache(maxsize=None)
def interpret_dma_supported() -> bool:
    """True iff interpret mode runs pltpu async-copy + DMA semaphores.

    Cached module-wide; the probe is a one-off ~ms interpret launch on
    concrete inputs (safe to call during tracing — concrete-array pallas
    execution is eager, never staged into an ambient trace).
    """
    try:
        def _k(x_ref, o_ref, buf, sem):
            pltpu.make_async_copy(x_ref.at[0], buf.at[0], sem.at[0]).start()
            pltpu.make_async_copy(x_ref.at[0], buf.at[0], sem.at[0]).wait()
            o_ref[...] = buf[0]

        out = pl.pallas_call(
            _k,
            in_specs=[pl.BlockSpec(memory_space=pltpu.ANY)],
            out_specs=pl.BlockSpec((8,), lambda: (0,)),
            out_shape=jax.ShapeDtypeStruct((8,), jnp.float32),
            scratch_shapes=[pltpu.VMEM((1, 8), jnp.float32),
                            pltpu.SemaphoreType.DMA((1,))],
            interpret=True,
        )(jnp.arange(8, dtype=jnp.float32)[None, :])
        return bool(jax.block_until_ready(out)[7] == 7.0)
    except Exception:
        return False
