"""Pure-jnp oracles for every kernel in this package.

These are the semantic ground truth: Pallas kernels are validated against
these in interpret mode (tests/test_kernels.py), and the distributed dry-run
lowers THESE implementations so cost/memory analysis reflects real data
movement (DESIGN.md §2). Shapes follow core/descriptor.py.
"""
from __future__ import annotations

import math
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp


# ---------------------------------------------------------------------------
# active block extents (work-skipping decode, DESIGN.md §12)
#
# The decode grid is fixed at (B, NB) — one compile per engine config — but
# for a slot at position t only the window blocks intersecting
# (t - near_window, t] carry any unmasked position. These helpers derive the
# per-slot half-open block extent [ext_lo, ext_hi) from the SAME descriptor
# fields the kernels already receive (window_base/seq_lens/slot_active), so
# the extent is a pure function of the committed descriptor — no layout
# change. core/descriptor.py holds the numpy twin used for host-side audit
# accounting; tests assert the two derivations agree.
# ---------------------------------------------------------------------------

def active_block_extent(window_base, seq_lens, slot_active, *,
                        near_window: int, nb: int, bt: int):
    """Per-slot half-open window-block extent [lo, hi) of unmasked work.

    Decode semantics: slot b's valid pool positions are
    ``pos in (t - near_window, t] ∩ [0, inf)`` with ``pos = wb + i*bt + j``.
    Retired slots (``slot_active == 0``) get an empty extent. Under the
    engine's window-base construction the extent is exact; when the current
    token rides outside the pool (``cur_k`` given) ``hi`` may be one block
    wide — never narrow, so skipping stays lossless. All inputs (B,) int;
    returns (lo, hi) each (B,) int32, clipped to [0, nb].
    """
    lo_pos = jnp.maximum(0, seq_lens + 1 - near_window)
    lo = (lo_pos - window_base) // bt
    hi = (seq_lens - window_base) // bt + 1
    act = slot_active > 0
    lo = jnp.clip(jnp.where(act, lo, 0), 0, nb).astype(jnp.int32)
    hi = jnp.clip(jnp.where(act, hi, 0), 0, nb).astype(jnp.int32)
    return lo, jnp.maximum(hi, lo)


def chunk_block_extent(window_base, start_pos, *, near_window: int,
                       nb: int, bt: int):
    """Prefill-chunk twin of :func:`active_block_extent`.

    A pool block is touched by ANY chunk row iff it holds a position in
    ``[max(0, start_pos - near_window + 1), start_pos - 1]`` (row 0 has the
    widest back-window; all rows stop strictly before the chunk). Scalar or
    (B,) ints; returns int32 (lo, hi) clipped to [0, nb].
    """
    has_ctx = start_pos > window_base
    lo_pos = jnp.maximum(0, start_pos - near_window + 1)
    lo = (lo_pos - window_base) // bt
    hi = jnp.where(has_ctx, (start_pos - 1 - window_base) // bt + 1, 0)
    lo = jnp.clip(jnp.where(has_ctx, lo, 0), 0, nb).astype(jnp.int32)
    hi = jnp.clip(hi, 0, nb).astype(jnp.int32)
    return lo, jnp.maximum(hi, lo)


# ---------------------------------------------------------------------------
# quantized KV-block tier (DESIGN.md §10)
#
# Pools may store K/V in a narrow dtype (int8 / float8_e4m3) with a sibling
# per-(layer, block, kv-head) f32 scale pool. The scale pool is indexed by
# the SAME physical block id as the data pool, so every pager verb that
# renames or copies blocks (alias/COW/swap) moves data and scale in lockstep
# with no extra bookkeeping. Quantization is symmetric absmax:
#     stored = clip(x / scale, ±QMAX)   scale = running_amax / QMAX
# The scale of a block only GROWS while the block is being appended to; when
# a new token raises it, the block's existing rows are requantized in place
# (ratio <= 1, so the rescale never saturates). A write at offset 0 treats
# the block as fresh (scale resets — physical blocks are recycled).
# ---------------------------------------------------------------------------

def quant_range(dtype) -> float:
    """Symmetric representable range of a narrow KV storage dtype."""
    d = jnp.dtype(dtype)
    if d == jnp.dtype(jnp.int8):
        return 127.0
    if d == jnp.dtype(jnp.float8_e4m3fn):
        return 448.0
    raise ValueError(f"not a quantized KV dtype: {dtype}")


def _quant_cast(x, dtype):
    """f32 -> narrow storage cast (round-to-nearest for ints, saturating:
    float8_e4m3fn overflows to nan, so the clip is load-bearing)."""
    qmax = quant_range(dtype)
    x = jnp.clip(x, -qmax, qmax)
    if jnp.dtype(dtype) == jnp.dtype(jnp.int8):
        x = jnp.round(x)
    return x.astype(dtype)


def dequant_gathered(win, scale):
    """Dequantize a gathered window: win (..., BT, KV, hd) narrow storage,
    scale (..., KV) f32 -> f32. Operates on the GATHERED window only — never
    convert the whole pool (see the hoisting note in
    paged_decode_attention_ref)."""
    return win.astype(jnp.float32) * scale[..., None, :, None]


def quant_pool_write_stacked_ref(pool, scale, vals, write_block, write_offset,
                                 active):
    """Quantizing variant of pool_write_stacked_ref (one token per slot,
    all layers): fold the token's per-head absmax into the block scale,
    requantize the block's existing rows if the scale grew, then store the
    token at the new scale.

    pool: (L, P, BT, KV, hd) narrow; scale: (L, P, KV) f32;
    vals: (L, B, KV, hd) full precision; write_block/offset/active: (B,).
    Returns (pool, scale). Inactive slots redirect to scratch block 0 and
    write back current values (same discipline as the bf16 op)."""
    L = pool.shape[0]
    qmax = quant_range(pool.dtype)
    blk = jnp.where(active > 0, write_block, 0)
    off = jnp.where(active > 0, write_offset, 0)
    l_idx = jnp.arange(L)[:, None]
    mask = active > 0                                      # (B,)
    v32 = vals.astype(jnp.float32)
    amax = jnp.abs(v32).max(axis=-1)                       # (L, B, KV)
    prev_raw = scale[l_idx, blk[None, :]]                  # (L, B, KV)
    prev = jnp.where((off == 0)[None, :, None], 0.0, prev_raw)
    new_scale = jnp.maximum(prev, amax / qmax)
    # requantize the written blocks' existing rows to the grown scale
    # (ratio 1 is a lossless roundtrip; ratio 0 zeroes a recycled block)
    ratio = prev / jnp.maximum(new_scale, 1e-12)           # (L, B, KV)
    rows_cur = pool[l_idx, blk[None, :]]                   # (L, B, BT, KV, hd)
    rows_q = _quant_cast(rows_cur.astype(jnp.float32)
                         * ratio[:, :, None, :, None], pool.dtype)
    pool = pool.at[l_idx, blk[None, :]].set(
        jnp.where(mask[None, :, None, None, None], rows_q, rows_cur),
        mode="drop")
    qtok = _quant_cast(v32 / jnp.maximum(new_scale, 1e-12)[..., None],
                       pool.dtype)                         # (L, B, KV, hd)
    cur_tok = pool[l_idx, blk[None, :], off[None, :]]
    pool = pool.at[l_idx, blk[None, :], off[None, :]].set(
        jnp.where(mask[None, :, None, None], qtok, cur_tok), mode="drop")
    scale = scale.at[l_idx, blk[None, :]].set(
        jnp.where(mask[None, :, None], new_scale, prev_raw), mode="drop")
    return pool, scale


def quant_pool_write_chunk_ref(pool, scale, vals, write_block, write_offset,
                               n_valid):
    """Quantizing variant of pool_write_chunk_ref (batched prefill chunk,
    all layers). Three phases per written block: (1) reset scales of blocks
    that START inside this chunk (a token at offset 0) and fold every chunk
    token's absmax into its block scale via scatter-max; (2) requantize the
    pre-chunk rows of partially-filled blocks the chunk appends to (exactly
    one 'first token in block' per block per chunk — a consecutive offset
    run); (3) store each token at its block's final scale.

    pool: (L, P, BT, KV, hd) narrow; scale: (L, P, KV) f32;
    vals: (L, B, C, KV, hd); write_block/write_offset: (B, C);
    n_valid: (B,). Returns (pool, scale)."""
    L, P, BT, KV, hd = pool.shape
    B, C = write_block.shape
    N = B * C
    qmax = quant_range(pool.dtype)
    valid = (jnp.arange(C)[None, :] < n_valid[:, None]).reshape(N)
    blk = jnp.where(valid, write_block.reshape(N), 0)
    off = jnp.where(valid, write_offset.reshape(N), 0)
    l_idx = jnp.arange(L)[:, None]
    v32 = vals.reshape(L, N, KV, hd).astype(jnp.float32)
    amax = jnp.abs(v32).max(axis=-1)                       # (L, N, KV)
    prev_raw = scale[l_idx, blk[None, :]]                  # (L, N, KV)
    fresh = valid & (off == 0)
    # a block's first chunk token: offset 0 (fresh block) or the slot's
    # first chunk token (chunks append a consecutive offset run, so every
    # other token's predecessor is in the same block)
    first = valid & ((off == 0) | (jnp.arange(N) % C == 0))
    prev = jnp.where(fresh[None, :, None], 0.0, prev_raw)  # (L, N, KV)
    # phase 1: reset fresh blocks (min against 0; scales are >= 0 so this
    # is an exact set, and duplicate indices commute), then fold absmax
    scale = scale.at[l_idx, blk[None, :]].min(
        jnp.where(fresh[None, :, None], 0.0, jnp.inf), mode="drop")
    scale = scale.at[l_idx, blk[None, :]].max(
        jnp.where(valid[None, :, None], amax / qmax, 0.0), mode="drop")
    new_scale = scale[l_idx, blk[None, :]]                 # (L, N, KV) final
    # phase 2: requantize pre-chunk rows (first-token rows only; a fresh
    # block's ratio is 0, zeroing recycled contents). Non-first tokens are
    # redirected to scratch block 0 so the duplicate-index scatter stays
    # conflict-free: every block is written by at most ONE first token,
    # and all scratch writes carry the same (current) block-0 rows.
    ratio = prev / jnp.maximum(new_scale, 1e-12)
    blk_first = jnp.where(first, blk, 0)
    rows_cur = pool[l_idx, blk_first[None, :]]             # (L, N, BT, KV, hd)
    rows_q = _quant_cast(rows_cur.astype(jnp.float32)
                         * ratio[:, :, None, :, None], pool.dtype)
    pool = pool.at[l_idx, blk_first[None, :]].set(
        jnp.where(first[None, :, None, None, None], rows_q, rows_cur),
        mode="drop")
    # phase 3: store the chunk tokens at the final block scales
    qtok = _quant_cast(v32 / jnp.maximum(new_scale, 1e-12)[..., None],
                       pool.dtype)
    cur_tok = pool[l_idx, blk[None, :], off[None, :]]
    pool = pool.at[l_idx, blk[None, :], off[None, :]].set(
        jnp.where(valid[None, :, None, None], qtok, cur_tok), mode="drop")
    return pool, scale


# ---------------------------------------------------------------------------
# pool write (this step's K/V -> reserved block slot)
# ---------------------------------------------------------------------------

def pool_write_ref(pool, new_vals, write_block, write_offset, active):
    """Scatter one token's payload per slot into the paged pool.

    pool: (P, BT, ...payload)   new_vals: (B, ...payload)
    write_block/write_offset/active: (B,) int32.
    Inactive slots are redirected to scratch block 0 (never allocated).
    """
    blk = jnp.where(active > 0, write_block, 0)
    off = jnp.where(active > 0, write_offset, 0)
    return pool.at[blk, off].set(
        jnp.where((active > 0)[(...,) + (None,) * (new_vals.ndim - 1)],
                  new_vals, pool[blk, off]),
        mode="drop")


def pool_write_stacked_ref(pool, vals, write_block, write_offset, active):
    """Scatter one token per slot across ALL layers at once (post-scan).

    pool: (L, P, BT, ...payload); vals: (L, B, ...payload);
    write_block/offset/active: (B,). The layer scan never carries the pool
    (read-only inside), so XLA neither copies nor converts it per layer
    (EXPERIMENTS.md §Perf iteration 8)."""
    L = pool.shape[0]
    B = vals.shape[1]
    blk = jnp.where(active > 0, write_block, 0)
    off = jnp.where(active > 0, write_offset, 0)
    l_idx = jnp.arange(L)[:, None]
    mask = (active > 0)[(None, ...) + (None,) * (vals.ndim - 2)]
    cur = pool[l_idx, blk[None, :], off[None, :]]
    return pool.at[l_idx, blk[None, :], off[None, :]].set(
        jnp.where(mask, vals, cur), mode="drop")


def pool_write_chunk_ref(pool, vals, write_block, write_offset, n_valid):
    """Scatter a batched prefill chunk's tokens (ALL layers) into the pool.

    pool: (L, P, BT, ...payload); vals: (L, B, C, ...payload);
    write_block/write_offset: (B, C); n_valid: (B,) — tokens beyond a slot's
    n_valid are chunk padding and are redirected to scratch block 0.
    """
    L = pool.shape[0]
    B, C = write_block.shape
    valid = (jnp.arange(C)[None, :] < n_valid[:, None]).reshape(B * C)
    blk = jnp.where(valid, write_block.reshape(B * C), 0)
    off = jnp.where(valid, write_offset.reshape(B * C), 0)
    vals = vals.reshape(vals.shape[0], B * C, *vals.shape[3:])
    l_idx = jnp.arange(L)[:, None]
    mask = valid[(None, ...) + (None,) * (vals.ndim - 2)]
    cur = pool[l_idx, blk[None, :], off[None, :]]
    return pool.at[l_idx, blk[None, :], off[None, :]].set(
        jnp.where(mask, vals, cur), mode="drop")


# ---------------------------------------------------------------------------
# paged decode attention (near window + optional far view) — GQA
# ---------------------------------------------------------------------------

def paged_decode_attention_ref(
    q,                      # (B, H, hd) current-token queries (roped)
    pool_k, pool_v,         # (P, BT, KV, hd) paged pools (post write)
    block_table,            # (B, NB)
    window_base,            # (B,)
    seq_lens,               # (B,)  position of the CURRENT token
    slot_active,            # (B,)
    *,
    near_window: int,
    far_k=None, far_v=None,  # (B, MAXC, KV, hd) far summary pools
    far_table=None, far_valid=None,  # (B, CAP)
    cur_k=None, cur_v=None,  # (B, KV, hd) CURRENT token (pool is read-only
                             # inside the layer scan; see §Perf iteration 8)
    k_scale=None, v_scale=None,  # (P, KV) per-block per-head dequant scales
                                 # (quantized KV tier, DESIGN.md §10)
    sm_scale: Optional[float] = None,
    skip_extent: bool = False,   # mirror the kernel's extent predication
) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (attn_out (B,H,hd), far_utility (B,CAP)).

    Shard-oblivious over a kv-head slice (DESIGN.md §4): every contraction,
    mask, and the softmax are independent per kv head, and the GQA grouping
    is derived as ``n_rep = H // KV`` from the *local* shapes — so calling
    this on a TP shard holding KV/tp kv heads and their H/tp grouped query
    heads (heads divisible by the TP degree) computes exactly the
    corresponding slice of the full output, with no collective. Under
    ``shard_map`` or jit-auto over a `model`-sharded pool the only cross-
    shard reduction in the whole layer is the output-projection psum that
    CONSUMES this function's result. (``far_utility`` sums over local kv
    heads; jit-auto inserts its psum automatically, shard_map callers far
    view is per-slot host policy and disabled under TP tests.)
    """
    B, H, hd = q.shape
    P, BT, KV, _ = pool_k.shape
    NB = block_table.shape[1]
    W = NB * BT
    assert H % KV == 0, (H, KV)          # holds globally AND per shard
    n_rep = H // KV
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(hd)

    # gather near window: (B, NB, BT, KV, hd) -> (B, W, KV, hd). Quantized
    # pools (DESIGN.md §10) dequantize the GATHERED blocks only — the
    # per-block scale gather rides the same block_table dereference, so the
    # multiply cannot hoist above the gather (contrast the .astype warning
    # below).
    if k_scale is not None:
        win_k = dequant_gathered(pool_k[block_table],
                                 k_scale[block_table]).reshape(B, W, KV, hd)
        win_v = dequant_gathered(pool_v[block_table],
                                 v_scale[block_table]).reshape(B, W, KV, hd)
    else:
        win_k = pool_k[block_table].reshape(B, W, KV, hd)
        win_v = pool_v[block_table].reshape(B, W, KV, hd)

    pos = window_base[:, None] + jnp.arange(W)[None, :]           # (B, W)
    t = seq_lens[:, None]
    upper = (pos < t) if cur_k is not None else (pos <= t)
    valid = upper & (pos > t - near_window) & (pos >= 0)
    valid &= (slot_active > 0)[:, None]
    if skip_extent:
        # AND the kernel's active-extent mask into validity (DESIGN.md §12):
        # a correct extent only removes already-masked positions (bitwise
        # no-op here); a too-narrow extent diverges this oracle from the
        # mask-only one, so the engine-level identity gates catch extent bugs
        ext_lo, ext_hi = active_block_extent(
            window_base, seq_lens, slot_active,
            near_window=near_window, nb=NB, bt=BT)
        bi = jnp.arange(NB, dtype=jnp.int32)
        blk_ok = (bi[None, :] >= ext_lo[:, None]) & (bi[None, :] < ext_hi[:, None])
        valid &= jnp.repeat(blk_ok, BT, axis=1)

    # IMPORTANT: never .astype() pool-derived tensors — XLA hoists the
    # convert above the gather and converts the ENTIRE pool every layer
    # (measured 830 GB/step; EXPERIMENTS.md §Perf iteration 7). Accumulate
    # in f32 via preferred_element_type instead.
    qg = q.reshape(B, KV, n_rep, hd)
    s_near = jnp.einsum("bkrd,bwkd->bkrw", qg, win_k,
                        preferred_element_type=jnp.float32) * scale  # (B,KV,rep,W)
    s_near = jnp.where(valid[:, None, None, :], s_near, -jnp.inf)
    NCUR = 0
    if cur_k is not None:
        NCUR = 1
        s_cur = jnp.einsum("bkrd,bkd->bkr", qg, cur_k.astype(qg.dtype),
                           preferred_element_type=jnp.float32)[..., None] * scale
        s_cur = jnp.where((slot_active > 0)[:, None, None, None], s_cur, -jnp.inf)
        s_near = jnp.concatenate([s_near, s_cur], axis=-1)

    if far_k is not None and far_table is not None:
        CAP = far_table.shape[1]
        fk = jnp.take_along_axis(far_k, far_table[:, :, None, None], axis=1)
        fv = jnp.take_along_axis(far_v, far_table[:, :, None, None], axis=1)
        s_far = jnp.einsum("bkrd,bckd->bkrc", qg, fk,
                           preferred_element_type=jnp.float32) * scale
        fmask = (far_valid > 0) & (slot_active > 0)[:, None]
        s_far = jnp.where(fmask[:, None, None, :], s_far, -jnp.inf)
        s_all = jnp.concatenate([s_far, s_near], axis=-1)
    else:
        CAP = 0
        s_all = s_near

    m = s_all.max(axis=-1, keepdims=True)
    m = jnp.where(jnp.isinf(m), 0.0, m)
    p = jnp.exp(s_all - m)
    p = jnp.where(jnp.isinf(s_all), 0.0, p)
    denom = jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-20)
    p = p / denom

    def pv(pn):
        if NCUR:
            p_win, p_cur = pn[..., :-1], pn[..., -1]
            out = jnp.einsum("bkrw,bwkd->bkrd", p_win.astype(win_v.dtype), win_v,
                             preferred_element_type=jnp.float32)
            out = out + p_cur[..., None] * cur_v[:, :, None, :].astype(jnp.float32)
            return out
        return jnp.einsum("bkrw,bwkd->bkrd", pn.astype(win_v.dtype), win_v,
                          preferred_element_type=jnp.float32)

    if CAP:
        p_far, p_near = p[..., :CAP], p[..., CAP:]
        ctx = pv(p_near) + jnp.einsum(
            "bkrc,bckd->bkrd", p_far.astype(fv.dtype), fv,
            preferred_element_type=jnp.float32)
        far_util = p_far.sum(axis=(1, 2))                          # (B, CAP)
    else:
        ctx = pv(p)
        far_util = jnp.zeros((B, 1), jnp.float32)

    out = ctx.reshape(B, H, hd).astype(q.dtype)
    out = jnp.where((slot_active > 0)[:, None, None], out, 0)
    return out, far_util


# ---------------------------------------------------------------------------
# chunked prefill attention (paged context + in-chunk causal) — GQA
# ---------------------------------------------------------------------------

def chunked_prefill_attention_ref(
    q,                      # (C, H, hd) chunk queries (roped at abs positions)
    pool_k, pool_v,         # (P, BT, KV, hd) paged pools (context BEFORE chunk)
    cur_k, cur_v,           # (C, KV, hd) this chunk's K/V (roped)
    block_table,            # (NB,) window blocks covering [window_base, start_pos)
    window_base,            # ()    absolute position of block_table[0] token 0
    start_pos,              # ()    absolute position of q[0]
    n_valid,                # ()    valid tokens in the chunk
    *,
    near_window: int,
    k_scale=None, v_scale=None,  # (P, KV) per-block dequant scales (§10)
    sm_scale: Optional[float] = None,
    skip_extent: bool = False,   # mirror the kernel's extent predication
):
    """One slot's prompt chunk: query i (abs pos p_i = start_pos + i) attends
    to pool context [max(0, p_i+1-W), start_pos) plus the chunk itself
    causally (j <= i, within W). Returns (C, H, hd); rows >= n_valid are
    zeroed (their KV writes are redirected to scratch by the caller).

    Semantically identical to feeding the chunk token-at-a-time through
    paged_decode_attention_ref (DESIGN.md §3) — the softmax for a given
    query sees exactly the same key set either way.
    """
    C, H, hd = q.shape
    P, BT, KV, _ = pool_k.shape
    NB = block_table.shape[0]
    Wn = NB * BT
    n_rep = H // KV
    scale = sm_scale if sm_scale is not None else 1.0 / math.sqrt(hd)

    if k_scale is not None:       # quantized tier: dequantize the gather (§10)
        win_k = dequant_gathered(pool_k[block_table],
                                 k_scale[block_table]).reshape(Wn, KV, hd)
        win_v = dequant_gathered(pool_v[block_table],
                                 v_scale[block_table]).reshape(Wn, KV, hd)
    else:
        win_k = pool_k[block_table].reshape(Wn, KV, hd)
        win_v = pool_v[block_table].reshape(Wn, KV, hd)

    qpos = start_pos + jnp.arange(C)                              # (C,)
    pos_w = window_base + jnp.arange(Wn)                          # (Wn,)
    valid_w = ((pos_w[None, :] < start_pos)                       # strictly pre-chunk
               & (pos_w[None, :] > qpos[:, None] - near_window)
               & (pos_w[None, :] >= 0))                           # (C, Wn)
    if skip_extent:
        # kernel's causal-upper-triangle block predication (DESIGN.md §12)
        ext_lo, ext_hi = chunk_block_extent(
            window_base, start_pos, near_window=near_window, nb=NB, bt=BT)
        bi = jnp.arange(NB, dtype=jnp.int32)
        blk_ok = (bi >= ext_lo) & (bi < ext_hi)
        valid_w &= jnp.repeat(blk_ok, BT)[None, :]

    qg = q.reshape(C, KV, n_rep, hd)
    s_w = jnp.einsum("ckrd,wkd->ckrw", qg, win_k,
                     preferred_element_type=jnp.float32) * scale  # (C,KV,rep,Wn)
    s_w = jnp.where(valid_w[:, None, None, :], s_w, -jnp.inf)

    # in-chunk causal scores (self included, window-bounded)
    ij = jnp.arange(C)
    valid_c = ((ij[None, :] <= ij[:, None])
               & (qpos[None, :] > qpos[:, None] - near_window)
               & (ij[None, :] < n_valid))                         # (C, C)
    s_c = jnp.einsum("ckrd,jkd->ckrj", qg, cur_k.astype(qg.dtype),
                     preferred_element_type=jnp.float32) * scale
    s_c = jnp.where(valid_c[:, None, None, :], s_c, -jnp.inf)

    s_all = jnp.concatenate([s_w, s_c], axis=-1)
    m = s_all.max(axis=-1, keepdims=True)
    m = jnp.where(jnp.isinf(m), 0.0, m)
    p = jnp.exp(s_all - m)
    p = jnp.where(jnp.isinf(s_all), 0.0, p)
    p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-20)

    p_w, p_c = p[..., :Wn], p[..., Wn:]
    ctx = (jnp.einsum("ckrw,wkd->ckrd", p_w.astype(win_v.dtype), win_v,
                      preferred_element_type=jnp.float32)
           + jnp.einsum("ckrj,jkd->ckrd", p_c.astype(cur_v.dtype), cur_v,
                        preferred_element_type=jnp.float32))

    out = ctx.reshape(C, H, hd).astype(q.dtype)
    return jnp.where((jnp.arange(C) < n_valid)[:, None, None], out, 0)


# ---------------------------------------------------------------------------
# paged decode attention — MLA (latent pool, absorbed projections)
# ---------------------------------------------------------------------------

def mla_decode_attention_ref(
    q_nope,                 # (B, H, dn)
    q_rope,                 # (B, H, dr) roped
    pool_lat,               # (P, BT, R)  R = kv_lora_rank + dr
    w_k_b,                  # (H, kv_lora_rank, dn)  latent -> per-head K
    w_v_b,                  # (H, kv_lora_rank, dv)  latent -> per-head V
    block_table, window_base, seq_lens, slot_active,
    *, near_window: int, kv_lora_rank: int,
    far_lat=None, far_table=None, far_valid=None,   # (B, MAXC, R), (B, CAP)
    cur_lat=None,                                   # (B, R) current token
):
    """Absorbed-matmul MLA decode: attention scored directly in latent space.

    score_h(w) = (W_kb[h] q_nope_h) . c_w + q_rope_h . k_rope_w
    out_h      = (sum_w p_hw c_w) @ W_vb[h]
    """
    B, H, dn = q_nope.shape
    P, BT, R = pool_lat.shape
    NB = block_table.shape[1]
    W = NB * BT
    dr = q_rope.shape[-1]
    scale = 1.0 / math.sqrt(dn + dr)

    win = pool_lat[block_table].reshape(B, W, R)   # keep pool dtype (see GQA note)
    c_kv, k_rope = win[..., :kv_lora_rank], win[..., kv_lora_rank:]

    # absorb: q_abs (B, H, R_lat)
    q_abs = jnp.einsum("bhd,hrd->bhr", q_nope, w_k_b,
                       preferred_element_type=jnp.float32)
    s = (jnp.einsum("bhr,bwr->bhw", q_abs.astype(win.dtype), c_kv,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bhd,bwd->bhw", q_rope, k_rope,
                      preferred_element_type=jnp.float32)) * scale

    pos = window_base[:, None] + jnp.arange(W)[None, :]
    t = seq_lens[:, None]
    upper = (pos < t) if cur_lat is not None else (pos <= t)
    valid = upper & (pos > t - near_window) & (pos >= 0)
    valid &= (slot_active > 0)[:, None]
    s = jnp.where(valid[:, None, :], s, -jnp.inf)
    NCUR = 0
    if cur_lat is not None:
        NCUR = 1
        cc, cr = cur_lat[..., :kv_lora_rank], cur_lat[..., kv_lora_rank:]
        s_cur = (jnp.einsum("bhr,br->bh", q_abs.astype(cc.dtype), cc,
                            preferred_element_type=jnp.float32)
                 + jnp.einsum("bhd,bd->bh", q_rope, cr,
                              preferred_element_type=jnp.float32))[..., None] * scale
        s_cur = jnp.where((slot_active > 0)[:, None, None], s_cur, -jnp.inf)
        s = jnp.concatenate([s, s_cur], axis=-1)

    if far_lat is not None and far_table is not None:
        CAP = far_table.shape[1]
        fl = jnp.take_along_axis(far_lat, far_table[:, :, None], axis=1)
        fc, fr = fl[..., :kv_lora_rank], fl[..., kv_lora_rank:]
        s_far = (jnp.einsum("bhr,bcr->bhc", q_abs.astype(fc.dtype), fc,
                            preferred_element_type=jnp.float32)
                 + jnp.einsum("bhd,bcd->bhc", q_rope, fr,
                              preferred_element_type=jnp.float32)) * scale
        fmask = (far_valid > 0) & (slot_active > 0)[:, None]
        s_far = jnp.where(fmask[:, None, :], s_far, -jnp.inf)
        s = jnp.concatenate([s_far, s], axis=-1)
    else:
        CAP = 0

    m = s.max(axis=-1, keepdims=True)
    m = jnp.where(jnp.isinf(m), 0.0, m)
    p = jnp.exp(s - m)
    p = jnp.where(jnp.isinf(s), 0.0, p)
    p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-20)

    def pc(pn):
        if NCUR:
            p_win, p_cur = pn[..., :-1], pn[..., -1]
            out = jnp.einsum("bhw,bwr->bhr", p_win.astype(c_kv.dtype), c_kv,
                             preferred_element_type=jnp.float32)
            return out + p_cur[..., None] * cc[:, None, :].astype(jnp.float32)
        return jnp.einsum("bhw,bwr->bhr", pn.astype(c_kv.dtype), c_kv,
                          preferred_element_type=jnp.float32)

    if CAP:
        p_far, p_near = p[..., :CAP], p[..., CAP:]
        ctx_lat = pc(p_near) + jnp.einsum(
            "bhc,bcr->bhr", p_far.astype(fc.dtype), fc,
            preferred_element_type=jnp.float32)
        far_util = p_far.sum(axis=1)
    else:
        ctx_lat = pc(p)
        far_util = jnp.zeros((B, 1), jnp.float32)

    out = jnp.einsum("bhr,hrd->bhd", ctx_lat, w_v_b.astype(jnp.float32))
    out = jnp.where((slot_active > 0)[:, None, None], out, 0)
    return out.astype(q_nope.dtype), far_util


def mla_decode_attention_naive(q_nope, q_rope, pool_lat, w_k_b, w_v_b,
                               block_table, window_base, seq_lens, slot_active,
                               *, near_window: int, kv_lora_rank: int):
    """Non-absorbed MLA path (materializes per-head K/V); oracle for the
    absorbed version."""
    B, H, dn = q_nope.shape
    P, BT, R = pool_lat.shape
    NB = block_table.shape[1]
    W = NB * BT
    dr = q_rope.shape[-1]
    scale = 1.0 / math.sqrt(dn + dr)

    win = pool_lat[block_table].reshape(B, W, R).astype(jnp.float32)
    c_kv, k_rope = win[..., :kv_lora_rank], win[..., kv_lora_rank:]
    k_nope = jnp.einsum("bwr,hrd->bwhd", c_kv, w_k_b.astype(jnp.float32))
    v = jnp.einsum("bwr,hrd->bwhd", c_kv, w_v_b.astype(jnp.float32))

    s = (jnp.einsum("bhd,bwhd->bhw", q_nope.astype(jnp.float32), k_nope)
         + jnp.einsum("bhd,bwd->bhw", q_rope.astype(jnp.float32), k_rope)) * scale
    pos = window_base[:, None] + jnp.arange(W)[None, :]
    t = seq_lens[:, None]
    valid = (pos <= t) & (pos > t - near_window) & (pos >= 0)
    valid &= (slot_active > 0)[:, None]
    s = jnp.where(valid[:, None, :], s, -jnp.inf)
    m = jnp.where(jnp.isinf(s.max(-1, keepdims=True)), 0.0, s.max(-1, keepdims=True))
    p = jnp.exp(s - m)
    p = jnp.where(jnp.isinf(s), 0.0, p)
    p = p / jnp.maximum(p.sum(-1, keepdims=True), 1e-20)
    out = jnp.einsum("bhw,bwhd->bhd", p, v)
    out = jnp.where((slot_active > 0)[:, None, None], out, 0)
    return out.astype(q_nope.dtype)


# ---------------------------------------------------------------------------
# far-view summarization (uniform aggregation over one sv_chunk)
# ---------------------------------------------------------------------------

def farview_summarize_ref(pool, chunk_blocks, n_tokens, do_summarize):
    """Mean-pool one completed chunk per slot.

    pool: (P, BT, ...payload); chunk_blocks: (B, CB) block ids of the chunk;
    n_tokens: (B,) valid token count (normally sv_chunk); do_summarize: (B,)
    0/1 gate. Returns (B, ...payload) summaries (zeros where gated off).
    """
    B, CB = chunk_blocks.shape
    BT = pool.shape[1]
    toks = pool[chunk_blocks]                         # (B, CB, BT, ...)
    toks = toks.reshape(B, CB * BT, *pool.shape[2:]).astype(jnp.float32)
    idx = jnp.arange(CB * BT)
    mask = (idx[None, :] < n_tokens[:, None]).astype(jnp.float32)
    mask = mask.reshape(B, CB * BT, *([1] * (toks.ndim - 2)))
    s = (toks * mask).sum(axis=1) / jnp.maximum(n_tokens, 1)[
        (...,) + (None,) * (toks.ndim - 2)]
    gate = (do_summarize > 0)[(...,) + (None,) * (toks.ndim - 2)]
    return jnp.where(gate, s, 0.0).astype(pool.dtype)


# ---------------------------------------------------------------------------
# prefill attention oracle (dense causal, optional window)
# ---------------------------------------------------------------------------

def prefill_attention_ref(q, k, v, *, causal=True, window=None):
    from repro.models.common import attention_dense
    return attention_dense(q, k, v, causal=causal, window=window)
