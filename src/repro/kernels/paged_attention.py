"""Pallas TPU kernel: paged near-window decode attention (KV-RM core).

One fixed-shape kernel instantiation per engine config (the paper's
shape-stable sliding decoder). The committed block table is a scalar-prefetch
operand: the grid walks the near window block-by-block and the BlockSpec
index_map dereferences the page mapping, so each grid step issues ONE
block-sized HBM->VMEM copy (~tau bytes — the merged transport quantum).
Because the pager places a session's blocks contiguously (tail-adjacent
RESERVE), consecutive grid steps touch physically-adjacent HBM regions and
Mosaic coalesces them into long DMA trains — descriptor merging realized as
a copy schedule (DESIGN.md §2).

Layout notes (TPU):
  * last dim = head_dim (>= 128-lane friendly for standard models);
  * KV block = (BT, KV*hd) rows — BT >= 8 sublanes;
  * softmax state kept in VMEM scratch as (H, 128) replicated lanes.

Tensor-parallel decode (DESIGN.md §4): the kernel is shard-oblivious over a
kv-head slice — grid, BlockSpecs, and the GQA grouping ``n_rep = H // KV``
are all derived from the LOCAL operand shapes, so each `model` shard
instantiates the identical executable over its KV/tp kv heads (per-shard
softmax state (KV/tp, n_rep); no cross-shard state). Launch it per shard
via shard_map with q sharded on H, pools on KV, table/meta replicated; the
layer's single psum happens downstream at the output projection.

Validated in interpret mode against kernels/ref.py on CPU.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(*refs, bt: int, kv: int, n_rep: int, hd: int,
                   near_window: int, scale: float, quant: bool):
    if quant:
        # quantized tier (DESIGN.md §10): per-block per-head dequant scales
        # arrive as extra scalar-prefetch operands (SMEM) and the HBM->VMEM
        # block copy grows a fused dequantize-on-load epilogue below
        (block_tbl_ref, meta_ref, ks_ref, vs_ref,
         q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref) = refs
    else:
        (block_tbl_ref, meta_ref,
         q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref) = refs
    b = pl.program_id(0)
    i = pl.program_id(1)
    nb = pl.num_programs(1)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    wb = meta_ref[b, 0]
    t = meta_ref[b, 1]
    active = meta_ref[b, 2]

    q = q_ref[0].astype(jnp.float32)             # (H, hd)
    kb = k_ref[0].astype(jnp.float32)            # (BT, KV, hd)
    vb = v_ref[0].astype(jnp.float32)
    if quant:
        blk = block_tbl_ref[b, i]
        kb = kb * ks_ref[blk][None, :, None]     # (KV,) scales from SMEM
        vb = vb * vs_ref[blk][None, :, None]

    # scores: group q heads per kv head
    qg = q.reshape(kv, n_rep, hd)
    s = jax.lax.dot_general(qg, kb, (((2,), (2,)), ((0,), (1,))),
                            preferred_element_type=jnp.float32)  # (KV, n_rep, BT)
    s = s * scale
    pos = wb + i * bt + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bt), 2)
    valid = (pos <= t) & (pos > t - near_window) & (pos >= 0) & (active > 0)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                          # (KV, n_rep)
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    p = jnp.where(valid, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    pv = jax.lax.dot_general(p, vb, (((2,), (0,)), ((0,), (1,))),
                             preferred_element_type=jnp.float32)  # (KV, n_rep, hd)
    acc_ref[...] = acc_ref[...] * corr[..., None] + pv
    m_ref[...] = m_new

    @pl.when(i == nb - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-20)[..., None]
        out = (acc_ref[...] / denom).reshape(kv * n_rep, hd)
        o_ref[0] = jnp.where(active > 0, out, 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("near_window", "interpret"))
def paged_decode_attention_pallas(q, pool_k, pool_v, block_table, window_base,
                                  seq_lens, slot_active, *, near_window,
                                  far_k=None, far_v=None, far_table=None,
                                  far_valid=None, k_scale=None, v_scale=None,
                                  interpret=True):
    """Near-window paged attention; optional far-view handled by a jnp side
    path merged via flash-combine (far view is the paper's optional policy).

    q: (B,H,hd); pool_k/pool_v: (P,BT,KV,hd); block_table: (B,NB).
    k_scale/v_scale: optional (P,KV) f32 per-block per-head dequant scales
    for narrow (int8 / float8_e4m3) pools — they ride as scalar-prefetch
    operands (SMEM) and each grid step's block copy dequantizes on load, so
    the descriptor contract and grid are unchanged (DESIGN.md §10).
    Returns (out (B,H,hd), far_util (B,CAP))."""
    B, H, hd = q.shape
    P, BT, KV, _ = pool_k.shape
    NB = block_table.shape[1]
    assert H % KV == 0, (H, KV)          # holds globally AND per TP shard
    n_rep = H // KV
    scale = 1.0 / math.sqrt(hd)
    quant = k_scale is not None

    meta = jnp.stack([window_base, seq_lens, slot_active.astype(jnp.int32)],
                     axis=1).astype(jnp.int32)           # (B, 3)

    grid = (B, NB)
    kernel = functools.partial(
        _decode_kernel, bt=BT, kv=KV, n_rep=n_rep, hd=hd,
        near_window=near_window, scale=scale, quant=quant)

    nsp = 4 if quant else 2
    def _ix(f):
        # index maps take one trailing arg per scalar-prefetch operand
        return (lambda b, i, tbl, meta, ks, vs: f(b, i, tbl)) if quant \
            else (lambda b, i, tbl, meta: f(b, i, tbl))
    gs = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=nsp,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, H, hd), _ix(lambda b, i, tbl: (b, 0, 0))),
            pl.BlockSpec((1, BT, KV, hd),
                         _ix(lambda b, i, tbl: (tbl[b, i], 0, 0, 0))),
            pl.BlockSpec((1, BT, KV, hd),
                         _ix(lambda b, i, tbl: (tbl[b, i], 0, 0, 0))),
        ],
        out_specs=pl.BlockSpec((1, H, hd), _ix(lambda b, i, tbl: (b, 0, 0))),
        scratch_shapes=[
            pltpu.VMEM((KV, n_rep, hd), jnp.float32),
            pltpu.VMEM((KV, n_rep), jnp.float32),
            pltpu.VMEM((KV, n_rep), jnp.float32),
        ],
    )
    sp_args = (block_table.astype(jnp.int32), meta)
    if quant:
        sp_args += (k_scale.astype(jnp.float32), v_scale.astype(jnp.float32))
    near_out = pl.pallas_call(
        kernel, grid_spec=gs,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=interpret,
    )(*sp_args, q, pool_k, pool_v)

    if far_k is None or far_table is None:
        return near_out, jnp.zeros((B, 1), jnp.float32)
    assert not quant, "far view and the quantized KV tier are exclusive (§10)"

    # --- far view (optional policy): jnp path + flash-combine --------------
    from repro.kernels import ref as _ref
    # near softmax stats must be recomputed for an exact merge; reuse the ref
    # full path for correctness (far view off the critical core path).
    out, fu = _ref.paged_decode_attention_ref(
        q, pool_k, pool_v, block_table, window_base, seq_lens, slot_active,
        near_window=near_window, far_k=far_k, far_v=far_v,
        far_table=far_table, far_valid=far_valid)
    return out, fu
