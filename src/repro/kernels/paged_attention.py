"""Pallas TPU kernel: paged near-window decode attention (KV-RM core).

One fixed-shape kernel instantiation per engine config (the paper's
shape-stable sliding decoder). The committed block table is a scalar-prefetch
operand: the grid walks the near window block-by-block and the BlockSpec
index_map dereferences the page mapping, so each grid step issues ONE
block-sized HBM->VMEM copy (~tau bytes — the merged transport quantum).
Because the pager places a session's blocks contiguously (tail-adjacent
RESERVE), consecutive grid steps touch physically-adjacent HBM regions and
Mosaic coalesces them into long DMA trains — descriptor merging realized as
a copy schedule (DESIGN.md §2).

Work skipping (DESIGN.md §12): the grid is still fixed at (B, NB) — one
compile per engine config — but the meta scalar-prefetch operand now carries
a per-slot *active block extent* [ext_lo, ext_hi): the first/last window
block with any position inside ``(t - near_window, t]`` (empty for retired
slots). Every grid step outside the extent is predicated off with
``@pl.when`` — zero dot products — and the K/V BlockSpec index map clamps
into the extent so the revisited index elides the HBM->VMEM copy too.
Fixed grid, variable work; skipping only ever removes fully-masked blocks,
so outputs are bitwise identical to the always-run kernel.

Device-side overlap (``prefetch_depth=1``): a double-buffered variant keeps
the pools in ANY memory space and stages block ``i+1``'s K/V (+ scale) into
VMEM with manual async copies while block ``i`` computes — the custom-kernel
prefetch the ROADMAP's latency-hiding item left open. A guarded fallback
(direct ANY-space reads, same two-buffer rotation, no semaphores) keeps the
path runnable where interpret mode lacks DMA primitives.

Layout notes (TPU):
  * last dim = head_dim (>= 128-lane friendly for standard models);
  * KV block = (BT, KV*hd) rows — BT >= 8 sublanes;
  * softmax state kept in VMEM scratch as (H, 128) replicated lanes.

Tensor-parallel decode (DESIGN.md §4): the kernel is shard-oblivious over a
kv-head slice — grid, BlockSpecs, and the GQA grouping ``n_rep = H // KV``
are all derived from the LOCAL operand shapes, so each `model` shard
instantiates the identical executable over its KV/tp kv heads (per-shard
softmax state (KV/tp, n_rep); no cross-shard state). Launch it per shard
via shard_map with q sharded on H, pools on KV, table/meta replicated; the
layer's single psum happens downstream at the output projection.

Validated in interpret mode against kernels/ref.py on CPU.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import interpret_dma_supported, resolve_interpret

NEG_INF = -1e30


def _online_block_update(acc_ref, m_ref, l_ref, qg, kb, vb, valid, scale):
    """One flash-style online-softmax block step (shared by both decode
    kernel variants so skip/prefetch A/Bs stay bitwise comparable)."""
    s = jax.lax.dot_general(qg, kb, (((2,), (2,)), ((0,), (1,))),
                            preferred_element_type=jnp.float32)  # (KV, n_rep, BT)
    s = s * scale
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]                          # (KV, n_rep)
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    p = jnp.where(valid, p, 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    pv = jax.lax.dot_general(p, vb, (((2,), (0,)), ((0,), (1,))),
                             preferred_element_type=jnp.float32)  # (KV, n_rep, hd)
    acc_ref[...] = acc_ref[...] * corr[..., None] + pv
    m_ref[...] = m_new


def _decode_kernel(*refs, bt: int, kv: int, n_rep: int, hd: int,
                   near_window: int, scale: float, quant: bool):
    if quant:
        # quantized tier (DESIGN.md §10): per-block per-head dequant scales
        # arrive as extra scalar-prefetch operands (SMEM) and the HBM->VMEM
        # block copy grows a fused dequantize-on-load epilogue below
        (block_tbl_ref, meta_ref, ks_ref, vs_ref,
         q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref) = refs
    else:
        (block_tbl_ref, meta_ref,
         q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref) = refs
    b = pl.program_id(0)
    i = pl.program_id(1)
    nb = pl.num_programs(1)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    wb = meta_ref[b, 0]
    t = meta_ref[b, 1]
    active = meta_ref[b, 2]
    ext_lo = meta_ref[b, 3]
    ext_hi = meta_ref[b, 4]

    # active-extent predication (DESIGN.md §12): out-of-extent blocks are
    # fully masked anyway — the online update they'd run is an exact no-op
    # (m_new == m_prev, corr == 1, p == 0) — so the whole step is skipped.
    @pl.when((i >= ext_lo) & (i < ext_hi))
    def _compute():
        q = q_ref[0].astype(jnp.float32)             # (H, hd)
        kb = k_ref[0].astype(jnp.float32)            # (BT, KV, hd)
        vb = v_ref[0].astype(jnp.float32)
        if quant:
            blk = block_tbl_ref[b, i]
            kb = kb * ks_ref[blk][None, :, None]     # (KV,) scales from SMEM
            vb = vb * vs_ref[blk][None, :, None]
        qg = q.reshape(kv, n_rep, hd)                # group q heads per kv head
        pos = wb + i * bt + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bt), 2)
        valid = (pos <= t) & (pos > t - near_window) & (pos >= 0) & (active > 0)
        _online_block_update(acc_ref, m_ref, l_ref, qg, kb, vb, valid, scale)

    @pl.when(i == nb - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-20)[..., None]
        out = (acc_ref[...] / denom).reshape(kv * n_rep, hd)
        o_ref[0] = jnp.where(active > 0, out, 0.0).astype(o_ref.dtype)


def _decode_kernel_db(*refs, bt: int, kv: int, n_rep: int, hd: int,
                      near_window: int, scale: float, quant: bool, dma: bool):
    """Double-buffered decode variant (prefetch_depth=1): pools live in ANY
    memory space; block i+1's K/V is staged into one of two VMEM buffers
    (async copy when `dma`, direct read otherwise) while block i computes."""
    if quant:
        (block_tbl_ref, meta_ref, ks_ref, vs_ref,
         q_ref, kh_ref, vh_ref, o_ref,
         kbuf, vbuf, acc_ref, m_ref, l_ref, *sems) = refs
    else:
        (block_tbl_ref, meta_ref,
         q_ref, kh_ref, vh_ref, o_ref,
         kbuf, vbuf, acc_ref, m_ref, l_ref, *sems) = refs
    b = pl.program_id(0)
    i = pl.program_id(1)
    nb = pl.num_programs(1)

    wb = meta_ref[b, 0]
    t = meta_ref[b, 1]
    active = meta_ref[b, 2]
    ext_lo = meta_ref[b, 3]
    ext_hi = meta_ref[b, 4]

    def _start_fetch(ib):
        slot = ib % 2
        blk = block_tbl_ref[b, ib]
        if dma:
            ksem, vsem = sems
            pltpu.make_async_copy(kh_ref.at[blk], kbuf.at[slot],
                                  ksem.at[slot]).start()
            pltpu.make_async_copy(vh_ref.at[blk], vbuf.at[slot],
                                  vsem.at[slot]).start()
        else:
            # interpret fallback: same two-buffer rotation, synchronous read
            kbuf[slot] = kh_ref[blk]
            vbuf[slot] = vh_ref[blk]

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

        @pl.when(ext_hi > ext_lo)
        def _prime():
            _start_fetch(ext_lo)

    @pl.when((i >= ext_lo) & (i < ext_hi))
    def _compute():
        slot = i % 2
        if dma:
            ksem, vsem = sems
            blk = block_tbl_ref[b, i]
            pltpu.make_async_copy(kh_ref.at[blk], kbuf.at[slot],
                                  ksem.at[slot]).wait()
            pltpu.make_async_copy(vh_ref.at[blk], vbuf.at[slot],
                                  vsem.at[slot]).wait()

        # overlap: issue block i+1's fetch before touching block i's data
        @pl.when(i + 1 < ext_hi)
        def _ahead():
            _start_fetch(i + 1)

        q = q_ref[0].astype(jnp.float32)             # (H, hd)
        kb = kbuf[slot].astype(jnp.float32)          # (BT, KV, hd)
        vb = vbuf[slot].astype(jnp.float32)
        if quant:
            blk = block_tbl_ref[b, i]
            kb = kb * ks_ref[blk][None, :, None]
            vb = vb * vs_ref[blk][None, :, None]
        qg = q.reshape(kv, n_rep, hd)
        pos = wb + i * bt + jax.lax.broadcasted_iota(jnp.int32, (1, 1, bt), 2)
        valid = (pos <= t) & (pos > t - near_window) & (pos >= 0) & (active > 0)
        _online_block_update(acc_ref, m_ref, l_ref, qg, kb, vb, valid, scale)

    @pl.when(i == nb - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-20)[..., None]
        out = (acc_ref[...] / denom).reshape(kv * n_rep, hd)
        o_ref[0] = jnp.where(active > 0, out, 0.0).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "near_window", "skip_extent", "prefetch_depth", "dma", "interpret"))
def _paged_decode_attention_impl(q, pool_k, pool_v, block_table, window_base,
                                 seq_lens, slot_active, *, near_window,
                                 k_scale=None, v_scale=None,
                                 skip_extent=True, prefetch_depth=0,
                                 dma=True, interpret=True):
    from repro.kernels.ref import active_block_extent

    B, H, hd = q.shape
    P, BT, KV, _ = pool_k.shape
    NB = block_table.shape[1]
    assert H % KV == 0, (H, KV)          # holds globally AND per TP shard
    n_rep = H // KV
    scale = 1.0 / math.sqrt(hd)
    quant = k_scale is not None

    ext_lo, ext_hi = active_block_extent(
        window_base, seq_lens, slot_active,
        near_window=near_window, nb=NB, bt=BT)
    if not skip_extent:
        # always-run baseline: full extents make the predication trivially
        # true — the exact masked kernel, same executable, for bitwise A/Bs
        ext_lo = jnp.zeros_like(ext_lo)
        ext_hi = jnp.full_like(ext_hi, NB)
    meta = jnp.stack([window_base, seq_lens, slot_active.astype(jnp.int32),
                      ext_lo, ext_hi], axis=1).astype(jnp.int32)   # (B, 5)

    grid = (B, NB)
    nsp = 4 if quant else 2

    def _ix(f):
        # index maps take one trailing arg per scalar-prefetch operand
        return (lambda b, i, tbl, meta, ks, vs: f(b, i, tbl, meta)) if quant \
            else (lambda b, i, tbl, meta: f(b, i, tbl, meta))

    def _blk_ix(b, i, tbl, meta):
        # clamp out-of-extent steps onto the extent boundary: the index map
        # revisits a block it already mapped, so Mosaic elides the copy for
        # every predicated-off grid step (the bandwidth half of the skip)
        j = jnp.clip(i, meta[b, 3], jnp.maximum(meta[b, 4] - 1, meta[b, 3]))
        return (tbl[b, j], 0, 0, 0)

    sp_args = (block_table.astype(jnp.int32), meta)
    if quant:
        sp_args += (k_scale.astype(jnp.float32), v_scale.astype(jnp.float32))

    if prefetch_depth > 0:
        # double-buffered manual staging: pools bypass the BlockSpec pipeline
        kernel = functools.partial(
            _decode_kernel_db, bt=BT, kv=KV, n_rep=n_rep, hd=hd,
            near_window=near_window, scale=scale, quant=quant, dma=dma)
        scratch = [
            pltpu.VMEM((2, BT, KV, hd), pool_k.dtype),
            pltpu.VMEM((2, BT, KV, hd), pool_v.dtype),
            pltpu.VMEM((KV, n_rep, hd), jnp.float32),
            pltpu.VMEM((KV, n_rep), jnp.float32),
            pltpu.VMEM((KV, n_rep), jnp.float32),
        ]
        if dma:
            scratch += [pltpu.SemaphoreType.DMA((2,)),
                        pltpu.SemaphoreType.DMA((2,))]
        gs = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=nsp,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, H, hd), _ix(lambda b, i, tbl, meta: (b, 0, 0))),
                pl.BlockSpec(memory_space=pltpu.ANY),
                pl.BlockSpec(memory_space=pltpu.ANY),
            ],
            out_specs=pl.BlockSpec((1, H, hd),
                                   _ix(lambda b, i, tbl, meta: (b, 0, 0))),
            scratch_shapes=scratch,
        )
    else:
        kernel = functools.partial(
            _decode_kernel, bt=BT, kv=KV, n_rep=n_rep, hd=hd,
            near_window=near_window, scale=scale, quant=quant)
        gs = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=nsp,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, H, hd), _ix(lambda b, i, tbl, meta: (b, 0, 0))),
                pl.BlockSpec((1, BT, KV, hd), _ix(_blk_ix)),
                pl.BlockSpec((1, BT, KV, hd), _ix(_blk_ix)),
            ],
            out_specs=pl.BlockSpec((1, H, hd),
                                   _ix(lambda b, i, tbl, meta: (b, 0, 0))),
            scratch_shapes=[
                pltpu.VMEM((KV, n_rep, hd), jnp.float32),
                pltpu.VMEM((KV, n_rep), jnp.float32),
                pltpu.VMEM((KV, n_rep), jnp.float32),
            ],
        )
    return pl.pallas_call(
        kernel, grid_spec=gs,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        interpret=interpret,
    )(*sp_args, q, pool_k, pool_v)


def paged_decode_attention_pallas(q, pool_k, pool_v, block_table, window_base,
                                  seq_lens, slot_active, *, near_window,
                                  far_k=None, far_v=None, far_table=None,
                                  far_valid=None, k_scale=None, v_scale=None,
                                  skip_extent=True, prefetch_depth=0,
                                  dma=None, interpret=None):
    """Near-window paged attention; optional far-view handled by a jnp side
    path merged via flash-combine (far view is the paper's optional policy).

    q: (B,H,hd); pool_k/pool_v: (P,BT,KV,hd); block_table: (B,NB).
    k_scale/v_scale: optional (P,KV) f32 per-block per-head dequant scales
    for narrow (int8 / float8_e4m3) pools — they ride as scalar-prefetch
    operands (SMEM) and each grid step's block copy dequantizes on load, so
    the descriptor contract and grid are unchanged (DESIGN.md §10).

    skip_extent=False pins every slot's extent to [0, NB) — the always-run
    masked baseline (same executable) for bitwise A/Bs. prefetch_depth=1
    selects the double-buffered manual-staging variant; dma=None probes
    whether interpret mode supports async copies (False forces the direct
    -read fallback — test hook). interpret=None resolves from the backend
    (kernels/runtime.py): CPU -> interpret, TPU/GPU -> compiled.
    Returns (out (B,H,hd), far_util (B,CAP))."""
    interpret = resolve_interpret(interpret)
    if dma is None:
        dma = (not interpret) or interpret_dma_supported()

    if far_k is not None and far_table is not None:
        assert k_scale is None, \
            "far view and the quantized KV tier are exclusive (§10)"
        # --- far view (optional policy): jnp path + flash-combine ----------
        from repro.kernels import ref as _ref
        # near softmax stats must be recomputed for an exact merge; reuse the
        # ref full path for correctness (far view off the critical core path).
        out, fu = _ref.paged_decode_attention_ref(
            q, pool_k, pool_v, block_table, window_base, seq_lens, slot_active,
            near_window=near_window, far_k=far_k, far_v=far_v,
            far_table=far_table, far_valid=far_valid, skip_extent=skip_extent)
        return out, fu

    near_out = _paged_decode_attention_impl(
        q, pool_k, pool_v, block_table, window_base, seq_lens, slot_active,
        near_window=near_window, k_scale=k_scale, v_scale=v_scale,
        skip_extent=bool(skip_extent), prefetch_depth=int(prefetch_depth),
        dma=bool(dma), interpret=interpret)
    B = q.shape[0]
    return near_out, jnp.zeros((B, 1), jnp.float32)
