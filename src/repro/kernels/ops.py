"""Jitted dispatch layer over kernel implementations.

``implementation`` selects:
  * 'jnp'     — pure-jnp reference (ref.py). Used by the distributed dry-run so
                cost/memory analysis reflects the real data movement.
  * 'pallas'  — Pallas TPU kernels (pl.pallas_call + BlockSpec). On this CPU
                container they run in interpret mode; on TPU they are the
                production path.

Models call these entry points and stay ignorant of paging internals.
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels import ref

_DEFAULT_IMPL = os.environ.get("REPRO_KERNEL_IMPL", "jnp")


def set_default_impl(impl: str) -> None:
    global _DEFAULT_IMPL
    assert impl in ("jnp", "pallas")
    _DEFAULT_IMPL = impl


def get_default_impl() -> str:
    return _DEFAULT_IMPL


# ---------------------------------------------------------------------------

def pool_write(pool, new_vals, write_block, write_offset, active):
    return ref.pool_write_ref(pool, new_vals, write_block, write_offset, active)


def pool_write_stacked(pool, vals, write_block, write_offset, active):
    return ref.pool_write_stacked_ref(pool, vals, write_block, write_offset,
                                      active)


def pool_write_chunk(pool, vals, write_block, write_offset, n_valid):
    return ref.pool_write_chunk_ref(pool, vals, write_block, write_offset,
                                    n_valid)


def quant_pool_write_stacked(pool, scale, vals, write_block, write_offset,
                             active):
    """Quantize-at-commit write for the decode executor (DESIGN.md §10):
    narrow pool + per-block per-head scale pool updated together."""
    return ref.quant_pool_write_stacked_ref(pool, scale, vals, write_block,
                                            write_offset, active)


def quant_pool_write_chunk(pool, scale, vals, write_block, write_offset,
                           n_valid):
    """Quantize-at-commit write for the chunked prefill executor (§10)."""
    return ref.quant_pool_write_chunk_ref(pool, scale, vals, write_block,
                                          write_offset, n_valid)


def paged_decode_attention(q, pool_k, pool_v, block_table, window_base,
                           seq_lens, slot_active, *, near_window,
                           far_k=None, far_v=None, far_table=None,
                           far_valid=None, cur_k=None, cur_v=None,
                           k_scale=None, v_scale=None, skip_extent=False,
                           impl: str | None = None):
    impl = impl or _DEFAULT_IMPL
    from repro.distributed.act_sharding import constrain_model_dim
    q = constrain_model_dim(q, -1)
    if impl == "pallas" and cur_k is None:
        from repro.kernels import paged_attention
        return paged_attention.paged_decode_attention_pallas(
            q, pool_k, pool_v, block_table, window_base, seq_lens, slot_active,
            near_window=near_window, far_k=far_k, far_v=far_v,
            far_table=far_table, far_valid=far_valid,
            k_scale=k_scale, v_scale=v_scale, skip_extent=skip_extent)
    return ref.paged_decode_attention_ref(
        q, pool_k, pool_v, block_table, window_base, seq_lens, slot_active,
        near_window=near_window, far_k=far_k, far_v=far_v,
        far_table=far_table, far_valid=far_valid, cur_k=cur_k, cur_v=cur_v,
        k_scale=k_scale, v_scale=v_scale, skip_extent=skip_extent)


def chunked_prefill_attention(q, pool_k, pool_v, cur_k, cur_v, block_table,
                              window_base, start_pos, n_valid, *,
                              near_window, k_scale=None, v_scale=None,
                              skip_extent=False, impl: str | None = None):
    """One slot's prompt-chunk attention: paged pre-chunk context + in-chunk
    causal (the chunked prefill executor's core; DESIGN.md §3)."""
    impl = impl or _DEFAULT_IMPL
    if impl == "pallas":
        from repro.kernels import prefill_attention as pfa
        return pfa.chunked_prefill_attention_pallas(
            q, pool_k, pool_v, cur_k, cur_v, block_table, window_base,
            start_pos, n_valid, near_window=near_window,
            k_scale=k_scale, v_scale=v_scale, skip_extent=skip_extent)
    return ref.chunked_prefill_attention_ref(
        q, pool_k, pool_v, cur_k, cur_v, block_table, window_base,
        start_pos, n_valid, near_window=near_window,
        k_scale=k_scale, v_scale=v_scale, skip_extent=skip_extent)


def mla_decode_attention(q_nope, q_rope, pool_lat, w_k_b, w_v_b, block_table,
                         window_base, seq_lens, slot_active, *, near_window,
                         kv_lora_rank, far_lat=None, far_table=None,
                         far_valid=None, cur_lat=None, impl: str | None = None):
    impl = impl or _DEFAULT_IMPL
    return ref.mla_decode_attention_ref(
        q_nope, q_rope, pool_lat, w_k_b, w_v_b, block_table, window_base,
        seq_lens, slot_active, near_window=near_window,
        kv_lora_rank=kv_lora_rank, far_lat=far_lat, far_table=far_table,
        far_valid=far_valid, cur_lat=cur_lat)


def farview_summarize(pool, chunk_blocks, n_tokens, do_summarize,
                      impl: str | None = None):
    impl = impl or _DEFAULT_IMPL
    if impl == "pallas":
        from repro.kernels import farview_summarize as fvs
        return fvs.farview_summarize_pallas(pool, chunk_blocks, n_tokens, do_summarize)
    return ref.farview_summarize_ref(pool, chunk_blocks, n_tokens, do_summarize)


def prefill_attention(q, k, v, *, causal=True, window=None,
                      impl: str | None = None):
    impl = impl or _DEFAULT_IMPL
    if impl == "pallas":
        from repro.kernels import prefill_attention as pfa
        return pfa.prefill_attention_pallas(q, k, v, causal=causal, window=window)
    from repro.models.common import attention_blocked, attention_dense
    if q.shape[1] > 1024:
        return attention_blocked(q, k, v, causal=causal, window=window)
    return attention_dense(q, k, v, causal=causal, window=window)
