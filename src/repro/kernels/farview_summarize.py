"""Pallas TPU kernel: far-view chunk summarization (uniform aggregation).

Mean-pools one completed sv_chunk per slot from the paged pool into a single
summary row (paper §4.4: O(1) per-block construction, no scoring kernels).
Grid (B, CB): each step copies one chunk block (scalar-prefetched id) and
accumulates into VMEM scratch; the gate predicates the whole slot.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import resolve_interpret


def _sum_kernel(chunk_tbl_ref, meta_ref, pool_ref, o_ref, acc_ref,
                *, bt: int, width: int):
    b = pl.program_id(0)
    i = pl.program_id(1)
    cb = pl.num_programs(1)

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    n_tok = meta_ref[b, 0]
    gate = meta_ref[b, 1]

    blk = pool_ref[0].astype(jnp.float32).reshape(bt, width)   # (BT, width)
    pos = i * bt + jax.lax.broadcasted_iota(jnp.int32, (bt, 1), 0)
    m = ((pos < n_tok) & (gate > 0)).astype(jnp.float32)
    acc_ref[...] += (blk * m).sum(axis=0, keepdims=True)

    @pl.when(i == cb - 1)
    def _fin():
        denom = jnp.maximum(n_tok, 1).astype(jnp.float32)
        out = acc_ref[...] / denom
        o_ref[...] = jnp.where(gate > 0, out, 0.0).astype(o_ref.dtype)


def farview_summarize_pallas(pool, chunk_blocks, n_tokens, do_summarize,
                             interpret=None):
    """pool: (P,BT,...payload); chunk_blocks: (B,CB); n_tokens/do_summarize:
    (B,). Returns (B, ...payload) mean summaries (zeros where gated off).
    interpret=None resolves from the backend (kernels/runtime.py)."""
    return _farview_summarize_impl(pool, chunk_blocks, n_tokens, do_summarize,
                                   interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("interpret",))
def _farview_summarize_impl(pool, chunk_blocks, n_tokens, do_summarize,
                            interpret=True):
    P, BT = pool.shape[:2]
    payload = pool.shape[2:]
    width = 1
    for d in payload:
        width *= d
    B, CB = chunk_blocks.shape
    pool2 = pool.reshape(P, BT, width)
    meta = jnp.stack([n_tokens, do_summarize.astype(jnp.int32)], axis=1
                     ).astype(jnp.int32)

    kernel = functools.partial(_sum_kernel, bt=BT, width=width)
    gs = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(B, CB),
        in_specs=[pl.BlockSpec((1, BT, width),
                               lambda b, i, tbl, meta: (tbl[b, i], 0, 0))],
        out_specs=pl.BlockSpec((1, width), lambda b, i, tbl, meta: (b, 0)),
        scratch_shapes=[pltpu.VMEM((1, width), jnp.float32)],
    )
    out = pl.pallas_call(kernel, grid_spec=gs,
                         out_shape=jax.ShapeDtypeStruct((B, width), pool.dtype),
                         interpret=interpret,
                         )(chunk_blocks.astype(jnp.int32), meta, pool2)
    return out.reshape((B,) + payload)
