"""Pallas TPU kernel: blocked causal (flash-style) prefill attention.

Grid (B, H, nQ, nK) with online softmax in VMEM scratch; causal blocks above
the diagonal are skipped via masking (TPU grids are static — the mask makes
the skipped block a no-op; Mosaic elides the copy when the index map is
revisited). q/k blocks are MXU-aligned (multiples of 128 recommended).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _prefill_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                    *, q_blk: int, k_blk: int, hd: int, causal: bool,
                    window: int, scale: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (q_blk, hd)
    k = k_ref[0, 0].astype(jnp.float32)          # (k_blk, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    qpos = iq * q_blk + jax.lax.broadcasted_iota(jnp.int32, (q_blk, k_blk), 0)
    kpos = ik * k_blk + jax.lax.broadcasted_iota(jnp.int32, (q_blk, k_blk), 1)
    valid = jnp.ones((q_blk, k_blk), jnp.bool_)
    if causal:
        valid &= kpos <= qpos
    if window > 0:
        valid &= kpos > qpos - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _fin():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-20)
                       ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_blk",
                                             "k_blk", "interpret"))
def prefill_attention_pallas(q, k, v, *, causal=True, window=None,
                             q_blk=128, k_blk=128, interpret=True):
    """q: (B,S,H,hd); k,v: (B,S,KV,hd) -> (B,S,H,hd). GQA via kv replication
    at the BlockSpec level (no materialized repeat)."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    n_rep = H // KV
    scale = 1.0 / math.sqrt(hd)
    q_blk = min(q_blk, S)
    k_blk = min(k_blk, S)
    assert S % q_blk == 0 and S % k_blk == 0
    grid = (B, H, S // q_blk, S // k_blk)

    qt = q.transpose(0, 2, 1, 3)                 # (B,H,S,hd)
    kt = k.transpose(0, 2, 1, 3)                 # (B,KV,S,hd)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_prefill_kernel, q_blk=q_blk, k_blk=k_blk,
                               hd=hd, causal=causal, window=window or 0,
                               scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q_blk, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, k_blk, hd),
                         lambda b, h, iq, ik: (b, h // n_rep, ik, 0)),
            pl.BlockSpec((1, 1, k_blk, hd),
                         lambda b, h, iq, ik: (b, h // n_rep, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_blk, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        scratch_shapes=[
            pltpu.VMEM((q_blk, hd), jnp.float32),
            pltpu.VMEM((q_blk, 1), jnp.float32),
            pltpu.VMEM((q_blk, 1), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
