"""Pallas TPU kernels: blocked causal (flash-style) prefill attention, plus
the chunked paged-prefill kernel used by the engine's prompt-ingestion
executor (DESIGN.md §3).

Dense kernel: grid (B, H, nQ, nK) with online softmax in VMEM scratch; causal
blocks above the diagonal are skipped via masking (TPU grids are static — the
mask makes the skipped block a no-op; Mosaic elides the copy when the index
map is revisited). q/k blocks are MXU-aligned (multiples of 128 recommended).

Chunked kernel: grid (KV, NB + 1) for ONE slot's C-token chunk. Steps
0..NB-1 walk the committed near-window block table (scalar prefetch, one
~tau-byte HBM->VMEM block copy per step — the same merged-transport contract
as the decode kernel); the final step folds the chunk's own K/V causally.
Pool steps outside the chunk's active block extent (DESIGN.md §12 — blocks
with no position in ``[max(0, start-W+1), start-1]``, i.e. the causal upper
triangle plus the window trailing edge) are predicated off with ``@pl.when``
and their copies elided via a clamped index map: fixed grid, variable work,
bitwise-identical output.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.runtime import resolve_interpret

NEG_INF = -1e30


def _prefill_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref,
                    *, q_blk: int, k_blk: int, hd: int, causal: bool,
                    window: int, scale: float):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # (q_blk, hd)
    k = k_ref[0, 0].astype(jnp.float32)          # (k_blk, hd)
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    qpos = iq * q_blk + jax.lax.broadcasted_iota(jnp.int32, (q_blk, k_blk), 0)
    kpos = ik * k_blk + jax.lax.broadcasted_iota(jnp.int32, (q_blk, k_blk), 1)
    valid = jnp.ones((q_blk, k_blk), jnp.bool_)
    if causal:
        valid &= kpos <= qpos
    if window > 0:
        valid &= kpos > qpos - window
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
    p = jnp.where(valid, jnp.exp(s - m_new), 0.0)
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(ik == nk - 1)
    def _fin():
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l_ref[...], 1e-20)
                       ).astype(o_ref.dtype)


def _chunk_kernel(*refs, bt: int, chunk: int, n_rep: int, hd: int,
                  near_window: int, scale: float, quant: bool):
    if quant:
        # quantized tier (DESIGN.md §10): per-block per-head dequant scales
        # as extra scalar-prefetch operands (SMEM); pool-block loads grow a
        # fused dequantize epilogue (the chunk's own K/V stays full width)
        (block_tbl_ref, meta_ref, ks_ref, vs_ref,
         q_ref, k_ref, v_ref, ck_ref, cv_ref, o_ref,
         acc_ref, m_ref, l_ref) = refs
    else:
        (block_tbl_ref, meta_ref, q_ref, k_ref, v_ref, ck_ref, cv_ref,
         o_ref, acc_ref, m_ref, l_ref) = refs
    g = pl.program_id(0)
    i = pl.program_id(1)
    nb = pl.num_programs(1) - 1                   # pool steps; last = chunk

    @pl.when(i == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    wb = meta_ref[0]
    start = meta_ref[1]
    n_valid = meta_ref[2]
    ext_lo = meta_ref[3]
    ext_hi = meta_ref[4]
    q = q_ref[:, 0].astype(jnp.float32)           # (C, n_rep, hd)
    qpos = start + jax.lax.broadcasted_iota(jnp.int32, (chunk, 1, 1), 0)

    def _online_update(s, valid):
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]                       # (C, n_rep)
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        p = jnp.where(valid, p, 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
        m_ref[...] = m_new
        return p, corr

    # pool steps (i < nb) run only inside the chunk's active block extent
    # (DESIGN.md §12); out-of-extent pool blocks are fully masked anyway, so
    # predication is a bitwise no-op that skips both dots and (with the
    # clamped index map) the HBM->VMEM copy. ext_hi <= nb always.
    @pl.when((i >= ext_lo) & (i < ext_hi))
    def _pool_block():
        kb = k_ref[0, :, 0].astype(jnp.float32)   # (BT, hd)
        vb = v_ref[0, :, 0].astype(jnp.float32)
        if quant:
            blk = block_tbl_ref[jnp.minimum(i, block_tbl_ref.shape[0] - 1)]
            kb = kb * ks_ref[blk, g]              # scalar scale from SMEM
            vb = vb * vs_ref[blk, g]
        s = jax.lax.dot_general(q, kb, (((2,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        pos = wb + i * bt + jax.lax.broadcasted_iota(
            jnp.int32, (1, 1, bt), 2)             # (1,1,BT)
        valid = (pos < start) & (pos > qpos - near_window) & (pos >= 0)
        p, corr = _online_update(s, valid)
        pv = jax.lax.dot_general(p, vb, (((2,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[..., None] + pv

    @pl.when(i == nb)
    def _chunk_causal():
        kc = ck_ref[:, 0].astype(jnp.float32)     # (C, hd)
        vc = cv_ref[:, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q, kc, (((2,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        j = jax.lax.broadcasted_iota(jnp.int32, (1, 1, chunk), 2)
        valid = (start + j <= qpos) & (start + j > qpos - near_window) \
            & (j < n_valid)
        p, corr = _online_update(s, valid)
        pv = jax.lax.dot_general(p, vc, (((2,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * corr[..., None] + pv
        # finalize (last grid step along axis 1)
        denom = jnp.maximum(l_ref[...], 1e-20)[..., None]
        row_ok = jax.lax.broadcasted_iota(jnp.int32, (chunk, 1, 1), 0) < n_valid
        o_ref[:, 0] = jnp.where(row_ok, acc_ref[...] / denom, 0.0
                                ).astype(o_ref.dtype)


def chunked_prefill_attention_pallas(q, pool_k, pool_v, cur_k, cur_v,
                                     block_table, window_base, start_pos,
                                     n_valid, *, near_window,
                                     k_scale=None, v_scale=None,
                                     skip_extent=True, interpret=None):
    """One slot's C-token prompt chunk over the paged near window.

    q: (C,H,hd); pool_k/v: (P,BT,KV,hd); cur_k/v: (C,KV,hd);
    block_table: (NB,). k_scale/v_scale: optional (P,KV) f32 per-block
    dequant scales for narrow pools (scalar-prefetch/SMEM; DESIGN.md §10).
    skip_extent=False pins the extent to [0, NB) — the always-run masked
    baseline. interpret=None resolves from the backend (kernels/runtime.py).
    Returns (C,H,hd) with rows >= n_valid zeroed.
    Validated against kernels/ref.py chunked_prefill_attention_ref."""
    return _chunked_prefill_attention_impl(
        q, pool_k, pool_v, cur_k, cur_v, block_table, window_base, start_pos,
        n_valid, near_window=near_window, k_scale=k_scale, v_scale=v_scale,
        skip_extent=bool(skip_extent), interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("near_window", "skip_extent",
                                             "interpret"))
def _chunked_prefill_attention_impl(q, pool_k, pool_v, cur_k, cur_v,
                                    block_table, window_base, start_pos,
                                    n_valid, *, near_window,
                                    k_scale=None, v_scale=None,
                                    skip_extent=True, interpret=True):
    from repro.kernels.ref import chunk_block_extent

    C, H, hd = q.shape
    P, BT, KV, _ = pool_k.shape
    NB = block_table.shape[0]
    n_rep = H // KV
    scale = 1.0 / math.sqrt(hd)
    quant = k_scale is not None

    ext_lo, ext_hi = chunk_block_extent(
        jnp.asarray(window_base), jnp.asarray(start_pos),
        near_window=near_window, nb=NB, bt=BT)
    if not skip_extent:
        ext_lo = jnp.zeros_like(ext_lo)
        ext_hi = jnp.full_like(ext_hi, NB)
    meta = jnp.stack([jnp.asarray(window_base, jnp.int32),
                      jnp.asarray(start_pos, jnp.int32),
                      jnp.asarray(n_valid, jnp.int32),
                      ext_lo, ext_hi]).astype(jnp.int32)          # (5,)
    qg = q.reshape(C, KV, n_rep, hd)

    grid = (KV, NB + 1)
    kernel = functools.partial(_chunk_kernel, bt=BT, chunk=C, n_rep=n_rep,
                               hd=hd, near_window=near_window, scale=scale,
                               quant=quant)

    def _ix(f):
        # index maps take one trailing arg per scalar-prefetch operand
        return (lambda g, i, tbl, meta, ks, vs: f(g, i, tbl, meta)) if quant \
            else (lambda g, i, tbl, meta: f(g, i, tbl, meta))

    def _blk_ix(g, i, tbl, meta):
        # clamp out-of-extent steps (incl. the final chunk step) onto the
        # extent boundary so the revisited index elides the block copy
        j = jnp.clip(i, meta[3], jnp.maximum(meta[4] - 1, meta[3]))
        return (tbl[jnp.minimum(j, tbl.shape[0] - 1)], 0, g, 0)
    gs = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=4 if quant else 2,
        grid=grid,
        in_specs=[
            pl.BlockSpec((C, 1, n_rep, hd),
                         _ix(lambda g, i, tbl, meta: (0, g, 0, 0))),
            pl.BlockSpec((1, BT, 1, hd), _ix(_blk_ix)),
            pl.BlockSpec((1, BT, 1, hd), _ix(_blk_ix)),
            pl.BlockSpec((C, 1, hd), _ix(lambda g, i, tbl, meta: (0, g, 0))),
            pl.BlockSpec((C, 1, hd), _ix(lambda g, i, tbl, meta: (0, g, 0))),
        ],
        out_specs=pl.BlockSpec((C, 1, n_rep, hd),
                               _ix(lambda g, i, tbl, meta: (0, g, 0, 0))),
        scratch_shapes=[
            pltpu.VMEM((C, n_rep, hd), jnp.float32),
            pltpu.VMEM((C, n_rep), jnp.float32),
            pltpu.VMEM((C, n_rep), jnp.float32),
        ],
    )
    sp_args = (block_table.astype(jnp.int32), meta)
    if quant:
        sp_args += (k_scale.astype(jnp.float32), v_scale.astype(jnp.float32))
    out = pl.pallas_call(
        kernel, grid_spec=gs,
        out_shape=jax.ShapeDtypeStruct((C, KV, n_rep, hd), q.dtype),
        interpret=interpret,
    )(*sp_args, qg, pool_k, pool_v, cur_k, cur_v)
    return out.reshape(C, H, hd)


def prefill_attention_pallas(q, k, v, *, causal=True, window=None,
                             q_blk=128, k_blk=128, interpret=None):
    """q: (B,S,H,hd); k,v: (B,S,KV,hd) -> (B,S,H,hd). GQA via kv replication
    at the BlockSpec level (no materialized repeat). interpret=None resolves
    from the backend (kernels/runtime.py)."""
    return _prefill_attention_impl(q, k, v, causal=causal, window=window,
                                   q_blk=q_blk, k_blk=k_blk,
                                   interpret=resolve_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("causal", "window", "q_blk",
                                             "k_blk", "interpret"))
def _prefill_attention_impl(q, k, v, *, causal=True, window=None,
                            q_blk=128, k_blk=128, interpret=True):
    B, S, H, hd = q.shape
    KV = k.shape[2]
    n_rep = H // KV
    scale = 1.0 / math.sqrt(hd)
    q_blk = min(q_blk, S)
    k_blk = min(k_blk, S)
    assert S % q_blk == 0 and S % k_blk == 0
    grid = (B, H, S // q_blk, S // k_blk)

    qt = q.transpose(0, 2, 1, 3)                 # (B,H,S,hd)
    kt = k.transpose(0, 2, 1, 3)                 # (B,KV,S,hd)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(_prefill_kernel, q_blk=q_blk, k_blk=k_blk,
                               hd=hd, causal=causal, window=window or 0,
                               scale=scale)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, q_blk, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, k_blk, hd),
                         lambda b, h, iq, ik: (b, h // n_rep, ik, 0)),
            pl.BlockSpec((1, 1, k_blk, hd),
                         lambda b, h, iq, ik: (b, h // n_rep, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, q_blk, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        scratch_shapes=[
            pltpu.VMEM((q_blk, hd), jnp.float32),
            pltpu.VMEM((q_blk, 1), jnp.float32),
            pltpu.VMEM((q_blk, 1), jnp.float32),
        ],
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
