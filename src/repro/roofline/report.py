"""Generate the EXPERIMENTS.md §Dry-run + §Roofline tables from the JSON
artifacts in experiments/dryrun/.

    PYTHONPATH=src python -m repro.roofline.report [--out EXPERIMENTS.md]
"""
from __future__ import annotations

import argparse
import glob
import json
import os

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                          "experiments", "dryrun")


def load(dirname=DRYRUN_DIR):
    recs = []
    for f in sorted(glob.glob(os.path.join(dirname, "*.json"))):
        with open(f) as fh:
            recs.append(json.load(fh))
    return recs


def _fmt_s(x):
    if x >= 1:
        return f"{x:.2f}s"
    if x >= 1e-3:
        return f"{x*1e3:.1f}ms"
    return f"{x*1e6:.0f}us"


def dryrun_table(recs, mesh):
    rows = ["| arch | shape | ok | semantics | mem/dev GiB | compile s | "
            "coll bytes/dev |",
            "|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh or r.get("variant"):
            continue
        if not r.get("ok"):
            rows.append(f"| {r['arch']} | {r['shape']} | FAIL | | | | "
                        f"{r.get('error','')[:60]} |")
            continue
        ro = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | ok | {r.get('semantics','')} | "
            f"{r['memory']['total_bytes_per_device']/2**30:.1f} | "
            f"{r['compile_s']:.0f} | {ro['coll_bytes']:.2e} |")
    return "\n".join(rows)


def roofline_table(recs, mesh="single"):
    rows = ["| arch | shape | compute | memory | collective | bottleneck | "
            "MODEL_FLOPS | useful ratio | roofline frac | note |",
            "|---|---|---|---|---|---|---|---|---|---|"]
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"])):
        if r["mesh"] != mesh or not r.get("ok") or r.get("variant"):
            continue
        ro = r["roofline"]
        note = _note(r)
        rows.append(
            f"| {r['arch']} | {r['shape']} | {_fmt_s(ro['compute_s'])} | "
            f"{_fmt_s(ro['memory_s'])} | {_fmt_s(ro['collective_s'])} | "
            f"**{ro['bottleneck']}** | {ro['model_flops']:.2e} | "
            f"{ro['useful_flops_ratio']:.2f} | {ro['roofline_fraction']:.3f} | "
            f"{note} |")
    return "\n".join(rows)


def _note(r):
    ro = r["roofline"]
    b = ro["bottleneck"]
    if b == "memory":
        if r["shape"].startswith("decode") or r["shape"].startswith("long"):
            return "KV window + pool copies dominate; larger trains / in-place pool writes"
        return "score-tensor HBM traffic; Pallas flash kernel keeps blocks in VMEM"
    if b == "collective":
        return "shrink TP collectives (bf16 psum, overlap with compute)"
    return "near compute roof; increase arithmetic intensity"


def variants_table(recs):
    rows = ["| arch | shape | variant | compute | memory | collective | "
            "bottleneck | frac |",
            "|---|---|---|---|---|---|---|---|"]
    any_ = False
    for r in sorted(recs, key=lambda r: (r["arch"], r["shape"], r.get("variant", ""))):
        if not r.get("variant") or not r.get("ok"):
            continue
        any_ = True
        ro = r["roofline"]
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['variant']} | "
            f"{_fmt_s(ro['compute_s'])} | {_fmt_s(ro['memory_s'])} | "
            f"{_fmt_s(ro['collective_s'])} | {ro['bottleneck']} | "
            f"{ro['roofline_fraction']:.3f} |")
    return "\n".join(rows) if any_ else "(no variant runs yet)"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default=DRYRUN_DIR)
    args = ap.parse_args()
    recs = load(args.dir)
    print("## §Dry-run — single pod (16x16 = 256 chips)\n")
    print(dryrun_table(recs, "single"))
    print("\n## §Dry-run — multi-pod (2x16x16 = 512 chips)\n")
    print(dryrun_table(recs, "multi"))
    print("\n## §Roofline — single pod baseline (per-chip terms)\n")
    print(roofline_table(recs, "single"))
    print("\n## §Perf — variant runs\n")
    print(variants_table(recs))


if __name__ == "__main__":
    main()
