"""Per-kernel achieved-vs-peak roofline rows for bench artifacts.

``benchmarks/run.py --json`` embeds these so every artifact carries a
model-level accounting of the kernels the serving path leans on: for
each kernel the trip-count-aware HLO walk (roofline.hlo_cost) yields
per-device FLOPs/bytes, and the roofline terms report how far the
*useful* work sits from the bound step time on the reference chip
(analysis.PEAK_FLOPS / HBM_BW) — ``roofline_fraction`` IS the
achieved-vs-peak figure under perfect overlap (see analysis docstring;
this is a compile-time dry-run metric, independent of the host the
bench happened to run on).

Kernels are compiled at small fixed shapes on the reduced config so the
rows are cheap (<~10 s total) and stable across runs: a chunked-prefill
style forward and a single-token decode-style forward, the two programs
the engine's step dispatch amortizes everything else against.
"""
from __future__ import annotations

import time
from typing import Dict, Optional

DEFAULT_ARCH = "qwen2.5-32b"

# (name, tokens-per-slot lowered, batch, kind, visible_window,
#  effective_window) — effective_window models the mean per-slot extent a
# skewed batch leaves after the extent-predicated kernels (DESIGN.md §12)
# drop fully-masked KV blocks; None means no skew (effective == padded).
KERNELS = (
    ("prefill_chunk", 128, 2, "prefill", None, None),
    ("decode_step", 1, 8, "decode", 512, None),
    # bimodal skew (1 long : 7 short slots) — same compiled program as
    # decode_step, accounted at the mean visible extent instead of padded
    ("decode_step_skewed", 1, 8, "decode", 512, 160),
)


def _cost_dict(compiled) -> dict:
    """compiled.cost_analysis() across jax versions: dict, list-of-dict
    (jax 0.4.x CPU), or unavailable."""
    try:
        cost = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return dict(cost or {})


def kernel_rows(arch: str = DEFAULT_ARCH) -> Dict[str, dict]:
    """Compile each reference kernel for the reduced config and summarize
    its roofline terms. Raises on breakage — callers wanting a
    best-effort artifact field use ``report``."""
    import jax
    import jax.numpy as jnp

    from repro.configs import get_reduced
    from repro.configs.base import ShapeConfig
    from repro.models import registry
    from repro.roofline import analysis

    cfg = get_reduced(arch)
    params = jax.eval_shape(lambda k: registry.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    rows: Dict[str, dict] = {}
    for name, toks, batch, kind, vis, eff in KERNELS:
        # seq_len feeds useful-work accounting (the decode kernel's KV
        # window), toks is what the kernel actually lowers per slot
        shape_cfg = ShapeConfig(name, max(toks, vis or 0), batch, kind)
        tok = jax.ShapeDtypeStruct((batch, toks), jnp.int32)
        t0 = time.perf_counter()
        compiled = jax.jit(
            lambda p, t: registry.forward(p, cfg, t)).lower(
            params, tok).compile()
        compile_s = time.perf_counter() - t0
        roof = analysis.summarize(
            _cost_dict(compiled), compiled.as_text(), cfg, shape_cfg,
            arch, name, "single", 1, visible_window=vis,
            effective_window=eff)
        d = roof.to_dict()
        rows[name] = {
            "kernel": name, "arch": arch, "kind": kind,
            "tokens": toks, "batch": batch,
            "compile_s": round(compile_s, 3),
            "hlo_flops": d["hlo_flops"], "hlo_bytes": d["hlo_bytes"],
            "coll_bytes": d["coll_bytes"],
            "compute_s": d["compute_s"], "memory_s": d["memory_s"],
            "collective_s": d["collective_s"],
            "bottleneck": d["bottleneck"],
            "bound_step_s": d["bound_step_s"],
            "ideal_step_s": d["ideal_step_s"],
            "roofline_fraction": d["roofline_fraction"],
            "effective_ideal_step_s": d["effective_ideal_step_s"],
            "effective_roofline_fraction": d["effective_roofline_fraction"],
            "work_skip_fraction": d["work_skip_fraction"],
            "peak_flops": analysis.PEAK_FLOPS,
            "peak_hbm_bw": analysis.HBM_BW,
        }
    return rows


def report(arch: str = DEFAULT_ARCH) -> dict:
    """Best-effort wrapper for artifact embedding: never raises, records
    the failure instead so a roofline breakage cannot sink a bench run."""
    try:
        return {"ok": True, "arch": arch, "kernels": kernel_rows(arch)}
    except Exception as e:                              # pragma: no cover
        return {"ok": False, "arch": arch,
                "error": f"{type(e).__name__}: {e}"}
