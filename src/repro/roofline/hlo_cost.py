"""Trip-count-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, not
multiplied by its trip count (verified empirically: a scan of 8 matmuls
reports 1/8 of the unrolled FLOPs). Every model here scans over layers /
microbatches / chunks, so raw cost_analysis under-reports by 1-3 orders of
magnitude. This module walks the post-SPMD HLO text instead:

  * computations are parsed into instruction lists with result shapes;
  * ``while`` trip counts are recovered from the loop-condition constant
    (lax.scan emits a canonical induction-variable < constant compare);
  * cost(computation) = local + sum(multiplier * cost(callee)) with
    multiplier = trip count for while bodies, 1 elsewhere;
  * FLOPs: dot_general = 2 * prod(result) * contraction; elementwise ~ 1/elem
    (fusion-internal instructions count toward FLOPs but not bytes);
  * bytes: per top-level instruction, result write + operand reads, with
    sliced-access ops (gather/dynamic-slice; scatter/dynamic-update-slice)
    counted by the sliced size, and a >=64x operand/result ratio heuristic
    for fusions that embed gathers;
  * collectives: result bytes, multiplied by enclosing trip counts.

Validated in tests/test_roofline.py against unrolled-vs-scanned programs and
closed-form transformer FLOP counts.
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"(pred|bf16|f16|f32|f64|s4|u4|s8|u8|s16|u16|s32|u32"
                       r"|s64|u64|c64|c128|token)\[([0-9,]*)\]")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "power",
    "exponential", "tanh", "rsqrt", "sqrt", "log", "negate", "abs", "select",
    "compare", "and", "or", "xor", "convert", "floor", "ceil", "sign",
    "cosine", "sine", "logistic", "clamp",
}

_NO_COST = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "after-all", "iota", "partition-id", "replica-id", "reshape", "broadcast",
    "transpose",  # layout ops usually fused / free-ish; copies counted below
}


def _shape_elems_bytes(type_str: str) -> Tuple[int, int]:
    """Total (elements, bytes) across all array shapes in a type string."""
    elems = 0
    byts = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


@dataclass
class Instr:
    name: str
    op: str
    result_type: str
    operands: List[str]
    attrs: str
    result_elems: int = 0
    result_bytes: int = 0


@dataclass
class Computation:
    name: str
    instrs: List[Instr] = field(default_factory=list)
    symbols: Dict[str, Instr] = field(default_factory=dict)


_COMP_HDR = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*\{\s*$")
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*((?:\([^)]*\)|[\w\[\],{}]+))\s+"
    r"([\w\-]+)\((.*?)\)(.*)$")
_OPERAND = re.compile(r"%([\w.\-]+)")
_CALLED = re.compile(r"(?:to_apply|calls|body|condition)=%?([\w.\-]+)")
_BRANCHES = re.compile(r"branch_computations=\{([^}]*)\}")


def parse_hlo(text: str) -> Tuple[Dict[str, Computation], Optional[str]]:
    comps: Dict[str, Computation] = {}
    entry = None
    cur: Optional[Computation] = None
    for line in text.splitlines():
        if cur is None:
            m = _COMP_HDR.match(line.strip())
            if m and line.rstrip().endswith("{"):
                cur = Computation(m.group(1))
                if line.strip().startswith("ENTRY"):
                    entry = cur.name
                continue
        else:
            if line.strip() == "}":
                comps[cur.name] = cur
                cur = None
                continue
            m = _INSTR.match(line)
            if m:
                name, rtype, op, args, attrs = m.groups()
                ins = Instr(name=name, op=op, result_type=rtype,
                            operands=_OPERAND.findall(args), attrs=attrs)
                ins.result_elems, ins.result_bytes = _shape_elems_bytes(rtype)
                cur.instrs.append(ins)
                cur.symbols[name] = ins
    return comps, entry


def _trip_count(cond: Computation) -> int:
    """lax.scan canonical form: induction var compared against a constant."""
    best = 1
    for ins in cond.instrs:
        if ins.op == "constant":
            m = re.search(r"constant\((-?\d+)\)", ins.attrs) or \
                re.search(r"\((-?\d+)\)", ins.result_type)
        else:
            m = None
        txt = ins.attrs or ""
        for mm in re.finditer(r"constant\((\d+)\)", txt):
            best = max(best, int(mm.group(1)))
    # constants appear as `%c = s32[] constant(64)`
    for ins in cond.instrs:
        if ins.op == "constant":
            mm = re.search(r"\bconstant\((\d+)\)", ins.attrs)
            if mm:
                best = max(best, int(mm.group(1)))
    return best


def _dot_flops(comp: Computation, ins: Instr) -> float:
    m = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", ins.attrs)
    if not m or not ins.operands:
        return 2.0 * ins.result_elems
    lhs = comp.symbols.get(ins.operands[0])
    if lhs is None:
        return 2.0 * ins.result_elems
    shapes = _SHAPE_RE.findall(lhs.result_type)
    if not shapes:
        return 2.0 * ins.result_elems
    dims = [int(d) for d in shapes[0][1].split(",") if d]
    k = 1
    for idx in (int(i) for i in m.group(1).split(",") if i):
        if idx < len(dims):
            k *= dims[idx]
    return 2.0 * ins.result_elems * k


@dataclass
class Cost:
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    coll_detail: Dict[str, float] = field(default_factory=lambda: {
        k: 0.0 for k in _COLLECTIVES})

    def __iadd__(self, o: "Cost"):
        self.flops += o.flops
        self.bytes += o.bytes
        self.coll_bytes += o.coll_bytes
        for k in _COLLECTIVES:
            self.coll_detail[k] += o.coll_detail[k]
        return self

    def scaled(self, m: float) -> "Cost":
        return Cost(self.flops * m, self.bytes * m, self.coll_bytes * m,
                    {k: v * m for k, v in self.coll_detail.items()})


SLICED_READ = {"gather", "dynamic-slice"}
SLICED_WRITE = {"scatter", "dynamic-update-slice"}


def _local_cost(comp: Computation, comps, fusion_ctx: bool) -> Cost:
    c = Cost()
    for ins in comp.instrs:
        op = ins.op
        base = op.replace("-start", "")
        if base in _COLLECTIVES:
            _, b = _shape_elems_bytes(ins.result_type)
            c.coll_bytes += b
            c.coll_detail[base] += b
            c.bytes += 2 * b
            continue
        if op.endswith("-done"):
            continue
        # flops
        if op in ("dot", "dot-general"):
            c.flops += _dot_flops(comp, ins)
        elif op in _ELEMENTWISE or op in ("reduce", "reduce-window", "map",
                                          "exponential-minus-one"):
            c.flops += float(ins.result_elems)
            if op == "reduce" and ins.operands:
                src = comp.symbols.get(ins.operands[0])
                if src is not None:
                    c.flops += float(src.result_elems)
        if fusion_ctx:
            continue  # fused instrs contribute flops only
        # bytes (HBM traffic model)
        if op in _NO_COST or op in ("while", "conditional", "call",
                                    "custom-call", "optimization-barrier"):
            continue  # control flow: children account for their own traffic
        write_b = ins.result_bytes
        read_b = 0
        if op in SLICED_READ:
            read_b = ins.result_bytes
        elif op in SLICED_WRITE:
            upd = comp.symbols.get(ins.operands[1]) if len(ins.operands) > 1 else None
            ub = upd.result_bytes if upd else ins.result_bytes
            write_b = ub
            read_b = ub
        else:
            sliced_fusion = False
            if op == "fusion":
                m = _CALLED.search(ins.attrs)
                body = comps.get(m.group(1)) if m else None
                if body is not None:
                    sliced_fusion = any(i.op in ("dynamic-slice", "gather")
                                        for i in body.instrs)
            for on in ins.operands:
                o = comp.symbols.get(on)
                if o is None:
                    continue
                ob = o.result_bytes
                # fusions embedding slices/gathers read slices, not the whole
                # stacked-weight / pool operand
                if op == "fusion" and sliced_fusion and \
                        ob > 2 * max(ins.result_bytes, 1):
                    ob = ins.result_bytes
                read_b += ob
        c.bytes += write_b + read_b
    return c


def analyze(text: str) -> Cost:
    comps, entry = parse_hlo(text)
    if entry is None:
        return Cost()
    memo: Dict[Tuple[str, bool], Cost] = {}

    # which computations are fusion bodies (flops-only)
    fusion_bodies = set()
    for comp in comps.values():
        for ins in comp.instrs:
            if ins.op == "fusion":
                m = _CALLED.search(ins.attrs)
                if m:
                    fusion_bodies.add(m.group(1))

    def cost_of(name: str, fusion_ctx: bool) -> Cost:
        key = (name, fusion_ctx)
        if key in memo:
            return memo[key]
        comp = comps.get(name)
        if comp is None:
            return Cost()
        memo[key] = Cost()          # cycle guard
        total = _local_cost(comp, comps, fusion_ctx)
        for ins in comp.instrs:
            if ins.op == "while":
                m_body = re.search(r"body=%?([\w.\-]+)", ins.attrs)
                m_cond = re.search(r"condition=%?([\w.\-]+)", ins.attrs)
                # XLA annotates scans with an explicit trip count
                m_trip = re.search(r'known_trip_count[^0-9]*(\d+)', ins.attrs)
                if m_trip:
                    trip = int(m_trip.group(1))
                else:
                    trip = _trip_count(comps[m_cond.group(1)]) if m_cond and \
                        m_cond.group(1) in comps else 1
                if m_body:
                    total += cost_of(m_body.group(1), fusion_ctx).scaled(trip)
            elif ins.op == "fusion":
                m = _CALLED.search(ins.attrs)
                if m:
                    total += cost_of(m.group(1), True)
            elif ins.op in ("call", "custom-call", "reduce", "scatter",
                            "sort", "map", "reduce-window", "select-and-scatter"):
                m = _CALLED.search(ins.attrs)
                if m and m.group(1) in comps:
                    total += cost_of(m.group(1), True)
            elif ins.op == "conditional":
                m = _BRANCHES.search(ins.attrs)
                if m:
                    for b in _OPERAND.findall(m.group(1)):
                        total += cost_of(b, fusion_ctx)
        memo[key] = total
        return total

    return cost_of(entry, False)
