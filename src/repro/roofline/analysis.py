"""Roofline analysis from compiled dry-run artifacts (no real hardware).

``compiled.cost_analysis()`` reports PER-DEVICE quantities (the SPMD
partitioned module), so the three terms are per-chip times directly:

    compute_s    = HLO_FLOPs / PEAK_FLOPS
    memory_s     = HLO_bytes / HBM_BW
    collective_s = collective_bytes / ICI_BW

collective bytes are parsed from the post-SPMD HLO text (result-shape bytes
of all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute,
counted once per op via the -start form when async).

The bound step time (perfect compute/memory/ICI overlap) is max(terms);
roofline_fraction = ideal_step / bound_step where ideal_step is what the
USEFUL work (MODEL_FLOPS and useful bytes: params once + KV window once)
would take on the dominant engine.

Hardware constants (TPU v5e-class target, per chip):
    197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, asdict
from typing import Dict, Optional

PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link / chip

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_SHAPE_RE = re.compile(r"\b(pred|bf16|f16|f32|f64|s8|u8|s16|u16|s32|u32|s64|u64)"
                       r"\[([0-9,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start)?\(", re.M)


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        n = 1
        if dims:
            for d in dims.split(","):
                if d:
                    n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Sum result-shape bytes per collective kind (skip *-done duplicates)."""
    out = {k: 0 for k in _COLLECTIVES}
    counts = {k: 0 for k in _COLLECTIVES}
    for m in _OP_RE.finditer(hlo_text):
        type_str, kind = m.group(1), m.group(2)
        out[kind] += _shape_bytes(type_str)
        counts[kind] += 1
    out["_counts"] = counts
    return out


@dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    chips: int
    # per-device quantities from the compiled module
    hlo_flops: float
    hlo_bytes: float
    coll_bytes: float
    coll_detail: Dict[str, int]
    # global useful-work quantities
    model_flops: float
    attn_flops: float
    useful_bytes: float
    # effective (work-skipped) useful work: what the step needs once the
    # extent-predicated kernels (DESIGN.md §12) drop fully-masked KV blocks.
    # Defaults to the padded figures when no effective_window was given.
    effective_attn_flops: float = 0.0
    effective_useful_bytes: float = 0.0
    # derived
    compute_s: float = 0.0
    memory_s: float = 0.0
    collective_s: float = 0.0
    bottleneck: str = ""
    useful_flops_ratio: float = 0.0
    bound_step_s: float = 0.0
    ideal_step_s: float = 0.0
    roofline_fraction: float = 0.0
    effective_ideal_step_s: float = 0.0
    effective_roofline_fraction: float = 0.0
    work_skip_fraction: float = 0.0

    def finalize(self) -> "Roofline":
        self.compute_s = self.hlo_flops / PEAK_FLOPS
        self.memory_s = self.hlo_bytes / HBM_BW
        self.collective_s = self.coll_bytes / ICI_BW
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        self.bottleneck = max(terms, key=terms.get)
        self.useful_flops_ratio = (self.model_flops / self.chips
                                   / self.hlo_flops if self.hlo_flops else 0.0)
        self.bound_step_s = max(terms.values())
        useful_flops = self.model_flops + self.attn_flops
        self.ideal_step_s = max(useful_flops / (self.chips * PEAK_FLOPS),
                                self.useful_bytes / (self.chips * HBM_BW))
        self.roofline_fraction = (self.ideal_step_s / self.bound_step_s
                                  if self.bound_step_s else 0.0)
        # effective (work-skipped) terms. roofline_fraction above stays on
        # the PADDED useful work so it remains comparable across PRs; the
        # effective_* figures bound what extent predication can recover.
        if not (self.effective_attn_flops or self.effective_useful_bytes):
            self.effective_attn_flops = self.attn_flops
            self.effective_useful_bytes = self.useful_bytes
        eff_flops = self.model_flops + self.effective_attn_flops
        self.effective_ideal_step_s = max(
            eff_flops / (self.chips * PEAK_FLOPS),
            self.effective_useful_bytes / (self.chips * HBM_BW))
        self.effective_roofline_fraction = (
            self.effective_ideal_step_s / self.bound_step_s
            if self.bound_step_s else 0.0)
        self.work_skip_fraction = (
            1.0 - self.effective_attn_flops / self.attn_flops
            if self.attn_flops else 0.0)
        return self

    def to_dict(self) -> dict:
        return asdict(self)


def model_flops_for(cfg, shape_cfg) -> float:
    """MODEL_FLOPS: 6*N_active*D for training (fwd+bwd), 2*N_active*D for
    inference (D = tokens processed by the lowered step)."""
    n_active = cfg.active_param_count()
    if shape_cfg.kind == "train":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 6.0 * n_active * tokens
    if shape_cfg.kind == "prefill":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 2.0 * n_active * tokens
    return 2.0 * n_active * shape_cfg.global_batch


def attn_flops_for(cfg, shape_cfg, visible_window: Optional[int] = None) -> float:
    """Analytical attention FLOPs (QK^T + PV), not captured by 6*N*D.
    Causal prefill/train does S^2/2 useful score work per head pair."""
    from repro.models import registry
    L = max(0, registry.n_paged_layers(cfg))
    H, hd = cfg.n_heads, cfg.head_dim
    B = shape_cfg.global_batch
    S = shape_cfg.seq_len
    if cfg.family == "ssm":
        return 0.0
    if shape_cfg.kind in ("train", "prefill"):
        if cfg.family == "encdec":
            Se = Sd = S // 2
            per = (Sd * Sd / 2 + Sd * Se) * cfg.dec_layers + \
                  (Se * Se) * cfg.enc_layers
            f = 4.0 * B * per * H * hd
        else:
            f = 4.0 * B * (S * S / 2) * H * hd * L
        return f * (3.0 if shape_cfg.kind == "train" else 1.0)
    win = min(S, visible_window or S)
    return 4.0 * B * win * H * hd * L


def useful_bytes_for(cfg, shape_cfg, visible_window: Optional[int] = None) -> float:
    """Minimum HBM traffic the step fundamentally requires (global bytes):
    read active params once; decode additionally reads each slot's visible KV
    window once and writes one token; train/prefill add activation-scale IO
    which is compute-dominated and ignored here."""
    from repro.models import registry
    pbytes = cfg.active_param_count() * 2.0
    if shape_cfg.kind == "train":
        # params read (fwd+bwd) + grads written + optimizer state r/w
        return 8.0 * pbytes
    if shape_cfg.kind == "prefill":
        kv_write = (shape_cfg.global_batch * shape_cfg.seq_len * cfg.kv_width
                    * 2.0 * max(1, registry.n_paged_layers(cfg)))
        return pbytes + kv_write
    win = min(shape_cfg.seq_len, visible_window or shape_cfg.seq_len)
    kv_read = (shape_cfg.global_batch * win * cfg.kv_width * 2.0
               * max(1, registry.n_paged_layers(cfg)))
    return pbytes + kv_read


def summarize(cost: dict, hlo_text: str, cfg, shape_cfg, arch: str,
              shape_name: str, mesh_name: str, chips: int,
              visible_window: Optional[int] = None,
              effective_window: Optional[int] = None) -> Roofline:
    """Trip-count-aware accounting via roofline.hlo_cost (XLA cost_analysis
    counts while bodies once — see hlo_cost docstring). The raw XLA numbers
    are kept in coll_detail['xla_raw'] for reference.

    effective_window: mean per-slot visible extent under a skewed length
    distribution — the work the extent-predicated kernels (DESIGN.md §12)
    actually perform, vs the padded visible_window the fixed grid lowers.
    """
    from repro.roofline import hlo_cost
    walked = hlo_cost.analyze(hlo_text)
    counts = collective_bytes(hlo_text).pop("_counts")
    eff = effective_window if effective_window is not None else visible_window
    return Roofline(
        arch=arch, shape=shape_name, mesh=mesh_name, chips=chips,
        hlo_flops=float(walked.flops),
        hlo_bytes=float(walked.bytes),
        coll_bytes=float(walked.coll_bytes),
        coll_detail={**{k: int(v) for k, v in walked.coll_detail.items()},
                     "counts": counts,
                     "xla_raw": {"flops": float(cost.get("flops", 0.0)),
                                 "bytes": float(cost.get("bytes accessed", 0.0))}},
        model_flops=model_flops_for(cfg, shape_cfg),
        attn_flops=attn_flops_for(cfg, shape_cfg, visible_window),
        useful_bytes=useful_bytes_for(cfg, shape_cfg, visible_window),
        effective_attn_flops=attn_flops_for(cfg, shape_cfg, eff),
        effective_useful_bytes=useful_bytes_for(cfg, shape_cfg, eff),
    ).finalize()
