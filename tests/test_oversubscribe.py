"""Host KV tier + preemption-aware scheduling (DESIGN.md §8): pager
residency state machine and COW-refcount interaction, transport swap-group
merging, scheduler preempt/resume + admission-stall reasons, and the
engine-level guarantee that a preempted-and-resumed run emits bitwise
identical tokens to an unpreempted one."""
import jax
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.configs import get_reduced
from repro.core.engine import EngineConfig, KVRMEngine
from repro.core.pager import (RES_DEVICE, RES_HOST, BlockPager, host_slot_of)
from repro.core.scheduler import Request, Scheduler
from repro.core.transport import MergeStagedTransport, merge_swap_pairs
from repro.models import registry


# ---------------------------------------------------------------------------
# pager: residency state machine + refcount interaction
# ---------------------------------------------------------------------------

def _paged(host=16, blocks=64):
    p = BlockPager(blocks, 16, bytes_per_block=1024, span_blocks=1,
                   host_pool_blocks=host)
    return p


def test_residency_roundtrip_device_host_device():
    p = _paged()
    p.open_session(0)
    p.reserve(0, 64)
    for _ in range(64):
        p.append_token(0)
    dev_before = list(p.sessions[0].blocks)
    pairs = p.swap_out_session(0)
    s = p.sessions[0]
    assert s.swap_state == RES_HOST
    assert [a for a, _ in pairs] == dev_before
    assert all(b < 0 for b in s.blocks)          # sign-encoded host entries
    assert p.host_used == 4 and p.reserved_blocks() == 0
    p.check_invariants()
    # swap back in (whole working set: from_local=0)
    back = p.swap_in_begin(0, 0)
    assert len(back) == 4
    assert [h for h, _ in back] == [host_slot_of(e) for e in
                                    [-(h + 1) for h, _ in back]]
    p.swap_in_commit(0)
    assert s.swap_state == RES_DEVICE
    assert all(b > 0 for b in s.blocks)
    assert p.host_used == 0 and p.reserved_blocks() == 4
    # appending continues where it left off
    p.reserve(0, 1)
    blk, off = p.append_token(0)
    assert blk > 0 and off == 0
    p.check_invariants()
    p.trim(0, close=True)
    p.check_invariants()
    assert p.reserved_blocks() == 0


def test_swap_refused_for_cow_aliased_blocks():
    """Swap-out of a session holding COW-shared blocks must be REFUSED
    (not torn): both alias sides are ineligible while the share lives."""
    p = _paged()
    p.open_session(0)
    p.reserve(0, 48)
    for _ in range(48):
        p.append_token(0)
    p.open_session(1)
    p.alias(0, 1, 32)                    # 2 full shared blocks
    assert not p.swap_eligible(0)
    assert not p.swap_eligible(1)
    assert p.swap_out_session(0) is None
    assert p.swap_out_session(1) is None
    assert p.stats["swap_refusals"] == 2
    p.check_invariants()
    # closing the alias drops refcounts back to 1: src eligible again
    p.trim(1, close=True)
    assert p.swap_eligible(0)
    assert p.swap_out_session(0) is not None
    p.check_invariants()


def test_cold_swap_skips_shared_and_partial_swaps_rest():
    """swap_out_cold moves only non-shared below-window blocks; the
    session stays device-resident and shared blocks stay put."""
    p = _paged()
    p.open_session(0)
    p.reserve(0, 96)
    for _ in range(96):
        p.append_token(0)
    p.open_session(1)
    p.alias(0, 1, 16)                    # share block 0 of session 0
    pairs = p.swap_out_cold(0, keep_from_local=3)
    # blocks 1, 2 move; block 0 is shared (refcount 2) and is skipped
    assert len(pairs) == 2
    s = p.sessions[0]
    assert s.swap_state == RES_DEVICE
    assert s.blocks[0] > 0 and s.blocks[1] < 0 and s.blocks[2] < 0
    assert all(b > 0 for b in s.blocks[3:])
    p.check_invariants()
    # idempotent: nothing cold left below 3
    assert p.swap_out_cold(0, keep_from_local=3) == []


def test_failed_reserve_rolls_back_partial_allocation():
    """A reserve that exhausts the pool mid-allocation must return the
    already-taken runs to the free list: §8 callers catch MemoryError and
    retry after preempting, so a partial take would leak blocks."""
    p = BlockPager(6, 8, span_blocks=1)       # 5 usable blocks
    p.open_session(0)
    p.reserve(0, 24)                          # 3 blocks; 2 free
    free_before = p.free_blocks()
    with pytest.raises(MemoryError):
        p.reserve(0, 26 + 24)                 # needs 4 more, only 2 free
    assert p.free_blocks() == free_before     # partial take rolled back
    p.check_invariants()
    assert len(p.reserve(0, 24 + 16)) == 2    # the 2 free blocks still work


def test_host_pool_exhaustion_raises():
    p = _paged(host=2)
    p.open_session(0)
    p.reserve(0, 64)
    for _ in range(64):
        p.append_token(0)
    with pytest.raises(MemoryError):
        p.swap_out_session(0)


def test_swap_preserves_frame_edit_log():
    p = _paged()
    p.open_session(0)
    p.reserve(0, 32)
    for _ in range(32):
        p.append_token(0)
    p.frame()
    p.swap_out_session(0)
    f = p.frame()
    assert any(e[0] == "swap_out" for e in f["edits"])
    p.swap_in_begin(0, 0)
    p.swap_in_commit(0)
    f2 = p.frame()
    assert any(e[0] == "swap_in" for e in f2["edits"])


@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["open", "reserve", "append",
                                           "cold", "preempt", "resume",
                                           "trim", "frame", "eos"]),
                          st.integers(0, 5), st.integers(1, 40)),
                min_size=1, max_size=60))
def test_swap_invariants_fuzz(ops):
    """Random verb sequences over BOTH tiers preserve refcount/free-list
    AND host-slot invariants; closing everything drains both pools. The
    ``eos`` verb injects a lagged-EOS overshoot (DESIGN.md §13): one
    reserve + append that is immediately scrubbed via
    ``reconcile_overshoot``, randomly interleaved with every other verb."""
    p = BlockPager(64, 8, span_blocks=1, host_pool_blocks=24)
    live = set()
    for op, sid, n in ops:
        try:
            if op == "open" and sid not in live:
                p.open_session(sid)
                live.add(sid)
            elif sid in live and p.sessions[sid].swap_state != RES_DEVICE:
                if op == "resume":
                    p.swap_in_begin(sid, max(0, n - 35))
                    p.swap_in_commit(sid)
            elif op == "reserve" and sid in live:
                p.reserve(sid, n)
            elif op == "append" and sid in live:
                s = p.sessions[sid]
                if s.length < len(s.blocks) * p.block_tokens:
                    p.append_token(sid)
            elif op == "eos" and sid in live:
                # overshot emission: the engine reserved and appended a
                # token the detected stop invalidates, then reconciles
                s = p.sessions[sid]
                newb = p.reserve(sid, 1)
                local = s.length - s.trimmed_prefix_blocks * p.block_tokens
                if s.blocks[local // p.block_tokens] > 0:
                    p.append_token(sid)
                    p.reconcile_overshoot(sid, newb, 1)
                else:        # write target cold-swapped: undo reserve only
                    p.reconcile_overshoot(sid, newb, 0)
            elif op == "cold" and sid in live:
                p.swap_out_cold(sid, min(n, len(p.sessions[sid].blocks)))
            elif op == "preempt" and sid in live:
                p.swap_out_session(sid)
            elif op == "trim" and sid in live:
                p.trim(sid, close=True)
                live.discard(sid)
            elif op == "frame":
                p.frame()
        except MemoryError:
            pass
        p.check_invariants()
    for sid in list(live):
        p.trim(sid, close=True)
    p.check_invariants()
    assert p.reserved_blocks() == 0 and p.host_used == 0


# ---------------------------------------------------------------------------
# transport: swap-group merging
# ---------------------------------------------------------------------------

def test_merge_swap_pairs_requires_both_coordinates_contiguous():
    # contiguous in both src and dst -> one group
    assert merge_swap_pairs([(5, 0), (6, 1), (7, 2)]) == [(5, 0, 3)]
    # contiguous in src only -> split (dst jumps)
    assert merge_swap_pairs([(5, 0), (6, 4)]) == [(5, 0, 1), (6, 4, 1)]
    # contiguous in dst only -> split (src jumps)
    assert merge_swap_pairs([(5, 0), (9, 1)]) == [(5, 0, 1), (9, 1, 1)]
    assert merge_swap_pairs([]) == []


def test_account_swap_directions_and_stats():
    t = MergeStagedTransport(block_bytes=1024, merge_threshold_bytes=8192,
                             max_hold_steps=2, max_trains=8)
    g1 = t.account_swap([(5, 0), (6, 1), (7, 2), (11, 3)], direction="out")
    assert [g[2] for g in g1] == [3, 1]
    g2 = t.account_swap([(0, 9), (1, 10)], direction="in")
    assert g2 == [(0, 9, 2)]
    st = t.stats
    assert st.swap_groups == 3
    assert st.swap_unmerged == 6
    assert st.swap_out_bytes == 4 * 1024
    assert st.swap_in_bytes == 2 * 1024
    assert st.swap_bytes == 6 * 1024
    assert st.avg_swap_group_blocks == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# scheduler: preempt/resume queue + admission-stall reasons
# ---------------------------------------------------------------------------

def _req(rid, plen=4, gen=3, arrival=0.0):
    return Request(rid=rid, prompt=np.arange(plen, dtype=np.int32),
                   gen_len=gen, arrival=arrival)


def test_preempted_requests_resume_first_with_same_sid():
    s = Scheduler(2)
    for i in range(3):
        s.submit(_req(i))
    adm = s.admit()
    assert len(adm) == 2
    req = s.preempt(0)
    assert req.preempt_count == 1
    req.swap_sid = adm[0][2]             # engine stamps the swapped session
    # resume beats the fresh rid=2 that has been waiting
    adm2 = s.admit()
    assert [a[1].rid for a in adm2] == [req.rid]
    assert adm2[0][2] == req.swap_sid    # session id reused


def test_admission_stall_reasons_split_compute_vs_memory():
    s = Scheduler(1)
    s.submit(_req(0))
    s.submit(_req(1))
    s.admit()            # rid 0 takes the only slot; rid 1 stalls (no_slot)
    assert s.admit_blocked["no_slot"] == 1
    s.admit()
    assert s.admit_blocked["no_slot"] == 2
    assert s.admit_blocked["kv_watermark"] == 0
    s.retire(0)
    s.admit(kv_ok=lambda req, is_resume: False)
    assert s.admit_blocked["kv_watermark"] == 1
    assert s.free_slots() == [0]         # still free: gate refused
    adm = s.admit(kv_ok=lambda req, is_resume: True)
    assert len(adm) == 1


def test_kv_gate_blocks_fresh_behind_blocked_resume():
    """No overtaking: a fresh request must not jump a blocked resume."""
    s = Scheduler(2)
    s.submit(_req(0))
    (slot, req0, sid0), = s.admit()
    req0.swap_sid = sid0
    s.preempt(slot)
    s.submit(_req(1))
    adm = s.admit(kv_ok=lambda req, is_resume: not is_resume)
    assert adm == []                     # resume blocked -> fresh waits too
    assert s.admit_blocked["kv_watermark"] == 1


# ---------------------------------------------------------------------------
# coupled scheduler + pager + transport fuzz with mid-round admission (§15)
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["submit", "admit", "append",
                                           "retire", "preempt", "cancel",
                                           "eos", "frame"]),
                          st.integers(0, 7), st.integers(1, 48)),
                min_size=1, max_size=80))
def test_continuous_admission_invariants_fuzz(ops):
    """Random interleavings of the engine's slot lifecycle verbs — with
    ``admit`` callable at ANY point, i.e. step-level (continuous)
    admission into mid-round freed slots (DESIGN.md §15) — preserve the
    pager's two-tier invariants AND the slot<->session consistency the
    engine relies on: every active slot's session is device-resident,
    every preempted request's session is host-resident, and draining
    everything empties both pools."""
    sched = Scheduler(3)
    p = BlockPager(48, 8, span_blocks=1, host_pool_blocks=24)
    t = MergeStagedTransport(block_bytes=512, merge_threshold_bytes=4096,
                             max_hold_steps=2, max_trains=8)
    next_rid = [0]
    bt = p.block_tokens

    def kv_ok_gate():
        # commit-on-accept, like the engine's §8 gate: later candidates in
        # the same admit() call must see earlier ones' demand or a burst
        # jointly overshoots the pool (swap_in_begin cannot roll back)
        budget = {"free": p.free_blocks()}

        def kv_ok(req, is_resume):
            if is_resume:
                s = p.sessions[req.swap_sid]
                need = sum(1 for b in s.blocks if b < 0) + 2
            else:
                need = -(-(len(req.prompt) + 1) // bt) + 2
            if budget["free"] < need:
                return False
            budget["free"] -= need
            return True
        return kv_ok

    def check():
        p.check_invariants()
        for slot in sched.active_slots():
            sid = sched.slots[slot].sid
            assert sid in p.sessions, f"active slot {slot} lost session"
            assert p.sessions[sid].swap_state == RES_DEVICE
        for req in sched.preempted:
            assert p.sessions[req.swap_sid].swap_state == RES_HOST
        # a retired/cancelled request's session never lingers: live pager
        # sessions are exactly the active + preempted ones
        live = {sched.slots[s].sid for s in sched.active_slots()}
        live |= {r.swap_sid for r in sched.preempted}
        assert set(p.sessions) == live

    def drop_active(slot):
        p.trim(sched.slots[slot].sid, close=True)
        sched.retire(slot)

    for op, k, n in ops:
        active = sched.active_slots()
        try:
            if op == "submit":
                sched.submit(_req(next_rid[0], plen=1 + k % 6, gen=n))
                next_rid[0] += 1
            elif op == "admit":
                # the §15 verb: admit with whatever mix of free/active
                # slots this interleaving produced — mid-round included
                for slot, req, sid in sched.admit(kv_ok=kv_ok_gate()):
                    if req.swap_sid == sid:          # resume
                        pairs = p.swap_in_begin(sid, 0)
                        t.account_swap(pairs, direction="in")
                        p.swap_in_commit(sid)
                        req.swap_sid = -1
                    else:                            # fresh
                        p.open_session(sid)
                        try:    # reserve rolls back on failure (§8); the
                            #     open session stays, appends retry later
                            p.reserve(sid, len(req.prompt) + 1)
                            for _ in range(len(req.prompt)):
                                p.append_token(sid)
                        except MemoryError:
                            pass
            elif op == "append" and active:
                sid = sched.slots[active[k % len(active)]].sid
                s = p.sessions[sid]
                if s.length >= len(s.blocks) * bt:
                    p.reserve(sid, bt)
                p.append_token(sid)
            elif op == "retire" and active:
                drop_active(active[k % len(active)])
            elif op == "preempt" and active:
                slot = active[k % len(active)]
                sid = sched.slots[slot].sid
                pairs = (p.swap_out_session(sid)
                         if p.swap_eligible(sid) else None)
                if pairs is not None:
                    t.account_swap(pairs, direction="out")
                    sched.preempt(slot).swap_sid = sid
            elif op == "cancel":
                # any lifecycle stage is cancellable: waiting (drop),
                # preempted (free host blocks), active (free the slot)
                pool = ([("w", r) for r in sched.waiting]
                        + [("p", r) for r in sched.preempted]
                        + [("a", s) for s in active])
                if pool:
                    kind, x = pool[k % len(pool)]
                    if kind == "w":
                        sched.waiting.remove(x)
                    elif kind == "p":
                        p.trim(x.swap_sid, close=True)
                        sched.preempted.remove(x)
                    else:
                        drop_active(x)
            elif op == "eos" and active:
                # lagged-EOS overshoot scrub (§13) on a live mid-round slot
                sid = sched.slots[active[k % len(active)]].sid
                s = p.sessions[sid]
                newb = p.reserve(sid, 1)
                local = s.length - s.trimmed_prefix_blocks * bt
                if s.blocks[local // bt] > 0:
                    p.append_token(sid)
                    p.reconcile_overshoot(sid, newb, 1)
                else:
                    p.reconcile_overshoot(sid, newb, 0)
            elif op == "frame":
                p.frame()
        except MemoryError:
            pass
        check()
    for slot in sched.active_slots():
        drop_active(slot)
    for req in list(sched.preempted):
        p.trim(req.swap_sid, close=True)
        sched.preempted.remove(req)
    check()
    assert p.reserved_blocks() == 0 and p.host_used == 0
    assert sched.free_slots() == list(range(3))


# ---------------------------------------------------------------------------
# engine: preempt -> resume round-trip is bitwise identical
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_reduced("qwen2.5-32b")
    params = registry.init_params(jax.random.PRNGKey(7), cfg)
    return cfg, params


def _uniform_reqs(vocab, n=6):
    # uniform lengths: concurrent sessions cross block boundaries on the
    # same step — the demand spike cold swap cannot absorb (forces
    # preemption once the device pool is oversubscribed)
    rng = np.random.default_rng(1)
    return [Request(rid=i, prompt=rng.integers(0, vocab, size=8)
                    .astype(np.int32), gen_len=48) for i in range(n)]


@pytest.mark.parametrize("depth", [0, 1])
def test_preempt_resume_tokens_bitwise_identical(dense_setup, depth):
    cfg, params = dense_setup
    ample = KVRMEngine(cfg, params, EngineConfig(
        mode="paged_merge", batch=4, max_seq=64, block_tokens=8,
        near_window=32, pipeline_depth=depth))
    for r in _uniform_reqs(cfg.vocab_size):
        ample.submit(r)
    ample.run(max_steps=1000)
    t_ample = {r.rid: list(r.generated) for r in ample.sched.finished}
    assert len(t_ample) == 6

    tight = KVRMEngine(cfg, params, EngineConfig(
        mode="paged_merge", batch=4, max_seq=64, block_tokens=8,
        near_window=32, pipeline_depth=depth,
        pool_budget_frac=0.1, host_pool_blocks=40))
    for r in _uniform_reqs(cfg.vocab_size):
        tight.submit(r)
    tight.run(max_steps=3000)
    t_tight = {r.rid: list(r.generated) for r in tight.sched.finished}

    a = tight.audit()
    assert tight.num_blocks < ample.num_blocks // 2   # truly oversubscribed
    assert a["preemptions"] >= 1, a
    assert a["swap_in_blocks"] >= 1
    assert a["swap_out_blocks"] >= a["swap_in_blocks"]
    assert a["host_blocks_peak"] >= 1
    assert a["single_commit_per_step"]
    assert a["compilations"] in (-1, 1)
    # the headline guarantee: preempt -> swap-out -> resume -> swap-in
    # changed NOTHING about any request's token stream
    assert t_tight == t_ample
    tight.pager.check_invariants()
    assert tight.pager.reserved_blocks() == 0         # EOS returned all
    assert tight.pager.host_used == 0


def test_sync_and_pipelined_oversubscribed_audits_match(dense_setup):
    """Preemption decisions are structural (free blocks vs need), so the
    depth-0 and depth-1 paths preempt/swap on identical timelines."""
    cfg, params = dense_setup
    audits = []
    for depth in (0, 1):
        eng = KVRMEngine(cfg, params, EngineConfig(
            mode="paged_merge", batch=4, max_seq=64, block_tokens=8,
            near_window=32, pipeline_depth=depth,
            pool_budget_frac=0.1, host_pool_blocks=40))
        for r in _uniform_reqs(cfg.vocab_size):
            eng.submit(r)
        eng.run(max_steps=3000)
        audits.append((eng.steps_run, eng.audit()))
    (s0, a0), (s1, a1) = audits
    assert s0 == s1
    for key in ("preemptions", "swap_out_blocks", "swap_in_blocks",
                "swap_groups", "host_blocks_peak", "frames_committed"):
        assert a0[key] == a1[key], key


def test_executor_never_observes_host_resident_block(dense_setup):
    """Every committed block table entry during an oversubscribed run is a
    device block id (>= 0): host residency is sign-encoded, so a negative
    entry in the descriptor would be the invariant violation."""
    cfg, params = dense_setup
    eng = KVRMEngine(cfg, params, EngineConfig(
        mode="paged_merge", batch=4, max_seq=64, block_tokens=8,
        near_window=32, pool_budget_frac=0.1, host_pool_blocks=40))
    for r in _uniform_reqs(cfg.vocab_size):
        eng.submit(r)
    steps = 0
    while (eng.sched.waiting or eng.sched.preempted
           or eng.sched.active_slots()) and steps < 3000:
        eng.step()
        d = eng._pdescr
        assert (d.block_table >= 0).all()
        assert (d.write_block >= 0).all()
        steps += 1
    eng.flush()
    assert eng.audit()["preemptions"] >= 1


def test_engine_audit_exposes_admission_reasons(dense_setup):
    cfg, params = dense_setup
    eng = KVRMEngine(cfg, params, EngineConfig(
        mode="paged_merge", batch=2, max_seq=64, block_tokens=8,
        near_window=32, pool_budget_frac=0.1, host_pool_blocks=40))
    rng = np.random.default_rng(3)
    for i in range(8):
        eng.submit(Request(rid=i, prompt=rng.integers(0, 100, size=6)
                           .astype(np.int32), gen_len=40))
    eng.run(max_steps=3000)
    a = eng.audit()
    assert len(eng.sched.finished) == 8
    # with 8 requests on 2 slots, both stall reasons must be observable
    assert a["admit_blocked_no_slot"] > 0
    assert "admit_blocked_kv_watermark" in a
    assert a["host_pool_blocks"] == 40


def test_resume_gate_accounts_same_call_pending(dense_setup):
    """Two resumes admitted by the same admit() call must not jointly
    overshoot the device pool: the gate reserves blocks on accept."""
    cfg, params = dense_setup
    eng = KVRMEngine(cfg, params, EngineConfig(
        mode="paged_merge", batch=4, max_seq=64, block_tokens=8,
        near_window=32, pool_budget_frac=0.1, host_pool_blocks=40))
    reqs = []
    for sid in (0, 1):
        eng.pager.open_session(sid)
        eng.pager.reserve(sid, 24)
        for _ in range(24):
            eng.pager.append_token(sid)
        assert eng.pager.swap_out_session(sid) is not None
        r = Request(rid=sid, prompt=np.zeros(4, np.int32), gen_len=8)
        r.swap_sid, r.resume_len = sid, 24
        reqs.append(r)
    eng._resume_pending = 0
    free = eng.pager.free_blocks()            # 10 of the 11-block pool
    assert eng._admission_ok(reqs[0], True)   # needs 3 + margin 5 <= 10
    # second resume must see the first's 3 pending blocks: 3+3+5 > 10
    assert not eng._admission_ok(reqs[1], True)
    assert eng.pager.free_blocks() == free    # gate itself allocates nothing


def test_alias_skipped_when_source_prefix_swapped(dense_setup):
    """Prefix aliasing shares physical device blocks; a cold-swapped source
    prefix must forfeit the share (full prefill), not crash admission."""
    cfg, params = dense_setup
    rng = np.random.default_rng(8)
    shared = rng.integers(0, 100, size=16).astype(np.int32)
    eng = KVRMEngine(cfg, params, EngineConfig(
        mode="paged_merge", batch=4, max_seq=64, block_tokens=8,
        near_window=16, span_blocks=1, host_pool_blocks=16))
    eng.submit(Request(rid=0, prompt=shared, gen_len=24))
    for _ in range(30):                       # run rid=0 past its prefix
        eng.step()
    src_sid = int(eng._slot_sid[0])
    s = eng.pager.sessions[src_sid]
    fl = eng._first_window_local(s, int(eng._slot_len[0]))
    assert eng.pager.swap_out_cold(src_sid, fl), "prefix should be cold"
    assert s.blocks[0] < 0                    # shared block now host-resident
    eng.submit(Request(rid=1, prompt=np.concatenate([shared, shared[:4]]),
                       gen_len=4, prefix_of=0, prefix_len=16))
    eng.run(max_steps=300)                    # no crash; alias was skipped
    assert len(eng.sched.finished) == 2
    r1 = next(r for r in eng.sched.finished if r.rid == 1)
    assert len(r1.generated) == 4


def test_host_tier_rejects_unsupported_configs(dense_setup):
    cfg, params = dense_setup
    with pytest.raises(ValueError):
        KVRMEngine(cfg, params, EngineConfig(
            mode="full", batch=2, max_seq=128, near_window=32,
            block_tokens=8, host_pool_blocks=8))
    hyb = get_reduced("zamba2-7b")
    hparams = registry.init_params(jax.random.PRNGKey(0), hyb)
    with pytest.raises(ValueError):
        KVRMEngine(hyb, hparams, EngineConfig(
            mode="paged_merge", batch=2, max_seq=64, block_tokens=8,
            kv_oversubscribe=1.5))
