"""Per-architecture smoke tests: reduced config, one forward (+train-style
loss/grad for a subset) and one paged decode step on CPU; asserts output
shapes and absence of NaNs. Full configs are exercised only via the dry-run.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_reduced
from repro.core.descriptor import empty_descriptor
from repro.models import registry

BT = 8        # block tokens
NB = 5        # near-window blocks in table
P = 32        # physical blocks
CAP = 4
MT = 6
B = 2
S = 32


def _descr(seq_lens):
    d = empty_descriptor(B, NB, CAP, MT, chunk_blocks=2)
    d = d._replace(
        block_table=np.arange(1, 1 + B * NB, dtype=np.int32).reshape(B, NB),
        window_base=np.zeros(B, np.int32),
        seq_lens=np.asarray(seq_lens, np.int32),
        slot_active=np.ones(B, np.int32),
        write_block=np.array([1, 1 + NB], np.int32),
        write_offset=np.asarray([s % BT for s in seq_lens], np.int32),
    )
    return jax.tree.map(jnp.asarray, d)


def _inputs(cfg):
    key = jax.random.PRNGKey(0)
    tokens = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    extra = None
    if cfg.frontend == "vision_stub":
        extra = jax.random.normal(key, (B, 8, cfg.d_model), jnp.bfloat16)
    elif cfg.frontend == "audio_stub":
        extra = jax.random.normal(key, (B, S, cfg.d_model), jnp.bfloat16)
    return tokens, extra


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_smoke(arch):
    cfg = get_reduced(arch)
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    tokens, extra = _inputs(cfg)
    kw = {"extra_embeds": extra} if extra is not None else {}
    logits = jax.jit(lambda p, t: registry.forward(p, cfg, t, **kw))(params, tokens)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_step_smoke(arch):
    cfg = get_reduced(arch)
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    tokens, extra = _inputs(cfg)
    pools = registry.init_decode_pools(
        cfg, batch=B, num_blocks=P, block_tokens=BT,
        enc_len=S if cfg.family == "encdec" else 0)
    if cfg.family == "encdec":
        pools["enc_len"] = jnp.full((B,), S, jnp.int32)
    d = _descr([3, 9])
    step = jax.jit(lambda p, t, pool, dd: registry.decode_step(p, cfg, t, pool, dd))
    logits, new_pools, fu = step(params, tokens[:, 0], pools, d)
    assert logits.shape == (B, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    # state buffers keep their shapes
    jax.tree.map(lambda a, b: None if a.shape == b.shape else pytest.fail(
        f"pool shape changed {a.shape} != {b.shape}"), new_pools, pools)


@pytest.mark.parametrize("arch", ["qwen3-32b", "deepseek-v3-671b", "zamba2-7b",
                                  "xlstm-125m"])
def test_train_grad_smoke(arch):
    cfg = get_reduced(arch)
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    tokens, extra = _inputs(cfg)
    kw = {"extra_embeds": extra} if extra is not None else {}

    def loss_fn(p):
        logits = registry.forward(p, cfg, tokens, **kw).astype(jnp.float32)
        tgt = jnp.roll(tokens, -1, axis=1)
        ll = jax.nn.log_softmax(logits, axis=-1)
        return -jnp.take_along_axis(ll, tgt[..., None], axis=-1).mean()

    loss, grads = jax.jit(jax.value_and_grad(loss_fn))(params)
    assert np.isfinite(float(loss))
    gnorm = jax.tree.reduce(
        lambda a, b: a + b,
        jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), grads))
    assert np.isfinite(float(gnorm)) and float(gnorm) > 0


def test_farview_decode_smoke():
    cfg = get_reduced("qwen3-32b")
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    tokens, _ = _inputs(cfg)
    pools = registry.init_decode_pools(cfg, batch=B, num_blocks=P,
                                       block_tokens=BT, max_chunks=8)
    # chunk summaries mean-pool EXISTING pool contents (writes land after the
    # layer scan) — fill the pool so summaries are nonzero
    pools["k"] = pools["k"] + 0.1
    pools["v"] = pools["v"] + 0.1
    d = _descr([40, 41])
    d = d._replace(
        far_table=jnp.asarray(np.tile(np.arange(CAP, dtype=np.int32), (B, 1))),
        far_valid=jnp.ones((B, CAP), jnp.int32),
        far_chunk_blocks=jnp.asarray(np.array([[1, 2], [6, 7]], np.int32)),
        far_chunk_tokens=jnp.full((B,), 2 * BT, jnp.int32),
        far_do_summarize=jnp.ones((B,), jnp.int32),
        far_write_idx=jnp.asarray(np.array([5, 6], np.int32)))
    step = jax.jit(lambda p, t, pool, dd: registry.decode_step(p, cfg, t, pool, dd))
    logits, new_pools, fu = step(params, tokens[:, 0], pools, d)
    assert logits.shape == (B, cfg.vocab_size)
    assert fu.shape == (B, CAP)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())
    # summaries were written at far_write_idx
    assert bool((new_pools["far_k"][0, 0, 5] != 0).any())
