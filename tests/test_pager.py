"""Pager unit + property tests: verb semantics, O(1) free lists, COW
refcounts, frame idempotency, and hypothesis-driven invariant fuzzing."""
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.core.pager import BlockPager


def test_reserve_blockalign():
    p = BlockPager(64, 16, bytes_per_block=1024, span_blocks=1)
    p.open_session(0)
    got = p.reserve(0, 1)          # 1 token -> 1 block
    assert len(got) == 1
    assert p.reserve(0, 16) == []  # 16 tokens fit the existing block exactly
    got2 = p.reserve(0, 17)        # 17 tokens -> 1 more block (BLOCKALIGN)
    assert len(got2) == 1
    assert p.reserved_blocks() == 2


def test_tail_adjacency_placement():
    p = BlockPager(256, 16)
    p.open_session(0)
    blocks = []
    for _ in range(10):
        blocks += p.reserve(0, 16)
        for _ in range(16):
            p.append_token(0)
    # lookahead placement keeps the session physically contiguous
    runs = sum(1 for i in range(1, len(blocks)) if blocks[i] != blocks[i-1] + 1)
    assert runs == 0, blocks


def test_interleaved_sessions_fragment_then_merge():
    """Span placement keeps interleaved session growth burst-friendly: 6
    blocks land in <=2 physically-contiguous runs instead of 6 singletons."""
    p = BlockPager(256, 16, span_blocks=4)
    for sid in (0, 1):
        p.open_session(sid)
    frag = {0: [], 1: []}
    for _ in range(6 * 16):
        for sid in (0, 1):
            p.reserve(sid, 1)
            p.append_token(sid)
    for sid in (0, 1):
        b = p.sessions[sid].blocks
        runs = 1 + sum(1 for i in range(1, len(b)) if b[i] != b[i-1] + 1)
        assert runs <= 2, (sid, b)
    # without spans, the same pattern fragments (documents the mechanism)
    p2 = BlockPager(256, 16, span_blocks=1)
    for sid in (0, 1):
        p2.open_session(sid)
    for _ in range(6 * 16):
        for sid in (0, 1):
            p2.reserve(sid, 1)
            p2.append_token(sid)
    b = p2.sessions[0].blocks
    runs = 1 + sum(1 for i in range(1, len(b)) if b[i] != b[i-1] + 1)
    assert runs >= 4, b


def test_trim_close_returns_blocks():
    p = BlockPager(64, 16)
    p.open_session(0)
    p.reserve(0, 100)
    n = p.reserved_blocks()
    assert n == 7
    p.trim(0, close=True)
    assert p.reserved_blocks() == 0
    p.check_invariants()


def test_alias_cow_refcount():
    p = BlockPager(64, 16)
    p.open_session(0)
    p.reserve(0, 48)
    for _ in range(40):
        p.append_token(0)
    p.open_session(1)
    p.alias(0, 1, 36)              # 2 full blocks + partial tail
    s1 = p.sessions[1]
    assert s1.shared_prefix_blocks == 2
    assert s1.cow_pending is not None
    assert s1.length == 36
    shared = p.sessions[0].blocks[:2]
    assert all(p.refcount[b] == 2 for b in shared)
    # closing the source keeps shared blocks alive for the alias
    p.trim(0, close=True)
    assert all(p.refcount[b] == 1 for b in shared)
    p.check_invariants()
    p.trim(1, close=True)
    assert p.reserved_blocks() == 0


def test_frame_idempotent_commit():
    p = BlockPager(64, 16, span_blocks=1)
    p.open_session(0)
    p.reserve(0, 16)
    f1 = p.frame()
    f2 = p.frame()                 # retry with no new edits
    assert f1 is f2
    assert p.epoch == 1
    p.reserve(0, 32)
    f3 = p.frame()
    assert f3["epoch"] == 2
    assert len(f3["edits"]) == 1


def test_pool_exhaustion_raises():
    p = BlockPager(8, 16)
    p.open_session(0)
    with pytest.raises(MemoryError):
        p.reserve(0, 16 * 10)


def test_far_prefix_trim():
    p = BlockPager(64, 16)
    p.open_session(0)
    p.reserve(0, 96)
    for _ in range(96):
        p.append_token(0)
    freed = p.trim(0, prefix_blocks=2)
    assert len(freed) == 2
    s = p.sessions[0]
    assert s.trimmed_prefix_blocks == 2
    # appending continues in local coordinates
    p.reserve(0, 16)
    blk, off = p.append_token(0)
    assert off == 0
    p.check_invariants()


# ---------------------------------------------------------------------------
# property test: random verb sequences preserve invariants
# ---------------------------------------------------------------------------

@settings(max_examples=50, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["open", "reserve", "append",
                                           "trim", "alias", "frame"]),
                          st.integers(0, 7), st.integers(1, 40)),
                min_size=1, max_size=60))
def test_pager_invariants_fuzz(ops):
    p = BlockPager(128, 8)
    sid_live = set()
    for op, sid, n in ops:
        try:
            if op == "open" and sid not in sid_live:
                p.open_session(sid)
                sid_live.add(sid)
            elif op == "reserve" and sid in sid_live:
                p.reserve(sid, n)
            elif op == "append" and sid in sid_live:
                s = p.sessions[sid]
                cap = len(s.blocks) * p.block_tokens
                local = s.length - s.trimmed_prefix_blocks * p.block_tokens
                if local < cap:
                    p.append_token(sid)
            elif op == "trim" and sid in sid_live:
                p.trim(sid, close=True)
                sid_live.discard(sid)
            elif op == "alias" and sid in sid_live:
                dst = max(sid_live, default=0) + 1 + n
                src = p.sessions[sid]
                if src.length >= p.block_tokens and dst not in sid_live:
                    p.open_session(dst)
                    sid_live.add(dst)
                    p.alias(sid, dst, min(n, src.length))
            elif op == "frame":
                p.frame()
        except MemoryError:
            pass
        p.check_invariants()
    # closing everything returns the pool to fully free
    for sid in list(sid_live):
        p.trim(sid, close=True)
    p.check_invariants()
    assert p.reserved_blocks() == 0
