"""On-device sampling (DESIGN.md §13): property-based sampler semantics vs
the numpy reference (top-k support, top-p mass bound, temperature->0 argmax
convergence, key determinism across batch placement / devices / mesh
layouts), plus engine-level contracts: stop tokens are rejected in legacy
greedy mode, submit-order invariance of sampled streams, and "greedy with
stop tokens" (temperature=0) truncating the legacy argmax stream exactly.

Property tests use coarse-grid integer logits and power-of-two temperatures
so every float32 filter threshold (x/t, the k-th value, the top-p cut) is
exact — no tie-edge flakiness; the top-p mass bound is checked against a
float64 softmax with an epsilon.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st
from repro.configs import get_reduced
from repro.core.engine import EngineConfig, KVRMEngine
from repro.core.sampling import (make_sampler, ref_probs, ref_support,
                                 slot_keys)
from repro.core.scheduler import Request
from repro.models import registry

TEMPS = [0.25, 0.5, 1.0, 2.0, 4.0]          # powers of two: exact x/t
TOPPS = [0.25, 0.5, 0.75, 0.9]

logits_row = st.lists(st.integers(-8, 8), min_size=4, max_size=24)
seeds = st.integers(0, 2**16)


@functools.lru_cache(maxsize=None)
def _jitted(t, k, p):
    return jax.jit(make_sampler(t, k, p))


def _one(seed, row, t, k, p):
    """Sample one token for a single logit row under a derived key."""
    key = jax.random.fold_in(jax.random.PRNGKey(0), seed)
    return int(_jitted(t, k, p)(key[None], jnp.asarray([row], jnp.float32))[0])


# ---------------------------------------------------------------------------
# sampler vs numpy reference
# ---------------------------------------------------------------------------
@settings(max_examples=60, deadline=None)
@given(row=logits_row, t=st.sampled_from(TEMPS), k=st.integers(0, 6),
       p=st.sampled_from(TOPPS + [1.0]), seed=seeds)
def test_sampled_token_in_reference_support(row, t, k, p, seed):
    tok = _one(seed, row, t, k, p)
    assert tok in ref_support(row, t, k, p)


@settings(max_examples=60, deadline=None)
@given(row=logits_row, k=st.integers(1, 6), seed=seeds)
def test_top_k_never_emits_out_of_k(row, k, seed):
    tok = _one(seed, row, 1.0, k, 1.0)
    x = np.asarray(row, np.float32)
    kth = np.sort(x)[-min(k, len(x))]
    assert x[tok] >= kth            # ties at the k-th value are included


@settings(max_examples=60, deadline=None)
@given(row=logits_row, t=st.sampled_from(TEMPS), p=st.sampled_from(TOPPS),
       seed=seeds)
def test_top_p_mass_bound(row, t, p, seed):
    tok = _one(seed, row, t, 0, p)
    probs = ref_probs(row, t)
    # the emitted token's strictly-greater-prob mass is < p (it was inside
    # the smallest prefix reaching p), and the kept support carries >= p
    excl = probs[probs > probs[tok]].sum()
    assert excl < p + 1e-6
    sup = sorted(ref_support(row, t, 0, p))
    assert probs[sup].sum() >= p - 1e-6


@settings(max_examples=40, deadline=None)
@given(row=logits_row, seed=seeds)
def test_temperature_zero_is_exact_argmax(row, seed):
    tok = _one(seed, row, 0.0, 0, 1.0)
    assert tok == int(np.argmax(np.asarray(row, np.float32)))


@settings(max_examples=40, deadline=None)
@given(row=logits_row, seed=seeds)
def test_temperature_converges_to_argmax(row, seed):
    row = list(row) + [9]           # unique max by construction (grid <= 8)
    assert _one(seed, row, 1.0 / 64, 0, 1.0) == len(row) - 1


# ---------------------------------------------------------------------------
# key determinism across placement
# ---------------------------------------------------------------------------
@settings(max_examples=40, deadline=None)
@given(row=logits_row, t=st.sampled_from(TEMPS), k=st.integers(0, 6),
       p=st.sampled_from(TOPPS + [1.0]), seed=seeds, slot=st.integers(0, 3))
def test_identical_key_identical_token_across_batch(row, t, k, p, seed, slot):
    """The token for (key, logits) is independent of which batch row holds
    it and of what the other rows contain — the property the engine's
    (seed, rid, position) key derivation relies on."""
    sampler = _jitted(t, k, p)
    key = jax.random.fold_in(jax.random.PRNGKey(0), seed)
    single = int(sampler(key[None], jnp.asarray([row], jnp.float32))[0])
    B = 4
    keys = jnp.stack([jax.random.fold_in(jax.random.PRNGKey(1), i)
                      for i in range(B)])
    keys = keys.at[slot].set(key)
    noise = np.tile(np.asarray(row, np.float32)[::-1], (B, 1))
    noise[slot] = np.asarray(row, np.float32)
    assert int(sampler(keys, jnp.asarray(noise))[slot]) == single


def test_identical_key_identical_token_across_devices():
    """Threefry sampling is a pure function of (key, logits): placing the
    same inputs on different devices or sharding the batch over a mesh
    yields the same tokens."""
    devs = jax.devices()
    if len(devs) < 2:
        pytest.skip("needs >= 2 devices")
    sampler = make_sampler(1.3, 5, 0.9)
    rng = np.random.default_rng(0)
    logits = rng.normal(size=(4, 32)).astype(np.float32)
    keys = slot_keys(jax.random.PRNGKey(3), jnp.arange(4),
                     jnp.arange(4) * 7)
    base = np.asarray(jax.jit(sampler)(keys, jnp.asarray(logits)))
    for dev in devs[:2]:
        got = jax.jit(sampler)(jax.device_put(keys, dev),
                               jax.device_put(jnp.asarray(logits), dev))
        np.testing.assert_array_equal(np.asarray(got), base)
    # mesh layout: batch sharded 2-ways vs fully replicated
    from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
    mesh = Mesh(np.array(devs[:2]), ("data",))
    sh = NamedSharding(mesh, P("data"))
    got = jax.jit(sampler)(jax.device_put(keys, sh),
                           jax.device_put(jnp.asarray(logits), sh))
    np.testing.assert_array_equal(np.asarray(got), base)


def test_slot_keys_fold_order():
    """slot_keys folds rid first, position second — distinct on both axes."""
    base = jax.random.PRNGKey(0)
    k = np.asarray(slot_keys(base, jnp.asarray([1, 1, 2]),
                             jnp.asarray([5, 6, 5])))
    assert not np.array_equal(k[0], k[1])     # same rid, different position
    assert not np.array_equal(k[0], k[2])     # different rid, same position


# ---------------------------------------------------------------------------
# engine-level contracts
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_reduced("qwen2.5-32b")
    params = registry.init_params(jax.random.PRNGKey(7), cfg)
    return cfg, params


def _reqs(vocab, stops=(), order=None):
    lens = [(5, 6), (17, 4), (3, 8), (9, 7), (4, 5), (6, 5)]
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, vocab, size=p).astype(np.int32)
               for p, _ in lens]
    idx = order if order is not None else range(len(lens))
    return [Request(rid=i, prompt=prompts[i], gen_len=lens[i][1],
                    stop_tokens=stops) for i in idx]


def _sampled_engine(cfg, params, depth=1, **kw):
    base = dict(mode="paged_merge", batch=4, max_seq=64, block_tokens=8,
                pipeline_depth=depth, greedy=False, temperature=1.2,
                top_k=50, top_p=0.95, sample_seed=123)
    base.update(kw)
    return KVRMEngine(cfg, params, EngineConfig(**base))


def test_stop_tokens_require_sampled_mode(dense_setup):
    cfg, params = dense_setup
    eng = KVRMEngine(cfg, params, EngineConfig(
        mode="paged_merge", batch=4, max_seq=64, block_tokens=8))
    with pytest.raises(ValueError, match="greedy"):
        eng.submit(Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                           gen_len=4, stop_tokens=(5,)))


def test_sampled_stream_invariant_to_submit_order(dense_setup):
    """Keys derive from (seed, rid, position), so slot assignment — here
    permuted via submit order — cannot change any request's tokens."""
    cfg, params = dense_setup
    outs = []
    for order in (None, [3, 1, 5, 0, 4, 2]):
        eng = _sampled_engine(cfg, params)
        for r in _reqs(cfg.vocab_size, order=order):
            eng.submit(r)
        eng.run(max_steps=400)
        outs.append({r.rid: list(map(int, r.generated))
                     for r in eng.sched.finished})
        assert len(outs[-1]) == 6
    assert outs[0] == outs[1]


def test_greedy_with_stop_tokens_truncates_argmax_stream(dense_setup):
    """greedy=False + temperature=0 is the sampler's exact argmax branch:
    with a stop token drawn from the legacy stream, the sampled run emits
    the identical prefix and retires on the detected stop."""
    cfg, params = dense_setup
    legacy = KVRMEngine(cfg, params, EngineConfig(
        mode="paged_merge", batch=4, max_seq=64, block_tokens=8,
        pipeline_depth=1))
    for r in _reqs(cfg.vocab_size):
        legacy.submit(r)
    legacy.run(max_steps=400)
    ref = {r.rid: list(map(int, r.generated)) for r in legacy.sched.finished}
    # pick a mid-stream token of rid 2 (gen_len 8) as the stop
    stop = ref[2][3]
    eng = _sampled_engine(cfg, params, temperature=0.0, top_k=0, top_p=1.0)
    for r in _reqs(cfg.vocab_size, stops=(stop,)):
        eng.submit(r)
    eng.run(max_steps=400)
    got = {r.rid: list(map(int, r.generated)) for r in eng.sched.finished}
    reasons = {r.rid: r.finish_reason for r in eng.sched.finished}
    for rid, toks in ref.items():
        cut = toks.index(stop) + 1 if stop in toks else len(toks)
        assert got[rid] == toks[:cut], rid
        assert reasons[rid] == ("stop" if stop in toks else "budget")
    assert eng.audit()["eos_detected"] == \
        sum(1 for t in ref.values() if stop in t)
    eng.pager.check_invariants()
    assert eng.pager.reserved_blocks() == 0
