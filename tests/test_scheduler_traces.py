"""Scheduler + trace-generator behaviour."""
import numpy as np

from repro.core.scheduler import Request, Scheduler
from repro.data import traces


def _req(rid, plen=4, gen=3, arrival=0.0):
    return Request(rid=rid, prompt=np.arange(plen, dtype=np.int32),
                   gen_len=gen, arrival=arrival)


def test_admission_respects_capacity_and_arrival():
    s = Scheduler(2)
    for i in range(4):
        s.submit(_req(i, arrival=float(i)))
    adm = s.admit(now=0.5)
    assert len(adm) == 1                     # only rid 0 has arrived
    adm = s.admit(now=10.0)
    assert len(adm) == 1                     # one slot left
    assert len(s.waiting) == 2


def test_prefill_then_generate_token_flow():
    s = Scheduler(1)
    s.submit(_req(0, plen=3, gen=2))
    s.admit()
    toks = [s.next_token(0, last_sampled=99) for _ in range(3)]
    assert toks == [0, 1, 2]
    assert not s.is_prefilling(0)
    assert s.next_token(0, last_sampled=42) == 42


def test_eos_retire_frees_slot():
    s = Scheduler(1)
    s.submit(_req(0, gen=1))
    s.submit(_req(1))
    s.admit()
    assert s.record_output(0, 7) is True     # gen_len 1 -> EOS
    s.retire(0)
    assert s.free_slots() == [0]
    assert len(s.admit()) == 1               # rid 1 admitted


def test_mixed_workload_matches_paper_heterogeneity():
    """Table 1 shape: heavy-tailed lengths, bursty arrivals."""
    reqs = traces.azure_like_replay(traces.TraceConfig(
        n_requests=400, token_scale=1.0, seed=0))
    s = traces.trace_summary(reqs)
    assert 50 <= s["gen_p50"] <= 200
    assert s["gen_p90"] >= 2 * s["gen_p50"]
    assert s["gen_p99"] >= 4 * s["gen_p50"]
    assert s["arrival_top10_share"] >= 0.15   # concentrated arrivals


def test_prefix_sharing_workload():
    reqs = traces.mixed_length_workload(traces.TraceConfig(
        n_requests=50, shared_prefix_frac=0.5, seed=1))
    shared = [r for r in reqs if r.prefix_of is not None]
    assert len(shared) >= 10
    for r in shared:
        assert r.prefix_len > 0
        np.testing.assert_array_equal(r.prompt[:r.prefix_len],
                                      reqs[0].prompt[:r.prefix_len])
