"""Roofline machinery: trip-count-aware HLO cost walker validated against
unrolled programs and closed-form transformer FLOPs; collective parser."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced, SHAPES
from repro.models import registry
from repro.roofline import analysis, hlo_cost


def test_walker_matches_unrolled_scan():
    def scanned(x, w):
        def body(x, wi):
            return jnp.tanh(x @ wi), None
        x, _ = jax.lax.scan(body, x, w)
        return x

    def unrolled(x, w):
        for i in range(8):
            x = jnp.tanh(x @ w[i])
        return x

    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 64, 64), jnp.float32)
    cs = hlo_cost.analyze(jax.jit(scanned).lower(x, w).compile().as_text())
    cu = hlo_cost.analyze(jax.jit(unrolled).lower(x, w).compile().as_text())
    dot_flops = 8 * 2 * 64 ** 3
    assert abs(cs.flops - cu.flops) / cu.flops < 0.05
    assert cs.flops >= dot_flops
    assert cs.flops < dot_flops * 1.2


def test_walker_matches_closed_form_transformer():
    cfg = get_reduced("qwen2.5-32b").replace(n_layers=4)
    params = jax.eval_shape(lambda k: registry.init_params(k, cfg),
                            jax.random.PRNGKey(0))
    B, S = 2, 128
    tok = jax.ShapeDtypeStruct((B, S), jnp.int32)
    txt = jax.jit(lambda p, t: registry.forward(p, cfg, t)).lower(
        params, tok).compile().as_text()
    c = hlo_cost.analyze(txt)
    flops_linear = 2 * cfg.param_count() * B * S
    flops_attn = 4 * cfg.n_layers * B * S * S * cfg.n_heads * cfg.head_dim
    analytic = flops_linear + flops_attn
    assert 0.7 < c.flops / analytic < 1.5, (c.flops, analytic)


def test_walker_counts_nested_scans():
    """Microbatch scan x layer scan multiplies through (the inner scan must
    depend on the outer carry or XLA hoists it — which the walker then
    correctly counts once)."""
    def f(x, w):
        def outer(x, _):
            def inner(x, wi):
                return jnp.tanh(x @ wi), None
            x, _ = jax.lax.scan(inner, x, w)
            return x, None
        x, _ = jax.lax.scan(outer, x, None, length=4)
        return x
    x = jax.ShapeDtypeStruct((32, 32), jnp.float32)
    w = jax.ShapeDtypeStruct((8, 32, 32), jnp.float32)
    c = hlo_cost.analyze(jax.jit(f).lower(x, w).compile().as_text())
    want = 4 * 8 * 2 * 32 ** 3
    assert 0.9 < c.flops / want < 1.3, (c.flops, want)


def test_collective_bytes_counted_inside_loops():
    import os
    if len(jax.devices()) < 2:
        pytest.skip("needs >1 device")


def test_collective_parser():
    txt = """
ENTRY %main (p: f32[16]) -> f32[16] {
  %p = f32[16]{0} parameter(0)
  %ar = f32[16]{0} all-reduce(%p), replica_groups={}, to_apply=%sum
  %ag = f32[32]{0} all-gather(%ar), dimensions={0}
  ROOT %out = f32[16]{0} slice(%ag), slice={[0:16]}
}
"""
    c = analysis.collective_bytes(txt)
    assert c["all-reduce"] == 64
    assert c["all-gather"] == 128


def test_model_flops_and_useful_bytes():
    cfg = get_reduced("qwen2.5-32b")
    tr = SHAPES["train_4k"]
    de = SHAPES["decode_32k"]
    mf_tr = analysis.model_flops_for(cfg, tr)
    mf_de = analysis.model_flops_for(cfg, de)
    assert mf_tr == 6.0 * cfg.active_param_count() * tr.global_batch * tr.seq_len
    assert mf_de == 2.0 * cfg.active_param_count() * de.global_batch
    ub = analysis.useful_bytes_for(cfg, de, visible_window=512)
    assert ub > cfg.active_param_count() * 2


def test_roofline_finalize_bottleneck():
    r = analysis.Roofline(
        arch="a", shape="s", mesh="m", chips=256,
        hlo_flops=1e12, hlo_bytes=1e9, coll_bytes=1e6, coll_detail={},
        model_flops=1e14, attn_flops=0.0, useful_bytes=1e11).finalize()
    assert r.bottleneck == "compute"
    assert 0 < r.roofline_fraction <= 1.01


def test_bench_kernel_rows_smoke():
    """The per-kernel rows run.py --json embeds: both reference kernels
    compile against the current registry/jax and yield self-consistent
    achieved-vs-peak terms (repro.roofline.bench)."""
    from repro.roofline import bench
    rows = bench.kernel_rows()
    assert set(rows) == {"prefill_chunk", "decode_step", "decode_step_skewed"}
    for r in rows.values():
        assert r["hlo_flops"] > 0 and r["hlo_bytes"] > 0
        assert r["bottleneck"] in ("compute", "memory", "collective")
        assert r["bound_step_s"] >= r["compute_s"] > 0
        assert r["bound_step_s"] >= r["memory_s"] > 0
        assert 0 < r["roofline_fraction"] <= 1.01
        assert r["compute_s"] == pytest.approx(
            r["hlo_flops"] / r["peak_flops"])
        assert 0.0 <= r["work_skip_fraction"] < 1.0
        assert r["effective_ideal_step_s"] <= r["ideal_step_s"] * (1 + 1e-9)
    # the skewed decode row accounts the same program at the mean visible
    # extent: identical padded terms, strictly smaller effective ideal
    sk, de = rows["decode_step_skewed"], rows["decode_step"]
    assert sk["bound_step_s"] == pytest.approx(de["bound_step_s"])
    assert sk["ideal_step_s"] == pytest.approx(de["ideal_step_s"])
    assert sk["work_skip_fraction"] > 0.0
    assert de["work_skip_fraction"] == 0.0
    assert sk["effective_ideal_step_s"] < de["ideal_step_s"]
    # the prefill kernel lowers 128x the tokens of the decode step
    assert rows["prefill_chunk"]["hlo_flops"] \
        > rows["decode_step"]["hlo_flops"]
    # best-effort wrapper never raises
    rep = bench.report()
    assert rep["ok"] and set(rep["kernels"]) == set(rows)
