"""Tensor-parallel sharded decode (DESIGN.md §4): token-for-token equality
with the single-device engine on a forced multi-device CPU mesh, with
pipelining and chunked prefill on; audit invariants unchanged (one
compilation per executor, single commit per step, identical DMA
groups/step); per-device KV accounting shrinks by the TP degree; the jnp
attention reference is shard-oblivious under shard_map; and the sharded
executor's collectives are exactly the f32 output-projection psums.

The >= 2 CPU devices come from tests/conftest.py
(--xla_force_host_platform_device_count=4).
"""
import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import get_reduced
from repro.core.engine import EngineConfig, KVRMEngine
from repro.core.scheduler import Request
from repro.distributed import sharding as shd
from repro.launch.mesh import make_engine_mesh, lane_meshes
from repro.models import registry

pytestmark = pytest.mark.skipif(
    len(jax.devices()) < 2, reason="needs a forced multi-device CPU topology")

MODES = ["arena", "paged", "paged_merge"]


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_reduced("qwen2.5-32b")
    params = registry.init_params(jax.random.PRNGKey(7), cfg)
    return cfg, params


def _reqs(vocab):
    rng = np.random.default_rng(1)
    lens = [(5, 6), (17, 4), (3, 8), (33, 5), (9, 7), (21, 3),
            (4, 5), (6, 5), (8, 5)]          # EOS burst tail
    return [Request(rid=i, prompt=rng.integers(0, vocab, size=p)
                    .astype(np.int32), gen_len=g)
            for i, (p, g) in enumerate(lens)]


def _run(cfg, params, mesh, mode="paged_merge", depth=1, chunk=8, **kw):
    eng = KVRMEngine(cfg, params, EngineConfig(
        mode=mode, batch=4, max_seq=64, block_tokens=8, mesh=mesh,
        pipeline_depth=depth, prefill_chunk=chunk, **kw))
    for r in _reqs(cfg.vocab_size):
        eng.submit(r)
    eng.run(max_steps=500)
    return eng


@pytest.mark.parametrize("mode", MODES)
def test_tp2_token_identical(dense_setup, mode):
    """model=2 TP decode is token-for-token identical to the single-device
    engine (pipelining + chunked prefill on), with the full audit contract:
    one compilation per executor, one frame commit per step, and the same
    DMA groups/step — the transport timeline must not see the mesh."""
    cfg, params = dense_setup
    e0 = _run(cfg, params, None, mode)
    e1 = _run(cfg, params, make_engine_mesh(1, 2), mode)
    t0 = {r.rid: r.generated for r in e0.sched.finished}
    t1 = {r.rid: r.generated for r in e1.sched.finished}
    assert len(t0) == len(t1) == 9
    assert t0 == t1
    a0, a1 = e0.audit(), e1.audit()
    assert e0.steps_run == e1.steps_run
    assert a1["compilations"] in (-1, 1), a1
    assert a1["prefill_compilations"] in (-1, 0, 1), a1
    assert a1["single_commit_per_step"]
    assert a0["frames_committed"] == a1["frames_committed"]
    assert a0["dma_groups_per_step"] == pytest.approx(a1["dma_groups_per_step"])
    assert a1["tp_degree"] == 2


def test_tp2_sampled_stop_tokens_identical(dense_setup):
    """Sampled decode (DESIGN.md §13) is mesh-transparent too: threefry
    keys derive from (seed, rid, position) and the sampler runs replicated
    on the logits, so a TP=2 run with temperature/top-k/top-p and detected
    stop-token retirement emits the exact tokens — and retires on the exact
    steps — of the single-device engine, sampling counters included."""
    cfg, params = dense_setup
    kw = dict(greedy=False, temperature=1.2, top_k=50, top_p=0.95,
              sample_seed=123)
    probe = _run(cfg, params, None, **kw)
    pool = sorted({t for r in probe.sched.finished
                   for t in r.generated[1:-2]})
    stops = tuple(pool[:6])

    def sampled(mesh):
        eng = KVRMEngine(cfg, params, EngineConfig(
            mode="paged_merge", batch=4, max_seq=64, block_tokens=8,
            mesh=mesh, pipeline_depth=1, prefill_chunk=8, **kw))
        for r in _reqs(cfg.vocab_size):
            r.stop_tokens = stops
            eng.submit(r)
        eng.run(max_steps=500)
        return eng

    e0, e1 = sampled(None), sampled(make_engine_mesh(1, 2))
    t0 = {r.rid: list(map(int, r.generated)) for r in e0.sched.finished}
    t1 = {r.rid: list(map(int, r.generated)) for r in e1.sched.finished}
    assert len(t0) == len(t1) == 9
    assert t0 == t1
    a0, a1 = e0.audit(), e1.audit()
    assert a0["eos_detected"] == a1["eos_detected"] > 0
    assert a0["eos_overshoot_tokens"] == a1["eos_overshoot_tokens"]
    assert a0["eos_reconciled_blocks"] == a1["eos_reconciled_blocks"]
    assert {r.rid: r.finish_reason for r in e0.sched.finished} == \
           {r.rid: r.finish_reason for r in e1.sched.finished}
    assert a1["compilations"] in (-1, 1)
    assert a1["single_commit_per_step"]
    assert e1.pager.reserved_blocks() == 0


def test_tp_with_data_axis(dense_setup):
    """A (data=2, model=2) mesh (pools replicated over `data`, sharded over
    `model`) still decodes token-for-token identically."""
    cfg, params = dense_setup
    if len(jax.devices()) < 4:
        pytest.skip("needs 4 devices")
    e0 = _run(cfg, params, None)
    e1 = _run(cfg, params, make_engine_mesh(2, 2))
    assert {r.rid: r.generated for r in e0.sched.finished} == \
           {r.rid: r.generated for r in e1.sched.finished}
    assert e1.audit()["kv_shards"] == 2


def test_lane_mesh_pure_dp(dense_setup):
    """A ('model',)=1 lane submesh (pure data-parallel lane) is the
    single-device engine with placement plumbing on — identical stream."""
    cfg, params = dense_setup
    lanes = lane_meshes(make_engine_mesh(2, 1))
    assert len(lanes) == 2
    e0 = _run(cfg, params, None)
    e1 = _run(cfg, params, lanes[0])
    assert {r.rid: r.generated for r in e0.sched.finished} == \
           {r.rid: r.generated for r in e1.sched.finished}
    assert e1.audit()["tp_degree"] == 1


def test_per_device_kv_accounting(dense_setup):
    """audit() per-device KV shrinks by the TP degree: the same workload's
    peak logical reservation is unchanged, but each device holds half."""
    cfg, params = dense_setup
    e0 = _run(cfg, params, None)
    e1 = _run(cfg, params, make_engine_mesh(1, 2))
    a0, a1 = e0.audit(), e1.audit()
    assert a0["peak_reserved_kv"] == a1["peak_reserved_kv"] > 0
    assert a1["kv_shards"] == 2
    assert a1["per_device_peak_reserved_kv"] * 2 == a1["peak_reserved_kv"]
    assert a1["per_device_peak_reserved_kv"] < a0["per_device_peak_reserved_kv"]
    # mid-flight live accounting shrinks the same way
    e2 = KVRMEngine(cfg, params, EngineConfig(
        mode="paged_merge", batch=4, max_seq=64, block_tokens=8,
        mesh=make_engine_mesh(1, 2)))
    for r in _reqs(cfg.vocab_size)[:4]:
        e2.submit(r)
    for _ in range(6):
        e2.step()
    a2 = e2.audit()
    assert a2["reserved_kv_bytes"] > 0
    assert a2["per_device_reserved_kv"] * 2 == a2["reserved_kv_bytes"]
    e2.run(max_steps=200)


def test_tp_divisibility_guard(dense_setup):
    """kv-heads not divisible by the TP degree is a clear constructor error
    (reduced config has n_kv_heads=2)."""
    cfg, params = dense_setup
    if len(jax.devices()) < 4:
        pytest.skip("needs 4 devices")
    with pytest.raises(ValueError, match="n_kv_heads"):
        KVRMEngine(cfg, params, EngineConfig(
            mode="paged_merge", batch=4, max_seq=64, block_tokens=8,
            mesh=make_engine_mesh(1, 4)))


@pytest.mark.parametrize("arch", ["zamba2-7b", "deepseek-v3-671b"])
def test_tp2_other_families(arch):
    """The mesh path serves the other families too: hybrid shards its
    attention-site KV pools (kv_shards=2); MLA keeps its head-shared latent
    pool replicated (kv_shards=1) and shards only head projections. Token
    streams match the single-device engine either way."""
    cfg = get_reduced(arch)
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    outs = {}
    for label, mesh in (("1dev", None), ("tp2", make_engine_mesh(1, 2))):
        eng = KVRMEngine(cfg, params, EngineConfig(
            mode="paged_merge", batch=2, max_seq=64, block_tokens=8,
            mesh=mesh))
        rng = np.random.default_rng(5)
        for i in range(3):
            eng.submit(Request(rid=i, prompt=rng.integers(0, 100, size=4)
                               .astype(np.int32), gen_len=4))
        eng.run(max_steps=200)
        assert len(eng.sched.finished) == 3
        assert eng.audit()["compilations"] in (-1, 1)
        outs[label] = {r.rid: r.generated for r in eng.sched.finished}
    assert outs["1dev"] == outs["tp2"]


def test_ref_attention_shard_map(dense_setup):
    """kernels/ref.paged_decode_attention_ref is shard-oblivious: running it
    per kv-head shard under shard_map (q sharded on H, pools on KV, control
    replicated) reproduces the full-head result exactly."""
    from jax.experimental.shard_map import shard_map

    from repro.kernels import ref

    B, H, KV, hd, BT, NBLK, NB, W = 4, 4, 2, 16, 8, 20, 4, 32
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(B, H, hd)), jnp.bfloat16)
    pk = jnp.asarray(rng.normal(size=(NBLK, BT, KV, hd)), jnp.bfloat16)
    pv = jnp.asarray(rng.normal(size=(NBLK, BT, KV, hd)), jnp.bfloat16)
    tbl = jnp.asarray(rng.integers(1, NBLK, size=(B, NB)), jnp.int32)
    wb = jnp.zeros((B,), jnp.int32)
    sl = jnp.asarray([5, 9, 17, 2], jnp.int32)
    act = jnp.ones((B,), jnp.int32)

    full, _ = ref.paged_decode_attention_ref(
        q, pk, pv, tbl, wb, sl, act, near_window=W)

    mesh = Mesh(np.array(jax.devices()[:2]), ("model",))
    sharded_fn = shard_map(
        lambda q_, pk_, pv_: ref.paged_decode_attention_ref(
            q_, pk_, pv_, tbl, wb, sl, act, near_window=W)[0],
        mesh=mesh,
        in_specs=(P(None, "model", None), P(None, None, "model", None),
                  P(None, None, "model", None)),
        out_specs=P(None, "model", None))
    got = sharded_fn(q, pk, pv)
    np.testing.assert_array_equal(np.asarray(full, np.float32),
                                  np.asarray(got, np.float32))


def test_sharded_executor_collectives(dense_setup):
    """The compiled sharded decode step contains only f32 all-reduces (the
    output-projection psums + the vocab-sharded embedding gather): attention
    itself is collective-free over the kv-head slice, and no psum runs in
    bf16 — that is what keeps TP greedy decode bit-identical."""
    cfg, params = dense_setup
    from repro.core.descriptor import descriptor_flat_size, unflatten_descriptor

    B, NB, CAP, MT, CB = 4, 9, 1, 10, 1
    pools = registry.init_decode_pools(cfg, batch=B, num_blocks=40,
                                       block_tokens=8, max_chunks=0, enc_len=0)
    cfg_dec = cfg.replace(serving=cfg.serving.__class__(near_window=64))
    D = descriptor_flat_size(B, NB, CAP, MT, CB)

    def step(params, flatv, prev_nxt, pools):
        descr = unflatten_descriptor(flatv[:D], B, NB, CAP, MT, CB)
        tokens = jnp.where(flatv[D + B:D + 2 * B] > 0, prev_nxt,
                           flatv[D:D + B])
        logits, pools, fu = registry.decode_step(params, cfg_dec, tokens,
                                                 pools, descr)
        return jnp.argmax(logits, -1).astype(jnp.int32), pools, fu

    mesh = make_engine_mesh(1, 2)
    psh = shd.to_shardings(mesh, shd.sanitize_specs(
        mesh, params, shd.param_specs(cfg, params)))
    poolsh = shd.to_shardings(mesh, shd.sanitize_specs(
        mesh, pools, registry.decode_pool_partition_specs(cfg, pools)))
    repl = NamedSharding(mesh, P())
    f = jax.jit(step, donate_argnums=(3,),
                in_shardings=(psh, repl, repl, poolsh),
                out_shardings=(repl, poolsh, repl))
    sds = lambda t: jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    hlo = f.lower(sds(params), jax.ShapeDtypeStruct((D + 2 * B,), jnp.int32),
                  jax.ShapeDtypeStruct((B,), jnp.int32),
                  sds(pools)).compile().as_text()
    ars = re.findall(r"= (\w+)\[[^\]]*\]\S* all-reduce\(", hlo)
    # layer scan keeps the body once in HLO: wo psum + mlp-down psum +
    # embed-gather psum — bounded, and every one of them f32
    assert 1 <= len(ars) <= 6, hlo.count("all-reduce(")
    assert all(t == "f32" for t in ars), ars
    assert hlo.count("all-to-all") == 0
