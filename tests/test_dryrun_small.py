"""Dry-run machinery on a small forced-device mesh (subprocess: the 512-device
flag must be set before jax initializes, and the main test process already
holds 1 device). Exercises the same builders as the production sweep."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import repro.launch.dryrun as dr
    from repro.configs import get_reduced, SHAPES
    from repro.configs.base import ShapeConfig

    # shrink the production mesh for the test (make_mesh handles the
    # AxisType API difference across jax versions)
    import repro.launch.mesh as mesh_mod
    mesh_mod.make_production_mesh = lambda multi_pod=False: mesh_mod.make_mesh(
        (2, 2, 2) if multi_pod else (4, 2),
        ("pod", "data", "model") if multi_pod else ("data", "model"))
    dr.make_production_mesh = mesh_mod.make_production_mesh

    # reduced configs + reduced shapes
    import repro.configs as C
    shapes = {
        "train_4k": ShapeConfig("train_4k", 64, 8, "train"),
        "decode_32k": ShapeConfig("decode_32k", 128, 8, "decode"),
        "prefill_32k": ShapeConfig("prefill_32k", 128, 4, "prefill"),
        "long_500k": ShapeConfig("long_500k", 512, 1, "decode"),
    }
    dr.SHAPES.clear(); dr.SHAPES.update(shapes)
    dr.BLOCK_TOKENS = 16

    arch, shape, mesh_name = json.loads(os.environ["CELL"])
    cfg = get_reduced(arch)
    rec = dr.run_cell(arch, shape, mesh_name, out_dir=os.environ["OUT"],
                      force=True, cfg_override=cfg)
    print(json.dumps({"ok": rec.get("ok"), "err": rec.get("error", "")}))
""")


@pytest.mark.parametrize("arch,shape,mesh", [
    ("qwen2.5-32b", "train_4k", "single"),
    ("qwen2.5-32b", "decode_32k", "multi"),
    ("deepseek-v3-671b", "train_4k", "single"),
    ("kimi-k2-1t-a32b", "decode_32k", "single"),
    ("zamba2-7b", "decode_32k", "single"),
    ("xlstm-125m", "long_500k", "multi"),
    ("seamless-m4t-medium", "prefill_32k", "single"),
    ("internvl2-26b", "train_4k", "multi"),
])
def test_dryrun_cell_reduced(arch, shape, mesh, tmp_path):
    env = dict(os.environ)
    env.update({
        "CELL": json.dumps([arch, shape, mesh]),
        "OUT": str(tmp_path),
        "PYTHONPATH": os.path.join(ROOT, "src"),
    })
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=420)
    assert out.returncode == 0, out.stderr[-2000:]
    res = json.loads(out.stdout.strip().splitlines()[-1])
    assert res["ok"], res["err"]
