"""Optional-hypothesis shim: property-based tests skip cleanly (instead of
failing collection with ModuleNotFoundError) when ``hypothesis`` is absent,
so the tier-1 suite runs on a bare environment. CI installs
requirements-dev.txt and runs the property tests for real.

Usage in a test module (pytest puts tests/ on sys.path):

    from _hypothesis_compat import HAVE_HYPOTHESIS, given, settings, st
"""
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                                   # pragma: no cover
    HAVE_HYPOTHESIS = False

    class _StrategyStub:
        """Absorbs strategy-construction expressions at decoration time."""
        def __getattr__(self, name):
            return lambda *a, **k: self

        def __call__(self, *a, **k):
            return self

    st = _StrategyStub()

    def given(*a, **k):
        return lambda f: pytest.mark.skip(
            reason="hypothesis not installed (see requirements-dev.txt)")(f)

    def settings(*a, **k):
        return lambda f: f
