"""Pallas kernels vs pure-jnp oracles (interpret mode on CPU).

Per kernel: shape/dtype sweeps + randomized property checks against ref.py.
"""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.kernels import ref
from repro.kernels.farview_summarize import farview_summarize_pallas
from repro.kernels.paged_attention import paged_decode_attention_pallas
from repro.kernels.prefill_attention import (chunked_prefill_attention_pallas,
                                             prefill_attention_pallas)


def _mk_paged(key, B, H, KV, hd, P, BT, NB, dtype, max_t=None):
    ks = jax.random.split(key, 5)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    pk = jax.random.normal(ks[1], (P, BT, KV, hd), dtype)
    pv = jax.random.normal(ks[2], (P, BT, KV, hd), dtype)
    # random DISTINCT physical blocks per slot (avoid scratch block 0)
    tbl = np.stack([np.random.default_rng(i).permutation(np.arange(1, P))[:NB]
                    for i in range(B)]).astype(np.int32)
    max_t = max_t or NB * BT
    seq = np.random.default_rng(9).integers(1, max_t, size=B).astype(np.int32)
    wb = np.zeros(B, np.int32)
    act = np.ones(B, np.int32)
    return q, pk, pv, jnp.asarray(tbl), jnp.asarray(wb), jnp.asarray(seq), jnp.asarray(act)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,H,KV,hd,BT,NB", [
    (2, 4, 2, 32, 8, 4),
    (3, 8, 8, 64, 16, 3),     # MHA
    (1, 16, 2, 128, 8, 5),    # wide GQA ratio
])
def test_paged_decode_matches_ref(B, H, KV, hd, BT, NB, dtype):
    P = NB * B + 4
    args = _mk_paged(jax.random.PRNGKey(0), B, H, KV, hd, P, BT, NB, dtype)
    q, pk, pv, tbl, wb, seq, act = args
    W = NB * BT
    out_p, _ = paged_decode_attention_pallas(q, pk, pv, tbl, wb, seq, act,
                                             near_window=W)
    out_r, _ = ref.paged_decode_attention_ref(q, pk, pv, tbl, wb, seq, act,
                                              near_window=W)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out_p, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=tol, atol=tol)


def test_paged_decode_inactive_slots_zero():
    B, H, KV, hd, BT, NB = 2, 4, 2, 32, 8, 4
    P = 16
    q, pk, pv, tbl, wb, seq, act = _mk_paged(
        jax.random.PRNGKey(1), B, H, KV, hd, P, BT, NB, jnp.float32)
    act = jnp.asarray([1, 0], jnp.int32)
    out, _ = paged_decode_attention_pallas(q, pk, pv, tbl, wb, seq, act,
                                           near_window=NB * BT)
    assert bool((out[1] == 0).all())
    assert not bool((out[0] == 0).all())


def test_paged_decode_sliding_window_mask():
    """Only the last W positions contribute (sliding semantics)."""
    B, H, KV, hd, BT, NB = 1, 2, 2, 16, 4, 4
    P = 8
    key = jax.random.PRNGKey(2)
    q, pk, pv, tbl, wb, seq, act = _mk_paged(key, B, H, KV, hd, P, BT, NB,
                                             jnp.float32)
    seq = jnp.asarray([15], jnp.int32)
    W = 6
    out_r, _ = ref.paged_decode_attention_ref(q, pk, pv, tbl, wb, seq, act,
                                              near_window=W)
    # corrupt all pool positions OUTSIDE the window; result must not change
    pos = np.arange(NB * BT)
    outside = pos[(pos <= 15 - W) | (pos > 15)]
    pk2, pv2 = np.asarray(pk).copy(), np.asarray(pv).copy()
    tbl_np = np.asarray(tbl)
    for p_ in outside:
        blk, off = divmod(int(p_), BT)
        pk2[tbl_np[0, blk], off] = 999.0
        pv2[tbl_np[0, blk], off] = 999.0
    out2, _ = ref.paged_decode_attention_ref(
        q, jnp.asarray(pk2), jnp.asarray(pv2), tbl, wb, seq, act, near_window=W)
    np.testing.assert_allclose(np.asarray(out_r), np.asarray(out2), rtol=1e-6)
    out_p, _ = paged_decode_attention_pallas(
        q, jnp.asarray(pk2), jnp.asarray(pv2), tbl, wb, seq, act, near_window=W)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r), rtol=1e-5,
                               atol=1e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("B,S,H,KV,hd,qb,kb", [
    (2, 256, 4, 2, 32, 64, 64),
    (1, 512, 8, 8, 64, 128, 128),
    (2, 128, 4, 1, 32, 64, 32),
])
def test_prefill_flash_matches_dense(B, S, H, KV, hd, qb, kb, dtype):
    from repro.models.common import attention_dense
    ks = jax.random.split(jax.random.PRNGKey(3), 3)
    q = jax.random.normal(ks[0], (B, S, H, hd), dtype)
    k = jax.random.normal(ks[1], (B, S, KV, hd), dtype)
    v = jax.random.normal(ks[2], (B, S, KV, hd), dtype)
    out_p = prefill_attention_pallas(q, k, v, causal=True, q_blk=qb, k_blk=kb)
    out_r = attention_dense(q, k, v, causal=True)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out_p, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("C,H,KV,hd,BT,NB,start,n_valid", [
    (8, 4, 2, 32, 4, 5, 10, 6),      # partial chunk, GQA
    (16, 8, 8, 64, 8, 3, 16, 16),    # full chunk, MHA, block-aligned start
    (4, 4, 2, 32, 4, 0, 0, 3),       # first chunk: no pool context
])
def test_chunked_prefill_matches_ref(C, H, KV, hd, BT, NB, start, n_valid, dtype):
    NBt = max(NB, 1)
    P = NBt * 2 + 4
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    q = jax.random.normal(ks[0], (C, H, hd), dtype)
    pk = jax.random.normal(ks[1], (P, BT, KV, hd), dtype)
    pv = jax.random.normal(ks[2], (P, BT, KV, hd), dtype)
    ck = jax.random.normal(ks[3], (C, KV, hd), dtype)
    cv = jax.random.normal(ks[4], (C, KV, hd), dtype)
    tbl = jnp.asarray((np.arange(NBt) % (P - 1) + 1).astype(np.int32))
    W = max(NBt * BT, C + 1)
    args = (q, pk, pv, ck, cv, tbl, jnp.int32(0), jnp.int32(start),
            jnp.int32(n_valid))
    out_p = chunked_prefill_attention_pallas(*args, near_window=W)
    out_r = ref.chunked_prefill_attention_ref(*args, near_window=W)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out_p, np.float32),
                               np.asarray(out_r, np.float32),
                               rtol=tol, atol=tol)
    # padded query rows contribute nothing downstream
    assert bool((np.asarray(out_p, np.float32)[n_valid:] == 0).all())


def test_chunked_prefill_equals_token_at_a_time():
    """Feeding a chunk through the chunked kernel == feeding its tokens one
    at a time through the decode kernel with incremental pool writes."""
    C, H, KV, hd, BT = 6, 4, 2, 16, 4
    P, W = 12, 20
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    q = jax.random.normal(ks[0], (C, H, hd), jnp.float32)
    pk = jax.random.normal(ks[1], (P, BT, KV, hd), jnp.float32)
    pv = jax.random.normal(ks[2], (P, BT, KV, hd), jnp.float32)
    ck = jax.random.normal(ks[3], (C, KV, hd), jnp.float32)
    cv = jax.random.normal(ks[4], (C, KV, hd), jnp.float32)
    start = 10                         # context tokens 0..9 in blocks 1..3
    chunk_tbl = jnp.asarray(np.array([1, 2, 3, 0, 0], np.int32))
    out_c = ref.chunked_prefill_attention_ref(
        q, pk, pv, ck, cv, chunk_tbl, jnp.int32(0), jnp.int32(start),
        jnp.int32(C), near_window=W)
    # oracle: incremental decode with chunk token j written at block 3/4/...
    wpos = [(3, 2), (3, 3), (4, 0), (4, 1), (4, 2), (4, 3)]
    dec_tbl = jnp.asarray(np.array([[1, 2, 3, 4, 5, 0]], np.int32))
    pki, pvi = pk, pv
    for i in range(C):
        o, _ = ref.paged_decode_attention_ref(
            q[i][None], pki, pvi, dec_tbl, jnp.zeros(1, jnp.int32),
            jnp.asarray([start + i], jnp.int32), jnp.ones(1, jnp.int32),
            near_window=W, cur_k=ck[i][None], cur_v=cv[i][None])
        np.testing.assert_allclose(np.asarray(o[0]), np.asarray(out_c[i]),
                                   rtol=1e-5, atol=1e-5)
        b, off = wpos[i]
        pki = pki.at[b, off].set(ck[i])
        pvi = pvi.at[b, off].set(cv[i])


def test_prefill_flash_window():
    from repro.models.common import attention_dense
    ks = jax.random.split(jax.random.PRNGKey(4), 3)
    B, S, H, hd = 1, 256, 2, 32
    q = jax.random.normal(ks[0], (B, S, H, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, hd), jnp.float32)
    out_p = prefill_attention_pallas(q, k, v, causal=True, window=64,
                                     q_blk=64, k_blk=64)
    out_r = attention_dense(q, k, v, causal=True, window=64)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("payload", [(4, 16), (64,), (2, 8, 4)])
def test_farview_summarize_matches_ref(payload):
    P, BT, B, CB = 12, 8, 3, 2
    key = jax.random.PRNGKey(5)
    pool = jax.random.normal(key, (P, BT) + payload, jnp.float32)
    tbl = jnp.asarray([[1, 2], [3, 4], [5, 6]], jnp.int32)
    n_tok = jnp.asarray([16, 12, 16], jnp.int32)
    gate = jnp.asarray([1, 1, 0], jnp.int32)
    out_p = farview_summarize_pallas(pool, tbl, n_tok, gate)
    out_r = ref.farview_summarize_ref(pool, tbl, n_tok, gate)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               rtol=1e-5, atol=1e-6)
    assert bool((out_p[2] == 0).all())


def test_mla_absorbed_equals_naive():
    """Absorbed-matmul MLA decode == naive per-head materialization."""
    B, H, dn, dr, dv, R_lat = 2, 4, 16, 8, 16, 32
    P, BT, NB = 12, 8, 3
    ks = jax.random.split(jax.random.PRNGKey(6), 6)
    q_nope = jax.random.normal(ks[0], (B, H, dn), jnp.float32)
    q_rope = jax.random.normal(ks[1], (B, H, dr), jnp.float32)
    pool = jax.random.normal(ks[2], (P, BT, R_lat + dr), jnp.float32)
    w_k_b = jax.random.normal(ks[3], (H, R_lat, dn), jnp.float32) * 0.1
    w_v_b = jax.random.normal(ks[4], (H, R_lat, dv), jnp.float32) * 0.1
    tbl = jnp.asarray([[1, 2, 3], [4, 5, 6]], jnp.int32)
    wb = jnp.zeros(B, jnp.int32)
    seq = jnp.asarray([10, 20], jnp.int32)
    act = jnp.ones(B, jnp.int32)
    out_a, _ = ref.mla_decode_attention_ref(
        q_nope, q_rope, pool, w_k_b, w_v_b, tbl, wb, seq, act,
        near_window=NB * BT, kv_lora_rank=R_lat)
    out_n = ref.mla_decode_attention_naive(
        q_nope, q_rope, pool, w_k_b, w_v_b, tbl, wb, seq, act,
        near_window=NB * BT, kv_lora_rank=R_lat)
    np.testing.assert_allclose(np.asarray(out_a), np.asarray(out_n),
                               rtol=1e-4, atol=1e-5)


def test_paged_decode_farview_consistency():
    """Far summaries with zero far_valid == pure near-window result."""
    B, H, KV, hd, BT, NB, CAP, MAXC = 2, 4, 2, 32, 8, 4, 4, 8
    P = 16
    q, pk, pv, tbl, wb, seq, act = _mk_paged(
        jax.random.PRNGKey(7), B, H, KV, hd, P, BT, NB, jnp.float32)
    fk = jax.random.normal(jax.random.PRNGKey(8), (B, MAXC, KV, hd))
    fv_ = jax.random.normal(jax.random.PRNGKey(9), (B, MAXC, KV, hd))
    ft = jnp.zeros((B, CAP), jnp.int32)
    fval = jnp.zeros((B, CAP), jnp.int32)
    W = NB * BT
    out0, fu0 = ref.paged_decode_attention_ref(q, pk, pv, tbl, wb, seq, act,
                                               near_window=W)
    out1, fu1 = ref.paged_decode_attention_ref(
        q, pk, pv, tbl, wb, seq, act, near_window=W,
        far_k=fk, far_v=fv_, far_table=ft, far_valid=fval)
    np.testing.assert_allclose(np.asarray(out0), np.asarray(out1),
                               rtol=1e-5, atol=1e-6)
    assert float(fu1.sum()) == 0.0


@settings(max_examples=15, deadline=None)
@given(st.integers(1, 3), st.integers(1, 4), st.integers(4, 32),
       st.integers(2, 5), st.data())
def test_paged_decode_property(B, KV, hd_pow, NB, data):
    """Property: pallas == ref across random geometry."""
    hd = (hd_pow // 4 + 1) * 16
    n_rep = data.draw(st.sampled_from([1, 2, 4]))
    H = KV * n_rep
    BT = data.draw(st.sampled_from([4, 8]))
    P = NB * B + 2
    q, pk, pv, tbl, wb, seq, act = _mk_paged(
        jax.random.PRNGKey(data.draw(st.integers(0, 100))),
        B, H, KV, hd, P, BT, NB, jnp.float32)
    W = data.draw(st.integers(2, NB * BT))
    out_p, _ = paged_decode_attention_pallas(q, pk, pv, tbl, wb, seq, act,
                                             near_window=W)
    out_r, _ = ref.paged_decode_attention_ref(q, pk, pv, tbl, wb, seq, act,
                                              near_window=W)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               rtol=1e-4, atol=1e-4)
