"""Radix prefix cache (DESIGN.md §9): pager external refs + alias_blocks
+ typed SwapRefused, radix index match/insert/evict semantics, and the
engine-level guarantees — bitwise-identical tokens with the cache on
(both pipeline depths, chunked prefill, COW tails), watermark accounting
of shared blocks, and the host-tier interplay (aliased blocks are never
swap candidates, eviction prefers unshared cold leaves, resume
re-indexes)."""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.engine import EngineConfig, KVRMEngine
from repro.core.pager import BlockPager, SwapError, SwapRefused
from repro.core.prefix_cache import PrefixCache
from repro.core.scheduler import Request
from repro.data import traces
from repro.models import registry


# ---------------------------------------------------------------------------
# pager: alias_blocks / external refs / SwapRefused
# ---------------------------------------------------------------------------

def _paged(host=16, blocks=64, bt=16):
    return BlockPager(blocks, bt, bytes_per_block=1024, span_blocks=1,
                      host_pool_blocks=host)


def _fill(p, sid, n_tokens):
    p.open_session(sid)
    p.reserve(sid, n_tokens)
    for _ in range(n_tokens):
        p.append_token(sid)
    return p.sessions[sid]


def test_alias_host_resident_prefix_raises_typed_swap_refused():
    """Regression: alias() over a cold-swapped source prefix used to die
    on a bare AssertionError; it must raise the typed SwapRefused (a
    SwapError) so the engine can catch it as a policy decision."""
    p = _paged()
    _fill(p, 0, 64)
    p.swap_out_cold(0, keep_from_local=2)        # blocks 0,1 -> host tier
    p.open_session(1)
    with pytest.raises(SwapRefused):
        p.alias(0, 1, 32)
    assert issubclass(SwapRefused, SwapError)
    # the refused alias must leave the fresh session untouched
    assert p.sessions[1].blocks == [] and p.sessions[1].length == 0
    p.check_invariants()


def test_retain_release_survives_session_close():
    p = _paged()
    s = _fill(p, 0, 48)
    blocks = list(s.blocks)
    for b in blocks:
        p.retain_block(b)
    p.check_invariants()
    p.trim(0, close=True)                        # EOS: session refs drop
    p.check_invariants()
    assert all(p.refcount[b] == 1 for b in blocks)   # cache keeps them live
    assert p.reserved_blocks() == len(blocks)
    # a fresh session can alias the retained chain with a COW tail
    p.open_session(1)
    p.alias_blocks(1, blocks, 40)                # 2 full blocks + 8-tok tail
    s1 = p.sessions[1]
    assert s1.shared_prefix_blocks == 2 and s1.length == 40
    assert s1.cow_pending == (blocks[2], s1.blocks[2])
    p.check_invariants()
    p.trim(1, close=True)
    for b in blocks:
        p.release_block(b)
    p.check_invariants()
    assert p.reserved_blocks() == 0


def test_alias_blocks_failed_tail_alloc_is_atomic():
    p = BlockPager(5, 16, span_blocks=1)         # 4 usable blocks
    s = _fill(p, 0, 48)                          # takes 3 of 4 blocks
    _fill(p, 2, 16)                              # last block: pool now full
    p.open_session(1)
    with pytest.raises(MemoryError):
        p.alias_blocks(1, s.blocks, 40)          # tail needs a 5th block
    assert p.sessions[1].blocks == [] and p.sessions[1].length == 0
    p.check_invariants()


def test_external_refs_block_swap_eligibility():
    """Aliased/cached blocks are never swap candidates: an external ref
    raises refcount above 1, which refuses both swap verbs."""
    p = _paged()
    _fill(p, 0, 64)
    p.retain_block(p.sessions[0].blocks[0])
    assert not p.swap_eligible(0)
    assert p.swap_out_session(0) is None
    pairs = p.swap_out_cold(0, keep_from_local=3)
    assert p.sessions[0].blocks[0] > 0           # retained block stayed put
    assert all(src != p.sessions[0].blocks[0] for src, _ in pairs)
    p.release_block(p.sessions[0].blocks[0])
    p.check_invariants()


# ---------------------------------------------------------------------------
# radix index: match / insert / dedup / eviction
# ---------------------------------------------------------------------------

def _cache(p, max_blocks=32):
    return PrefixCache(p, p.block_tokens, max_blocks)


def test_radix_match_insert_dedup():
    p = _paged(bt=4)
    pc = _cache(p)
    toks_a = np.arange(16, dtype=np.int32)       # 4 blocks
    sa = _fill(p, 0, 16)
    assert pc.insert(toks_a, sa.blocks) == 4
    # second prompt shares 2 blocks then diverges
    toks_b = np.concatenate([toks_a[:8], 100 + np.arange(8)]).astype(np.int32)
    sb = _fill(p, 1, 16)
    assert pc.insert(toks_b, sb.blocks) == 2     # shared chunks deduplicated
    assert pc.blocks_cached == 6
    pc.check_invariants()
    m = pc.match(toks_a)
    assert m.tokens == 16 and m.blocks == sa.blocks[:4]
    m = pc.match(toks_b)
    assert m.tokens == 16
    assert m.blocks[:2] == sa.blocks[:2]         # canonical shared chain
    assert m.blocks[2:] == sb.blocks[2:4]
    assert pc.match(np.asarray([7, 7, 7, 7])).tokens == 0
    # partial-block prompts never match below one block
    assert pc.match(toks_a[:3]).tokens == 0


def test_eviction_prefers_unshared_cold_leaves():
    """Two leaves: a COLD one whose block a live session still shares
    (refcount 2) and a HOT cache-only one (refcount 1). Eviction must
    take the unshared leaf first — it returns a device block NOW — even
    though LRU alone would pick the shared (colder) one."""
    p = _paged(bt=4)
    pc = _cache(p)
    sa = _fill(p, 0, 8)                          # stays live (shared)
    sb = _fill(p, 1, 4)
    pc.insert(np.arange(8, dtype=np.int32), sa.blocks)       # cold path
    pc.insert(50 + np.arange(4, dtype=np.int32), sb.blocks)  # hot path
    p.trim(1, close=True)                        # sb block: cache-only now
    free_before = p.free_blocks()
    assert pc.evict(1) == 1
    assert p.free_blocks() == free_before + 1    # unshared leaf freed a block
    pc.check_invariants()
    assert pc.match(np.arange(8, dtype=np.int32)).tokens == 8   # untouched
    # next eviction is forced onto the shared leaf: budget drops, no block
    free_before = p.free_blocks()
    assert pc.evict(1) == 1
    assert p.free_blocks() == free_before        # session still owns it
    p.check_invariants()


def test_pins_shield_matched_paths_until_flush():
    p = _paged(bt=4)
    pc = _cache(p, max_blocks=4)
    s = _fill(p, 0, 16)
    pc.insert(np.arange(16, dtype=np.int32), s.blocks)
    m = pc.match(np.arange(16, dtype=np.int32))
    pc.hit(m.nodes, m.tokens)                    # pin-on-match
    assert pc.evict(4) == 0                      # everything pinned
    assert pc.blocks_cached == 4
    dropped = pc.flush_for_pressure()            # pressure overrides pins
    assert dropped == 4 and pc.blocks_cached == 0
    pc.unpin_path(m.nodes)                       # resilient after flush
    pc.check_invariants()
    p.check_invariants()


def test_insert_cap_evicts_lru():
    p = _paged(bt=4, blocks=64)
    pc = _cache(p, max_blocks=2)
    sa = _fill(p, 0, 8)
    sb = _fill(p, 1, 8)
    pc.insert(np.arange(8, dtype=np.int32), sa.blocks)
    assert pc.blocks_cached == 2
    pc.insert(90 + np.arange(8, dtype=np.int32), sb.blocks)
    assert pc.blocks_cached == 2                 # cap held: LRU evicted
    assert pc.match(90 + np.arange(8, dtype=np.int32)).tokens == 8
    assert pc.stats["evicted_blocks"] == 2
    pc.check_invariants()
    p.check_invariants()


# ---------------------------------------------------------------------------
# engine: bitwise-identical reuse, COW tails, watermarks, host tier
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_reduced("qwen2.5-32b")
    params = registry.init_params(jax.random.PRNGKey(7), cfg)
    return cfg, params


def _shared_reqs(vocab, n=6, prefix_len=64, seed=0):
    rng = np.random.default_rng(seed)
    pfx = rng.integers(0, vocab, size=prefix_len).astype(np.int32)
    out = []
    for i in range(n):
        sfx = rng.integers(0, vocab, size=5 + (i % 3)).astype(np.int32)
        out.append(Request(rid=i, prompt=np.concatenate([pfx, sfx]),
                           gen_len=8))
    return out


def _run(cfg, params, reqs, **ekw):
    eng = KVRMEngine(cfg, params, EngineConfig(
        mode="paged_merge", batch=4, max_seq=128, block_tokens=8,
        near_window=64, **ekw))
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=3000)
    return eng, {r.rid: list(r.generated) for r in eng.sched.finished}


@pytest.mark.parametrize("depth,chunk", [(0, 0), (1, 0), (1, 16)])
def test_prefix_cache_tokens_bitwise_identical(dense_setup, depth, chunk):
    """The headline §9 guarantee: enabling the cache changes NOTHING about
    any request's token stream, at either pipeline depth, chunked or not."""
    cfg, params = dense_setup
    kw = dict(pipeline_depth=depth, prefill_chunk=chunk)
    _, t_cold = _run(cfg, params, _shared_reqs(cfg.vocab_size), **kw)
    warm, t_warm = _run(cfg, params, _shared_reqs(cfg.vocab_size),
                        prefix_cache=True, **kw)
    assert len(t_warm) == 6
    assert t_warm == t_cold
    a = warm.audit()
    assert a["prefix_hits"] >= 1
    assert a["prefix_tokens_reused"] >= 64
    assert a["single_commit_per_step"]
    assert a["compilations"] in (-1, 1)
    warm.pager.check_invariants()
    warm.prefix_cache.check_invariants()
    assert warm.pager.host_used == 0


@pytest.mark.parametrize("depth", [0, 1])
def test_cow_tail_copy_bitwise_identical(dense_setup, depth):
    """An identical-prompt rematch aliases len(prompt)-1 tokens — NOT
    block-aligned — so the partial tail must be materialized by a real
    device-side COW copy (accounted as its own transport group kind)."""
    cfg, params = dense_setup
    rng = np.random.default_rng(3)
    prompt = rng.integers(0, cfg.vocab_size, size=64).astype(np.int32)

    def go(pc):
        eng = KVRMEngine(cfg, params, EngineConfig(
            mode="paged_merge", batch=2, max_seq=128, block_tokens=8,
            near_window=64, pipeline_depth=depth, prefix_cache=pc))
        eng.submit(Request(rid=0, prompt=prompt.copy(), gen_len=10))
        eng.run(max_steps=500)                   # rid 0 finishes, indexed
        eng.submit(Request(rid=1, prompt=prompt.copy(), gen_len=10))
        eng.run(max_steps=500)
        return eng, {r.rid: list(r.generated) for r in eng.sched.finished}

    _, t_cold = go(False)
    warm, t_warm = go(True)
    assert t_warm == t_cold
    a = warm.audit()
    assert a["prefix_hits"] == 1
    assert a["prefix_tokens_reused"] == 63
    assert a["cow_copies"] == 1 and a["cow_groups"] == 1
    assert a["cow_bytes"] == warm.block_bytes


def test_chained_same_round_cow_aliases_bitwise_identical(dense_setup):
    """Regression: C aliases B which aliased A in the SAME admit round —
    C's COW source block is B's dst, which the round's single batched
    scatter has not materialized yet. The engine must resolve the chain
    to the origin block or C reads uninitialized KV."""
    cfg, params = dense_setup
    rng = np.random.default_rng(21)
    prompt = rng.integers(0, cfg.vocab_size, size=25).astype(np.int32)

    def reqs(hints):
        a = Request(rid=0, prompt=prompt.copy(), gen_len=12)
        b = Request(rid=1, prompt=np.concatenate([prompt[:23], prompt[:4]]),
                    gen_len=8)
        c = Request(rid=2, prompt=np.concatenate([prompt[:23], prompt[5:9]]),
                    gen_len=8)
        if hints:
            b.prefix_of, b.prefix_len = 0, 23    # unaligned: COW tail
            c.prefix_of, c.prefix_len = 1, 23    # chained onto B's alias
        return a, b, c

    outs = {}
    for hints in (False, True):
        eng = KVRMEngine(cfg, params, EngineConfig(
            mode="paged_merge", batch=4, max_seq=64, block_tokens=8,
            near_window=32, span_blocks=1))
        a, b, c = reqs(hints)
        eng.submit(a)
        for _ in range(30):                      # A commits its prompt
            eng.step()
        eng.submit(b)
        eng.submit(c)                            # B, C: same admit round
        eng.run(max_steps=500)
        assert len(eng.sched.finished) == 3
        outs[hints] = {r.rid: list(r.generated) for r in eng.sched.finished}
    assert outs[True][2] == outs[False][2]       # C survived the chain
    assert outs[True] == outs[False]


def test_watermark_discounts_shared_blocks(dense_setup):
    """The §8 admission gate charges an aliased request only its OWN
    blocks: with a cached prefix the committed footprint shrinks by the
    shared blocks, and retirement releases exactly what was charged."""
    cfg, params = dense_setup
    eng = KVRMEngine(cfg, params, EngineConfig(
        mode="paged_merge", batch=4, max_seq=128, block_tokens=8,
        near_window=64, prefix_cache=True, host_pool_blocks=24))
    reqs = _shared_reqs(cfg.vocab_size, n=2, prefix_len=64)
    eng.submit(reqs[0])
    eng.run(max_steps=400)                       # indexed, pool warm
    assert eng._committed_blocks == 0
    m = eng.prefix_cache.match(reqs[1].prompt)
    assert m.tokens >= 64
    assert eng._admission_ok(reqs[1], False)
    full = eng._footprint_blocks(reqs[1])
    assert reqs[1].committed_blocks == full - 64 // eng.bt
    eng._committed_blocks -= reqs[1].committed_blocks    # undo the peek
    eng.submit(reqs[1])
    eng.run(max_steps=400)
    assert eng._committed_blocks == 0            # retire released the charge
    assert len(eng.sched.finished) == 2


def test_gate_charge_reconciled_when_alias_shrinks(dense_setup):
    """Regression: the kv_ok gate discounts its cache peek, but if the
    share fails (or shrinks) at admit time the charge must be re-stamped
    — an under-charged request would let later bursts overshoot the
    watermark the host pool was sized by."""
    cfg, params = dense_setup
    eng = KVRMEngine(cfg, params, EngineConfig(
        mode="paged_merge", batch=4, max_seq=128, block_tokens=8,
        near_window=64, prefix_cache=True, host_pool_blocks=24))
    reqs = _shared_reqs(cfg.vocab_size, n=2, prefix_len=64)
    eng.submit(reqs[0])
    eng.run(max_steps=400)                       # prompt indexed
    assert eng._admission_ok(reqs[1], False)     # gate: discounted charge
    full = eng._footprint_blocks(reqs[1])
    assert reqs[1].committed_blocks == full - 64 // eng.bt
    # the cache empties between the gate and the alias (pressure flush):
    # the admit-time match finds nothing and the charge snaps back to full
    eng.prefix_cache.flush_for_pressure()
    sid = 999
    eng.pager.open_session(sid)
    assert not eng._prefix_admit(0, reqs[1], sid)
    assert reqs[1].committed_blocks == full
    assert eng._committed_blocks == full
    eng.pager.trim(sid, close=True)
    eng._committed_blocks = 0                    # undo the manual peek


def test_preempt_restamps_full_footprint(dense_setup):
    """Regression: preemption swaps out EVERY block of the victim —
    prefix included — so a cache-hit request's discounted admission
    charge must snap back to the full footprint, or the watermark
    under-counts host demand while the request sits preempted."""
    cfg, params = dense_setup
    eng = KVRMEngine(cfg, params, EngineConfig(
        mode="paged_merge", batch=4, max_seq=128, block_tokens=8,
        near_window=64, prefix_cache=True, host_pool_blocks=64))
    reqs = _shared_reqs(cfg.vocab_size, n=2, prefix_len=64)
    eng.submit(reqs[0])
    eng.run(max_steps=400)                       # indexed, then retired
    eng.submit(reqs[1])
    eng.step()                                   # admitted via cache hit
    eng.step()                                   # first frame clears the COW
    full = eng._footprint_blocks(reqs[1])
    assert reqs[1].committed_blocks == full - 64 // eng.bt
    eng.prefix_cache.flush_for_pressure()        # hit blocks: refcount 1
    slot = next(s for s in eng.sched.active_slots()
                if eng.sched.request_at(s).rid == 1)
    eng._preempt_slot(slot)
    assert reqs[1].committed_blocks == full      # charge snapped back
    assert eng._committed_blocks == full
    eng.run(max_steps=800)                       # resume + finish cleanly
    assert len(eng.sched.finished) == 2
    assert eng._committed_blocks == 0


def test_cache_eviction_relieves_pool_pressure(dense_setup):
    """Without a host tier, a full pool must be relieved by evicting
    unpinned cache leaves (not by MemoryError): retired prompts pin cache
    budget, new prompts need blocks."""
    cfg, params = dense_setup
    rng = np.random.default_rng(11)
    eng = KVRMEngine(cfg, params, EngineConfig(
        mode="paged_merge", batch=2, max_seq=64, block_tokens=8,
        near_window=32, span_blocks=1, pool_budget_frac=0.35,
        prefix_cache=True, prefix_cache_blocks=64))
    for i in range(6):                           # distinct prompts: all miss
        eng.submit(Request(
            rid=i, prompt=rng.integers(0, cfg.vocab_size, size=24)
            .astype(np.int32), gen_len=10))
    eng.run(max_steps=1500)                      # no MemoryError
    assert len(eng.sched.finished) == 6
    assert eng.audit()["prefix_evicted_blocks"] >= 1
    eng.pager.check_invariants()
    eng.prefix_cache.check_invariants()


def test_indexed_prompts_are_never_swap_candidates(dense_setup):
    """Host-tier interplay: a session whose prompt is indexed shares its
    blocks with the cache (refcount 2) — cold swap must skip them and the
    session must be preempt-ineligible until the cache lets go."""
    cfg, params = dense_setup
    rng = np.random.default_rng(5)
    eng = KVRMEngine(cfg, params, EngineConfig(
        mode="paged_merge", batch=2, max_seq=64, block_tokens=8,
        near_window=16, span_blocks=1, prefix_cache=True,
        host_pool_blocks=16))
    eng.submit(Request(rid=0, prompt=rng.integers(0, cfg.vocab_size, size=24)
                       .astype(np.int32), gen_len=30))
    for _ in range(46):                          # window slides past prompt
        eng.step()
    sid = int(eng._slot_sid[0])
    s = eng.pager.sessions[sid]
    assert eng.prefix_cache.blocks_cached == 3   # 24-token prompt indexed
    assert not eng.pager.swap_eligible(sid)
    fl = eng._first_window_local(s, int(eng._slot_len[0]))
    assert fl >= 3                               # prompt is below the window
    pairs = eng.pager.swap_out_cold(sid, fl)
    cached = set(eng.prefix_cache.match(
        eng.sched.requests[0].prompt[:24]).blocks)
    assert all(src not in cached for src, _ in pairs)
    assert all(b > 0 for b in s.blocks[:3])      # indexed blocks stayed put
    # once the cache flushes, the session becomes a victim again
    eng.prefix_cache.flush_for_pressure()
    assert eng.pager.swap_eligible(sid)
    eng.run(max_steps=500)
    eng.pager.check_invariants()


def test_resume_reindexes_prompt(dense_setup):
    """Preempt -> resume must RE-INDEX the resumed prompt: the preempt
    dropped it from the cache (swap eligibility required refcount 1), and
    after swap-in its device-resident blocks are committed KV again."""
    cfg, params = dense_setup
    rng = np.random.default_rng(9)
    prompt = rng.integers(0, cfg.vocab_size, size=24).astype(np.int32)
    eng = KVRMEngine(cfg, params, EngineConfig(
        mode="paged_merge", batch=2, max_seq=64, block_tokens=8,
        near_window=32, span_blocks=1, prefix_cache=True,
        host_pool_blocks=24))
    eng.submit(Request(rid=0, prompt=prompt.copy(), gen_len=24))
    for _ in range(28):                          # prompt committed + indexed
        eng.step()
    eng.flush()
    assert eng.prefix_cache.blocks_cached == 3
    # force a §8 eviction: flush the cache (making rid 0 eligible), preempt
    eng.prefix_cache.flush_for_pressure()
    assert eng.prefix_cache.match(prompt).tokens == 0
    eng._preempt_slot(0)
    assert 0 in [r.rid for r in eng.sched.preempted]
    eng.run(max_steps=800)                       # resume + finish
    assert len(eng.sched.finished) == 1
    # the resume re-indexed the (window-covered) prompt blocks
    assert eng.prefix_cache.match(prompt).tokens == 24
    # and a rematch serves a later identical prompt bitwise-identically
    eng.submit(Request(rid=1, prompt=prompt.copy(), gen_len=24))
    eng.run(max_steps=800)
    toks = {r.rid: list(r.generated) for r in eng.sched.finished}
    assert toks[1] == toks[0]
    assert eng.audit()["prefix_hits"] >= 1
    eng.pager.check_invariants()
    eng.prefix_cache.check_invariants()


def test_prefix_cache_rejects_unsupported_configs(dense_setup):
    cfg, params = dense_setup
    with pytest.raises(ValueError):
        KVRMEngine(cfg, params, EngineConfig(
            mode="full", batch=2, max_seq=128, near_window=32,
            block_tokens=8, prefix_cache=True))
    with pytest.raises(ValueError):               # no silent disable
        KVRMEngine(cfg, params, EngineConfig(
            mode="arena", batch=2, max_seq=128, near_window=32,
            block_tokens=8, prefix_cache=True))
    hyb = get_reduced("zamba2-7b")
    hparams = registry.init_params(jax.random.PRNGKey(0), hyb)
    with pytest.raises(ValueError):
        KVRMEngine(hyb, hparams, EngineConfig(
            mode="paged_merge", batch=2, max_seq=64, block_tokens=8,
            prefix_cache=True))


def test_shared_prefix_trace_family():
    tcfg = traces.TraceConfig(n_requests=40, vocab=128, seed=2,
                              shared_prefix_len=32, n_prefixes=3,
                              prompt_mean=6, gen_mean=12, window_s=10.0)
    reqs = traces.shared_prefix_workload(tcfg)
    assert len(reqs) == 40
    heads = {tuple(r.prompt[:32]) for r in reqs}
    assert len(heads) <= 3                       # at most n_prefixes tenants
    assert all(len(r.prompt) > 32 for r in reqs)
    assert all(r.prefix_of is None for r in reqs)    # sharing is implicit
    arr = [r.arrival for r in reqs]
    assert arr == sorted(arr) and arr[-1] <= 10.0
