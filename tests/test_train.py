"""Training substrate: loss decreases on structured synthetic data, grad
accumulation is consistent with full-batch, compression error feedback stays
bounded, checkpoint/restore resumes bit-exactly (fault tolerance)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import registry
from repro.training.checkpoint import CheckpointManager
from repro.training.optimizer import OptimizerConfig, init_opt_state
from repro.training.train_loop import TrainConfig, make_train_step
from repro.distributed import compression


@pytest.fixture(scope="module")
def setup():
    cfg = get_reduced("qwen2.5-32b")
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=2, total_steps=50)
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                                  global_batch=8, seed=1))
    return cfg, params, ocfg, data


def test_loss_decreases(setup):
    cfg, params, ocfg, data = setup
    step = jax.jit(make_train_step(cfg, ocfg, TrainConfig(remat=False)))
    opt = init_opt_state(params, ocfg)
    err = compression.init_error_feedback(params)
    losses = []
    for i in range(12):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, opt, err, m = step(params, opt, err, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0] - 0.1, losses
    assert np.isfinite(losses).all()


def test_grad_accum_consistent(setup):
    cfg, params, ocfg, data = setup
    b = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    opt = init_opt_state(params, ocfg)
    err = compression.init_error_feedback(params)
    s1 = jax.jit(make_train_step(cfg, ocfg, TrainConfig(remat=False, microbatches=1)))
    s4 = jax.jit(make_train_step(cfg, ocfg, TrainConfig(remat=False, microbatches=4)))
    p1, _, _, m1 = s1(params, opt, err, b)
    p4, _, _, m4 = s4(params, opt, err, b)
    # same data, same step: losses match and params stay close
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 1e-2
    d = jax.tree.map(lambda a, b_: float(jnp.max(jnp.abs(
        a.astype(jnp.float32) - b_.astype(jnp.float32)))), p1, p4)
    assert max(jax.tree.leaves(d)) < 1e-2


def test_remat_matches_no_remat(setup):
    cfg, params, ocfg, data = setup
    from repro.training.train_loop import lm_loss
    b = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    l1, _ = lm_loss(params, cfg, b["tokens"], remat=False)
    l2, _ = lm_loss(params, cfg, b["tokens"], remat=True)
    assert abs(float(l1) - float(l2)) < 1e-4


@pytest.mark.parametrize("scheme", ["bf16", "int8"])
def test_compression_error_feedback(setup, scheme):
    cfg, params, ocfg, data = setup
    step = jax.jit(make_train_step(cfg, ocfg,
                                   TrainConfig(remat=False, compression=scheme)))
    opt = init_opt_state(params, ocfg)
    err = compression.init_error_feedback(params)
    losses = []
    for i in range(10):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, opt, err, m = step(params, opt, err, b)
        losses.append(float(m["loss"]))
    assert losses[-1] < losses[0], losses
    # error feedback stays bounded (no divergence)
    enorm = max(jax.tree.leaves(jax.tree.map(
        lambda e: float(jnp.max(jnp.abs(e.astype(jnp.float32)))), err)))
    assert np.isfinite(enorm)


def test_compression_wire_bytes(setup):
    cfg, params, _, _ = setup
    g = jax.tree.map(lambda p: jnp.ones(p.shape, jnp.float32), params)
    err = compression.init_error_feedback(params)
    wire_b, _ = compression.compress_bf16(g, err)
    (wire_i, scales), _ = compression.compress_int8(g, err)
    full = compression.wire_bytes(g)
    assert compression.wire_bytes(wire_b) == full // 2
    assert compression.wire_bytes(wire_i) == full // 4


def test_checkpoint_resume_bitexact(setup, tmp_path):
    """Node-failure drill: train 6 steps w/ checkpoint at 3, kill, restore,
    replay 3..6 — final params must be bit-identical."""
    cfg, params, ocfg, data = setup
    tcfg = TrainConfig(remat=False)
    step = jax.jit(make_train_step(cfg, ocfg, tcfg))
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=True)

    opt = init_opt_state(params, ocfg)
    err = compression.init_error_feedback(params)
    p = params
    for i in range(6):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        p, opt, err, _ = step(p, opt, err, b)
        if i == 2:
            mgr.save(i + 1, {"params": p, "opt": opt, "err": err,
                             "host": {"data_step": i + 1}})
    mgr.wait()
    final_a = jax.tree.map(np.asarray, p)

    # --- simulated failure: fresh process state, restore, replay
    template = {"params": params, "opt": init_opt_state(params, ocfg),
                "err": compression.init_error_feedback(params)}
    restored = mgr.restore(template)
    assert restored["host"]["data_step"] == 3
    p2, opt2, err2 = restored["params"], restored["opt"], restored["err"]
    for i in range(restored["host"]["data_step"], 6):
        b = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        p2, opt2, err2, _ = step(p2, opt2, err2, b)
    final_b = jax.tree.map(np.asarray, p2)
    jax.tree.map(lambda a, b_: np.testing.assert_array_equal(a, b_),
                 final_a, final_b)


def test_checkpoint_retention_and_atomicity(setup, tmp_path):
    cfg, params, ocfg, _ = setup
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    for s in (1, 2, 3, 4):
        mgr.save(s, {"params": params, "host": {}})
    names = sorted(os.listdir(tmp_path))
    assert names == ["ckpt_00000003", "ckpt_00000004"]
    assert mgr.latest_step() == 4
    # torn write is invisible: a .tmp dir is never listed as a checkpoint
    os.makedirs(tmp_path / "ckpt_00000009.tmp")
    assert mgr.latest_step() == 4


def test_data_pipeline_deterministic():
    d1 = SyntheticLM(DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=7))
    d2 = SyntheticLM(DataConfig(vocab_size=100, seq_len=16, global_batch=4, seed=7))
    np.testing.assert_array_equal(d1.batch(5)["tokens"], d2.batch(5)["tokens"])
    assert not np.array_equal(d1.batch(5)["tokens"], d1.batch(6)["tokens"])
