"""Docs cannot rot: operator-reference regression tests + link check.

* Every field of the typed :class:`repro.serving.api.AuditReport` (the
  schema behind ``engine.audit()``, §14) must be documented in
  ``docs/OPERATIONS.md`` (the counter tables), and every ``serve.py``
  flag must appear there too — adding a counter or flag without
  documenting it fails CI. Diffing the dataclass needs no live engine
  run: the field list IS the audit surface.
* Every relative markdown link in the repo's ``*.md`` files must resolve
  to a real file, and a ``#fragment`` must match a heading anchor in the
  target (GitHub slugification).
"""
import re
from pathlib import Path

import jax
import pytest

REPO = Path(__file__).resolve().parent.parent
OPERATIONS = REPO / "docs" / "OPERATIONS.md"


# ---------------------------------------------------------------------------
# audit-doc regression: AuditReport schema vs docs/OPERATIONS.md
# ---------------------------------------------------------------------------

def _documented_keys(text):
    """Keys documented as `code` spans (counter tables use `key` cells)."""
    return set(re.findall(r"`([A-Za-z_][A-Za-z0-9_]*)`", text))


def test_every_audit_field_documented():
    from repro.serving.api import AuditReport
    text = OPERATIONS.read_text()
    # split composite cells like `a` / `b` too — the regex already
    # captures each span separately
    documented = _documented_keys(text)
    missing = sorted(set(AuditReport.field_names()) - documented)
    assert not missing, (
        f"AuditReport fields missing from docs/OPERATIONS.md: {missing} — "
        f"document each new counter with the invariant it witnesses")


def test_audit_report_matches_live_audit():
    """The typed schema and a live ``engine.audit()`` dict agree exactly:
    same keys (``as_dict`` is the back-compat surface), no drift."""
    import numpy as np
    from repro.configs import get_reduced
    from repro.core.engine import EngineConfig, KVRMEngine
    from repro.core.scheduler import Request
    from repro.models import registry
    from repro.serving.api import AuditReport
    cfg = get_reduced("qwen2.5-32b")
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    eng = KVRMEngine(cfg, params, EngineConfig(
        mode="paged_merge", batch=2, max_seq=32, block_tokens=8))
    eng.submit(Request(rid=0, prompt=np.arange(4, dtype=np.int32),
                       gen_len=4))
    eng.run(max_steps=64)
    rep = eng.audit_report()
    assert isinstance(rep, AuditReport)
    assert list(eng.audit()) == list(AuditReport.field_names())
    assert eng.audit() == rep.as_dict()


def test_every_serve_flag_documented():
    from repro.launch.serve import build_arg_parser
    text = OPERATIONS.read_text()
    flags = [opt for a in build_arg_parser()._actions
             for opt in a.option_strings if opt.startswith("--")]
    assert flags, "serve parser exposes no flags?"
    missing = sorted(f for f in flags if f != "--help" and f not in text)
    assert not missing, (
        f"serve.py flags missing from docs/OPERATIONS.md: {missing}")


# ---------------------------------------------------------------------------
# benchmark hygiene: every bench engine goes through the serving factory
# ---------------------------------------------------------------------------

def test_no_benchmark_constructs_engine_directly():
    """Benchmarks must build engines via ``serving.build`` (through
    ``benchmarks.common.engine``), never hand-roll ``KVRMEngine(...)`` /
    ``EngineConfig(...)`` — the factory is where params caching, lane
    wiring and flag defaults live (§14), and a hand-rolled engine
    silently diverges from what ``serve.py`` actually runs. common.py may
    IMPORT the class for type annotations; nothing may instantiate it."""
    errors = []
    for py in sorted((REPO / "benchmarks").glob("*.py")):
        text = py.read_text()
        for pat in (r"\bKVRMEngine\s*\(", r"\bEngineConfig\s*\("):
            for m in re.finditer(pat, text):
                line = text.count("\n", 0, m.start()) + 1
                errors.append(f"{py.relative_to(REPO)}:{line}: "
                              f"direct {m.group(0).rstrip('(').strip()}() "
                              f"construction — use benchmarks.common.engine")
        if py.name != "common.py" and "core.engine" in text:
            errors.append(f"{py.relative_to(REPO)}: imports repro.core."
                          f"engine — route through benchmarks.common")
    assert not errors, "\n".join(errors)


# ---------------------------------------------------------------------------
# markdown link check: relative links resolve, fragments match headings
# ---------------------------------------------------------------------------

_LINK = re.compile(r"(?<!!)\[[^\]]*\]\(([^)\s]+)\)")
_HEADING = re.compile(r"^#{1,6}\s+(.*?)\s*#*\s*$", re.M)
_CODE_FENCE = re.compile(r"```.*?```", re.S)


def _slugify(heading: str) -> str:
    """GitHub-style anchor: lowercase, drop punctuation, spaces -> '-'."""
    h = heading.strip().lower()
    h = re.sub(r"[^\w\- ]", "", h, flags=re.UNICODE)
    return h.replace(" ", "-")


def _anchors(md: Path) -> set:
    text = _CODE_FENCE.sub("", md.read_text())
    return {_slugify(m) for m in _HEADING.findall(text)}


def _md_files():
    skip = {".git", "__pycache__", ".pytest_cache", ".hypothesis"}
    return [p for p in REPO.rglob("*.md")
            if not (set(p.relative_to(REPO).parts[:-1]) & skip)]


def test_markdown_relative_links_resolve():
    errors = []
    for md in _md_files():
        text = _CODE_FENCE.sub("", md.read_text())
        for target in _LINK.findall(text):
            if re.match(r"^[a-z][a-z0-9+.-]*:", target):   # URL scheme
                continue
            path_part, _, frag = target.partition("#")
            dest = md if not path_part else (md.parent / path_part).resolve()
            if path_part and not dest.exists():
                errors.append(f"{md.relative_to(REPO)}: broken link "
                              f"-> {target}")
                continue
            if frag and dest.suffix == ".md" and dest.exists():
                if frag.lower() not in _anchors(dest):
                    errors.append(f"{md.relative_to(REPO)}: bad anchor "
                                  f"-> {target}")
    assert not errors, "\n".join(errors)


def test_link_checker_catches_breakage(tmp_path):
    """The checker itself must flag a broken link (fail-closed sanity)."""
    bad = "[x](does-not-exist-9f3.md) and [y](OPERATIONS.md#no-such-anchor)"
    text = _CODE_FENCE.sub("", bad)
    found = _LINK.findall(text)
    assert found == ["does-not-exist-9f3.md", "OPERATIONS.md#no-such-anchor"]
    assert "no-such-anchor" not in _anchors(OPERATIONS)
