"""Async movement engine (DESIGN.md §11): deferred swap-out readback
fences, the in-flight-out pager residency state, double-buffered staging
reuse, and the headline A/B guarantee — overlap changes WHEN transfers
run, never WHAT lands before a consuming dispatch, so tokens and every
transport accounting figure are identical with the engine on or off, at
both pipeline depths. Plus the launch/xla_flags profile module."""
import os

import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.engine import EngineConfig, KVRMEngine
from repro.core.pager import (RES_DEVICE, RES_HOST, RES_IN_FLIGHT_OUT,
                              BlockPager, SwapError)
from repro.core.scheduler import Request
from repro.core.transport import MergeStagedTransport
from repro.launch import xla_flags
from repro.models import registry


# ---------------------------------------------------------------------------
# transport: per-transfer fence table
# ---------------------------------------------------------------------------

def _transport():
    return MergeStagedTransport(block_bytes=1024,
                                merge_threshold_bytes=8192,
                                max_hold_steps=2, max_trains=8)


def test_fence_table_drains_fifo():
    t = _transport()
    fids = [t.fence_issue({"n": i}) for i in range(3)]
    assert len(set(fids)) == 3
    assert t.fences_pending() == 3
    drained = t.fence_drain_all()
    # FIFO: a host slot reused between two transfers must take the LATER
    # transfer's bytes, so drain order reproduces the sync schedule
    assert [p["n"] for p in drained] == [0, 1, 2]
    assert t.fences_pending() == 0
    assert t.stats.deferred_readbacks == 3
    assert t.fence_drain_all() == []
    assert t.stats.deferred_readbacks == 3


def test_overlap_counted_only_while_fences_pend():
    t = _transport()
    t.note_dispatch_overlap()
    assert t.stats.overlap_steps == 0
    t.fence_issue({})
    t.note_dispatch_overlap()
    t.note_dispatch_overlap()
    assert t.stats.overlap_steps == 2
    t.fence_drain_all()
    t.note_dispatch_overlap()
    assert t.stats.overlap_steps == 2


def test_staging_reuse_accounting():
    t = _transport()
    t.account_staging_reuse(4096)
    t.account_staging_reuse(4096)
    assert t.stats.staging_reuse_bytes == 8192


# ---------------------------------------------------------------------------
# pager: in-flight-out residency state
# ---------------------------------------------------------------------------

def _paged(host=16, blocks=64):
    return BlockPager(blocks, 16, bytes_per_block=1024, span_blocks=1,
                      host_pool_blocks=host)


def _fill(p, sid, tokens=64):
    p.open_session(sid)
    p.reserve(sid, tokens)
    for _ in range(tokens):
        p.append_token(sid)


def test_deferred_swap_out_commits_to_host():
    p = _paged()
    _fill(p, 0)
    pairs = p.swap_out_session(0, deferred=True)
    s = p.sessions[0]
    assert s.swap_state == RES_IN_FLIGHT_OUT
    assert pairs and all(b < 0 for b in s.blocks)   # host entries assigned
    p.check_invariants()                 # in-flight-out holds no device blocks
    # the gather has not synchronized: resuming now would read garbage
    with pytest.raises(SwapError):
        p.swap_in_begin(0, 0)
    p.swap_out_commit(0)
    assert s.swap_state == RES_HOST
    p.swap_in_begin(0, 0)
    p.swap_in_commit(0)
    assert s.swap_state == RES_DEVICE
    p.check_invariants()


def test_commit_guards_state_and_tolerates_vanished_session():
    p = _paged()
    _fill(p, 0)
    with pytest.raises(SwapError):
        p.swap_out_commit(0)             # device-resident: nothing in flight
    p.swap_out_commit(99)                # unknown sid: no-op (retired while
    #                                      a cold fence was pending)
    pairs = p.swap_out_session(0, deferred=False)
    assert pairs and p.sessions[0].swap_state == RES_HOST
    with pytest.raises(SwapError):
        p.swap_out_commit(0)             # not deferred: nothing to commit


# ---------------------------------------------------------------------------
# engine: A/B identity + overlap witnesses
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_reduced("qwen2.5-32b")
    params = registry.init_params(jax.random.PRNGKey(7), cfg)
    return cfg, params


def _run_coalescing_workload(eng, vocab):
    """Two-phase workload that coalesces all three transport group kinds:
    rid 0 runs alone first so its 16-token prompt is committed and
    radix-indexed; then a lockstep burst where rids 1-2 re-use that prompt
    (an identical-prompt rematch aliases 15 tokens = one full block hit +
    a 7-token tail materialized by a real COW copy) while uniform lengths
    force preemption + swap under the tight pool."""
    rng = np.random.default_rng(5)
    shared = rng.integers(0, vocab, size=16).astype(np.int32)
    eng.submit(Request(rid=0, prompt=shared.copy(), gen_len=10))
    eng.run(max_steps=500)
    for i in range(1, 6):
        p = shared.copy() if i <= 2 else \
            rng.integers(0, vocab, size=16).astype(np.int32)
        eng.submit(Request(rid=i, prompt=p, gen_len=40))
    eng.run(max_steps=3000)


# the accounting surface that must be blind to WHEN transfers run
_INVARIANT_KEYS = (
    "preemptions", "swap_groups", "swap_bytes", "swap_out_bytes",
    "swap_in_bytes", "swap_out_blocks", "swap_in_blocks",
    "avg_swap_group_blocks", "cow_groups", "cow_bytes", "cow_copies",
    "dma_groups_per_step", "unmerged_groups_per_step", "train_overflows",
    "quant_bytes_saved", "quant_scale_bytes", "frames_committed",
    "host_blocks_peak", "prefix_hits", "prefix_tokens_reused",
)


@pytest.mark.parametrize("depth", [0, 1])
def test_async_ab_identical_tokens_and_accounting(dense_setup, depth):
    """Same oversubscribed shared-prefix quantized workload, async ON vs
    OFF: bitwise-identical tokens, identical transport/pager accounting,
    and the overlap witnesses move only on the ON side."""
    cfg, params = dense_setup
    runs = {}
    for async_on in (False, True):
        eng = KVRMEngine(cfg, params, EngineConfig(
            mode="paged_merge", batch=4, max_seq=64, block_tokens=8,
            near_window=32, pipeline_depth=depth, pool_budget_frac=0.25,
            host_pool_blocks=40, prefix_cache=True, kv_dtype="fp8_e4m3",
            async_movement=async_on))
        _run_coalescing_workload(eng, cfg.vocab_size)
        toks = {r.rid: list(r.generated) for r in eng.sched.finished}
        assert len(toks) == 6
        eng.pager.check_invariants()
        assert eng.pager.host_used == 0
        runs[async_on] = (toks, eng.audit())
    (t_off, a_off), (t_on, a_on) = runs[False], runs[True]
    # the workload actually coalesced all three group kinds + preempted
    assert a_on["swap_out_blocks"] >= 1 and a_on["swap_in_blocks"] >= 1
    assert a_on["cow_copies"] >= 1 and a_on["prefix_hits"] >= 1
    assert a_on["quant_bytes_saved"] > 0
    assert a_on["preemptions"] >= 1
    # headline: overlap changed nothing observable
    assert t_on == t_off
    for key in _INVARIANT_KEYS:
        assert a_on[key] == a_off[key], key
    # witnesses: deferred path actually ran, and only there
    assert a_on["deferred_readbacks"] >= 1
    assert a_on["overlap_steps"] >= 1
    assert a_on["staging_reuse_bytes"] > 0      # >= 2 swap-in transfers
    assert a_off["deferred_readbacks"] == a_off["overlap_steps"] \
        == a_off["staging_reuse_bytes"] == 0
    assert a_off["swap_stall_ms"] > 0


def test_async_matches_seed_sync_tokens(dense_setup):
    """Cross-depth cross-flag: the async pipelined engine emits the same
    tokens as the seed-exact sync engine with async off."""
    cfg, params = dense_setup
    toks = []
    for depth, async_on in ((0, False), (1, True)):
        eng = KVRMEngine(cfg, params, EngineConfig(
            mode="paged_merge", batch=4, max_seq=64, block_tokens=8,
            near_window=32, pipeline_depth=depth, pool_budget_frac=0.1,
            host_pool_blocks=40, async_movement=async_on))
        rng = np.random.default_rng(1)
        for i in range(6):
            eng.submit(Request(rid=i, prompt=rng.integers(
                0, cfg.vocab_size, size=8).astype(np.int32), gen_len=48))
        eng.run(max_steps=3000)
        assert eng.audit()["preemptions"] >= 1
        toks.append({r.rid: list(r.generated) for r in eng.sched.finished})
    assert toks[0] == toks[1]


# ---------------------------------------------------------------------------
# launch/xla_flags: profile module
# ---------------------------------------------------------------------------

def test_profiles_and_flag_lists():
    assert "default" in xla_flags.profile_names()
    assert "latency_hiding" in xla_flags.profile_names()
    flags = xla_flags.profile_flags("latency_hiding")
    assert any("latency_hiding_scheduler" in f for f in flags)
    assert xla_flags.profile_flags("default") == []


def test_apply_profile_appends_and_records(monkeypatch):
    monkeypatch.setenv("XLA_FLAGS",
                       "--xla_gpu_enable_latency_hiding_scheduler=false")
    monkeypatch.delenv("REPRO_XLA_PROFILE", raising=False)
    monkeypatch.delenv("TF_CPP_MIN_LOG_LEVEL", raising=False)
    info = xla_flags.apply_profile("latency_hiding")
    env = os.environ["XLA_FLAGS"]
    # user's flag survives (appended-only, already-present names skipped)
    assert env.startswith("--xla_gpu_enable_latency_hiding_scheduler=false")
    assert env.count("latency_hiding_scheduler") == 1
    assert "--xla_gpu_enable_pipelined_all_gather=true" in env
    assert os.environ["TF_CPP_MIN_LOG_LEVEL"] == "4"
    assert xla_flags.active_profile() == "latency_hiding"
    assert info["late"] is True          # jax imported by this test module
    # reapplying is idempotent on XLA_FLAGS
    xla_flags.apply_profile("latency_hiding")
    assert os.environ["XLA_FLAGS"] == env


def test_shell_exports_cover_process_external_setup():
    sh = xla_flags.shell_exports("latency_hiding")
    assert "LD_PRELOAD" in sh and "tcmalloc" in sh
    assert "XLA_FLAGS" in sh
    assert "REPRO_XLA_PROFILE=latency_hiding" in sh
