"""Fault tolerance: heartbeat failure detection, straggler hedging policy,
elastic resize planning, and an end-to-end failure drill (engine checkpoint
-> kill -> restore -> identical continuation)."""
import numpy as np
import jax

from repro.distributed.fault import (ElasticPlan, HeartbeatMonitor,
                                     StragglerMitigator, plan_resize)


def test_heartbeat_detects_failure_once():
    failed = []
    mon = HeartbeatMonitor(["w0", "w1", "w2"], timeout=5.0,
                           on_failure=failed.append)
    for t in range(4):
        for w in ("w0", "w1", "w2"):
            mon.beat(w, float(t))
    # w1 goes silent
    for t in range(4, 12):
        mon.beat("w0", float(t))
        mon.beat("w2", float(t))
        mon.check(float(t))
    assert failed == ["w1"]
    assert set(mon.alive()) == {"w0", "w2"}
    # rejoin
    mon.beat("w1", 20.0)
    assert "w1" in mon.alive()


def test_straggler_flags_outliers_only():
    m = StragglerMitigator(threshold=3.0)
    flagged = [m.observe(i, 0.01 + 0.001 * (i % 3)) for i in range(20)]
    assert not any(flagged)
    assert m.observe(20, 0.5) is True          # 50x spike -> hedge
    assert m.observe(21, 0.01) is False        # baseline not poisoned
    assert m.hedged_steps == [20]


def test_elastic_shrink_moves_only_orphans():
    sessions = {0: 0, 1: 1, 2: 2, 3: 3, 4: 0, 5: 3}
    plan = plan_resize(sessions, old_groups=4, new_groups=3)
    moved = {s for s, _, _ in plan.session_moves}
    assert moved == {3, 5}                      # only group-3 sessions move
    assert all(tgt < 3 for _, _, tgt in plan.session_moves)
    assert plan.pool_reshard


def test_elastic_grow_is_noop_for_sessions():
    sessions = {0: 0, 1: 1}
    plan = plan_resize(sessions, old_groups=2, new_groups=4)
    assert plan.moved_sessions == 0
    assert plan.pool_reshard


def test_engine_failure_drill():
    """Serving failure drill: engine state (pager + scheduler + pools) is
    checkpointed; a fresh engine restores and continues to the same tokens."""
    from repro.configs import get_reduced
    from repro.core.engine import EngineConfig, KVRMEngine
    from repro.core.scheduler import Request
    from repro.models import registry

    cfg = get_reduced("qwen2.5-32b")
    params = registry.init_params(jax.random.PRNGKey(3), cfg)
    ecfg = EngineConfig(mode="paged_merge", batch=2, max_seq=64, block_tokens=8)

    def mk():
        e = KVRMEngine(cfg, params, ecfg)
        rng = np.random.default_rng(0)
        for i in range(2):
            e.submit(Request(rid=i, prompt=rng.integers(0, 100, 6).astype(np.int32),
                             gen_len=10))
        return e

    ref = mk()
    ref.run(max_steps=100)
    want = {r.rid: r.generated for r in ref.sched.finished}

    # run half, snapshot host+device state, 'crash', restore into new engine
    eng = mk()
    for _ in range(8):
        eng.step()
    # quiesce the dispatch pipeline first: a consistent checkpoint requires
    # reading back in-flight steps (DESIGN.md §3); the restored engine then
    # re-seeds its device-side token feedback from _last_token
    eng.flush()
    snap_pools = jax.tree.map(np.asarray, eng.pools)
    import copy
    snap_host = copy.deepcopy((eng.pager, eng.sched, eng._slot_len,
                               eng._slot_sid, eng._last_token))
    del eng

    eng2 = KVRMEngine(cfg, params, ecfg)
    eng2.pools = jax.tree.map(lambda a: jax.numpy.asarray(a), snap_pools)
    eng2.pager, eng2.sched, eng2._slot_len, eng2._slot_sid, eng2._last_token = \
        copy.deepcopy(snap_host)
    eng2.run(max_steps=100)
    got = {r.rid: r.generated for r in eng2.sched.finished}
    assert got == want
