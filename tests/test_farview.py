"""Far-view policy: EMA utility scoring, cap selection, slot recycling."""
import numpy as np

from repro.core.farview import FarViewPolicy


def _policy(cap=4, max_chunks=16):
    return FarViewPolicy(batch=2, max_chunks=max_chunks, cap=cap,
                         sv_chunk=32, block_tokens=8)


def test_select_before_any_chunks():
    p = _policy()
    tbl, val = p.select(0)
    assert val.sum() == 0


def test_select_under_cap_keeps_all():
    p = _policy(cap=4)
    for _ in range(3):
        p.on_chunk_summarized(0)
    tbl, val = p.select(0)
    assert val.sum() == 3
    assert list(tbl[:3]) == [0, 1, 2]


def test_ema_drives_selection_over_cap():
    p = _policy(cap=2, max_chunks=8)
    for _ in range(6):
        p.on_chunk_summarized(0)
    # feed utility: chunk 1 and 4 are hot
    ftab = np.array([[1, 4], [0, 0]], np.int32)
    futil = np.array([[0.9, 0.8], [0, 0]], np.float32)
    for _ in range(5):
        p.observe_utility(futil, ftab)
    tbl, val = p.select(0)
    assert val.sum() == 2
    assert set(tbl.tolist()) == {1, 4}


def test_budget_exhaustion_recycles_lowest_utility():
    p = _policy(cap=2, max_chunks=3)
    idxs = [p.on_chunk_summarized(0) for _ in range(3)]
    assert idxs == [0, 1, 2]
    ftab = np.array([[0, 2], [0, 0]], np.int32)
    futil = np.array([[0.5, 0.9], [0, 0]], np.float32)
    p.observe_utility(futil, ftab)
    nxt = p.on_chunk_summarized(0)     # recycle argmin EMA -> chunk 1
    assert nxt == 1


def test_reset_slot_clears_state():
    p = _policy()
    p.on_chunk_summarized(1)
    p.reset_slot(1)
    assert p.n_chunks[1] == 0
    _, val = p.select(1)
    assert val.sum() == 0
