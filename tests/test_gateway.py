"""Serving-gateway semantics (DESIGN.md §14).

* cancel-mid-decode frees every pager block (zero leaks — the PR 8
  reconciliation invariant, ``pager.check_invariants`` + closed sessions);
* backpressure rejects carry the right typed reason (queue_full vs
  slo_shed, extending the §8 admit_blocked_* taxonomy);
* the affinity router sends a warm-prefix request to the lane holding the
  cached prefix even when another lane is less loaded;
* gateway-vs-replay token streams are bitwise-identical for the same
  requests at pipeline depths 0 and 1 (the gateway changes WHEN work is
  scheduled, never WHAT tokens a request produces).
"""
import asyncio

import numpy as np
import pytest

from repro import serving
from repro.data import traces
from repro.launch.serve import run_lanes
from repro.serving.admission import AdmissionController
from repro.serving.factory import build
from repro.serving.router import AffinityRouter


def _greq(rid, prompt, gen_len, *, tenant="t0", slo=serving.STANDARD,
          arrival=None):
    return serving.GenerationRequest(
        rid=rid, prompt=tuple(int(t) for t in prompt), gen_len=gen_len,
        tenant=tenant, slo=slo, arrival=arrival)


def _rand_prompt(rng, n=6):
    return rng.integers(0, 100, size=n)


def _assert_no_leaks(eng):
    eng.pager.check_invariants()
    assert not eng.pager.sessions, "cancel leaked an open pager session"


# ---------------------------------------------------------------------------
# cancel
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("depth", [0, 1])
def test_cancel_mid_decode_frees_all_blocks(depth):
    rng = np.random.default_rng(0)
    engines = build("qwen2.5-32b", mode="paged_merge", batch=2, max_seq=64,
                    block_tokens=8, lanes=1, pipeline_depth=depth)
    gw = serving.Gateway(engines)

    async def main():
        # rid 0/1 fill both slots; rid 2's far-future arrival keeps it in
        # the GATEWAY queue (pump releases only arrived requests)
        streams = [gw.submit(_greq(0, _rand_prompt(rng), 40)),
                   gw.submit(_greq(1, _rand_prompt(rng), 40)),
                   gw.submit(_greq(2, _rand_prompt(rng), 40, arrival=1e9))]
        ev0 = await streams[0].__anext__()
        assert not ev0.finished and ev0.index == 0
        assert gw.cancel(0)                 # mid-decode, blocks held
        assert gw.cancel(2)                 # still gateway-queued
        assert not gw.cancel(0)             # double-cancel refused
        tails = []
        for s in streams:
            tails.append([ev async for ev in s])
        await gw.drain()
        gw.close()
        return tails

    t0, t1, t2 = asyncio.run(main())
    assert t0[-1].finished and t0[-1].finish_reason == "cancelled"
    assert t1[-1].finished and t1[-1].finish_reason == "budget"
    assert len([e for e in t1 if e.token >= 0]) == 40
    assert len(t2) == 1 and t2[0].finish_reason == "cancelled"
    assert gw.result(0).finish_reason == "cancelled"
    assert gw.result(2).tokens == ()
    eng = engines[0]
    _assert_no_leaks(eng)                   # zero-leak: PR 8 invariant
    assert eng.audit()["cancelled"] == 1    # rid 2 never reached the engine
    assert gw.audit()["cancelled"] == 2


def test_cancel_preempted_request_frees_host_blocks():
    # oversubscribed single lane (§8): force a preemption, then cancel the
    # host-resident request — trim(close=True) must free its host slots
    rng = np.random.default_rng(1)
    engines = build("qwen2.5-32b", mode="paged_merge", batch=4, max_seq=64,
                    block_tokens=8, near_window=32, lanes=1,
                    pool_budget_frac=0.1, host_pool_blocks=40)
    eng = engines[0]
    from repro.core.scheduler import Request
    for i in range(6):
        eng.submit(Request(rid=i, prompt=rng.integers(0, 100, size=8)
                           .astype(np.int32), gen_len=48))
    for _ in range(3000):
        eng.step()
        if eng.sched.preempted:
            break
    assert eng.sched.preempted, "workload never triggered a preemption"
    victim = eng.sched.preempted[0].rid
    assert eng.cancel(victim)
    eng.run(max_steps=3000)
    assert len(eng.sched.finished) == 6
    _assert_no_leaks(eng)
    assert eng.pager.host_used == 0
    assert eng.audit()["cancelled"] == 1


# ---------------------------------------------------------------------------
# typed backpressure
# ---------------------------------------------------------------------------

def test_backpressure_reasons():
    rng = np.random.default_rng(1)
    engines = build("qwen2.5-32b", mode="paged_merge", batch=2, max_seq=64,
                    block_tokens=8, lanes=1)
    adm = AdmissionController(tenant_queue_max=2, max_outstanding=100)
    gw = serving.Gateway(engines, admission=adm)

    async def main():
        # tenant bound: submits back-to-back (no await -> pump never runs),
        # so two gateway-queued for t-greedy means the third must reject
        for i in range(2):
            gw.submit(_greq(i, _rand_prompt(rng), 4, tenant="t-greedy"))
        with pytest.raises(serving.AdmissionRejected) as ei:
            gw.submit(_greq(9, _rand_prompt(rng), 4, tenant="t-greedy"))
        assert ei.value.reason == serving.REJECT_QUEUE_FULL
        # slo shed: interactive depth bound is max_queue_depth * lanes
        cap = serving.INTERACTIVE.max_queue_depth
        for i in range(cap):
            gw.submit(_greq(100 + i, _rand_prompt(rng), 4,
                            tenant=f"u{i}", slo=serving.INTERACTIVE))
        with pytest.raises(serving.AdmissionRejected) as ei:
            gw.submit(_greq(200, _rand_prompt(rng), 4, tenant="u-late",
                            slo=serving.INTERACTIVE))
        assert ei.value.reason == serving.REJECT_SLO_SHED
        await gw.drain()
        gw.close()

    asyncio.run(main())
    st = gw.audit()
    assert st["rejected_per_class"]["standard"] == 1
    assert st["shed_per_class"]["interactive"] == 1
    assert st["admitted"] == 2 + serving.INTERACTIVE.max_queue_depth
    _assert_no_leaks(engines[0])


# ---------------------------------------------------------------------------
# affinity routing
# ---------------------------------------------------------------------------

def test_affinity_router_prefers_warm_lane():
    rng = np.random.default_rng(2)
    engines = build("qwen2.5-32b", mode="paged_merge", batch=2, max_seq=64,
                    block_tokens=8, lanes=2, prefix_cache=True)
    from repro.core.scheduler import Request
    pfx = rng.integers(0, 100, size=24)
    # warm lane 0's radix index with the shared prefix (closed loop)
    engines[0].submit(Request(rid=0, prompt=pfx.astype(np.int32), gen_len=3))
    engines[0].run(max_steps=100)
    assert engines[0].prefix_cache.match(pfx.astype(np.int32)).tokens >= 8

    router = AffinityRouter()
    warm = _greq(1, np.concatenate([pfx, _rand_prompt(rng)]), 4)
    cold = _greq(2, _rand_prompt(rng, 24), 4)
    # lane 0 is warm but MORE loaded — affinity must still pick it ...
    assert router.route(warm, engines, [5, 0]) == 0
    assert router.affinity_hits == 1
    # ... while a cold prompt falls back to least-loaded (lane 1)
    assert router.route(cold, engines, [5, 0]) == 1
    assert router.affinity_misses == 1


# ---------------------------------------------------------------------------
# gateway-vs-replay bitwise identity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("depth", [0, 1])
def test_gateway_token_streams_match_replay(depth):
    tcfg = traces.TraceConfig(n_requests=6, vocab=100, token_scale=0.1,
                              seed=11)
    kw = dict(mode="paged_merge", batch=2, max_seq=64, block_tokens=8,
              pipeline_depth=depth)

    reqs = traces.mixed_length_workload(tcfg)
    engines = build("qwen2.5-32b", lanes=2, **kw)
    out = run_lanes(engines, reqs, max_steps=5000)
    assert out["finished"] == len(reqs)
    replay = {r.rid: list(r.generated)
              for e in engines for r in e.sched.finished}

    greqs = [_greq(r.rid, r.prompt, r.gen_len, tenant=f"t{i % 3}")
             for i, r in enumerate(traces.mixed_length_workload(tcfg))]
    gw = build("qwen2.5-32b", lanes=2, gateway=True, **kw)

    async def main():
        res = await asyncio.gather(*[gw.generate(g) for g in greqs])
        await gw.drain()
        gw.close()
        return res

    results = asyncio.run(main())
    got = {r.rid: list(r.tokens) for r in results}
    assert got == replay, "gateway re-scheduled WHAT, not just WHEN"
    for eng in gw.engines:
        _assert_no_leaks(eng)
