"""Legacy-mode regression pin (DESIGN.md §13): greedy=True + budget-EOS is
the exact PR 7 decode path — the sampling tentpole must not move a single
bit of it. The seeded mixed-length trace's token digest and pager counters
were captured on the pristine pre-sampling tree; this test replays the
trace at depths 0 and 1 and pins both against that baseline.

The digest covers every generated token of every request (sha256 over the
sorted rid->tokens JSON), so any drift in argmax decode, descriptor
layout, dispatch bookkeeping, or retirement order fails loudly. The token
digest is a function of jax's PRNG + reduced-model numerics, which are
version-stable in practice but not contractually; if a jax upgrade ever
moves it, the within-run depth-0 == depth-1 assertions still hold the
actual §13 contract (legacy pipelining is bitwise transparent) and the
pinned constants should be re-captured.
"""
import hashlib
import json

import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.engine import EngineConfig, KVRMEngine
from repro.core.scheduler import Request
from repro.models import registry

# captured on the PR 7 tree (pre-sampling), qwen2.5-32b reduced,
# params = init_params(PRNGKey(7)), prompts from default_rng(1)
GOLDEN_DIGEST = \
    "fb8c0f9acb339f55b44e7f4a6cc0ee09e97282a9dbd0e4c4e0ad66ca898a0812"
GOLDEN_STEPS_RUN = 40
GOLDEN_PAGER_STATS = {"alias_ops": 0, "blocks_allocated": 40,
                      "blocks_freed": 40, "frames": 9, "reserve_ops": 10,
                      "swap_in_blocks": 0, "swap_out_blocks": 0,
                      "swap_refusals": 0, "trim_ops": 9}

LENS = [(5, 6), (17, 4), (3, 8), (33, 5), (9, 7), (21, 3),
        (4, 5), (6, 5), (8, 5)]


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_reduced("qwen2.5-32b")
    params = registry.init_params(jax.random.PRNGKey(7), cfg)
    return cfg, params


def _reqs(vocab):
    rng = np.random.default_rng(1)
    return [Request(rid=i, prompt=rng.integers(0, vocab, size=p)
                    .astype(np.int32), gen_len=g)
            for i, (p, g) in enumerate(LENS)]


def _run(cfg, params, depth, **kw):
    eng = KVRMEngine(cfg, params, EngineConfig(
        mode="paged_merge", batch=4, max_seq=64, block_tokens=8,
        pipeline_depth=depth, **kw))
    for r in _reqs(cfg.vocab_size):
        eng.submit(r)
    eng.run(max_steps=500)
    toks = {r.rid: list(map(int, r.generated)) for r in eng.sched.finished}
    digest = hashlib.sha256(
        json.dumps(toks, sort_keys=True).encode()).hexdigest()
    return eng, toks, digest


def test_legacy_greedy_pinned_to_pr7_baseline(dense_setup):
    cfg, params = dense_setup
    runs = {d: _run(cfg, params, d) for d in (0, 1)}
    # the §13 contract proper: depth is bitwise transparent in legacy mode
    assert runs[1][1] == runs[0][1]
    for d, (eng, toks, digest) in runs.items():
        assert len(toks) == len(LENS)
        assert digest == GOLDEN_DIGEST, \
            f"legacy token stream drifted at depth {d}: {digest}"
        assert eng.steps_run == GOLDEN_STEPS_RUN
        got = {k: eng.pager.stats[k] for k in GOLDEN_PAGER_STATS}
        assert got == GOLDEN_PAGER_STATS, f"depth {d}"
        a = eng.audit()
        # legacy runs never touch the sampled-retirement counters
        assert a["greedy"] is True
        assert a["eos_detected"] == 0
        assert a["eos_overshoot_tokens"] == 0
        assert a["eos_reconciled_blocks"] == 0
        assert a["single_commit_per_step"]
        assert a["compilations"] in (-1, 1)
        eng.pager.check_invariants()
        assert eng.pager.reserved_blocks() == 0


def test_round_based_baseline_pinned_to_same_golden_stream(dense_setup):
    """--no-continuous-batching (DESIGN.md §15) moves WHEN the queued tail
    of this 9-request trace runs (slots drain round-by-round, so more
    engine steps), but per-rid token streams are schedule-invariant: the
    round-based baseline must reproduce the exact golden digest at depths
    0 and 1, with the barrier's cost audited and the continuous witnesses
    identically zero."""
    cfg, params = dense_setup
    for depth in (0, 1):
        eng, toks, digest = _run(cfg, params, depth,
                                 continuous_batching=False)
        assert len(toks) == len(LENS)
        assert digest == GOLDEN_DIGEST, \
            f"round-based stream drifted at depth {depth}: {digest}"
        a = eng.audit()
        assert a["continuous_batching"] is False
        assert a["continuous_admits"] == 0
        assert a["slot_idle_steps_saved"] == 0
        # 9 requests on 4 slots: the round barrier held someone back
        assert a["admit_blocked_round_barrier"] > 0
        assert eng.steps_run > GOLDEN_STEPS_RUN
        eng.pager.check_invariants()
        assert eng.pager.reserved_blocks() == 0
