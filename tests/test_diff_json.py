"""benchmarks/diff_json verdict split (CI gate): correctness fields
(token_divergence / alloc_failures) hard-fail with a nonzero exit, perf
metrics stay warn-only."""
import json

from benchmarks.diff_json import correctness_failures, diff, main


def _artifact(**rows):
    return {"benches": {"oversubscribe": rows}, "audits": {}, "failed": []}


def _write(tmp_path, name, payload):
    p = tmp_path / name
    p.write_text(json.dumps(payload))
    return str(p)


CLEAN = {"tok_s": 100.0, "token_divergence": 0, "alloc_failures": 0}


def test_injected_token_divergence_exits_nonzero(tmp_path):
    new = _write(tmp_path, "new.json",
                 _artifact(row={**CLEAN, "token_divergence": 2}))
    assert main(["--new", new]) != 0


def test_injected_alloc_failure_exits_nonzero(tmp_path):
    old = _write(tmp_path, "old.json", _artifact(row=CLEAN))
    new = _write(tmp_path, "new.json",
                 _artifact(row={**CLEAN, "alloc_failures": 1}))
    assert main(["--old", old, "--new", new]) != 0


def test_clean_artifact_exits_zero(tmp_path):
    old = _write(tmp_path, "old.json", _artifact(row=CLEAN))
    new = _write(tmp_path, "new.json", _artifact(row=CLEAN))
    assert main(["--old", old, "--new", new]) == 0
    assert main(["--new", new]) == 0          # gate runs without --old too


def test_perf_regression_stays_warn_only(tmp_path):
    """A 50% tok_s drop is a WARNING, never a failure (CPU CI noise)."""
    old = _artifact(row={**CLEAN, "tok_s": 200.0})
    new = _artifact(row=CLEAN)
    warnings, gate_errors = diff(old, new)
    assert any("tok_s" in w for w in warnings)
    assert gate_errors == []
    po = _write(tmp_path, "old.json", old)
    pn = _write(tmp_path, "new.json", new)
    assert main(["--old", po, "--new", pn]) == 0


def test_gated_row_promotes_regression_to_failure(tmp_path):
    """--gate bench:row:metric flips a beyond-tolerance drop on that row
    (and only that row) from warn to hard fail."""
    old = _artifact(row={**CLEAN, "tok_s": 200.0},
                    other={**CLEAN, "tok_s": 300.0})
    new = _artifact(row=CLEAN, other=CLEAN)
    warnings, gate_errors = diff(old, new,
                                 gates={("oversubscribe", "row", "tok_s")})
    assert any("row.tok_s" in e for e in gate_errors)
    assert any("other.tok_s" in w for w in warnings)
    po = _write(tmp_path, "old.json", old)
    pn = _write(tmp_path, "new.json", new)
    assert main(["--old", po, "--new", pn,
                 "--gate", "oversubscribe:row:tok_s"]) != 0
    assert main(["--old", po, "--new", pn,
                 "--gate", "oversubscribe:other:tok_s"]) != 0


def test_gated_row_within_tolerance_passes(tmp_path):
    old = _artifact(row={**CLEAN, "tok_s": 100.0})
    new = _artifact(row={**CLEAN, "tok_s": 95.0})   # -5% < 15% tolerance
    po = _write(tmp_path, "old.json", old)
    pn = _write(tmp_path, "new.json", new)
    assert main(["--old", po, "--new", pn,
                 "--gate", "oversubscribe:row:tok_s"]) == 0


def test_gate_fails_closed(tmp_path):
    """A gate that cannot be evaluated (missing row, missing old artifact)
    must fail, not silently pass."""
    new = _write(tmp_path, "new.json", _artifact(row=CLEAN))
    old = _write(tmp_path, "old.json", _artifact(row=CLEAN))
    # gated row absent from both artifacts
    assert main(["--old", old, "--new", new,
                 "--gate", "oversubscribe:nope:tok_s"]) != 0
    # old artifact unreadable
    assert main(["--old", str(tmp_path / "missing.json"), "--new", new,
                 "--gate", "oversubscribe:row:tok_s"]) != 0
    # no --old at all
    assert main(["--new", new, "--gate", "oversubscribe:row:tok_s"]) != 0


def test_failed_module_fails_gate(tmp_path):
    payload = _artifact(row=CLEAN)
    payload["failed"] = ["prefix_reuse"]
    new = _write(tmp_path, "new.json", payload)
    assert main(["--new", new]) != 0


def test_correctness_scan_reports_each_row():
    art = {"benches": {
        "oversubscribe": {"a": {**CLEAN, "token_divergence": 1},
                          "b": CLEAN},
        "prefix_reuse": {"c": {**CLEAN, "alloc_failures": 3}},
    }}
    errs = correctness_failures(art)
    assert len(errs) == 2
    assert any("oversubscribe/a.token_divergence" in e for e in errs)
    assert any("prefix_reuse/c.alloc_failures" in e for e in errs)


def test_unreadable_new_artifact_fails_closed(tmp_path):
    assert main(["--new", str(tmp_path / "missing.json")]) != 0
    bad = tmp_path / "truncated.json"
    bad.write_text('{"benches": {"oversubscribe"')
    assert main(["--new", str(bad)]) != 0


def test_missing_old_artifact_still_gates(tmp_path):
    new = _write(tmp_path, "new.json",
                 _artifact(row={**CLEAN, "token_divergence": 1}))
    assert main(["--old", str(tmp_path / "nope.json"), "--new", new]) != 0
