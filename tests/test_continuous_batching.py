"""Step-level (continuous) batching (DESIGN.md §15).

* **identity matrix** — continuous vs round-based admission may only
  move WHEN a request runs, never WHAT it computes: per-rid token
  streams are bitwise identical between the two modes at pipeline
  depths 0/1/2 across the feature matrix {prefix cache, fp8/int8
  quantized KV, oversubscription + preemption, sampled stop-token
  decode}, with the A/B counter witnesses checked on both arms
  (``continuous_admits`` / ``slot_idle_steps_saved`` identically 0 on
  the round arm, ``admit_blocked_round_barrier`` 0 on the continuous
  arm).
* **slot reuse inside the pipeline-lag window** — a slot retired by a
  detected stop at depth 2 is re-admitted while its predecessor's
  overshoot dispatches are still in flight; the §15 rid-stamped
  ``eos_meta`` ownership assert in ``_scrub_overshoot`` guards the
  successor from being scrubbed for the predecessor's overshoot.
* **gateway cancel-then-immediate-readmit** — cancelling a mid-decode
  request and submitting a replacement in the same pump cycle reuses
  the freed slot with zero leaked blocks.
* **round-barrier scheduler unit** — ``admit(hold=True)`` admits
  nothing and audits a stall exactly when an arrived request exists.
"""
import asyncio

import jax
import numpy as np
import pytest

from repro import serving
from repro.configs import get_reduced
from repro.core.engine import EngineConfig, KVRMEngine
from repro.core.scheduler import Request, Scheduler
from repro.models import registry
from repro.serving.factory import build

BASE = dict(mode="paged_merge", batch=4, max_seq=64, block_tokens=8)


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_reduced("qwen2.5-32b")
    params = registry.init_params(jax.random.PRNGKey(7), cfg)
    return cfg, params


def _mixed_reqs(vocab, n=8, stops=(), shared_len=0, seed=5):
    """Variable gen lengths on purpose: slots free at different steps, so
    the continuous arm admits mid-round while the round arm barriers."""
    lens = [(6, 20), (5, 3), (9, 12), (4, 2), (7, 8), (6, 2), (5, 5), (8, 3)]
    rng = np.random.default_rng(seed)
    shared = (rng.integers(0, vocab, size=shared_len).astype(np.int32)
              if shared_len else None)
    reqs = []
    for i, (p, g) in enumerate(lens[:n]):
        pr = rng.integers(0, vocab, size=p).astype(np.int32)
        if shared is not None:
            pr = np.concatenate([shared, pr])
        reqs.append(Request(rid=i, prompt=pr, gen_len=g, stop_tokens=stops))
    return reqs


def _oversub_reqs(vocab):
    # staggered lengths, tuned against the §8 watermark: the two 48s keep
    # the 0.4-budget pool oversubscribed long enough to force preemption,
    # while the mid-length requests retire one at a time so pressure
    # relaxes below the admission gate WHILE the longs still run — the
    # queued shorts then land mid-round (a uniform workload either drains
    # all at once or stays pinned above the watermark, and never admits
    # mid-round at all)
    rng = np.random.default_rng(1)
    lens = [48, 48, 36, 24, 12, 6, 6, 6]
    return [Request(rid=i, prompt=rng.integers(0, vocab, size=4)
                    .astype(np.int32), gen_len=g)
            for i, g in enumerate(lens)]


# the feature matrix: every stateful subsystem mid-round admission
# intersects (§9 aliasing, §10 scale pools, §8 preemption, §13 readback
# retirement). fp8 and int8 split across depths to bound suite time while
# covering both storage widths.
MATRIX = [
    ("prefix_cache", 0, dict(prefix_cache=True)),
    ("prefix_cache", 1, dict(prefix_cache=True)),
    ("prefix_cache", 2, dict(prefix_cache=True)),
    ("quant_fp8", 0, dict(kv_dtype="fp8_e4m3")),
    ("quant_int8", 1, dict(kv_dtype="int8")),
    ("quant_fp8", 2, dict(kv_dtype="fp8_e4m3")),
    ("oversubscribe", 0, dict(near_window=32, pool_budget_frac=0.4,
                              host_pool_blocks=40)),
    ("oversubscribe", 1, dict(near_window=32, pool_budget_frac=0.4,
                              host_pool_blocks=40)),
    ("oversubscribe", 2, dict(near_window=32, pool_budget_frac=0.4,
                              host_pool_blocks=40)),
    ("sampled_stop", 0, dict(greedy=False, temperature=1.2, top_k=50,
                             top_p=0.95, sample_seed=123)),
    ("sampled_stop", 1, dict(greedy=False, temperature=1.2, top_k=50,
                             top_p=0.95, sample_seed=123)),
    ("sampled_stop", 2, dict(greedy=False, temperature=1.2, top_k=50,
                             top_p=0.95, sample_seed=123)),
]


def _reqs_for(feature, vocab):
    if feature == "oversubscribe":
        return _oversub_reqs(vocab)
    if feature == "prefix_cache":
        return _mixed_reqs(vocab, shared_len=16)
    if feature == "sampled_stop":
        return _mixed_reqs(vocab, stops=(7,))
    return _mixed_reqs(vocab)


@pytest.mark.parametrize("feature,depth,kw",
                         MATRIX, ids=[f"{f}-d{d}" for f, d, _ in MATRIX])
def test_stream_identity_continuous_vs_round(dense_setup, feature, depth, kw):
    cfg, params = dense_setup
    streams, engines = {}, {}
    for cb in (True, False):
        eng = KVRMEngine(cfg, params, EngineConfig(
            **BASE, pipeline_depth=depth, continuous_batching=cb, **kw))
        for r in _reqs_for(feature, cfg.vocab_size):
            eng.submit(r)
        eng.run(max_steps=4000)
        streams[cb] = {r.rid: list(map(int, r.generated))
                       for r in eng.sched.finished}
        engines[cb] = eng

    n = len(_reqs_for(feature, cfg.vocab_size))
    assert len(streams[True]) == len(streams[False]) == n
    # same rid => same tokens: admission schedule moved, streams did not
    assert streams[True] == streams[False], feature

    ca, ra = engines[True].audit(), engines[False].audit()
    assert ca["continuous_batching"] and not ra["continuous_batching"]
    # the A/B witnesses: each arm's zero side proves its mode
    assert ca["continuous_admits"] > 0, "no mid-round admission exercised"
    assert ca["slot_idle_steps_saved"] > 0
    assert ca["admit_blocked_round_barrier"] == 0
    assert ra["continuous_admits"] == 0
    assert ra["slot_idle_steps_saved"] == 0
    assert ra["admit_blocked_round_barrier"] > 0, "barrier never held anyone"
    if feature == "oversubscribe":
        # the feature actually intersected mid-round admission: the pool
        # really was oversubscribed in both arms
        assert ca["preemptions"] >= 1 and ra["preemptions"] >= 1
    for eng in engines.values():
        eng.pager.check_invariants()
        if feature != "prefix_cache":    # the radix index legitimately pins
            assert eng.pager.reserved_blocks() == 0
            assert eng.pager.host_used == 0


# ---------------------------------------------------------------------------
# slot reuse inside the pipeline-lag window (§15 scrub ownership)
# ---------------------------------------------------------------------------

def test_slot_reuse_inside_lag_window_never_scrubbed(dense_setup):
    """rid 0 stops early at depth 2, so its slot retires at readback with
    overshoot dispatches still in flight; the very next step admits a
    successor into the SAME slot — inside the lag window. The §15 rid
    stamp in ``eos_meta`` asserts the successor is never scrubbed for the
    predecessor's overshoot, and the streams must equal the depth-0 run's."""
    cfg, params = dense_setup

    def _reqs(stop):
        rng = np.random.default_rng(9)
        # batch=2: rids 0/1 fill the round; 2/3 queue behind it
        return [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=5)
                        .astype(np.int32), gen_len=12,
                        stop_tokens=(stop,) if i == 0 else ())
                for i in range(4)]

    # derive rid 0's early stop from its own argmax stream (temperature=0
    # is the sampler's exact argmax branch, so the stop WILL be emitted)
    probe = KVRMEngine(cfg, params, EngineConfig(
        mode="paged_merge", batch=2, max_seq=64, block_tokens=8,
        pipeline_depth=0, greedy=False, temperature=0.0))
    for r in _reqs(stop=-1):
        probe.submit(r)
    probe.run(max_steps=500)
    ref = {r.rid: list(map(int, r.generated)) for r in probe.sched.finished}
    stop = ref[0][2]

    outs = {}
    for depth in (0, 2):
        eng = KVRMEngine(cfg, params, EngineConfig(
            mode="paged_merge", batch=2, max_seq=64, block_tokens=8,
            pipeline_depth=depth, greedy=False, temperature=0.0))
        for r in _reqs(stop):
            eng.submit(r)
        eng.run(max_steps=500)
        outs[depth] = {r.rid: list(map(int, r.generated))
                       for r in eng.sched.finished}
        a = eng.audit()
        assert a["continuous_admits"] >= 2      # rids 2/3 landed mid-round
        if depth == 2:
            # the hazard actually occurred: overshoot was in flight when
            # the slot retired and the successor took it the next step
            assert a["eos_detected"] == 1
            assert a["eos_overshoot_tokens"] > 0
            assert a["eos_reconciled_blocks"] >= 0
        eng.pager.check_invariants()
        assert eng.pager.reserved_blocks() == 0

    cut = ref[0].index(stop) + 1
    assert outs[0][0] == ref[0][:cut]
    assert outs[2] == outs[0]     # depth changed nothing, scrub included


# ---------------------------------------------------------------------------
# gateway: cancel then immediate readmit reuses the slot, zero leaks
# ---------------------------------------------------------------------------

def test_gateway_cancel_then_immediate_readmit_zero_leak():
    rng = np.random.default_rng(3)
    engines = build("qwen2.5-32b", mode="paged_merge", batch=2, max_seq=64,
                    block_tokens=8, lanes=1, pipeline_depth=1)
    gw = serving.Gateway(engines)

    def _greq(rid, gen_len):
        return serving.GenerationRequest(
            rid=rid, prompt=tuple(int(t) for t in rng.integers(0, 100, 6)),
            gen_len=gen_len)

    async def main():
        s0 = gw.submit(_greq(0, 40))
        s1 = gw.submit(_greq(1, 40))
        ev = await s0.__anext__()
        assert not ev.finished
        # cancel rid 0 mid-decode and readmit a replacement IMMEDIATELY —
        # the freed slot must be reused on the next pump step, while rid 1
        # keeps decoding (no round drain in between)
        assert gw.cancel(0)
        s2 = gw.submit(_greq(2, 4))
        t2 = [e async for e in s2]
        t1 = [e async for e in s1]
        t0 = [e async for e in s0]
        await gw.drain()
        gw.close()
        return t0, t1, t2

    t0, t1, t2 = asyncio.run(main())
    assert t0[-1].finish_reason == "cancelled"
    assert t1[-1].finish_reason == "budget"
    assert len([e for e in t1 if e.token >= 0]) == 40
    assert t2[-1].finish_reason == "budget"
    assert len([e for e in t2 if e.token >= 0]) == 4
    eng = engines[0]
    a = eng.audit()
    # rid 2 landed while rid 1 was mid-round: continuous admission at work
    assert a["continuous_admits"] >= 1
    assert a["cancelled"] == 1
    eng.pager.check_invariants()
    assert not eng.pager.sessions, "cancel-then-readmit leaked a session"
    assert eng.pager.reserved_blocks() == 0
    assert eng.pager.host_used == 0


# ---------------------------------------------------------------------------
# scheduler unit: the round barrier
# ---------------------------------------------------------------------------

def test_admit_hold_admits_nothing_and_audits_the_stall():
    s = Scheduler(2)
    assert s.admit(hold=True) == []
    assert s.admit_blocked["round_barrier"] == 0    # nobody was held
    s.submit(Request(rid=0, prompt=np.arange(4, dtype=np.int32), gen_len=3))
    assert s.admit(hold=True) == []
    assert s.admit_blocked["round_barrier"] == 1
    assert s.free_slots() == [0, 1]                 # barrier placed nothing
    # a not-yet-arrived request is not "held" by the barrier
    s.waiting[0].arrival = 50.0
    assert s.admit(now=10.0, hold=True) == []
    assert s.admit_blocked["round_barrier"] == 1
    (slot, req, sid), = s.admit(now=100.0)          # barrier lifted
    assert slot == 0 and req.rid == 0
