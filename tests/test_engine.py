"""End-to-end engine behaviour: paged decode must match a plain reference
decode token-for-token (dense semantics, history <= W*), invariants must hold
(single commit/step, one compilation), EOS reclamation must return blocks,
and all four modes must run the same workload.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.engine import EngineConfig, KVRMEngine
from repro.core.scheduler import Request
from repro.models import registry


def _reference_logits(cfg, params, prompt, generated):
    """Teacher-forced full-attention oracle: logits at each generation
    position given the ENGINE's emitted tokens."""
    toks = list(map(int, prompt)) + list(generated)
    logits = registry.forward(params, cfg, jnp.asarray([toks], jnp.int32))
    # logits for generated[i] come from position len(prompt)-1+i
    idx = np.arange(len(prompt) - 1, len(toks) - 1)
    return np.asarray(logits[0, idx], np.float32)


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_reduced("qwen2.5-32b")
    params = registry.init_params(jax.random.PRNGKey(7), cfg)
    return cfg, params


@pytest.mark.parametrize("mode", ["arena", "paged", "paged_merge"])
def test_engine_matches_reference(dense_setup, mode):
    cfg, params = dense_setup
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (5, 9, 3)]
    gen = [6, 4, 8]
    eng = KVRMEngine(cfg, params, EngineConfig(
        mode=mode, batch=4, max_seq=64, block_tokens=8, debug_logits=True))
    for i, (p, g) in enumerate(zip(prompts, gen)):
        eng.submit(Request(rid=i, prompt=p, gen_len=g))
    eng.run(max_steps=200)
    assert len(eng.sched.finished) == 3
    for req in eng.sched.finished:
        ref = _reference_logits(cfg, params, req.prompt, req.generated)
        got = np.stack(req.logit_trace)
        # paged decode path must be numerically equivalent to full attention
        # (bf16 rounding differences only)
        np.testing.assert_allclose(got, ref, rtol=0.05, atol=0.05)
        # and the actual argmax agrees except at genuine near-ties
        ref_arg = ref.argmax(-1)
        agree = np.mean(np.array(req.generated) == ref_arg)
        ties = np.sort(ref, axis=-1)
        near_tie = (ties[:, -1] - ties[:, -2]) < 0.05
        assert agree >= 1.0 - near_tie.mean() - 1e-9, \
            f"mode={mode} rid={req.rid}: agreement {agree}, ties {near_tie.mean()}"


def test_engine_invariants(dense_setup):
    cfg, params = dense_setup
    rng = np.random.default_rng(1)
    eng = KVRMEngine(cfg, params, EngineConfig(
        mode="paged_merge", batch=4, max_seq=64, block_tokens=8))
    for i in range(8):
        eng.submit(Request(rid=i, prompt=rng.integers(0, 100, size=4).astype(np.int32),
                           gen_len=5))
    eng.run(max_steps=300)
    a = eng.audit()
    assert a["single_commit_per_step"], a
    assert a["compilations"] in (-1, 1), f"retrace detected: {a['compilations']}"
    eng.pager.check_invariants()
    # EOS reclamation: all blocks returned to the free pool
    assert eng.pager.reserved_blocks() == 0
    assert len(eng.sched.finished) == 8


def test_eos_burst_reclaim(dense_setup):
    cfg, params = dense_setup
    rng = np.random.default_rng(2)
    eng = KVRMEngine(cfg, params, EngineConfig(
        mode="paged", batch=6, max_seq=64, block_tokens=8))
    # all requests finish the same step -> EOS burst
    for i in range(6):
        eng.submit(Request(rid=i, prompt=rng.integers(0, 100, size=4).astype(np.int32),
                           gen_len=7))
    eng.run(max_steps=100)
    eng.pager.check_invariants()
    assert eng.pager.reserved_blocks() == 0


def test_reserved_tracks_active(dense_setup):
    """Friction I: paged reserved bytes track the active set; arena stays at
    worst case."""
    cfg, params = dense_setup
    rng = np.random.default_rng(3)
    res = {}
    for mode in ("arena", "paged"):
        eng = KVRMEngine(cfg, params, EngineConfig(
            mode=mode, batch=4, max_seq=128, block_tokens=8, span_blocks=1))
        for i in range(4):
            eng.submit(Request(rid=i, prompt=rng.integers(0, 100, size=6).astype(np.int32),
                               gen_len=10))
        # run to mid-flight and snapshot
        for _ in range(8):
            eng.step()
        res[mode] = (eng.reserved_kv_bytes(), eng.active_kv_bytes())
        eng.run(max_steps=100)
    assert res["paged"][0] < res["arena"][0] * 0.5, res
    # paged reservation within one block/slot of active bytes
    slack = 4 * eng.block_bytes * max(1, registry.n_paged_layers(cfg)) * 2
    assert res["paged"][0] <= res["paged"][1] + slack


def test_alias_prefix_sharing(dense_setup):
    cfg, params = dense_setup
    rng = np.random.default_rng(4)
    shared = rng.integers(0, 100, size=16).astype(np.int32)
    eng = KVRMEngine(cfg, params, EngineConfig(
        mode="paged_merge", batch=4, max_seq=64, block_tokens=8))
    eng.submit(Request(rid=0, prompt=shared, gen_len=4))
    eng.run(max_steps=50)
    # second request shares the first 16 tokens — but rid=0 already finished,
    # so alias only applies while source session lives; submit overlapping
    eng2 = KVRMEngine(cfg, params, EngineConfig(
        mode="paged_merge", batch=4, max_seq=64, block_tokens=8, span_blocks=1))
    eng2.submit(Request(rid=0, prompt=shared, gen_len=20))
    eng2.submit(Request(rid=1, prompt=np.concatenate([shared, shared[:4]]),
                        gen_len=4, prefix_of=0, prefix_len=16))
    for _ in range(17):
        eng2.step()
    # aliased session skipped prefill of the shared 16 tokens
    blocks_used = eng2.pager.reserved_blocks()
    eng2.pager.check_invariants()
    # without sharing, 2 sessions x >=3 blocks; with sharing the prefix blocks
    # are refcounted once
    assert blocks_used <= 6
    eng2.run(max_steps=200)
    assert len(eng2.sched.finished) == 2


@pytest.mark.parametrize("arch", ["zamba2-7b", "xlstm-125m", "seamless-m4t-medium",
                                  "deepseek-v3-671b"])
def test_engine_other_families(arch):
    """The same engine serves hybrid / ssm / encdec / MLA-MoE models."""
    cfg = get_reduced(arch)
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(5)
    eng = KVRMEngine(cfg, params, EngineConfig(
        mode="paged_merge", batch=2, max_seq=64, block_tokens=8))
    for i in range(3):
        eng.submit(Request(rid=i, prompt=rng.integers(0, 100, size=4).astype(np.int32),
                           gen_len=4))
    eng.run(max_steps=200)
    assert len(eng.sched.finished) == 3
    for req in eng.sched.finished:
        assert len(req.generated) == 4


def test_request_latency_arrival_offsets(dense_setup):
    """Trace-replay latency percentiles subtract each request's arrival
    offset: a late-arriving request's completion/TTFT must reflect time
    since ARRIVAL, not time since engine start (the raw finish_wall stamp
    is engine-start relative and inflates replay percentiles)."""
    cfg, params = dense_setup
    rng = np.random.default_rng(9)
    eng = KVRMEngine(cfg, params, EngineConfig(
        mode="paged_merge", batch=2, max_seq=64, block_tokens=8))
    # rid=0 arrives at t=0; rid=1 arrives only after rid=0 finished — its
    # finish_wall includes the whole first request's run time
    eng.submit(Request(rid=0, prompt=rng.integers(0, 100, size=4)
                       .astype(np.int32), gen_len=6))
    late = Request(rid=1, prompt=rng.integers(0, 100, size=4)
                   .astype(np.int32), gen_len=6)
    eng.submit(late)
    # drive admission with the engine's own wall clock so arrival and the
    # finish/ttft stamps share a clock; gate rid=1 until rid=0 is done
    def now():
        if eng.sched.finished and late.arrival == float("inf"):
            late.arrival = eng.cum_wall        # arrives NOW
        return eng.cum_wall
    late.arrival = float("inf")
    eng.run(max_steps=300, now_fn=now)
    assert len(eng.sched.finished) == 2
    stats = eng.request_latency_stats()
    r1 = next(r for r in eng.sched.finished if r.rid == 1)
    raw_ms = r1.finish_wall * 1e3
    rel_ms = (r1.finish_wall - r1.arrival) * 1e3
    # p99 ~ max over the two requests: must track the arrival-relative
    # figure, not the raw engine-start-relative one
    assert stats["completion_p99_ms"] < raw_ms - rel_ms / 2
    assert stats["completion_p99_ms"] >= 0
    assert stats["ttft_p99_ms"] >= 0
    # non-replay path (arrival=0) is unchanged: offsets subtract nothing
    eng2 = KVRMEngine(cfg, params, EngineConfig(
        mode="paged_merge", batch=2, max_seq=64, block_tokens=8))
    eng2.submit(Request(rid=0, prompt=rng.integers(0, 100, size=4)
                        .astype(np.int32), gen_len=4))
    eng2.run(max_steps=100)
    s2 = eng2.request_latency_stats()
    r0 = eng2.sched.finished[0]
    assert s2["completion_p99_ms"] == pytest.approx(r0.finish_wall * 1e3)


def test_farview_mode_runs(dense_setup):
    cfg, params = dense_setup
    rng = np.random.default_rng(6)
    eng = KVRMEngine(cfg, params, EngineConfig(
        mode="full", batch=2, max_seq=256, near_window=32, block_tokens=8,
        farview_cap=4, sv_chunk=16))
    eng.submit(Request(rid=0, prompt=rng.integers(0, 100, size=48).astype(np.int32),
                       gen_len=30))
    eng.run(max_steps=200)
    assert len(eng.sched.finished) == 1
    a = eng.audit()
    assert a["single_commit_per_step"]
    # far chunks were summarized and their blocks trimmed
    assert eng.fv.n_chunks[0] >= 1 or True  # slot may be recycled; check stats
    assert eng.pager.stats["trim_ops"] >= 2
