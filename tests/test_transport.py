"""Merge-staged transport tests: run merging, tau splitting, delta holds,
fragmentation regimes, and hypothesis coverage-equivalence property."""
import numpy as np

from _hypothesis_compat import given, settings, st

from repro.core.transport import MergeStagedTransport, StagedDescriptor, merge_runs


def _mk(tau_blocks=8, delta=2, mt=16, block_bytes=1024):
    return MergeStagedTransport(block_bytes=block_bytes,
                                merge_threshold_bytes=tau_blocks * block_bytes,
                                max_hold_steps=delta, max_trains=mt)


def test_merge_contiguous_run():
    assert merge_runs([5, 6, 7, 8]) == [(5, 4, 0)]


def test_merge_fragmented():
    assert merge_runs([5, 6, 9, 10, 11, 3]) == [(5, 2, 0), (9, 3, 2), (3, 1, 5)]


def test_reduce_counts_groups():
    t = _mk()
    trains, groups = t.reduce([1, 2, 3, 7, 8])
    assert groups == 2
    assert t.stats.unmerged_groups_per_step == 5.0
    assert t.stats.avg_group_bytes == 5 * 1024 / 2


def test_tau_splits_oversized_trains():
    t = _mk(tau_blocks=2, block_bytes=1024)   # cap = 2*tau = 4 blocks
    trains, groups = t.reduce(list(range(1, 11)))   # 10 contiguous blocks
    assert all(ln <= 4 for _, ln, _ in trains)
    assert sum(ln for _, ln, _ in trains) == 10


def test_far_train_counts_one_group():
    t = _mk()
    _, groups = t.reduce([1, 2, 3], far_blocks=4)
    assert groups == 2                         # near train + one far train


def test_staged_descriptor_hold_and_release():
    t = _mk(delta=2)
    t.stage([StagedDescriptor(block=50, dst=9)])
    # age 1 < delta and not adjacent -> held
    _, g1 = t.reduce([1, 2, 3])
    assert g1 == 1 and len(t._staged) == 1
    # age reaches delta -> folded in
    trains, g2 = t.reduce([1, 2, 3])
    assert any(s == 50 for s, _, _ in trains)
    assert len(t._staged) == 0


def test_staged_adjacent_merges_immediately():
    t = _mk(delta=5)
    t.stage([StagedDescriptor(block=4, dst=3)])
    trains, g = t.reduce([1, 2, 3])
    assert trains == [(1, 4, 0)]               # merged into the tail train
    assert g == 1


def test_fragmentation_regimes_degrade_gracefully():
    """Paper Fig. 7(d-f): groups grow sub-linearly vs unmerged under harsher
    fragmentation."""
    rng = np.random.default_rng(0)
    regimes = {
        "contiguous": list(range(1, 33)),
        "mild": [b + (i // 8) * 4 for i, b in enumerate(range(1, 33))],
        "strong": [b + (i // 2) * 3 for i, b in enumerate(range(1, 33))],
        "adversarial": list(rng.permutation(np.arange(1, 200))[:32]),
    }
    prev_groups = 0
    for name, blocks in regimes.items():
        t = _mk(tau_blocks=64, mt=64)
        _, groups = t.reduce(blocks)
        unmerged = len(blocks)
        assert groups <= unmerged
        assert groups >= prev_groups or name == "adversarial"
        prev_groups = min(groups, 32)
    # adversarial random is near-unmergeable but never exceeds block count
    t = _mk(tau_blocks=64, mt=64)
    _, g = t.reduce(regimes["adversarial"])
    assert g <= 32


def test_fill_train_arrays_overflow_sentinel():
    """Overflow beyond MT: the folded remainder trains are generally NOT
    physically contiguous, so no (start, len) copy describes them; the last
    slot must be an explicit degenerate sentinel (train_start=-1) covering
    the remainder's block count, and the stress event must be counted."""
    t = _mk(mt=2)
    trains = [(1, 1, 0), (5, 1, 1), (9, 1, 2), (13, 1, 3)]
    ts = np.zeros((1, 2), np.int32)
    tl = np.zeros((1, 2), np.int32)
    td = np.zeros((1, 2), np.int32)
    t.fill_train_arrays(trains, ts, tl, td, 0)
    assert tl[0].sum() == 4                    # coverage preserved
    assert (ts[0, 0], tl[0, 0], td[0, 0]) == (1, 1, 0)   # in-bounds train
    assert ts[0, 1] == -1                      # degenerate sentinel ...
    assert tl[0, 1] == 3                       # ... covers the remainder
    assert td[0, 1] == 1                       # first folded window position
    assert t.stats.train_overflows == 1
    # no overflow -> no sentinel, no stress count
    t.fill_train_arrays([(1, 2, 0), (7, 1, 2)], ts, tl, td, 0)
    assert ts[0, 1] == 7 and tl[0, 1] == 1
    assert t.stats.train_overflows == 1


def test_held_descriptors_drain():
    """held_descriptors must fall back to zero when staged descriptors fold
    into trains (it used to grow monotonically)."""
    t = _mk(delta=2)
    t.stage([StagedDescriptor(block=50, dst=9), StagedDescriptor(block=60, dst=10)])
    assert t.stats.held_descriptors == 2
    t.reduce([1, 2, 3])                        # age 1 < delta: still held
    assert t.stats.held_descriptors == 2
    t.reduce([1, 2, 3])                        # age hits delta: both drain
    assert t.stats.held_descriptors == 0
    assert len(t._staged) == 0


def test_account_batch_matches_reduce():
    """Vectorized stats accounting == per-slot reduce() accounting."""
    windows = [[1, 2, 3, 7, 8], [4, 5], [10, 12, 14]]
    t1 = _mk()
    for w in windows:
        t1.reduce(w)
    t2 = _mk()
    trains = t2.reduce_batch(windows)
    t2.account_batch([len(w) for w in windows],
                     [len(tr) for tr in trains], [0, 0, 0])
    for f in ("steps", "total_groups", "total_bytes", "max_groups",
              "unmerged_groups"):
        assert getattr(t1.stats, f) == getattr(t2.stats, f), f


@settings(max_examples=100, deadline=None)
@given(st.lists(st.integers(1, 500), min_size=1, max_size=64, unique=True))
def test_merge_preserves_coverage(blocks):
    """Property: merged trains cover exactly the input blocks, in order."""
    trains = merge_runs(blocks)
    recon = []
    for s, ln, dst in trains:
        assert dst == len(recon)
        recon += list(range(s, s + ln))
    assert recon == list(blocks)
