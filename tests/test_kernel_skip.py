"""Work-skipping kernels: active-extent predication (DESIGN.md §12).

Covers the extent math (jnp / numpy twins + brute-force mask check), the
Pallas decode kernel's skip-on-vs-always-run bitwise identity across
pipeline depths / dtypes / dma modes, the chunked-prefill twin, the
interpret-resolution helper, and the engine-level token identity +
audit-counter accounting.
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.descriptor import active_block_extents
from repro.core.engine import EngineConfig, KVRMEngine
from repro.core.scheduler import Request
from repro.kernels import ref
from repro.kernels.paged_attention import paged_decode_attention_pallas
from repro.kernels.prefill_attention import chunked_prefill_attention_pallas
from repro.kernels.runtime import resolve_interpret
from repro.models import registry


# ---------------------------------------------------------------------------
# extent math: jnp twin == numpy twin == brute-forced mask support
# ---------------------------------------------------------------------------

def _brute_extent(wb, t, act, W, nb, bt):
    """Smallest [lo, hi) covering every unmasked decode position."""
    blocks = []
    for i in range(nb):
        pos = wb + i * bt + np.arange(bt)
        if act > 0 and np.any((pos <= t) & (pos > t - W) & (pos >= 0)):
            blocks.append(i)
    if not blocks:
        return 0, 0
    return min(blocks), max(blocks) + 1


@pytest.mark.parametrize("W,nb,bt", [(32, 5, 8), (24, 4, 8), (64, 5, 16)])
def test_extent_twins_and_brute_force(W, nb, bt):
    rng = np.random.default_rng(0)
    B = 64
    t = rng.integers(0, nb * bt + 8, size=B)
    wb = np.maximum(0, (t + 1 - W) // bt * bt)       # engine construction
    act = rng.integers(0, 2, size=B)
    lo_n, hi_n = active_block_extents(wb, t, act, near_window=W, nb=nb, bt=bt)
    lo_j, hi_j = ref.active_block_extent(
        jnp.asarray(wb), jnp.asarray(t), jnp.asarray(act),
        near_window=W, nb=nb, bt=bt)
    np.testing.assert_array_equal(lo_n, np.asarray(lo_j))
    np.testing.assert_array_equal(hi_n, np.asarray(hi_j))
    for b in range(B):
        blo, bhi = _brute_extent(wb[b], t[b], act[b], W, nb, bt)
        # exact under the engine's window-base construction: never narrower
        # (lossless) and never wider than the brute-forced support
        assert (lo_n[b], hi_n[b]) == (blo, bhi), \
            (b, wb[b], t[b], act[b], (lo_n[b], hi_n[b]), (blo, bhi))
    assert np.all(hi_n[act == 0] == lo_n[act == 0])


def test_chunk_extent_brute_force():
    W, nb, bt = 32, 5, 8
    for start in range(0, nb * bt):
        for wb in (0, 8, 16):
            if start < wb:
                continue
            lo, hi = ref.chunk_block_extent(jnp.asarray(wb), jnp.asarray(start),
                                            near_window=W, nb=nb, bt=bt)
            lo, hi = int(lo), int(hi)
            touched = []
            for i in range(nb):
                pos = wb + i * bt + np.arange(bt)
                # any chunk row attends pool positions in
                # [max(0, start - W + 1), start - 1]
                if np.any((pos >= max(0, start - W + 1)) & (pos < start)):
                    touched.append(i)
            blo, bhi = (min(touched), max(touched) + 1) if touched else (0, 0)
            assert (lo, hi) == (blo, bhi), (start, wb, (lo, hi), (blo, bhi))


# ---------------------------------------------------------------------------
# decode kernel: skip on == always-run, bitwise, across variants
# ---------------------------------------------------------------------------

def _skewed_case(seed, B, H, KV, hd, BT, NB, dtype=jnp.bfloat16):
    P = NB * B + 4
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, H, hd), dtype)
    pk = jax.random.normal(ks[1], (P, BT, KV, hd), dtype)
    pv = jax.random.normal(ks[2], (P, BT, KV, hd), dtype)
    tbl = np.stack([np.random.default_rng(i).permutation(np.arange(1, P))[:NB]
                    for i in range(B)]).astype(np.int32)
    # skewed lengths: one deep slot, short tails, and a retired slot
    # (extent == 0) when B allows
    rng = np.random.default_rng(seed + 9)
    seq = rng.integers(1, BT + 2, size=B).astype(np.int32)
    seq[0] = NB * BT - 1
    act = np.ones(B, np.int32)
    if B > 2:
        act[-1] = 0
    return (q, pk, pv, jnp.asarray(tbl), jnp.zeros(B, jnp.int32),
            jnp.asarray(seq), jnp.asarray(act))


@pytest.mark.parametrize("B,H,KV,hd,BT,NB", [
    (4, 4, 2, 32, 8, 4),
    (3, 8, 8, 64, 16, 3),     # MHA
    (2, 16, 2, 64, 8, 5),     # wide GQA ratio
])
def test_decode_skip_parity_and_identity(B, H, KV, hd, BT, NB):
    q, pk, pv, tbl, wb, seq, act = _skewed_case(0, B, H, KV, hd, BT, NB)
    W = NB * BT
    out_ref, _ = ref.paged_decode_attention_ref(q, pk, pv, tbl, wb, seq, act,
                                                near_window=W)
    out_ref_skip, _ = ref.paged_decode_attention_ref(
        q, pk, pv, tbl, wb, seq, act, near_window=W, skip_extent=True)
    # the extent mask only removes already-masked work: bitwise no-op
    assert jnp.array_equal(out_ref, out_ref_skip)
    outs = {}
    for depth in (0, 1):
        for skip in (True, False):
            outs[(depth, skip)], _ = paged_decode_attention_pallas(
                q, pk, pv, tbl, wb, seq, act, near_window=W,
                skip_extent=skip, prefetch_depth=depth)
    base = outs[(0, False)]
    for key, out in outs.items():
        assert jnp.array_equal(out, base), f"variant {key} not bitwise"
    np.testing.assert_allclose(np.asarray(base, np.float32),
                               np.asarray(out_ref, np.float32),
                               rtol=2e-2, atol=2e-2)


def test_decode_skip_retired_slot_zero():
    q, pk, pv, tbl, wb, seq, act = _skewed_case(1, 4, 4, 2, 32, 8, 4)
    out, _ = paged_decode_attention_pallas(q, pk, pv, tbl, wb, seq, act,
                                           near_window=32, skip_extent=True)
    assert bool((out[-1] == 0).all())          # retired slot: extent == 0
    assert not bool((out[0] == 0).all())


def test_decode_skip_dma_fallback_bitwise():
    """Double-buffered kernel: async-copy staging vs the interpret direct
    -read fallback must agree bitwise (same dequant + update order)."""
    q, pk, pv, tbl, wb, seq, act = _skewed_case(2, 3, 8, 2, 32, 8, 4)
    W = 32
    kw = dict(near_window=W, skip_extent=True, prefetch_depth=1)
    out_dma, _ = paged_decode_attention_pallas(q, pk, pv, tbl, wb, seq, act,
                                               dma=True, **kw)
    out_direct, _ = paged_decode_attention_pallas(q, pk, pv, tbl, wb, seq, act,
                                                  dma=False, **kw)
    assert jnp.array_equal(out_dma, out_direct)


def test_decode_skip_quant_bitwise():
    """int8 pools + SMEM scales: predication/double-buffering must not
    perturb the dequantizing path."""
    P, BT, KV, hd, B, H, NB = 20, 8, 2, 32, 3, 4, 4
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    kq = (jax.random.normal(ks[1], (P, BT, KV, hd)) * 60).astype(jnp.int8)
    vq = (jax.random.normal(ks[2], (P, BT, KV, hd)) * 60).astype(jnp.int8)
    ksc = jax.random.uniform(ks[3], (P, KV), minval=0.005, maxval=0.02)
    vsc = jax.random.uniform(ks[4], (P, KV), minval=0.005, maxval=0.02)
    tbl = jnp.asarray(np.stack([np.random.default_rng(i).permutation(
        np.arange(1, P))[:NB] for i in range(B)]).astype(np.int32))
    wb = jnp.zeros(B, jnp.int32)
    seq = jnp.asarray([NB * BT - 1, 3, 9], jnp.int32)
    act = jnp.ones(B, jnp.int32)
    W = NB * BT
    outs = [paged_decode_attention_pallas(
        q, kq, vq, tbl, wb, seq, act, near_window=W, k_scale=ksc,
        v_scale=vsc, skip_extent=skip, prefetch_depth=depth)[0]
        for depth in (0, 1) for skip in (True, False)]
    for out in outs[1:]:
        assert jnp.array_equal(out, outs[0])
    out_r, _ = ref.paged_decode_attention_ref(
        q, kq, vq, tbl, wb, seq, act, near_window=W,
        k_scale=ksc, v_scale=vsc)
    np.testing.assert_allclose(np.asarray(outs[0]), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# chunked prefill twin
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("start,n_valid", [(0, 8), (24, 6), (33, 8)])
def test_chunk_skip_parity_and_identity(start, n_valid):
    C, H, KV, hd, BT, NB = 8, 4, 2, 32, 8, 5
    P = NB + 4
    W = 32
    ks = jax.random.split(jax.random.PRNGKey(4), 5)
    q = jax.random.normal(ks[0], (C, H, hd), jnp.float32)
    pk = jax.random.normal(ks[1], (P, BT, KV, hd), jnp.float32)
    pv = jax.random.normal(ks[2], (P, BT, KV, hd), jnp.float32)
    ck = jax.random.normal(ks[3], (C, KV, hd), jnp.float32)
    cv = jax.random.normal(ks[4], (C, KV, hd), jnp.float32)
    tbl = jnp.asarray(np.random.default_rng(0).permutation(
        np.arange(1, P))[:NB].astype(np.int32))
    wb = jnp.asarray(max(0, (start + 1 - W) // BT * BT), jnp.int32)
    args = (q, pk, pv, ck, cv, tbl, wb, jnp.asarray(start, jnp.int32),
            jnp.asarray(n_valid, jnp.int32))
    out_on = chunked_prefill_attention_pallas(*args, near_window=W,
                                              skip_extent=True)
    out_off = chunked_prefill_attention_pallas(*args, near_window=W,
                                               skip_extent=False)
    assert jnp.array_equal(out_on, out_off)
    out_ref = ref.chunked_prefill_attention_ref(*args, near_window=W)
    out_ref_skip = ref.chunked_prefill_attention_ref(*args, near_window=W,
                                                     skip_extent=True)
    assert jnp.array_equal(out_ref, out_ref_skip)
    np.testing.assert_allclose(np.asarray(out_on), np.asarray(out_ref),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# interpret resolution
# ---------------------------------------------------------------------------

def test_resolve_interpret():
    assert resolve_interpret(True) is True
    assert resolve_interpret(False) is False
    resolved = resolve_interpret(None)
    if os.environ.get("REPRO_PALLAS_INTERPRET") is None:
        assert resolved == (jax.default_backend() == "cpu")


# ---------------------------------------------------------------------------
# engine: token identity + audit counters
# ---------------------------------------------------------------------------

def _run_engine(cfg, params, *, skip, depth, n=5):
    rng = np.random.default_rng(2)
    eng = KVRMEngine(cfg, params, EngineConfig(
        mode="paged_merge", batch=4, max_seq=64, block_tokens=8,
        pipeline_depth=depth, kernel_skip_extent=skip))
    for i in range(n):
        # bimodal skew: one long generation, short tails
        eng.submit(Request(rid=i, prompt=rng.integers(0, 100, size=4)
                           .astype(np.int32), gen_len=40 if i == 0 else 8))
    eng.run(max_steps=400)
    assert len(eng.sched.finished) == n
    return eng


def test_engine_skip_extent_token_identity():
    cfg = get_reduced("qwen2.5-32b")
    params = registry.init_params(jax.random.PRNGKey(7), cfg)
    runs = {}
    for depth in (0, 1):
        for skip in (True, False):
            eng = _run_engine(cfg, params, skip=skip, depth=depth)
            runs[(depth, skip)] = {r.rid: list(r.generated)
                                   for r in eng.sched.finished}
            a = eng.audit()
            assert a["kernel_skip_extent"] is skip
            assert a["kernel_blocks_total"] > 0
            if skip:
                # skewed lengths on a fixed grid MUST skip padded blocks,
                # and never more than the descriptor-side padded count
                assert 0 < a["kernel_blocks_skipped"] \
                    < a["kernel_blocks_total"]
            else:
                assert a["kernel_blocks_skipped"] == 0
    base = runs[(0, False)]
    for key, toks in runs.items():
        assert toks == base, f"tokens diverged for depth/skip {key}"
