"""Force a multi-device CPU topology for the whole suite.

The sharded-decode tests (tests/test_sharding.py) need >= 2 devices IN the
pytest process, and jax locks the host device count at first backend
initialization — so the flag must be set here, before any test module
imports jax. Everything else is unaffected: unsharded computations stay on
device 0, and subprocess-based tests (test_dryrun_small) set their own
count inside the child.
"""
import os

_FLAG = "--xla_force_host_platform_device_count=4"
if "xla_force_host_platform_device_count" not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " " + _FLAG).strip()
