"""Quantized KV-block storage tier (DESIGN.md §10).

Unit layer: symmetric absmax quantize/requantize ops and the dequantizing
attention epilogues (jnp ref + Pallas interpret mode). Engine layer: bf16
stays bitwise-identical to the default path, narrow dtypes cut reserved KV
~2x, and the tier composes with every subsystem — pipeline depths, chunked
prefill, the radix prefix cache (a hit aliases data+scale chains
atomically), the host tier (swap moves scales in lockstep), and TP.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.engine import EngineConfig, KVRMEngine
from repro.core.scheduler import Request
from repro.kernels import ref
from repro.models import registry

ARCH = "qwen2.5-32b"


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_reduced(ARCH)
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _mk_engine(cfg, params, **kw):
    e = dict(mode="paged_merge", batch=4, max_seq=96, block_tokens=8)
    e.update(kw)
    return KVRMEngine(cfg, params, EngineConfig(**e))


def _reqs(seed=3, n=6, plen=12, gen=24, vocab=256):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, vocab, size=plen).astype(np.int32),
                    gen_len=gen) for i in range(n)]


def _run(eng, reqs):
    for r in reqs:
        eng.submit(r)
    eng.run(max_steps=5000)
    return {r.rid: list(r.generated) for r in eng.sched.finished}


# ---------------------------------------------------------------------------
# unit: quantize-at-commit ops (ref.py)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("dtype,rtol", [(jnp.int8, 0.02),
                                        (jnp.float8_e4m3fn, 0.08)])
def test_stacked_write_roundtrip(dtype, rtol):
    """Tokens written one at a time into a block dequantize back to the
    original values within the dtype's quantization error, including after
    the running scale grew (requantization of earlier tokens)."""
    L, P, BT, KV, hd, B = 2, 6, 8, 2, 16, 4
    pool = jnp.zeros((L, P, BT, KV, hd), dtype)
    scale = jnp.zeros((L, P, KV), jnp.float32)
    rng = np.random.default_rng(0)
    # magnitudes GROW with the offset so every append raises the scale —
    # the hardest case for in-place requantization
    vals = [jnp.asarray(rng.normal(size=(L, B, KV, hd)) * (1 + 3 * off),
                        jnp.float32) for off in range(BT)]
    blk = jnp.arange(1, B + 1, dtype=jnp.int32)          # one block per slot
    act = jnp.ones(B, jnp.int32)
    for off in range(BT):
        pool, scale = ref.quant_pool_write_stacked_ref(
            pool, scale, vals[off], blk, jnp.full(B, off, jnp.int32), act)
    got = np.asarray(pool[:, blk], np.float32) \
        * np.asarray(scale[:, blk])[:, :, None, :, None]   # (L,B,BT,KV,hd)
    want = np.stack([np.asarray(v) for v in vals], axis=2)
    denom = np.abs(want).max()
    assert np.abs(got - want).max() / denom < rtol


@pytest.mark.parametrize("dtype", [jnp.int8, jnp.float8_e4m3fn])
def test_chunk_write_matches_stacked(dtype):
    """A chunk write and the equivalent token-at-a-time writes agree to
    quantization error (same final scale; chunk quantizes once, stacked
    requantizes incrementally)."""
    L, P, BT, KV, hd, B, C = 1, 8, 8, 2, 16, 2, 12
    rng = np.random.default_rng(1)
    vals = jnp.asarray(rng.normal(size=(L, B, C, KV, hd)), jnp.float32)
    # slots write C=12 consecutive tokens starting mid-block (offset 4)
    blocks = np.array([[1, 2], [3, 4]])                    # (B, 2)
    idx = 4 + np.arange(C)
    wb = jnp.asarray(blocks[np.arange(B)[:, None], idx[None, :] // BT],
                     jnp.int32)                            # (B, C)
    wo = jnp.asarray(np.tile(idx % BT, (B, 1)), jnp.int32)
    nv = jnp.full(B, C, jnp.int32)
    pool_c = jnp.zeros((L, P, BT, KV, hd), dtype)
    scale_c = jnp.zeros((L, P, KV), jnp.float32)
    pool_c, scale_c = ref.quant_pool_write_chunk_ref(
        pool_c, scale_c, vals, wb, wo, nv)
    pool_s = jnp.zeros((L, P, BT, KV, hd), dtype)
    scale_s = jnp.zeros((L, P, KV), jnp.float32)
    act = jnp.ones(B, jnp.int32)
    for c in range(C):
        pool_s, scale_s = ref.quant_pool_write_stacked_ref(
            pool_s, scale_s, vals[:, :, c], wb[:, c], wo[:, c], act)
    # final scales are identical (running max == batch max)
    np.testing.assert_allclose(np.asarray(scale_c), np.asarray(scale_s),
                               rtol=1e-6)
    dq_c = np.asarray(pool_c, np.float32) * \
        np.asarray(scale_c)[:, :, None, :, None]
    dq_s = np.asarray(pool_s, np.float32) * \
        np.asarray(scale_s)[:, :, None, :, None]
    tol = 0.02 if dtype == jnp.int8 else 0.1
    assert np.abs(dq_c - dq_s).max() <= tol * max(1e-6, np.abs(dq_s).max())


def test_fresh_block_resets_scale():
    """A write at offset 0 treats the block as recycled: stale contents and
    the stale scale must not leak into the new occupant."""
    L, P, BT, KV, hd = 1, 4, 8, 2, 16
    pool = jnp.full((L, P, BT, KV, hd), 100, jnp.int8)     # stale garbage
    scale = jnp.full((L, P, KV), 99.0, jnp.float32)        # stale scale
    vals = jnp.full((L, 1, KV, hd), 0.5, jnp.float32)
    pool, scale = ref.quant_pool_write_stacked_ref(
        pool, scale, vals, jnp.asarray([2], jnp.int32),
        jnp.asarray([0], jnp.int32), jnp.asarray([1], jnp.int32))
    s = np.asarray(scale[0, 2])
    np.testing.assert_allclose(s, 0.5 / 127.0, rtol=1e-6)
    dq = np.asarray(pool[0, 2, 0], np.float32) * s[:, None]
    np.testing.assert_allclose(dq, 0.5, rtol=0.02)
    # rows beyond the written token were zeroed (ratio 0), not left stale
    assert (np.asarray(pool[0, 2, 1:]) == 0).all()


def test_inactive_slots_leave_pool_untouched():
    L, P, BT, KV, hd = 1, 4, 8, 2, 16
    rng = np.random.default_rng(2)
    pool = jnp.asarray(rng.integers(-50, 50, size=(L, P, BT, KV, hd)),
                       jnp.int8)
    scale = jnp.asarray(rng.uniform(0.01, 1, size=(L, P, KV)), jnp.float32)
    vals = jnp.asarray(rng.normal(size=(L, 2, KV, hd)), jnp.float32)
    p2, s2 = ref.quant_pool_write_stacked_ref(
        pool, scale, vals, jnp.asarray([1, 2], jnp.int32),
        jnp.asarray([3, 3], jnp.int32), jnp.asarray([0, 0], jnp.int32))
    assert (np.asarray(p2) == np.asarray(pool)).all()
    np.testing.assert_allclose(np.asarray(s2), np.asarray(scale))


# ---------------------------------------------------------------------------
# unit: dequantizing attention epilogues (ref + Pallas interpret)
# ---------------------------------------------------------------------------

def _quant_pool_case(seed=0, B=2, H=4, KV=2, hd=32, BT=8, NB=4):
    P = NB * B + 4
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.float32)
    kq = (jax.random.normal(ks[1], (P, BT, KV, hd)) * 60).astype(jnp.int8)
    vq = (jax.random.normal(ks[2], (P, BT, KV, hd)) * 60).astype(jnp.int8)
    ksc = jax.random.uniform(ks[3], (P, KV), minval=0.005, maxval=0.02)
    vsc = jax.random.uniform(ks[4], (P, KV), minval=0.005, maxval=0.02)
    tbl = np.stack([np.random.default_rng(i).permutation(
        np.arange(1, P))[:NB] for i in range(B)]).astype(np.int32)
    seq = np.random.default_rng(9).integers(1, NB * BT, size=B).astype(np.int32)
    return (q, kq, vq, ksc, vsc, jnp.asarray(tbl), jnp.zeros(B, jnp.int32),
            jnp.asarray(seq), jnp.ones(B, jnp.int32))


def test_ref_dequant_equals_explicit():
    """The scale path equals dequantizing the pool up front and running the
    plain bf16 ref — the epilogue is a pure layout optimization."""
    q, kq, vq, ksc, vsc, tbl, wb, seq, act = _quant_pool_case()
    W = tbl.shape[1] * kq.shape[1]
    out_q, _ = ref.paged_decode_attention_ref(
        q, kq, vq, tbl, wb, seq, act, near_window=W,
        k_scale=ksc, v_scale=vsc)
    k_f = kq.astype(jnp.float32) * ksc[:, None, :, None]
    v_f = vq.astype(jnp.float32) * vsc[:, None, :, None]
    out_f, _ = ref.paged_decode_attention_ref(
        q, k_f, v_f, tbl, wb, seq, act, near_window=W)
    np.testing.assert_allclose(np.asarray(out_q), np.asarray(out_f),
                               rtol=1e-5, atol=1e-5)


def test_pallas_quant_decode_matches_ref():
    q, kq, vq, ksc, vsc, tbl, wb, seq, act = _quant_pool_case()
    W = tbl.shape[1] * kq.shape[1]
    from repro.kernels.paged_attention import paged_decode_attention_pallas
    out_p, _ = paged_decode_attention_pallas(
        q, kq, vq, tbl, wb, seq, act, near_window=W,
        k_scale=ksc, v_scale=vsc)
    out_r, _ = ref.paged_decode_attention_ref(
        q, kq, vq, tbl, wb, seq, act, near_window=W,
        k_scale=ksc, v_scale=vsc)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)


def test_pallas_quant_chunked_prefill_matches_ref():
    _, kq, vq, ksc, vsc, tbl, _, _, _ = _quant_pool_case()
    C, H, KV, hd = 8, 4, 2, 32
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    qc = jax.random.normal(ks[0], (C, H, hd), jnp.float32)
    ck = jax.random.normal(ks[1], (C, KV, hd), jnp.float32)
    cv = jax.random.normal(ks[2], (C, KV, hd), jnp.float32)
    W = tbl.shape[1] * kq.shape[1]
    from repro.kernels.prefill_attention import \
        chunked_prefill_attention_pallas
    args = (qc, kq, vq, ck, cv, tbl[0], jnp.int32(0), jnp.int32(17),
            jnp.int32(6))
    out_p = chunked_prefill_attention_pallas(
        *args, near_window=W, k_scale=ksc, v_scale=vsc)
    out_r = ref.chunked_prefill_attention_ref(
        *args, near_window=W, k_scale=ksc, v_scale=vsc)
    np.testing.assert_allclose(np.asarray(out_p), np.asarray(out_r),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# engine: bf16 identity, memory reduction, audit surface
# ---------------------------------------------------------------------------

def test_bf16_kv_dtype_is_the_default_path(dense_setup):
    """kv_dtype='bf16' allocates NO scale pools and keeps the storage dtype
    — the executor traces the exact seed computation (bitwise identity with
    the default config follows: same pools, same code path)."""
    cfg, params = dense_setup
    eng = _mk_engine(cfg, params, kv_dtype="bf16")
    dflt = _mk_engine(cfg, params)
    assert "k_scale" not in eng.pools and "v_scale" not in eng.pools
    assert eng.pools["k"].dtype == dflt.pools["k"].dtype
    assert eng.block_bytes == dflt.block_bytes
    assert eng.scale_bytes_per_block == 0


@pytest.mark.parametrize("kvd", ["fp8_e4m3", "int8"])
def test_quant_engine_runs_and_halves_reserved_kv(dense_setup, kvd):
    cfg, params = dense_setup
    base = _mk_engine(cfg, params)
    _run(base, _reqs())
    q = _mk_engine(cfg, params, kv_dtype=kvd)
    tq = _run(q, _reqs())
    assert len(tq) == 6 and all(len(v) == 24 for v in tq.values())
    ratio = base.peak_reserved_kv / q.peak_reserved_kv
    assert ratio >= 1.8, f"{kvd} reserved-KV ratio {ratio:.2f} < 1.8"
    a = q.audit()
    assert a["kv_dtype"] == kvd
    assert a["quant_bytes_saved"] > 0
    assert a["quant_scale_bytes"] > 0
    assert a["compilations"] == 1 and a["single_commit_per_step"]


def test_quant_pipeline_depths_identical(dense_setup):
    cfg, params = dense_setup
    t0 = _run(_mk_engine(cfg, params, kv_dtype="fp8_e4m3",
                         pipeline_depth=0), _reqs())
    t1 = _run(_mk_engine(cfg, params, kv_dtype="fp8_e4m3",
                         pipeline_depth=1), _reqs())
    assert t0 == t1


def test_quant_chunked_prefill_runs(dense_setup):
    cfg, params = dense_setup
    eng = _mk_engine(cfg, params, kv_dtype="int8", prefill_chunk=8)
    tq = _run(eng, _reqs(plen=24))
    assert eng.audit()["prefill_chunks_run"] > 0
    assert len(tq) == 6


def test_scale_pools_are_block_indexed(dense_setup):
    """The lockstep invariant's mechanical root: scale pools share the data
    pools' physical block axis, so the COW-copy and swap gather/scatter
    loops (engine._block_pool_keys) move them automatically."""
    cfg, params = dense_setup
    eng = _mk_engine(cfg, params, kv_dtype="fp8_e4m3")
    assert set(eng._block_pool_keys) == {"k", "v", "k_scale", "v_scale"}
    assert eng.pools["k_scale"].shape[1] == eng.num_blocks


# ---------------------------------------------------------------------------
# engine: composition with §8 host tier, §9 prefix cache, §4 TP
# ---------------------------------------------------------------------------

def test_quant_prefix_hit_bitwise_identical(dense_setup):
    """A prefix-cache hit aliases the cached (data, scale) chains
    atomically: the warm run reuses byte-identical quantized KV, so its
    token streams match the cold quantized run exactly."""
    cfg, params = dense_setup
    pfx = np.random.default_rng(7).integers(0, 256, size=16).astype(np.int32)

    def preqs():
        rng = np.random.default_rng(11)
        return [Request(rid=i, prompt=np.concatenate(
            [pfx, rng.integers(0, 256, size=5).astype(np.int32)]),
            gen_len=16) for i in range(6)]

    cold = _mk_engine(cfg, params, kv_dtype="fp8_e4m3")
    t_cold = _run(cold, preqs())
    warm = _mk_engine(cfg, params, kv_dtype="fp8_e4m3", prefix_cache=True)
    t_warm = _run(warm, preqs())
    a = warm.audit()
    assert a["prefix_hits"] > 0
    assert t_cold == t_warm


def test_quant_preempt_resume_bitwise_identical(dense_setup):
    """Swap round-trips move narrow blocks AND their scales in lockstep;
    a preempted-and-resumed quantized request matches the unpreempted
    quantized run token for token."""
    cfg, params = dense_setup

    def lreqs():
        rng = np.random.default_rng(1)
        return [Request(rid=i,
                        prompt=rng.integers(0, 256, size=8).astype(np.int32),
                        gen_len=48) for i in range(6)]

    kw = dict(batch=4, max_seq=64, near_window=32, block_tokens=8,
              kv_dtype="fp8_e4m3")
    base = _mk_engine(cfg, params, **kw)
    t_base = _run(base, lreqs())
    over = _mk_engine(cfg, params, pool_budget_frac=0.1,
                      host_pool_blocks=40, **kw)
    t_over = _run(over, lreqs())
    a = over.audit()
    assert a["preemptions"] >= 1, "burst failed to preempt"
    assert a["swap_out_blocks"] > 0
    assert t_base == t_over


def test_quant_under_tp_matches_single_device(dense_setup):
    """Scale pools shard their kv-head axis with the data pools (§4); the
    dequant epilogue is per-kv-head local, so TP greedy decode stays
    token-identical to the single-device quantized engine."""
    cfg, params = dense_setup
    from repro.launch import mesh as mesh_mod
    if len(jax.devices()) < 2:
        pytest.skip("needs >= 2 devices")
    lane = mesh_mod.lane_meshes(mesh_mod.make_engine_mesh(1, 2))[0]
    t_sd = _run(_mk_engine(cfg, params, kv_dtype="fp8_e4m3"), _reqs())
    t_tp = _run(_mk_engine(cfg, params, kv_dtype="fp8_e4m3", mesh=lane),
                _reqs())
    assert t_sd == t_tp


# ---------------------------------------------------------------------------
# engine: unsupported-config guards
# ---------------------------------------------------------------------------

def test_quant_guards(dense_setup):
    cfg, params = dense_setup
    with pytest.raises(ValueError, match="full"):
        _mk_engine(cfg, params, mode="full", kv_dtype="fp8_e4m3")
    with pytest.raises(ValueError, match="kv_dtype"):
        _mk_engine(cfg, params, kv_dtype="fp4")
    cfg_ssm = get_reduced("xlstm-125m")
    params_ssm = registry.init_params(jax.random.PRNGKey(0), cfg_ssm)
    with pytest.raises(ValueError, match="family|dense"):
        KVRMEngine(cfg_ssm, params_ssm,
                   EngineConfig(mode="paged_merge", batch=4, max_seq=96,
                                block_tokens=8, kv_dtype="int8"))
