"""Pipelined decode + chunked prefill: token-for-token equivalence of
pipeline_depth=1 vs the seed-exact pipeline_depth=0 path (and vs the
teacher-forced reference via debug_logits) across all four engine modes,
including EOS bursts, prefix-aliased admissions, and chunked-prefill
boundaries; plus the prefill step-count guarantee and audit invariants
(one compilation per executor, single commit per step, unchanged DMA
groups under pipelining). DESIGN.md §3.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.core.engine import EngineConfig, KVRMEngine
from repro.core.scheduler import Request
from repro.models import registry

MODES = ["arena", "paged", "paged_merge", "full"]


@pytest.fixture(scope="module")
def dense_setup():
    cfg = get_reduced("qwen2.5-32b")
    params = registry.init_params(jax.random.PRNGKey(7), cfg)
    return cfg, params


def _mk_engine(cfg, params, mode, depth, chunk, **kw):
    base = dict(mode=mode, batch=4, max_seq=64, block_tokens=8,
                debug_logits=True, pipeline_depth=depth, prefill_chunk=chunk)
    if mode == "full":
        base.update(max_seq=128, near_window=32, farview_cap=4, sv_chunk=16)
    base.update(kw)
    return KVRMEngine(cfg, params, EngineConfig(**base))


def _run(cfg, params, mode, depth, chunk, reqs_fn, **kw):
    eng = _mk_engine(cfg, params, mode, depth, chunk, **kw)
    for r in reqs_fn():
        eng.submit(r)
    eng.run(max_steps=500)
    return eng


def _mixed_reqs(vocab, with_burst=True):
    rng = np.random.default_rng(0)
    lens = [(5, 6), (17, 4), (3, 8), (33, 5), (9, 7), (21, 3)]
    if with_burst:                      # EOS burst: several finish together
        lens += [(4, 5), (6, 5), (8, 5)]
    def make():
        rng2 = np.random.default_rng(1)
        return [Request(rid=i, prompt=rng2.integers(0, vocab, size=p)
                        .astype(np.int32), gen_len=g)
                for i, (p, g) in enumerate(lens)]
    return make


@pytest.mark.parametrize("mode", MODES)
def test_depth1_matches_depth0(dense_setup, mode):
    """Pipelined decode is bit-identical to the synchronous seed path: same
    tokens, same logits, same step count, same DMA/frame accounting."""
    cfg, params = dense_setup
    reqs = _mixed_reqs(cfg.vocab_size)
    e0 = _run(cfg, params, mode, 0, 0, reqs)
    e1 = _run(cfg, params, mode, 1, 0, reqs)
    t0 = {r.rid: r.generated for r in e0.sched.finished}
    t1 = {r.rid: r.generated for r in e1.sched.finished}
    assert len(t0) == len(t1) == 9
    assert t0 == t1
    for r0 in e0.sched.finished:
        r1 = next(r for r in e1.sched.finished if r.rid == r0.rid)
        np.testing.assert_array_equal(np.stack(r0.logit_trace),
                                      np.stack(r1.logit_trace))
    a0, a1 = e0.audit(), e1.audit()
    assert e0.steps_run == e1.steps_run
    assert a1["single_commit_per_step"]
    assert a1["compilations"] in (-1, 1), a1
    assert a0["dma_groups_per_step"] == pytest.approx(a1["dma_groups_per_step"])
    assert a0["frames_committed"] == a1["frames_committed"]


@pytest.mark.parametrize("depth", [0, 1])
def test_chunked_prefill_matches_tokenwise(dense_setup, depth):
    """Chunked prefill produces the same greedy decode as token-at-a-time
    prefill (bf16-rounding-level logit agreement, identical tokens here) at
    both pipeline depths, across chunk/block boundary cases."""
    cfg, params = dense_setup
    # prompt lengths straddle chunk (8) and block (8) boundaries:
    # below / exact / +1 / multiple / multiple+1 / non-aligned
    lens = [(7, 4), (8, 4), (9, 4), (16, 4), (17, 4), (27, 4)]
    def reqs():
        rng = np.random.default_rng(2)
        return [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=p)
                        .astype(np.int32), gen_len=g)
                for i, (p, g) in enumerate(lens)]
    base = _run(cfg, params, "paged_merge", depth, 0, reqs)
    chk = _run(cfg, params, "paged_merge", depth, 8, reqs)
    tb = {r.rid: r.generated for r in base.sched.finished}
    tc = {r.rid: r.generated for r in chk.sched.finished}
    assert len(tb) == len(tc) == len(lens)
    assert tb == tc
    # chunked path ran fewer engine steps (prompts ingested C tokens/step)
    assert chk.steps_run < base.steps_run
    a = chk.audit()
    assert a["prefill_compilations"] in (-1, 1), a
    assert a["compilations"] in (-1, 1), a
    assert a["single_commit_per_step"]
    assert a["prefill_chunks_run"] > 0
    chk.pager.check_invariants()
    assert chk.pager.reserved_blocks() == 0


def test_chunked_pipeline_matches_reference(dense_setup):
    """depth=1 + chunked prefill vs the teacher-forced full-attention oracle
    (same tolerance contract as the seed engine-vs-reference test)."""
    cfg, params = dense_setup
    def reqs():
        rng = np.random.default_rng(3)
        return [Request(rid=i, prompt=rng.integers(0, cfg.vocab_size, size=p)
                        .astype(np.int32), gen_len=g)
                for i, (p, g) in enumerate([(12, 6), (25, 4)])]
    eng = _run(cfg, params, "paged_merge", 1, 8, reqs)
    import jax.numpy as jnp
    for req in eng.sched.finished:
        toks = list(map(int, req.prompt)) + list(req.generated)
        logits = registry.forward(params, cfg, jnp.asarray([toks], jnp.int32))
        idx = np.arange(len(req.prompt) - 1, len(toks) - 1)
        ref = np.asarray(logits[0, idx], np.float32)
        got = np.stack(req.logit_trace)
        np.testing.assert_allclose(got, ref, rtol=0.05, atol=0.05)


def test_pipelined_alias_prefix(dense_setup):
    """Prefix-aliased admission under pipelining + chunking: the aliased
    session skips the shared prefix and decodes identically to depth 0."""
    cfg, params = dense_setup
    rng = np.random.default_rng(4)
    shared = rng.integers(0, cfg.vocab_size, size=16).astype(np.int32)
    def reqs():
        return [Request(rid=0, prompt=shared.copy(), gen_len=20),
                Request(rid=1, prompt=np.concatenate([shared, shared[:5]]),
                        gen_len=4, prefix_of=0, prefix_len=16)]
    outs = {}
    for depth, chunk in ((0, 0), (1, 0), (1, 8)):
        eng = _run(cfg, params, "paged_merge", depth, chunk, reqs,
                   span_blocks=1)
        assert len(eng.sched.finished) == 2
        outs[(depth, chunk)] = {r.rid: r.generated for r in eng.sched.finished}
        eng.pager.check_invariants()
        assert eng.pager.reserved_blocks() == 0
    assert outs[(0, 0)] == outs[(1, 0)] == outs[(1, 8)]


def _sampled_run(cfg, params, depth, stops, **kw):
    base = dict(mode="paged_merge", batch=4, max_seq=64, block_tokens=4,
                span_blocks=1, pipeline_depth=depth, greedy=False,
                temperature=1.2, top_k=50, top_p=0.95, sample_seed=123)
    base.update(kw)
    eng = KVRMEngine(cfg, params, EngineConfig(**base))
    rng = np.random.default_rng(1)
    lens = [(5, 12), (17, 10), (3, 14), (9, 11), (4, 10), (6, 9)]
    for i, (p, g) in enumerate(lens):
        eng.submit(Request(rid=i, prompt=rng.integers(0, cfg.vocab_size,
                                                      size=p)
                           .astype(np.int32), gen_len=g, stop_tokens=stops))
    eng.run(max_steps=400)
    return eng


# pager counters that must be byte-identical across pipeline depths for a
# sampled run after overshoot reconciliation; 'frames'/'steps' are coupled
# to steps_run, which legitimately differs by the trailing scrubbed-empty
# step when the stopping request was the last active one
PAGER_IDENTITY_EXCLUDE = {"frames", "steps"}


def _pager_subset(eng):
    return {k: v for k, v in eng.pager.stats.items()
            if k not in PAGER_IDENTITY_EXCLUDE}


def _transport_subset(eng, placement=False):
    """Transport counters that must match across depths for a sampled run.
    The count-based figures (slot-steps, bytes, block counts) are exact
    after overshoot scrubbing. ``total_groups`` is placement-SENSITIVE
    (merge trains follow physical contiguity): a stop-retired request frees
    its blocks ``depth`` readback steps later than at depth 0, so a
    neighbour reserving inside that lag window can land on different
    physical blocks — the documented §13 limit. Compare it only in
    uncontended scenarios (``placement=True``)."""
    s = eng.transport.stats
    out = {"steps": s.steps, "total_bytes": s.total_bytes,
           "unmerged_groups": s.unmerged_groups,
           "quant_bytes_saved": s.quant_bytes_saved}
    if placement:
        out["total_groups"] = s.total_groups
    return out


def test_sampled_lagged_eos_depth_identity(dense_setup):
    """DESIGN.md §13 acceptance: sampled decode with per-request stop
    tokens retires on DETECTED EOS at depths 0, 1 and 2 — the host learns
    of a stop ``depth`` steps late, scrubs the overshoot dispatches, and
    the depth>0 token streams AND pager/transport audits come out
    byte-identical to depth 0, with zero leaked blocks. span_blocks=1 +
    block_tokens=4 force overshoot steps across block boundaries so the
    reconcile path actually pops committed tail blocks."""
    cfg, params = dense_setup
    # harvest stop ids from a stop-free probe so stops are guaranteed to
    # land mid-stream (detected EOS, not just the budget cap)
    probe = _sampled_run(cfg, params, 0, ())
    pool = sorted({t for r in probe.sched.finished for t in r.generated[1:-2]})
    stops = tuple(pool[:6])
    runs = {d: _sampled_run(cfg, params, d, stops) for d in (0, 1, 2)}

    toks = {d: {r.rid: list(map(int, r.generated))
                for r in e.sched.finished} for d, e in runs.items()}
    assert len(toks[0]) == 6
    a0 = runs[0].audit()
    assert a0["eos_detected"] > 0          # stops actually fired
    assert any(r.finish_reason == "stop" for r in runs[0].sched.finished)
    assert any(r.finish_reason == "budget" for r in runs[0].sched.finished)
    assert a0["eos_overshoot_tokens"] == 0  # depth 0 never overshoots
    for d in (1, 2):
        ad = runs[d].audit()
        assert toks[d] == toks[0], f"depth {d} token stream diverged"
        assert ad["eos_detected"] == a0["eos_detected"]
        # every overshoot dispatch was scrubbed: one per in-flight step per
        # stop-retired request, bounded by depth * detected stops
        assert 0 < ad["eos_overshoot_tokens"] <= d * len(toks[0])
        assert ad["eos_reconciled_blocks"] > 0   # tail blocks were popped
        assert _pager_subset(runs[d]) == _pager_subset(runs[0])
        assert _transport_subset(runs[d]) == _transport_subset(runs[0])
        assert ad["kernel_blocks_total"] == a0["kernel_blocks_total"]
        assert ad["kernel_blocks_skipped"] == a0["kernel_blocks_skipped"]
        assert ad["single_commit_per_step"]
        runs[d].pager.check_invariants()
        assert runs[d].pager.reserved_blocks() == 0   # zero leaked blocks
    # throughput numerator excludes scrubbed tokens: emitted sums match
    assert sum(m.emitted for m in runs[1].metrics) == \
        sum(m.emitted for m in runs[0].metrics)


def test_sampled_budget_eos_depth_identity(dense_setup):
    """Budget-capped sampled requests (no stop set) ALSO retire at readback
    and overshoot by <= depth dispatches — the same reconcile path must
    leave the audits byte-identical to depth 0."""
    cfg, params = dense_setup
    runs = {d: _sampled_run(cfg, params, d, ()) for d in (0, 1)}
    toks = {d: {r.rid: list(map(int, r.generated))
                for r in e.sched.finished} for d, e in runs.items()}
    assert toks[1] == toks[0]
    assert all(r.finish_reason == "budget" for r in runs[1].sched.finished)
    a1 = runs[1].audit()
    assert a1["eos_detected"] == 0
    assert a1["eos_overshoot_tokens"] > 0
    assert _pager_subset(runs[1]) == _pager_subset(runs[0])
    assert _transport_subset(runs[1], placement=True) == \
        _transport_subset(runs[0], placement=True)
    assert runs[1].pager.reserved_blocks() == 0


def test_sampled_uncontended_stop_full_identity(dense_setup):
    """With non-overlapping request lifetimes (no neighbour allocates
    inside a retirement lag window) the §13 reconcile restores the pager's
    free structure POSITIONALLY, so even the placement-sensitive merge
    group count is byte-identical across depths: the late request's blocks
    land exactly where the depth-0 timeline put them."""
    cfg, params = dense_setup
    rng = np.random.default_rng(1)
    prompts = [rng.integers(0, cfg.vocab_size, size=p).astype(np.int32)
               for p in (5, 7)]

    def run(depth, stops):
        eng = KVRMEngine(cfg, params, EngineConfig(
            mode="paged_merge", batch=4, max_seq=64, block_tokens=4,
            span_blocks=1, pipeline_depth=depth, greedy=False,
            temperature=1.2, top_k=50, top_p=0.95, sample_seed=123))
        eng.submit(Request(rid=0, prompt=prompts[0], gen_len=14,
                           stop_tokens=stops))
        # rid 1 arrives only after rid 0 has fully retired (and any
        # overshoot was reconciled) at every depth under test
        eng.submit(Request(rid=1, prompt=prompts[1], gen_len=8,
                           arrival=40.0, stop_tokens=stops))
        eng.run(max_steps=300, now_fn=lambda: float(eng.steps_run))
        return eng

    probe = run(0, ())
    toks0 = {r.rid: r.generated for r in probe.sched.finished}
    stops = (toks0[0][5],)      # mid-stream stop for rid 0
    runs = {d: run(d, stops) for d in (0, 1, 2)}
    toks = {d: {r.rid: list(map(int, r.generated))
                for r in e.sched.finished} for d, e in runs.items()}
    assert runs[0].audit()["eos_detected"] >= 1
    for d in (1, 2):
        assert toks[d] == toks[0]
        assert runs[d].audit()["eos_overshoot_tokens"] > 0
        assert _pager_subset(runs[d]) == _pager_subset(runs[0])
        assert _transport_subset(runs[d], placement=True) == \
            _transport_subset(runs[0], placement=True)
        assert runs[d].pager.reserved_blocks() == 0


def test_prefill_step_count():
    """A 256-token prompt completes prefill in <= 256/chunk + 1 engine steps
    (vs 256 at seed): the chunked executor ingests C tokens per step and the
    decode step feeds the final prompt token."""
    cfg = get_reduced("qwen2.5-32b")
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    C = 64
    prompt = np.random.default_rng(5).integers(
        0, cfg.vocab_size, size=256).astype(np.int32)
    eng = KVRMEngine(cfg, params, EngineConfig(
        mode="paged_merge", batch=2, max_seq=512, block_tokens=8,
        pipeline_depth=1, prefill_chunk=C))
    eng.submit(Request(rid=0, prompt=prompt, gen_len=3))
    eng.run(max_steps=300)
    req = eng.sched.finished[0]
    # first_token_step is the engine step that fed the LAST prompt token
    steps_to_prefill = req.first_token_step - req.start_step + 1
    assert steps_to_prefill <= 256 // C + 1, steps_to_prefill
    assert len(req.generated) == 3
    a = eng.audit()
    assert a["prefill_chunks_run"] == -(-255 // C)
    assert a["single_commit_per_step"]


def test_pipeline_flush_on_partial_run(dense_setup):
    """Manually stepped engines finalize generated tokens on flush()."""
    cfg, params = dense_setup
    eng = _mk_engine(cfg, params, "paged_merge", 1, 0)
    eng.submit(Request(rid=0, prompt=np.arange(4, dtype=np.int32), gen_len=6))
    for _ in range(6):
        eng.step()
    eng.flush()
    req = eng.sched.requests[0]
    # 4 prefill steps + 2 decode emissions read back after flush
    assert len(req.generated) == 3  # steps 4,5,6 emit; 3 values after flush
    eng.run(max_steps=50)
    assert len(eng.sched.finished) == 1
    assert len(req.generated) == 6
