"""End-to-end driver: replay a bursty Azure-like window through the
static-arena baseline and KV-RM, side by side — the paper's Fig. 4(a-b)
experiment at CPU scale.

    PYTHONPATH=src python examples/serve_trace_replay.py
"""
import time

import jax
import numpy as np

from repro.configs import get_reduced
from repro.core.engine import EngineConfig, KVRMEngine
from repro.data import traces
from repro.models import registry


def replay(mode: str, slots: int, budget: float):
    cfg = get_reduced("qwen2.5-32b")
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    eng = KVRMEngine(cfg, params, EngineConfig(
        mode=mode, batch=slots, max_seq=256, block_tokens=8,
        pool_budget_frac=budget))
    reqs = traces.azure_like_replay(traces.TraceConfig(
        n_requests=32, token_scale=0.25, vocab=cfg.vocab_size, seed=11))
    for r in reqs:
        eng.submit(r)
    t0 = time.perf_counter()
    eng.run(max_steps=100_000,
            now_fn=lambda: (time.perf_counter() - t0) / 0.01)
    return eng


def main():
    tcfg = traces.TraceConfig(n_requests=32, token_scale=0.25, vocab=256, seed=11)
    print("trace heterogeneity:", traces.trace_summary(
        traces.azure_like_replay(tcfg)))
    print(f"\n{'system':14s} {'tok/s':>8s} {'p99 ms':>8s} {'p99.9 ms':>9s} "
          f"{'max spike':>10s} {'reserved KV':>12s}")
    # same device budget: arena worst-case buys 4 slots, paged buys 8
    for mode, slots, budget in (("arena", 4, 1.0), ("paged_merge", 8, 0.5)):
        eng = replay(mode, slots, budget)
        lat = eng.latency_stats()
        print(f"{mode:14s} {eng.throughput():8.1f} {lat['p99_ms']:8.2f} "
              f"{lat['p999_ms']:9.2f} {lat['max_ms']:10.2f} "
              f"{eng.reserved_kv_bytes():12d}")


if __name__ == "__main__":
    main()
