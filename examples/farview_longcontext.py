"""Far-view summarization demo (the paper's optional bounded-budget policy):
serve a long-context request whose history exceeds the near window; far
chunks are summarized on-device, their blocks trimmed, and the EMA utility
scorer keeps the summaries the query actually attends to.

    PYTHONPATH=src python examples/farview_longcontext.py
"""
import jax
import numpy as np

from repro.configs import get_reduced
from repro.core.engine import EngineConfig, KVRMEngine
from repro.core.scheduler import Request
from repro.models import registry


def main():
    cfg = get_reduced("qwen3-32b")
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    eng = KVRMEngine(cfg, params, EngineConfig(
        mode="full",             # core path + far-view summarization
        batch=2, max_seq=512,
        near_window=32,          # W*: tiny so far history accumulates fast
        farview_cap=6, sv_chunk=16, block_tokens=8))

    rng = np.random.default_rng(0)
    eng.submit(Request(rid=0,
                       prompt=rng.integers(0, cfg.vocab_size, 120).astype(np.int32),
                       gen_len=60))
    eng.run()

    a = eng.audit()
    print("chunks summarized :", int(eng.fv.n_chunks.sum()) if eng.fv else 0)
    print("blocks trimmed    :", eng.pager.stats["blocks_freed"])
    print("reserved KV bytes :", a["reserved_kv_bytes"],
          "(stays O(W* + cap) despite 180-token history)")
    print("DMA groups/step   :", round(a["dma_groups_per_step"], 2),
          "(near train + far train)")
    print("single-commit     :", a["single_commit_per_step"])


if __name__ == "__main__":
    main()
