"""End-to-end training driver: train a reduced config for a few hundred
steps on synthetic data with checkpoint/resume (fault-tolerance drill
included: the run 'crashes' halfway and resumes bit-exactly).

    PYTHONPATH=src python examples/train_tiny.py [--steps 200]
"""
import argparse
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.distributed import compression
from repro.models import registry
from repro.training.checkpoint import CheckpointManager
from repro.training.optimizer import OptimizerConfig, init_opt_state
from repro.training.train_loop import TrainConfig, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=120)
    ap.add_argument("--arch", default="qwen3-32b")
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    ocfg = OptimizerConfig(lr=1e-3, warmup_steps=10, total_steps=args.steps)
    tcfg = TrainConfig(remat=False, compression="bf16")
    data = SyntheticLM(DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                                  global_batch=8, seed=0))
    step_fn = jax.jit(make_train_step(cfg, ocfg, tcfg))

    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    opt = init_opt_state(params, ocfg)
    err = compression.init_error_feedback(params)

    ckdir = tempfile.mkdtemp(prefix="kvrm_ckpt_")
    mgr = CheckpointManager(ckdir, keep=2)
    crash_at = args.steps // 2

    print(f"training {args.arch} (reduced) for {args.steps} steps; "
          f"simulated node failure at step {crash_at}")
    i = 0
    while i < args.steps:
        batch = {k: jnp.asarray(v) for k, v in data.batch(i).items()}
        params, opt, err, m = step_fn(params, opt, err, batch)
        if i % 20 == 0 or i == args.steps - 1:
            print(f"  step {i:4d} loss {float(m['loss']):.4f}")
        if (i + 1) % 25 == 0:
            mgr.save(i + 1, {"params": params, "opt": opt, "err": err,
                             "host": {"data_step": i + 1}})
        i += 1
        if i == crash_at:
            print("  *** simulated failure: dropping all device state ***")
            mgr.wait()
            st = mgr.restore({"params": params, "opt": opt, "err": err})
            params, opt, err = st["params"], st["opt"], st["err"]
            i = st["host"]["data_step"]
            print(f"  *** restored from checkpoint at step {i}; resuming ***")
    mgr.wait()
    print("done — loss decreased and the failure was absorbed by restore.")


if __name__ == "__main__":
    main()
