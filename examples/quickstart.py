"""Quickstart: serve a small model through the KV-RM engine and inspect the
paper's invariants (fixed-shape decode, single frame commit per step, merged
transport trains, reserved-vs-active KV tracking).

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_reduced
from repro.core.engine import EngineConfig, KVRMEngine
from repro.core.scheduler import Request
from repro.models import registry


def main():
    # 1. a reduced qwen2.5 config (same family the paper serves) ------------
    cfg = get_reduced("qwen2.5-32b")
    params = registry.init_params(jax.random.PRNGKey(0), cfg)

    # 2. the KV-RM engine: fixed slot width, paged KV, merged transport -----
    eng = KVRMEngine(cfg, params, EngineConfig(
        mode="paged_merge",      # the paper's dense-semantic core path
        batch=4,                 # fixed execution width (compiled once)
        max_seq=128,
        block_tokens=8))         # BLOCKALIGN quantum

    # 3. submit mixed-length requests ---------------------------------------
    rng = np.random.default_rng(0)
    for i, (plen, glen) in enumerate([(12, 20), (5, 8), (30, 4)]):
        eng.submit(Request(rid=i,
                           prompt=rng.integers(0, cfg.vocab_size, plen).astype(np.int32),
                           gen_len=glen))

    # 4. run to completion; everything happens under ONE compiled decode step
    eng.run()

    for req in eng.sched.finished:
        print(f"request {req.rid}: prompt[{len(req.prompt)}] -> "
              f"generated {req.generated}")

    # 5. the invariants the paper audits ------------------------------------
    audit = eng.audit()
    print("\n--- invariant audit ---")
    print(f"decode compilations          : {audit['compilations']} (must be 1)")
    print(f"single frame commit per step : {audit['single_commit_per_step']}")
    print(f"host control share           : {audit['submit_share']:.1%}")
    print(f"frame commit cost            : {audit['frame_commit_us']:.0f} us/step")
    print(f"DMA groups per step (merged) : {audit['dma_groups_per_step']:.2f}")
    print(f"avg merged transfer          : {audit['avg_dma_bytes']/1024:.1f} KiB")
    print(f"reserved KV after idle       : {audit['reserved_kv_bytes']} bytes")


if __name__ == "__main__":
    main()
