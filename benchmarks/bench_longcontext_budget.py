"""Fig. 5 — long-context scaling under full vs tight-20% KV budgets
(bounded-budget far-view path keeps reserved bytes and control costs flat
as context grows)."""
from benchmarks.common import engine, print_rows, row, run_workload
from repro.data import traces
from repro.core.scheduler import Request
import numpy as np


def run():
    rows = []
    for ctx in (128, 256, 512):
        for budget, tag in ((1.0, "full"), (0.8, "tight20")):
            eng = engine("full", batch=2, max_seq=ctx + 64, near_window=32,
                         farview_cap=8, sv_chunk=16, pool_budget=budget)
            rng = np.random.default_rng(ctx)
            for i in range(3):
                eng.submit(Request(
                    rid=i, prompt=rng.integers(0, 200, size=ctx // 2).astype(np.int32),
                    gen_len=24))
            run_workload(eng, [])
            a = eng.audit()
            lat = eng.latency_stats()
            rows.append(row(f"longctx/ctx={ctx}/{tag}", lat["mean_ms"] * 1e3,
                            tok_s=eng.throughput(), p99_ms=lat["p99_ms"],
                            peak_reserved_kv=a["peak_reserved_kv"],
                            frame_commit_us=a["frame_commit_us"],
                            submit_share=a["submit_share"],
                            dma_groups=a["dma_groups_per_step"]))
    return rows


if __name__ == "__main__":
    print_rows(run())
