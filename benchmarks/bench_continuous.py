"""Step-level (continuous) batching A/B (DESIGN.md §15).

Two sections:

* **short_behind_long** — the head-of-line-blocking headline: a handful
  of long requests pin their slots while a stream of short requests
  queues behind them, all offered at t=0. The round-based baseline
  (``continuous_batching=False``) holds every freed slot idle until the
  whole round drains, so a queued short request's TTFT is bounded below
  by the LONGEST co-scheduled request; continuous admission refills each
  slot the step after it frees, so short TTFT collapses to the first
  freed short slot. The PR acceptance bar — continuous p99 TTFT <= 0.6x
  round-based at equal offered load — is asserted in-run, and CI
  promotes this row's ``ttft_p99_ms`` to a hard perf gate.
* **identity** — the mode moves WHEN a request runs, never WHAT it
  computes: per-rid token streams must be bitwise identical between the
  continuous and round-based arms at pipeline depths 0 and 1
  (``token_divergence`` hard-gated), with zero leaked blocks
  (``alloc_failures``) and the A/B counter witnesses intact
  (``continuous_admits`` / ``slot_idle_steps_saved`` identically 0 on
  the round arm, ``admit_blocked_round_barrier`` 0 on the continuous
  arm).
"""
import numpy as np

from benchmarks.common import (engine, print_rows, record_audit, row,
                               run_workload, smoke_scale)
from repro.core.scheduler import Request

KW = dict(mode="paged_merge", batch=4, max_seq=64, block_tokens=8)


def _warm(eng, vocab=256):
    """Pay the one-time executor compile (seconds on CPU) before the timed
    run, so TTFT measures queueing, not compilation."""
    rng = np.random.default_rng(99)
    eng.submit(Request(rid=10_000, prompt=rng.integers(0, vocab, size=8)
                       .astype(np.int32), gen_len=3))
    eng.run(max_steps=100)
    eng.sched.finished.clear()


def _leaks(eng) -> int:
    return eng.pager.reserved_blocks() + eng.pager.host_used


def _short_behind_long():
    """2 long + N short requests, all arrived at t=0. FIFO admission puts
    both longs (and 2 shorts) in the first round; every remaining short
    queues behind the longs — the workload the round barrier hurts most."""
    rng = np.random.default_rng(11)
    long_gen = max(24, int(48 * smoke_scale()))
    n_short = max(8, int(10 * smoke_scale()))
    reqs = [Request(rid=i, prompt=rng.integers(0, 256, size=8)
                    .astype(np.int32), gen_len=long_gen) for i in range(2)]
    reqs += [Request(rid=2 + i, prompt=rng.integers(0, 256, size=4)
                     .astype(np.int32), gen_len=2) for i in range(n_short)]
    return reqs


def _run_arm(continuous: bool, depth: int):
    eng = engine(**KW, pipeline_depth=depth,
                 continuous_batching=continuous)
    _warm(eng)
    step0, wall0 = eng.steps_run, eng.cum_wall
    reqs = _short_behind_long()
    for r in reqs:
        # anchor arrivals at the post-warm clock so the warm run's compile
        # wall never pollutes latency accounting
        r.arrival = eng.cum_wall
    run_workload(eng, reqs, warmup=0)
    eng.flush()
    streams = {r.rid: list(map(int, r.generated)) for r in eng.sched.finished}
    # TTFT from the dispatch-step schedule, scaled by this arm's mean step
    # wall time: the admission schedule is deterministic (greedy decode,
    # fixed workload), so step-anchored TTFT is bitwise-reproducible
    # across runs and XLA profiles — a raw wall-clock p99 over ~12
    # requests is a max-like statistic where one scheduler hiccup on one
    # queued short flips the A/B gate
    step_ms = (eng.cum_wall - wall0) / max(1, eng.steps_run - step0) * 1e3
    tt = sorted(r.first_token_step - step0 for r in eng.sched.finished)
    tpot = eng.request_latency_stats()["tpot_p99_ms"]
    stats = {"ttft_p50_steps": float(np.percentile(tt, 50)),
             "ttft_p99_steps": float(np.percentile(tt, 99)),
             "ttft_p50_ms": float(np.percentile(tt, 50)) * step_ms,
             "ttft_p99_ms": float(np.percentile(tt, 99)) * step_ms,
             "tpot_p99_ms": tpot}
    return eng, streams, stats


def _divergence(a: dict, b: dict) -> int:
    return sum(1 for rid in set(a) | set(b) if a.get(rid) != b.get(rid))


def _assert_witnesses(cont_audit: dict, round_audit: dict) -> None:
    assert round_audit["continuous_admits"] == 0 \
        and round_audit["slot_idle_steps_saved"] == 0, \
        "round arm admitted mid-round — the barrier leaked"
    assert cont_audit["admit_blocked_round_barrier"] == 0, \
        "continuous arm hit the round barrier"
    assert cont_audit["continuous_admits"] > 0, \
        "short-behind-long never exercised a mid-round admission"


def _short_behind_long_rows(rows):
    arms = {cb: _run_arm(cb, depth=1) for cb in (True, False)}
    (ce, cs, cstat), (re_, rs, rstat) = arms[True], arms[False]
    div = _divergence(cs, rs)
    leaks = _leaks(ce) + _leaks(re_)
    ca, ra = ce.audit(), re_.audit()
    _assert_witnesses(ca, ra)
    # the A/B ratio compares the deterministic dispatch-step schedules, so
    # it cannot flap on per-arm step wall-time variance
    ratio = cstat["ttft_p99_steps"] / max(1e-9, rstat["ttft_p99_steps"])

    tag = "continuous/short_behind_long"
    rows.append(row(tag, cstat["ttft_p50_ms"] * 1e3,
                    ttft_p99_ms=cstat["ttft_p99_ms"],
                    ttft_p99_steps=cstat["ttft_p99_steps"],
                    tpot_p99_ms=cstat["tpot_p99_ms"],
                    ttft_p99_ratio=ratio,
                    continuous_admits=ca["continuous_admits"],
                    slot_idle_steps_saved=ca["slot_idle_steps_saved"],
                    finished=len(cs),
                    token_divergence=div, alloc_failures=leaks))
    record_audit(tag, ca)
    rtag = "continuous/round_baseline"
    rows.append(row(rtag, rstat["ttft_p50_ms"] * 1e3,
                    ttft_p99_ms=rstat["ttft_p99_ms"],
                    ttft_p99_steps=rstat["ttft_p99_steps"],
                    tpot_p99_ms=rstat["tpot_p99_ms"],
                    round_barrier_stalls=ra["admit_blocked_round_barrier"],
                    finished=len(rs),
                    token_divergence=0, alloc_failures=0))
    record_audit(rtag, ra)

    assert div == 0, f"{tag}: continuous batching changed WHAT, not WHEN"
    assert leaks == 0, f"{tag}: {leaks} leaked blocks"
    assert len(cs) == len(rs) == len(_short_behind_long())
    assert ratio <= 0.6, \
        f"continuous p99 TTFT {cstat['ttft_p99_steps']:.0f} steps not <= " \
        f"0.6x round-based {rstat['ttft_p99_steps']:.0f} steps at equal " \
        f"offered load"


def _identity_rows(rows):
    for depth in (0, 1):
        arms = {cb: _run_arm(cb, depth=depth) for cb in (True, False)}
        (ce, cs, _), (re_, rs, _) = arms[True], arms[False]
        div = _divergence(cs, rs)
        leaks = _leaks(ce) + _leaks(re_)
        _assert_witnesses(ce.audit(), re_.audit())
        tag = f"continuous/identity_d{depth}"
        rows.append(row(tag, 0.0, token_divergence=div,
                        alloc_failures=leaks, finished=len(cs)))
        assert div == 0, f"{tag}: stream identity broken at depth {depth}"
        assert leaks == 0, f"{tag}: {leaks} leaked blocks"
        for eng in (ce, re_):
            eng.pager.check_invariants()


def run():
    rows = []
    _short_behind_long_rows(rows)
    _identity_rows(rows)
    return rows


if __name__ == "__main__":
    print_rows(run())
