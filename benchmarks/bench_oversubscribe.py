"""Host-tier KV oversubscription under bursts (DESIGN.md §8).

Replays the same bursty heavy-tailed trace twice: once with an ample
device pool (the baseline that defines the true working set), then with
the device pool shrunk to ``peak / OVERSUB`` and the host tier absorbing
the difference via cold swap-out + preemption-aware scheduling. The
oversubscribed run must complete with ZERO allocation failures and ZERO
token-level divergence vs the baseline (swap round-trips preserve KV
bytes exactly; block remapping is invisible through the block table).

Reported per row: tokens/s, step p99, request completion/TTFT p99, swap
bytes/groups, preemption count, host-pool peak, achieved oversubscription
ratio — all folded into the ``run.py --json`` artifact (BENCH_PR<n>.json)
and recorded engine audits.
"""
import numpy as np

from benchmarks.common import engine, print_rows, record_audit, row, \
    run_workload, smoke_scale
from repro.core.scheduler import Request
from repro.data import traces

OVERSUB = 1.5          # target device-KV oversubscription ratio


def _tokens(eng):
    return {r.rid: list(r.generated) for r in eng.sched.finished}


def _mk_reqs(n):
    # moderately uniform lengths on top of the bursty arrival process:
    # simultaneous block-boundary crossings are what force preemption
    tcfg = traces.TraceConfig(n_requests=n, token_scale=1.0, vocab=256,
                              seed=17, burstiness=2.0, prompt_mean=24)
    reqs = traces.azure_like_replay(tcfg)
    # near-homogeneous generation lengths on the bursty arrival process
    # (same-task fanout bursts): concurrent sessions grow in near-lockstep,
    # so their block-boundary crossings collide — the demand spike cold
    # swap cannot absorb, forcing preemption + resume
    for r in reqs:
        r.gen_len = min(144 + (r.rid % 3) * 8, 224 - len(r.prompt))
    return reqs


def run():
    rows = []
    n = max(8, int(24 * smoke_scale()))
    # near_window sized so the batch's windows do NOT all fit the shrunken
    # device pool: cold swap alone can't absorb the burst and the scheduler
    # must preempt (the baseline pool still holds everything)
    kw = dict(batch=4, max_seq=256, near_window=128, block_tokens=8)

    # --- baseline: ample device pool, no host tier --------------------
    base = engine("paged_merge", pool_budget=1.0, **kw)
    run_workload(base, _mk_reqs(n), replay_scale=0.01)
    t_base = _tokens(base)
    lat = base.latency_stats()
    rl = base.request_latency_stats()
    # peak_reserved_kv counts all paged layers; back out the block count
    n_layers = base.pool_bytes_total // ((base.num_blocks - 1)
                                         * base.block_bytes)
    peak_blocks = -(-base.peak_reserved_kv // (base.block_bytes * n_layers))
    rows.append(row("oversubscribe/baseline", lat["mean_ms"] * 1e3,
                    tok_s=base.throughput(), step_p99_ms=lat["p99_ms"],
                    completion_p99_ms=rl["completion_p99_ms"],
                    ttft_p99_ms=rl["ttft_p99_ms"],
                    peak_reserved_kv=base.peak_reserved_kv,
                    peak_blocks=peak_blocks,
                    finished=len(base.sched.finished)))
    record_audit("oversubscribe/baseline", base.audit())

    # --- oversubscribed: device pool = peak / OVERSUB + host tier -----
    worst = kw["batch"] * (-(-kw["max_seq"] // kw["block_tokens"]) + 1)
    dev_blocks = max(12, int(peak_blocks / OVERSUB))   # floor: ratio >= 1.5
    host_blocks = peak_blocks - dev_blocks + 8      # slack for span placement
    over = engine("paged_merge", pool_budget=dev_blocks / worst,
                  host_pool_blocks=host_blocks, **kw)
    alloc_failures = 0
    try:
        run_workload(over, _mk_reqs(n), replay_scale=0.01)
    except MemoryError:
        alloc_failures = 1
        raise
    finally:
        t_over = _tokens(over)
        diverged = sum(1 for rid, toks in t_over.items()
                       if t_base.get(rid) != toks)
        a = over.audit()
        lat = over.latency_stats()
        rl = over.request_latency_stats() or {"completion_p99_ms": 0.0,
                                              "ttft_p99_ms": 0.0}
        rows.append(row(
            f"oversubscribe/host_tier_{OVERSUB}x", lat["mean_ms"] * 1e3,
            tok_s=over.throughput(), step_p99_ms=lat["p99_ms"],
            completion_p99_ms=rl["completion_p99_ms"],
            ttft_p99_ms=rl["ttft_p99_ms"],
            oversubscribe_ratio=peak_blocks / (over.num_blocks - 1),
            device_pool_blocks=over.num_blocks - 1,
            host_pool_blocks=a["host_pool_blocks"],
            host_blocks_peak=a["host_blocks_peak"],
            preemptions=a["preemptions"],
            swap_bytes=a["swap_bytes"], swap_groups=a["swap_groups"],
            swap_out_blocks=a["swap_out_blocks"],
            swap_in_blocks=a["swap_in_blocks"],
            admit_blocked_no_slot=a["admit_blocked_no_slot"],
            admit_blocked_kv_watermark=a["admit_blocked_kv_watermark"],
            alloc_failures=alloc_failures, token_divergence=diverged,
            peak_reserved_kv=over.peak_reserved_kv,
            finished=len(over.sched.finished)))
        record_audit(f"oversubscribe/host_tier_{OVERSUB}x", a)
    assert diverged == 0, f"{diverged} requests diverged under oversubscription"

    # --- async movement A/B (DESIGN.md §11): same 1.5x-oversubscribed
    # burst replay with the async movement engine ON vs OFF, at both
    # pipeline depths. The overlap may only change WHEN transfers run:
    # every row must emit bitwise-identical tokens vs the ample-pool
    # baseline with zero allocation failures, while the ON rows show the
    # blocking-movement stall (swap_stall_ms) shrinking and the overlap
    # witnesses (overlap_steps / deferred_readbacks) moving off zero.
    for depth in (0, 1):
        for async_on in (False, True):
            ab = engine("paged_merge", pool_budget=dev_blocks / worst,
                        host_pool_blocks=host_blocks, pipeline_depth=depth,
                        async_movement=async_on, **kw)
            ab_failures = 0
            try:
                run_workload(ab, _mk_reqs(n), replay_scale=0.01)
            except MemoryError:
                ab_failures = 1
                raise
            finally:
                t_ab = _tokens(ab)
                ab_div = sum(1 for rid, toks in t_ab.items()
                             if t_base.get(rid) != toks)
                a = ab.audit()
                lat = ab.latency_stats()
                tag = (f"oversubscribe/async_{'on' if async_on else 'off'}"
                       f"_depth{depth}")
                rows.append(row(
                    tag, lat["mean_ms"] * 1e3,
                    tok_s=ab.throughput(), step_p99_ms=lat["p99_ms"],
                    swap_stall_ms=a["swap_stall_ms"],
                    overlap_steps=a["overlap_steps"],
                    deferred_readbacks=a["deferred_readbacks"],
                    staging_reuse_bytes=a["staging_reuse_bytes"],
                    swap_bytes=a["swap_bytes"],
                    swap_out_blocks=a["swap_out_blocks"],
                    swap_in_blocks=a["swap_in_blocks"],
                    preemptions=a["preemptions"],
                    alloc_failures=ab_failures, token_divergence=ab_div,
                    finished=len(ab.sched.finished)))
                record_audit(tag, a)
            assert ab_div == 0, \
                f"{tag}: {ab_div} requests diverged under async A/B"
            if not async_on:
                assert a["overlap_steps"] == a["deferred_readbacks"] \
                    == a["staging_reuse_bytes"] == 0, \
                    f"{tag}: overlap counters moved with async off"

    # --- lockstep burst: deterministic preemption/resume exercise ------
    # The replay rows above gate admission on the wall clock, so WHETHER a
    # preemption fires varies run to run (cold swap + watermarks may absorb
    # the burst entirely). This clock-free burst (all arrivals at t=0,
    # uniform lengths -> colliding block-boundary crossings, pool at ~1/3)
    # preempts deterministically, so the swap-in/resume path and its audit
    # fields are exercised on every CI run.
    def _lockstep_reqs():
        rng = np.random.default_rng(1)
        return [Request(rid=i, prompt=rng.integers(0, 256, size=8)
                        .astype(np.int32), gen_len=48) for i in range(6)]

    lk = dict(batch=4, max_seq=64, near_window=32, block_tokens=8)
    lbase = engine("paged_merge", **lk)
    run_workload(lbase, _lockstep_reqs())
    t_lbase = _tokens(lbase)
    lover = engine("paged_merge", pool_budget=0.1, host_pool_blocks=40, **lk)
    run_workload(lover, _lockstep_reqs())
    a = lover.audit()
    diverged = sum(1 for rid, toks in _tokens(lover).items()
                   if t_lbase.get(rid) != toks)
    lat = lover.latency_stats()
    rows.append(row("oversubscribe/lockstep_burst", lat["mean_ms"] * 1e3,
                    tok_s=lover.throughput(), step_p99_ms=lat["p99_ms"],
                    device_pool_blocks=lover.num_blocks - 1,
                    preemptions=a["preemptions"],
                    swap_bytes=a["swap_bytes"], swap_groups=a["swap_groups"],
                    swap_in_blocks=a["swap_in_blocks"],
                    host_blocks_peak=a["host_blocks_peak"],
                    swap_stall_ms=a["swap_stall_ms"],
                    deferred_readbacks=a["deferred_readbacks"],
                    staging_reuse_bytes=a["staging_reuse_bytes"],
                    token_divergence=diverged,
                    finished=len(lover.sched.finished)))
    record_audit("oversubscribe/lockstep_burst", a)
    assert diverged == 0
    assert a["preemptions"] >= 1, "lockstep burst failed to preempt"
    return rows


if __name__ == "__main__":
    print_rows(run())
