"""Fig. 7 — boundary stress: (a-c) concurrency sweep auditing the
single-commit invariant and control-plane share; (d-f) fragmentation regimes
for descriptor merging."""
import numpy as np

from benchmarks.common import engine, print_rows, row, run_workload
from repro.core.transport import MergeStagedTransport
from repro.data import traces


def run():
    rows = []
    # (a-c) concurrency sweep
    for B in (4, 8, 16, 32):
        eng = engine("paged_merge", batch=B, max_seq=128, pool_budget=0.75)
        reqs = traces.mixed_length_workload(traces.TraceConfig(
            n_requests=2 * B, token_scale=0.2, vocab=eng.cfg.vocab_size, seed=B))
        run_workload(eng, reqs)
        a = eng.audit()
        rows.append(row(f"stress/concurrency/B={B}",
                        eng.latency_stats()["mean_ms"] * 1e3,
                        single_commit=int(a["single_commit_per_step"]),
                        compilations=a["compilations"],
                        submit_share=a["submit_share"],
                        frame_commit_us=a["frame_commit_us"],
                        tok_s=eng.throughput(),
                        p99_ms=eng.latency_stats()["p99_ms"]))
    # (d-f) fragmentation regimes
    rng = np.random.default_rng(0)
    regimes = {
        "contiguous": list(range(1, 33)),
        "mild": [b + (i // 8) * 4 for i, b in enumerate(range(1, 33))],
        "strong": [b + (i // 2) * 3 for i, b in enumerate(range(1, 33))],
        "adversarial": list(rng.permutation(np.arange(1, 400))[:32]),
    }
    for name, blocks in regimes.items():
        for merging in (True, False):
            t = MergeStagedTransport(block_bytes=4096,
                                     merge_threshold_bytes=128 * 1024,
                                     max_hold_steps=2, max_trains=64)
            _, groups = t.reduce(blocks, merging=merging)
            tag = "merged" if merging else "unmerged"
            rows.append(row(f"stress/frag/{name}/{tag}", 0.0,
                            dma_groups=groups,
                            avg_bytes=t.stats.avg_group_bytes))
    return rows


if __name__ == "__main__":
    print_rows(run())
