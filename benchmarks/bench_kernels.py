"""Kernel-level microbench: jnp reference paged decode attention under
merged-contiguous vs fragmented block tables, and prefill flash vs dense.
(Wall numbers are CPU-reference; TPU behavior is covered by the dry-run
roofline — this tracks relative regressions.)"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_rows, row
from repro.kernels import ref
from repro.models.common import attention_blocked, attention_dense


def _time(f, *a, iters=10):
    out = f(*a)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*a)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def run():
    rows = []
    B, H, KVh, hd, BT, NB = 8, 8, 2, 64, 16, 32
    P = B * NB + 2
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.bfloat16)
    pk = jax.random.normal(ks[1], (P, BT, KVh, hd), jnp.bfloat16)
    pv = jax.random.normal(ks[2], (P, BT, KVh, hd), jnp.bfloat16)
    wb = jnp.zeros(B, jnp.int32)
    seq = jnp.full((B,), NB * BT - 1, jnp.int32)
    act = jnp.ones(B, jnp.int32)

    fn = jax.jit(lambda q, pk, pv, tbl: ref.paged_decode_attention_ref(
        q, pk, pv, tbl, wb, seq, act, near_window=NB * BT)[0])
    tbl_c = jnp.asarray(np.stack([1 + b * NB + np.arange(NB) for b in range(B)])
                        .astype(np.int32))
    rng = np.random.default_rng(0)
    tbl_f = jnp.asarray(np.stack([rng.permutation(np.arange(1, P))[:NB]
                                  for _ in range(B)]).astype(np.int32))
    rows.append(row("kernel/paged_decode/contiguous", _time(fn, q, pk, pv, tbl_c)))
    rows.append(row("kernel/paged_decode/fragmented", _time(fn, q, pk, pv, tbl_f)))

    S = 512
    qq = jax.random.normal(ks[0], (2, S, H, hd), jnp.bfloat16)
    kk = jax.random.normal(ks[1], (2, S, KVh, hd), jnp.bfloat16)
    vv = jax.random.normal(ks[2], (2, S, KVh, hd), jnp.bfloat16)
    f_blk = jax.jit(lambda q, k, v: attention_blocked(q, k, v, causal=True,
                                                      q_block=128, kv_block=128))
    f_dn = jax.jit(lambda q, k, v: attention_dense(q, k, v, causal=True))
    rows.append(row("kernel/prefill/blocked", _time(f_blk, qq, kk, vv)))
    rows.append(row("kernel/prefill/dense", _time(f_dn, qq, kk, vv)))
    return rows


if __name__ == "__main__":
    print_rows(run())
