"""Sampled decode + detected-EOS retirement (DESIGN.md §13).

Three sections:

* **identity** — the §13 depth-transparency contract as a hard CI gate:
  the seeded stop-token trace decodes at pipeline depths 0, 1 and 2 and
  every pair must emit bitwise-identical per-request token streams
  (``token_divergence``), with identical detected-EOS counts and zero
  leaked blocks after overshoot reconciliation (``alloc_failures`` counts
  still-reserved device blocks + stranded host slots at drain). CI's
  diff_json correctness tier hard-fails either field nonzero.
* **varlen** — variable-length decode driven by on-device stop detection:
  ``stop_token_workload`` traces where gen_len is only a budget cap and
  the ACTUAL lengths are decided by the sampled stream. Reports tokens/s,
  the stop-retired share, and the token budget saved by detected EOS —
  the §13 payoff: slots recycle as soon as the stream stops instead of
  burning the full cap.
* **legacy** — greedy budget-EOS baseline on the same budgets, so the
  varlen rows have an apples-to-apples tokens/s reference (same compiled
  path minus the sampler).
"""
import numpy as np

from benchmarks.common import engine, print_rows, record_audit, row, \
    run_workload, smoke_scale
from repro.data import traces

SAMPLE_KW = dict(greedy=False, temperature=1.2, top_k=50, top_p=0.95,
                 sample_seed=123)


def _tokens(eng):
    return {r.rid: list(map(int, r.generated)) for r in eng.sched.finished}


def _diverged(a, b):
    return sum(1 for rid in set(a) | set(b) if a.get(rid) != b.get(rid))


def _leaks(eng):
    return eng.pager.reserved_blocks() + eng.pager.host_used


def _stop_trace(n, vocab, stops=(), seed=17):
    tcfg = traces.TraceConfig(n_requests=n, vocab=vocab, token_scale=0.12,
                              prompt_mean=24, seed=seed, stop_tokens=stops)
    return traces.stop_token_workload(tcfg)


def _harvest_stops(vocab, n=6):
    """Stop ids the sampler actually emits: probe a short sampled run and
    take interior tokens, so detected-EOS fires well before the caps."""
    probe = engine("paged_merge", batch=4, max_seq=64, block_tokens=8,
                   **SAMPLE_KW)
    run_workload(probe, _stop_trace(8, vocab))
    pool = sorted({t for r in probe.sched.finished
                   for t in r.generated[1:-2]})
    return tuple(pool[:n])


# ---------------------------------------------------------------------------
# section 1: depth-identity A/B — bitwise tokens, zero leaks (CI hard gate)
# ---------------------------------------------------------------------------

def _identity_rows(rows, vocab, stops):
    n = max(8, int(12 * smoke_scale()))
    runs = {}
    for depth in (0, 1, 2):
        # small blocks + no span growth: overshot emissions cross block
        # boundaries, so the reconcile path returns actual blocks
        eng = engine("paged_merge", batch=4, max_seq=64, block_tokens=4,
                     span_blocks=1, pipeline_depth=depth, **SAMPLE_KW)
        run_workload(eng, _stop_trace(n, vocab, stops))
        runs[depth] = eng
    base = _tokens(runs[0])
    a0 = runs[0].audit()
    assert a0["eos_detected"] > 0, "identity trace detected no stop"
    for depth, eng in runs.items():
        a = eng.audit()
        lat = eng.latency_stats()
        div = _diverged(base, _tokens(eng))
        tag = f"sampling_eos/identity_depth{depth}"
        rows.append(row(
            tag, lat["mean_ms"] * 1e3,
            tok_s=eng.throughput(),
            token_divergence=div, alloc_failures=_leaks(eng),
            eos_detected=a["eos_detected"],
            eos_overshoot_tokens=a["eos_overshoot_tokens"],
            eos_reconciled_blocks=a["eos_reconciled_blocks"],
            finished=len(eng.sched.finished)))
        record_audit(tag, a)
        assert div == 0, f"{tag}: {div} requests diverged from depth 0"
        assert a["eos_detected"] == a0["eos_detected"], tag
        # every retirement (stop OR budget) overshoots at most `depth`
        # dispatched-ahead tokens, all scrubbed by the reconcile path
        assert a["eos_overshoot_tokens"] <= depth * len(eng.sched.finished)
        if depth > 0:
            assert a["eos_overshoot_tokens"] > 0, tag
            assert a["eos_reconciled_blocks"] > 0, \
                f"{tag}: no overshoot crossed a block boundary"
        eng.pager.check_invariants()
        assert _leaks(eng) == 0, f"{tag}: leaked blocks after reconcile"


# ---------------------------------------------------------------------------
# sections 2+3: variable-length decode vs greedy budget baseline
# ---------------------------------------------------------------------------

def _varlen_rows(rows, vocab, stops):
    n = max(12, int(24 * smoke_scale()))
    kw = dict(batch=8, max_seq=128, block_tokens=8, pipeline_depth=1)
    reqs = _stop_trace(n, vocab, stops, seed=29)
    budget = sum(r.gen_len for r in reqs)

    eng = engine("paged_merge", **kw, **SAMPLE_KW)
    run_workload(eng, _stop_trace(n, vocab, stops, seed=29))
    a = eng.audit()
    lat = eng.latency_stats()
    fin = eng.sched.finished
    stopped = [r for r in fin if r.finish_reason == "stop"]
    emitted = sum(len(r.generated) for r in fin)
    tag = "sampling_eos/varlen_stop"
    rows.append(row(
        tag, lat["mean_ms"] * 1e3,
        tok_s=eng.throughput(), step_p99_ms=lat["p99_ms"],
        finished=len(fin), stop_retired_share=len(stopped) / len(fin),
        saved_token_share=1.0 - emitted / budget,
        eos_detected=a["eos_detected"],
        eos_overshoot_tokens=a["eos_overshoot_tokens"],
        eos_reconciled_blocks=a["eos_reconciled_blocks"],
        token_divergence=0, alloc_failures=_leaks(eng)))
    record_audit(tag, a)
    assert len(stopped) > 0, "varlen trace retired nothing on detected EOS"
    assert _leaks(eng) == 0

    # greedy budget-EOS baseline: same budgets, legacy dispatch retirement
    base = engine("paged_merge", **kw)
    legacy_reqs = _stop_trace(n, vocab, stops, seed=29)
    for r in legacy_reqs:
        r.stop_tokens = ()
    run_workload(base, legacy_reqs)
    blat = base.latency_stats()
    btag = "sampling_eos/legacy_budget"
    rows.append(row(
        btag, blat["mean_ms"] * 1e3,
        tok_s=base.throughput(), step_p99_ms=blat["p99_ms"],
        finished=len(base.sched.finished),
        token_divergence=0, alloc_failures=_leaks(base)))
    record_audit(btag, base.audit())
    assert base.audit()["eos_detected"] == 0
    assert _leaks(base) == 0


def run():
    rows = []
    vocab = 256
    stops = _harvest_stops(vocab)
    _identity_rows(rows, vocab, stops)
    _varlen_rows(rows, vocab, stops)
    return rows


if __name__ == "__main__":
    print_rows(run())
