"""Fig. 1(a) — idle memory floor: after-idle reserved KV bytes, static arena
vs paged runtime. The arena retains its worst-case contiguous reservation
after all requests complete; the pager converges back to ~zero."""
from benchmarks.common import engine, print_rows, row
from repro.data import traces


def run():
    rows = []
    for mode in ("arena", "paged_merge"):
        eng = engine(mode, batch=8, max_seq=256)
        reqs = traces.mixed_length_workload(traces.TraceConfig(
            n_requests=16, token_scale=0.25, vocab=eng.cfg.vocab_size))
        for r in reqs:
            eng.submit(r)
        eng.run(max_steps=50_000)
        assert not eng.sched.active_slots()          # idle
        rows.append(row(f"idle_floor/{mode}",
                        eng.latency_stats().get("mean_ms", 0) * 1e3,
                        after_idle_reserved=eng.reserved_kv_bytes(),
                        peak_reserved=eng.peak_reserved_kv,
                        peak_active=eng.peak_active_kv,
                        worst_case_bytes=(eng.num_blocks - 1) * eng.block_bytes
                        * max(1, __import__("repro.models.registry",
                                            fromlist=["x"]).n_paged_layers(eng.cfg))))
    return rows


if __name__ == "__main__":
    print_rows(run())
