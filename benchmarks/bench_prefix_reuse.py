"""Shared-prefix KV reuse via the radix prefix cache (DESIGN.md §9).

Replays a shared-system-prompt trace (``traces.shared_prefix_workload``:
a few distinct system prompts, unique user suffixes, chat-length
generations) twice per pipeline depth: once cold (prefix cache disabled —
every request re-prefills its full prompt) and once warm (cache enabled —
admissions COW-alias the committed prefix blocks and skip the covered
prefill chunks). The warm run must emit BITWISE-IDENTICAL tokens per
request, spend >= 2x fewer prefill-executor steps, and deliver higher
tokens/s; the correctness fields (``token_divergence``,
``alloc_failures``) are hard-failed by CI's diff_json gate.

Reported per row: tokens/s, prefill-executor steps, hit rate, tokens
served from cache, COW tail copies (own transport group kind), cache
occupancy/evictions — all folded into the ``run.py --json`` artifact and
recorded engine audits.
"""
import numpy as np

from benchmarks.common import engine, print_rows, record_audit, row, \
    run_workload, smoke_scale
from repro.data import traces


def _tokens(eng):
    return {r.rid: list(r.generated) for r in eng.sched.finished}


def _mk_reqs(n):
    # 20-block system prompts (3 tenants) + ~8-token unique suffixes:
    # prefill dominates the cold run, which is exactly the regime the
    # prefix cache targets. All arrivals at t=0 keeps admission order
    # structural (slot availability), so hit counts are deterministic.
    tcfg = traces.TraceConfig(n_requests=n, vocab=256, seed=23,
                              shared_prefix_len=160, n_prefixes=3,
                              prompt_mean=8, gen_mean=18, window_s=0.0)
    reqs = traces.shared_prefix_workload(tcfg)
    for r in reqs:
        r.arrival = 0.0
    return reqs


def run():
    rows = []
    n = max(12, int(32 * smoke_scale()))
    kw = dict(batch=4, max_seq=256, near_window=128, block_tokens=8,
              prefill_chunk=16)

    def _run_pair(depth):
        cold = engine("paged_merge", pipeline_depth=depth, **kw)
        run_workload(cold, _mk_reqs(n))
        warm = engine("paged_merge", pipeline_depth=depth,
                      prefix_cache=True, prefix_cache_blocks=96, **kw)
        run_workload(warm, _mk_reqs(n))
        return cold, warm

    for depth in (0, 1):
        # a MemoryError in either run raises out of run(): run.py records
        # the module under "failed", which the diff_json gate hard-fails —
        # so a completed pair IS the alloc_failures=0 evidence
        cold, warm = _run_pair(depth)
        t_cold = _tokens(cold)
        a_cold = cold.audit()
        lat = cold.latency_stats()
        rows.append(row(f"prefix_reuse/cold_depth{depth}",
                        lat["mean_ms"] * 1e3,
                        tok_s=cold.throughput(), step_p99_ms=lat["p99_ms"],
                        prefill_steps=a_cold["prefill_chunks_run"],
                        steps=cold.steps_run,
                        finished=len(cold.sched.finished)))
        record_audit(f"prefix_reuse/cold_depth{depth}", a_cold)

        t_warm = _tokens(warm)
        diverged = sum(1 for rid, toks in t_warm.items()
                       if t_cold.get(rid) != toks)
        a = warm.audit()
        lat = warm.latency_stats()
        hits, misses = a["prefix_hits"], a["prefix_misses"]
        rows.append(row(
            f"prefix_reuse/warm_depth{depth}", lat["mean_ms"] * 1e3,
            tok_s=warm.throughput(), step_p99_ms=lat["p99_ms"],
            prefill_steps=a["prefill_chunks_run"],
            prefill_steps_cold=a_cold["prefill_chunks_run"],
            steps=warm.steps_run,
            hit_rate=hits / max(1, hits + misses),
            prefix_hits=hits, prefix_misses=misses,
            prefix_tokens_reused=a["prefix_tokens_reused"],
            prefix_cached_blocks=a["prefix_cached_blocks"],
            prefix_evicted_blocks=a["prefix_evicted_blocks"],
            cow_copies=a["cow_copies"], cow_bytes=a["cow_bytes"],
            # measured, not asserted-by-construction: a request that never
            # finished means an allocation dead-ended somewhere
            alloc_failures=n - len(warm.sched.finished),
            token_divergence=diverged,
            finished=len(warm.sched.finished)))
        record_audit(f"prefix_reuse/warm_depth{depth}", a)
        # the §9 contract, asserted per depth: bitwise-identical output,
        # >= 2x fewer prefill-executor steps, faster end to end
        assert diverged == 0, \
            f"{diverged} requests diverged with the prefix cache on"
        assert hits >= 1, "shared-prefix trace produced no cache hits"
        assert 2 * a["prefill_chunks_run"] <= a_cold["prefill_chunks_run"], \
            (a["prefill_chunks_run"], a_cold["prefill_chunks_run"])
        assert warm.steps_run < cold.steps_run
        # wall-clock assert: the warm run does strictly less work for the
        # same emissions, but shared-CI timing is noisy — one re-measure
        # of the pair before declaring a perf regression
        if not warm.throughput() > cold.throughput():
            cold, warm = _run_pair(depth)
            assert warm.throughput() > cold.throughput(), \
                (warm.throughput(), cold.throughput())
    return rows


if __name__ == "__main__":
    print_rows(run())
