"""Fig. 4(a-b) + Table 1 — bursty replay window: heavy-tailed lengths,
concentrated arrivals, EOS bursts. Static-graph baseline (fewer slots at the
same budget) exhibits head-of-line spikes; KV-RM tightens the tail."""
from benchmarks.common import engine, print_rows, row, run_workload
from repro.data import traces


def run():
    rows = []
    tcfg = traces.TraceConfig(n_requests=32, token_scale=0.25, vocab=256,
                              seed=11, burstiness=2.0)
    summary = traces.trace_summary(traces.azure_like_replay(tcfg))
    rows.append(row("trace/heterogeneity", 0.0, **summary))
    for mode, slots, budget in (("arena", 4, 1.0), ("paged", 8, 0.5),
                                ("paged_merge", 8, 0.5)):
        eng = engine(mode, batch=slots, max_seq=256, pool_budget=budget)
        reqs = traces.azure_like_replay(tcfg)
        run_workload(eng, reqs, replay_scale=0.01)
        lat = eng.latency_stats()
        rl = eng.request_latency_stats()
        rows.append(row(f"replay/{mode}", lat["mean_ms"] * 1e3,
                        tok_s=eng.throughput(), p99_ms=lat["p99_ms"],
                        p999_ms=lat["p999_ms"], max_spike_ms=lat["max_ms"],
                        ttft_p99_ms=rl["ttft_p99_ms"],
                        completion_p99_ms=rl["completion_p99_ms"],
                        peak_reserved_kv=eng.peak_reserved_kv,
                        finished=len(eng.sched.finished)))
    return rows


if __name__ == "__main__":
    print_rows(run())
