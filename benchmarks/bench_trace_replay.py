"""Fig. 4(a-b) + Table 1 — bursty replay window: heavy-tailed lengths,
concentrated arrivals, EOS bursts. Static-graph baseline (fewer slots at the
same budget) exhibits head-of-line spikes; KV-RM tightens the tail.

``replay/paged_merge/sync`` vs ``.../pipelined_chunked`` A/Bs the overlapped
decode loop + chunked prefill against the seed-equivalent synchronous path
under bursty arrivals (admissions + EOS bursts mid-pipeline)."""
from benchmarks.common import engine, print_rows, row, run_workload
from repro.data import traces


def run():
    rows = []
    tcfg = traces.TraceConfig(n_requests=32, token_scale=0.25, vocab=256,
                              seed=11, burstiness=2.0, prompt_mean=96)
    summary = traces.trace_summary(traces.azure_like_replay(tcfg))
    rows.append(row("trace/heterogeneity", 0.0, **summary))
    configs = (
        ("replay/arena", "arena", 4, 1.0, {}),
        ("replay/paged", "paged", 8, 0.5, {}),
        ("replay/paged_merge/sync", "paged_merge", 8, 0.5,
         dict(pipeline_depth=0, prefill_chunk=0)),
        ("replay/paged_merge/pipelined_chunked", "paged_merge", 8, 0.5,
         dict(pipeline_depth=1, prefill_chunk=32)),
    )
    for name, mode, slots, budget, kw in configs:
        eng = engine(mode, batch=slots, max_seq=256, pool_budget=budget, **kw)
        reqs = traces.azure_like_replay(tcfg)
        run_workload(eng, reqs, replay_scale=0.01)
        lat = eng.latency_stats()
        rl = eng.request_latency_stats()
        a = eng.audit()
        rows.append(row(name, lat["mean_ms"] * 1e3,
                        tok_s=eng.throughput(), p99_ms=lat["p99_ms"],
                        p999_ms=lat["p999_ms"], max_spike_ms=lat["max_ms"],
                        ttft_p99_ms=rl["ttft_p99_ms"],
                        completion_p99_ms=rl["completion_p99_ms"],
                        peak_reserved_kv=eng.peak_reserved_kv,
                        submit_share=a["submit_share"],
                        finished=len(eng.sched.finished)))
    return rows


if __name__ == "__main__":
    print_rows(run())
