"""Perf-trajectory diff: compare two aggregated bench JSONs (run.py --json)
and WARN on regressions of key metrics. Never fails the build — CPU CI
timing is noisy; the warnings are a review signal, the committed
BENCH_PR<n>.json sequence is the record.

    python -m benchmarks.diff_json --old BENCH_PR1.json --new BENCH_PR2.json
"""
import argparse
import json
import sys

# metric -> direction ('up' = bigger is better, 'down' = smaller is better)
KEY_METRICS = {
    "tok_s": "up",
    "lane_tok_s": "up",
    "submit_share": "down",
    "step_p99_ms": "down",
    "completion_p99_ms": "down",
    "ttft_p99_ms": "down",
    "per_device_peak_reserved_kv": "down",
    "peak_reserved_kv": "down",
    "dma_groups": "down",
}
TOLERANCE = 0.15     # relative slack before a change counts as a regression


def diff(old: dict, new: dict) -> list:
    warnings = []
    ob, nb = old.get("benches", old), new.get("benches", new)
    for bench, rows in nb.items():
        orows = ob.get(bench)
        if not isinstance(orows, dict) or not isinstance(rows, dict):
            continue
        for rname, rvals in rows.items():
            ovals = orows.get(rname)
            if not isinstance(ovals, dict) or not isinstance(rvals, dict):
                continue
            for metric, direction in KEY_METRICS.items():
                if metric not in rvals or metric not in ovals:
                    continue
                try:
                    o, n = float(ovals[metric]), float(rvals[metric])
                except (TypeError, ValueError):
                    continue
                if o == 0:
                    continue
                rel = (n - o) / abs(o)
                worse = rel < -TOLERANCE if direction == "up" \
                    else rel > TOLERANCE
                if worse:
                    warnings.append(
                        f"WARN {bench}/{rname}.{metric}: "
                        f"{o:.4g} -> {n:.4g} ({rel:+.1%})")
    return warnings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--old", required=True)
    ap.add_argument("--new", required=True)
    args = ap.parse_args(argv)
    try:
        with open(args.old) as f:
            old = json.load(f)
        with open(args.new) as f:
            new = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        print(f"# diff skipped: {e}", file=sys.stderr)
        return 0
    warnings = diff(old, new)
    for w in warnings:
        print(w)
    print(f"# {len(warnings)} regression warning(s) "
          f"({args.old} -> {args.new}); warn-only, not failing")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
