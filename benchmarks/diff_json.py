"""Perf-trajectory diff + correctness gate over aggregated bench JSONs
(run.py --json).

Two verdict tiers (CI uses both in one invocation):

* **Correctness fields** (``CORRECTNESS_METRICS``) are HARD-FAILED: any
  nonzero ``token_divergence`` or ``alloc_failures`` row in the NEW
  artifact exits nonzero. These are absolute invariants of the runtime
  (oversubscribed replay and prefix-cache reuse must be bitwise exact and
  allocation-clean) — timing noise cannot excuse them, so the multi-device
  CI job gates on this.
* **Perf metrics** (``KEY_METRICS``) stay WARN-ONLY vs the committed
  BENCH_PR<n>.json — CPU CI timing is noisy; the warnings are a review
  signal, the committed sequence is the record.

    python -m benchmarks.diff_json --old BENCH_PR3.json --new BENCH_PR4.json
    python -m benchmarks.diff_json --new bench_pr_ci.json   # gate only
"""
import argparse
import json
import sys

# metric -> direction ('up' = bigger is better, 'down' = smaller is better)
KEY_METRICS = {
    "tok_s": "up",
    "lane_tok_s": "up",
    "submit_share": "down",
    "step_p99_ms": "down",
    "completion_p99_ms": "down",
    "ttft_p99_ms": "down",
    "per_device_peak_reserved_kv": "down",
    "peak_reserved_kv": "down",
    "dma_groups": "down",
}
TOLERANCE = 0.15     # relative slack before a change counts as a regression

# absolute correctness invariants: nonzero in the new artifact = build FAIL
CORRECTNESS_METRICS = ("token_divergence", "alloc_failures")


def correctness_failures(new: dict) -> list:
    """Scan every row of the new artifact for nonzero correctness fields."""
    errors = []
    for bench, rows in new.get("benches", new).items():
        if not isinstance(rows, dict):
            continue
        for rname, rvals in rows.items():
            if not isinstance(rvals, dict):
                continue
            for metric in CORRECTNESS_METRICS:
                try:
                    v = float(rvals.get(metric, 0))
                except (TypeError, ValueError):
                    continue
                if v != 0:
                    errors.append(f"FAIL {bench}/{rname}.{metric} = {v:g} "
                                  f"(must be 0)")
    for mod in new.get("failed", []):
        errors.append(f"FAIL bench module raised: {mod}")
    return errors


def diff(old: dict, new: dict) -> list:
    warnings = []
    ob, nb = old.get("benches", old), new.get("benches", new)
    for bench, rows in nb.items():
        orows = ob.get(bench)
        if not isinstance(orows, dict) or not isinstance(rows, dict):
            continue
        for rname, rvals in rows.items():
            ovals = orows.get(rname)
            if not isinstance(ovals, dict) or not isinstance(rvals, dict):
                continue
            for metric, direction in KEY_METRICS.items():
                if metric not in rvals or metric not in ovals:
                    continue
                try:
                    o, n = float(ovals[metric]), float(rvals[metric])
                except (TypeError, ValueError):
                    continue
                if o == 0:
                    continue
                rel = (n - o) / abs(o)
                worse = rel < -TOLERANCE if direction == "up" \
                    else rel > TOLERANCE
                if worse:
                    warnings.append(
                        f"WARN {bench}/{rname}.{metric}: "
                        f"{o:.4g} -> {n:.4g} ({rel:+.1%})")
    return warnings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--old", default=None,
                    help="committed artifact to diff against (perf metrics, "
                         "warn-only); omit to run the correctness gate alone")
    ap.add_argument("--new", required=True)
    args = ap.parse_args(argv)
    try:
        with open(args.new) as f:
            new = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        # fail CLOSED: an unreadable fresh artifact means the correctness
        # gate cannot run — a truncated bench_pr_ci.json must not go green
        print(f"FAIL cannot read --new artifact ({e}): "
              f"correctness gate did not run", file=sys.stderr)
        return 2

    # hard gate first: correctness fields in the new artifact
    errors = correctness_failures(new)
    for e in errors:
        print(e)

    # perf diff: warn-only, and only when an old artifact is readable
    warnings = []
    if args.old is not None:
        try:
            with open(args.old) as f:
                old = json.load(f)
            warnings = diff(old, new)
        except (OSError, json.JSONDecodeError) as e:
            print(f"# perf diff skipped: {e}", file=sys.stderr)
    for w in warnings:
        print(w)
    print(f"# {len(warnings)} regression warning(s) (warn-only), "
          f"{len(errors)} correctness failure(s) (hard gate) "
          f"[{args.old or '-'} -> {args.new}]")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
