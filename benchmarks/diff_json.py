"""Perf-trajectory diff + correctness gate over aggregated bench JSONs
(run.py --json).

Two verdict tiers (CI uses both in one invocation):

* **Correctness fields** (``CORRECTNESS_METRICS``) are HARD-FAILED: any
  nonzero ``token_divergence`` or ``alloc_failures`` row in the NEW
  artifact exits nonzero. These are absolute invariants of the runtime
  (oversubscribed replay and prefix-cache reuse must be bitwise exact and
  allocation-clean) — timing noise cannot excuse them, so the multi-device
  CI job gates on this.
* **Perf metrics** (``KEY_METRICS``) stay WARN-ONLY vs the committed
  BENCH_PR<n>.json — CPU CI timing is noisy; the warnings are a review
  signal, the committed sequence is the record.

Selected perf rows can be PROMOTED to the hard tier with ``--gate
bench:row:metric`` (repeatable; colon-separated because row names carry
'/'): a regression beyond TOLERANCE on a gated row fails the build like
a correctness error. CI gates the mesh-2x2 tokens/s row this way.

    python -m benchmarks.diff_json --old BENCH_PR3.json --new BENCH_PR4.json
    python -m benchmarks.diff_json --new bench_pr_ci.json   # gate only
    python -m benchmarks.diff_json --old BENCH_PR5.json --new ci.json \
        --gate scaling:scaling/2x2:tok_s
"""
import argparse
import json
import sys

# metric -> direction ('up' = bigger is better, 'down' = smaller is better)
KEY_METRICS = {
    "tok_s": "up",
    "lane_tok_s": "up",
    "submit_share": "down",
    "step_p99_ms": "down",
    "completion_p99_ms": "down",
    "ttft_p99_ms": "down",
    "tpot_p99_ms": "down",
    "goodput": "up",
    "per_device_peak_reserved_kv": "down",
    "peak_reserved_kv": "down",
    "dma_groups": "down",
}
TOLERANCE = 0.15     # relative slack before a change counts as a regression

# absolute correctness invariants: nonzero in the new artifact = build FAIL
CORRECTNESS_METRICS = ("token_divergence", "alloc_failures")


def correctness_failures(new: dict) -> list:
    """Scan every row of the new artifact for nonzero correctness fields."""
    errors = []
    for bench, rows in new.get("benches", new).items():
        if not isinstance(rows, dict):
            continue
        for rname, rvals in rows.items():
            if not isinstance(rvals, dict):
                continue
            for metric in CORRECTNESS_METRICS:
                try:
                    v = float(rvals.get(metric, 0))
                except (TypeError, ValueError):
                    continue
                if v != 0:
                    errors.append(f"FAIL {bench}/{rname}.{metric} = {v:g} "
                                  f"(must be 0)")
    for mod in new.get("failed", []):
        errors.append(f"FAIL bench module raised: {mod}")
    return errors


def parse_gates(specs) -> set:
    """--gate bench:row:metric specs (colon-separated; row names contain
    '/'). Unknown metrics are rejected up front — a typo'd gate must not
    silently pass."""
    gates = set()
    for spec in specs or ():
        parts = spec.split(":")
        if len(parts) != 3 or parts[2] not in KEY_METRICS:
            raise SystemExit(f"bad --gate spec {spec!r} "
                             f"(want bench:row:metric, metric one of "
                             f"{sorted(KEY_METRICS)})")
        gates.add(tuple(parts))
    return gates


def diff(old: dict, new: dict, gates=()) -> tuple:
    """Returns (warnings, gate_errors): perf regressions beyond TOLERANCE,
    split by whether the row is promoted to the hard tier via --gate."""
    warnings, gate_errors = [], []
    gates = set(gates)
    seen = set()
    ob, nb = old.get("benches", old), new.get("benches", new)
    for bench, rows in nb.items():
        orows = ob.get(bench)
        if not isinstance(orows, dict) or not isinstance(rows, dict):
            continue
        for rname, rvals in rows.items():
            ovals = orows.get(rname)
            if not isinstance(ovals, dict) or not isinstance(rvals, dict):
                continue
            for metric, direction in KEY_METRICS.items():
                if metric not in rvals or metric not in ovals:
                    continue
                try:
                    o, n = float(ovals[metric]), float(rvals[metric])
                except (TypeError, ValueError):
                    continue
                if o == 0:
                    continue
                gated = (bench, rname, metric) in gates
                if gated:
                    seen.add((bench, rname, metric))
                rel = (n - o) / abs(o)
                worse = rel < -TOLERANCE if direction == "up" \
                    else rel > TOLERANCE
                if worse and gated:
                    gate_errors.append(
                        f"FAIL {bench}/{rname}.{metric}: "
                        f"{o:.4g} -> {n:.4g} ({rel:+.1%}, gated)")
                elif worse:
                    warnings.append(
                        f"WARN {bench}/{rname}.{metric}: "
                        f"{o:.4g} -> {n:.4g} ({rel:+.1%})")
    # fail CLOSED: a gate naming a row absent from either artifact would
    # otherwise green-light exactly the runs that dropped the row
    for g in sorted(gates - seen):
        gate_errors.append(f"FAIL gated row {':'.join(g)} missing from "
                           f"old or new artifact")
    return warnings, gate_errors


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--old", default=None,
                    help="committed artifact to diff against (perf metrics, "
                         "warn-only); omit to run the correctness gate alone")
    ap.add_argument("--new", required=True)
    ap.add_argument("--gate", action="append", default=[],
                    help="bench:row:metric to promote from warn to hard "
                         "fail (repeatable), e.g. scaling:scaling/2x2:tok_s")
    args = ap.parse_args(argv)
    gates = parse_gates(args.gate)
    try:
        with open(args.new) as f:
            new = json.load(f)
    except (OSError, json.JSONDecodeError) as e:
        # fail CLOSED: an unreadable fresh artifact means the correctness
        # gate cannot run — a truncated bench_pr_ci.json must not go green
        print(f"FAIL cannot read --new artifact ({e}): "
              f"correctness gate did not run", file=sys.stderr)
        return 2

    # hard gate first: correctness fields in the new artifact
    errors = correctness_failures(new)
    for e in errors:
        print(e)

    # perf diff: warn-only except for --gate-promoted rows, and only when
    # an old artifact is readable
    warnings = []
    if args.old is not None:
        try:
            with open(args.old) as f:
                old = json.load(f)
            warnings, gate_errors = diff(old, new, gates)
            errors.extend(gate_errors)
            for e in gate_errors:
                print(e)
        except (OSError, json.JSONDecodeError) as e:
            if gates:
                # fail CLOSED: gates were requested but cannot be evaluated
                msg = f"FAIL cannot read --old artifact ({e}): " \
                      f"perf gate did not run"
                errors.append(msg)
                print(msg)
            else:
                print(f"# perf diff skipped: {e}", file=sys.stderr)
    elif gates:
        msg = "FAIL --gate requires --old"
        errors.append(msg)
        print(msg)
    for w in warnings:
        print(w)
    print(f"# {len(warnings)} regression warning(s) (warn-only), "
          f"{len(errors)} hard failure(s) (correctness + gated perf) "
          f"[{args.old or '-'} -> {args.new}]")
    return 1 if errors else 0


if __name__ == "__main__":
    raise SystemExit(main())
