"""Shared benchmark helpers: engine factory, workload runners, CSV rows."""
from __future__ import annotations

import json
import os
import time
from typing import List, Tuple

import numpy as np

from repro.core.engine import KVRMEngine
from repro.data import traces
from repro.serving.factory import build as serving_build

ARCH = "qwen2.5-32b"      # bench model family (paper uses qwen2.5-7B)

# engine audits recorded during a bench run, aggregated by run.py --json
# into the per-PR perf-trajectory artifact (BENCH_PR<n>.json)
_AUDITS = {}


def record_audit(name: str, audit: dict) -> None:
    _AUDITS[name] = {k: (float(v) if hasattr(v, "item") else v)
                     for k, v in audit.items()}


def collected_audits() -> dict:
    return dict(_AUDITS)


def engine(mode: str, *, batch=8, max_seq=256, near_window=None,
           block_tokens=8, pool_budget=1.0, arch=ARCH, seed=0, **kw) -> KVRMEngine:
    """One engine via the consolidated serving factory (§14); params stay
    cached per (arch, seed) inside the factory."""
    return serving_build(arch, mode=mode, batch=batch, max_seq=max_seq,
                         near_window=near_window, block_tokens=block_tokens,
                         pool_budget=pool_budget, seed=seed, **kw)[0]


def run_workload(eng: KVRMEngine, reqs, warmup: int = 3, replay_scale=None):
    if replay_scale is not None:
        # compress trace time into WALL seconds up front so arrivals and the
        # engine's finish/ttft stamps share one clock — admission timing is
        # unchanged (arrival*s <= wall  <=>  arrival <= wall/s) and
        # request_latency_stats' arrival subtraction is dimensionally right
        for r in reqs:
            r.arrival *= replay_scale
    for r in reqs:
        eng.submit(r)
    if replay_scale is not None:
        t0 = time.perf_counter()
        eng.run(max_steps=200_000,
                now_fn=lambda: time.perf_counter() - t0)
    else:
        eng.run(max_steps=200_000)
    return eng


def row(name: str, us: float, **derived) -> Tuple[str, float, dict]:
    return (name, us, derived)


def print_rows(rows: List[Tuple[str, float, dict]]):
    for name, us, derived in rows:
        dv = ";".join(f"{k}={v:.4g}" if isinstance(v, float) else f"{k}={v}"
                      for k, v in derived.items())
        print(f"{name},{us:.2f},{dv}")


def rows_to_json(rows: List[Tuple[str, float, dict]]) -> dict:
    return {name: {"us_per_call": us, **{k: (float(v) if hasattr(v, "item")
                                             else v) for k, v in derived.items()}}
            for name, us, derived in rows}


def write_json(rows: List[Tuple[str, float, dict]], path: str) -> None:
    """Persist a bench's rows as a JSON summary (CI perf-trajectory artifact)."""
    with open(path, "w") as f:
        json.dump(rows_to_json(rows), f, indent=2, sort_keys=True)
    print(f"# wrote {path}")


def smoke_scale() -> float:
    """CI smoke runs set REPRO_BENCH_SMOKE=1 to shrink workloads ~4x."""
    return 0.25 if os.environ.get("REPRO_BENCH_SMOKE") else 1.0
