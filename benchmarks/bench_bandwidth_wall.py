"""Fig. 1(b) — the O(T) bandwidth wall: per-token decode cost grows with
visible history T under dense attention, and flattens once the working set is
capped at W* (diagnostic sweep, single decode step timed directly)."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import print_rows, row
from repro.configs import get_reduced
from repro.core.descriptor import empty_descriptor
from repro.models import registry


def _step_time(cfg, params, T, window, B=4, bt=8, iters=20):
    # the capped working set gathers only ceil(W/bt)+1 blocks per step —
    # KV-RM's explicit working-set boundary; dense gathers the full history
    NB = min(T, window) // bt + 1
    P = B * NB + 2
    pools = registry.init_decode_pools(cfg, batch=B, num_blocks=P, block_tokens=bt)
    d = empty_descriptor(B, NB, 1, NB + 1)
    tbl = np.zeros((B, NB), np.int32)
    for b in range(B):
        tbl[b] = 1 + b * NB + np.arange(NB)
    wb = max(0, ((T - min(T, window)) // bt) * bt)
    d = d._replace(block_table=tbl,
                   window_base=np.full(B, wb, np.int32),
                   seq_lens=np.full(B, T - 1, np.int32),
                   slot_active=np.ones(B, np.int32),
                   write_block=tbl[:, -1], write_offset=np.zeros(B, np.int32))
    d = jax.tree.map(jnp.asarray, d)
    cfgw = cfg.replace(serving=cfg.serving.__class__(near_window=window))
    tok = jnp.zeros((B,), jnp.int32)

    @jax.jit
    def step(params, tok, pools, d):
        logits, pools, _ = registry.decode_step(params, cfgw, tok, pools, d)
        return jnp.argmax(logits, -1), pools

    out, pools = step(params, tok, pools, d)       # compile
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out, pools = step(params, tok, pools, d)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters


def run():
    cfg = get_reduced("qwen2.5-32b")
    params = registry.init_params(jax.random.PRNGKey(0), cfg)
    rows = []
    W = 64
    for T in (64, 128, 256, 512, 1024):
        dense = _step_time(cfg, params, T, window=T)
        capped = _step_time(cfg, params, T, window=W)
        rows.append(row(f"bandwidth_wall/T={T}", dense * 1e6,
                        dense_us=dense * 1e6, capped_us=capped * 1e6,
                        ratio=dense / max(capped, 1e-9)))
    return rows


if __name__ == "__main__":
    print_rows(run())
