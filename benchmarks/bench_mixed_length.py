"""Fig. 4(c-d) + Table 5 — controlled mixed-length serving with core-path
attribution. All four cumulative configurations serve the SAME workload under
the SAME device memory budget; the arena's worst-case reservation buys fewer
concurrent slots (width penalty), while the pager tracks the active set.

Reported per mode: throughput (tok/s), p99 step latency, reserved KV bytes,
DMA groups/step, avg merged DMA bytes."""
import numpy as np

from benchmarks.common import engine, print_rows, row, run_workload
from repro.data import traces

MAX_SEQ = 256
BUDGET_SLOTS_ARENA = 4          # same device bytes buys 4 arena slots ...
BUDGET_SLOTS_PAGED = 8          # ... or 8 paged slots at 0.5 budget frac


def run():
    rows = []
    results = {}
    for mode in ("arena", "paged", "paged_merge", "full"):
        if mode == "arena":
            eng = engine(mode, batch=BUDGET_SLOTS_ARENA, max_seq=MAX_SEQ)
        else:
            kw = {}
            if mode == "full":
                kw = dict(near_window=64, farview_cap=8, sv_chunk=32)
            eng = engine(mode, batch=BUDGET_SLOTS_PAGED, max_seq=MAX_SEQ,
                         pool_budget=0.5, **kw)
        reqs = traces.mixed_length_workload(traces.TraceConfig(
            n_requests=24, token_scale=0.3, vocab=eng.cfg.vocab_size, seed=3))
        run_workload(eng, reqs)
        lat = eng.latency_stats()
        rl = eng.request_latency_stats()
        a = eng.audit()
        results[mode] = (eng.throughput(), rl["completion_p99_ms"])
        rows.append(row(
            f"mixed_length/{mode}", lat["mean_ms"] * 1e3,
            tok_s=eng.throughput(), step_p99_ms=lat["p99_ms"],
            completion_p99_ms=rl["completion_p99_ms"],
            ttft_p99_ms=rl["ttft_p99_ms"],
            peak_reserved_kv=a["peak_reserved_kv"],
            peak_active_kv=a["peak_active_kv"],
            dma_groups=a["dma_groups_per_step"],
            avg_dma_bytes=a["avg_dma_bytes"],
            submit_share=a["submit_share"],
            finished=len(eng.sched.finished)))
    # attribution summary (Table 5 shape): core path share of full gain
    base_t, base_p = results["arena"]
    full_t, full_p = results["full"]
    core_t, core_p = results["paged_merge"]
    if full_t > base_t:
        rows.append(row("mixed_length/attribution", 0.0,
                        core_tput_share=(core_t - base_t) / max(full_t - base_t, 1e-9),
                        core_p99_share=(base_p - core_p) / max(base_p - full_p, 1e-9)))
    return rows


if __name__ == "__main__":
    print_rows(run())
