"""Fig. 4(c-d) + Table 5 — controlled mixed-length serving with core-path
attribution. All four cumulative configurations serve the SAME workload under
the SAME device memory budget; the arena's worst-case reservation buys fewer
concurrent slots (width penalty), while the pager tracks the active set.

Reported per mode: throughput (tok/s), p99 step latency, reserved KV bytes,
DMA groups/step, avg merged DMA bytes.

The ``pipeline/*`` rows A/B the overlapped host-device decode loop + chunked
prefill (DESIGN.md §3) against the seed-equivalent synchronous path
(pipeline_depth=0, prefill_chunk=0) on the same workloads, including a
prompt-heavy mix where chunked prefill dominates."""
import argparse

import numpy as np

from benchmarks.common import (engine, print_rows, record_audit, row,
                               run_workload, smoke_scale, write_json)
from repro.core.scheduler import Request
from repro.data import traces

MAX_SEQ = 256
BUDGET_SLOTS_ARENA = 4          # same device bytes buys 4 arena slots ...
BUDGET_SLOTS_PAGED = 8          # ... or 8 paged slots at 0.5 budget frac
PREFILL_CHUNK = 32


def _prompt_heavy_reqs(n, vocab, seed=7):
    """Long prompts, short generations: the regime where prompt ingestion
    dominates and chunked prefill changes throughput by ~an order."""
    rng = np.random.default_rng(seed)
    reqs = []
    for i in range(n):
        plen = int(rng.integers(64, 161))
        glen = int(rng.integers(12, 25))
        reqs.append(Request(rid=i, prompt=rng.integers(0, vocab, size=plen)
                            .astype(np.int32), gen_len=glen))
    return reqs


def _pipeline_ab(rows):
    scale = smoke_scale()
    workloads = {
        "mixed": lambda vocab: traces.mixed_length_workload(traces.TraceConfig(
            n_requests=max(6, int(24 * scale)), token_scale=0.3, vocab=vocab,
            seed=3)),
        "prompt_heavy": lambda vocab: _prompt_heavy_reqs(
            max(4, int(16 * scale)), vocab),
    }
    for wname, mk in workloads.items():
        for label, depth, chunk in (("sync", 0, 0),
                                    ("pipelined", 1, 0),
                                    ("pipelined_chunked", 1, PREFILL_CHUNK)):
            eng = engine("paged_merge", batch=BUDGET_SLOTS_PAGED,
                         max_seq=MAX_SEQ, pool_budget=0.5,
                         pipeline_depth=depth, prefill_chunk=chunk)
            run_workload(eng, mk(eng.cfg.vocab_size))
            lat = eng.latency_stats()
            a = eng.audit()
            rows.append(row(
                f"pipeline/{wname}/{label}", lat["mean_ms"] * 1e3,
                tok_s=eng.throughput(), steps=a["steps"],
                submit_share=a["submit_share"],
                dma_groups=a["dma_groups_per_step"],
                prefill_chunks=a["prefill_chunks_run"],
                step_p99_ms=lat["p99_ms"],
                finished=len(eng.sched.finished)))


def run():
    rows = []
    results = {}
    for mode in ("arena", "paged", "paged_merge", "full"):
        if mode == "arena":
            eng = engine(mode, batch=BUDGET_SLOTS_ARENA, max_seq=MAX_SEQ)
        else:
            kw = {}
            if mode == "full":
                kw = dict(near_window=64, farview_cap=8, sv_chunk=32)
            eng = engine(mode, batch=BUDGET_SLOTS_PAGED, max_seq=MAX_SEQ,
                         pool_budget=0.5, **kw)
        reqs = traces.mixed_length_workload(traces.TraceConfig(
            n_requests=24, token_scale=0.3, vocab=eng.cfg.vocab_size, seed=3))
        run_workload(eng, reqs)
        lat = eng.latency_stats()
        rl = eng.request_latency_stats()
        a = eng.audit()
        record_audit(f"mixed_length/{mode}", a)
        results[mode] = (eng.throughput(), rl["completion_p99_ms"])
        rows.append(row(
            f"mixed_length/{mode}", lat["mean_ms"] * 1e3,
            tok_s=eng.throughput(), step_p99_ms=lat["p99_ms"],
            completion_p99_ms=rl["completion_p99_ms"],
            ttft_p99_ms=rl["ttft_p99_ms"],
            peak_reserved_kv=a["peak_reserved_kv"],
            peak_active_kv=a["peak_active_kv"],
            dma_groups=a["dma_groups_per_step"],
            avg_dma_bytes=a["avg_dma_bytes"],
            submit_share=a["submit_share"],
            finished=len(eng.sched.finished)))
    # attribution summary (Table 5 shape): core path share of full gain
    base_t, base_p = results["arena"]
    full_t, full_p = results["full"]
    core_t, core_p = results["paged_merge"]
    if full_t > base_t:
        rows.append(row("mixed_length/attribution", 0.0,
                        core_tput_share=(core_t - base_t) / max(full_t - base_t, 1e-9),
                        core_p99_share=(base_p - core_p) / max(base_p - full_p, 1e-9)))
    _pipeline_ab(rows)
    _tp_ab(rows)
    return rows


def _tp_ab(rows):
    """TP decode A/B on the same workload (DESIGN.md §4): single-device vs a
    2-way model mesh — identical token stream, halved per-device KV. Only
    emitted when the process holds >= 2 devices (the multi-device CI job and
    bench_scaling's forced-topology child do; the default lane skips)."""
    import jax
    if len(jax.devices()) < 2:
        return
    from repro.launch.mesh import make_engine_mesh
    scale = smoke_scale()
    for label, mesh in (("tp1", None), ("tp2", make_engine_mesh(1, 2))):
        eng = engine("paged_merge", batch=BUDGET_SLOTS_PAGED, max_seq=MAX_SEQ,
                     pool_budget=0.5, pipeline_depth=1,
                     prefill_chunk=PREFILL_CHUNK, mesh=mesh)
        reqs = traces.mixed_length_workload(traces.TraceConfig(
            n_requests=max(6, int(24 * scale)), token_scale=0.3,
            vocab=eng.cfg.vocab_size, seed=3))
        run_workload(eng, reqs)
        a = eng.audit()
        record_audit(f"mixed_length/{label}", a)
        rows.append(row(
            f"mixed_length/{label}", eng.latency_stats()["mean_ms"] * 1e3,
            tok_s=eng.throughput(), tp=a["tp_degree"],
            per_device_peak_reserved_kv=a["per_device_peak_reserved_kv"],
            submit_share=a["submit_share"],
            dma_groups=a["dma_groups_per_step"],
            finished=len(eng.sched.finished)))


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=None,
                    help="also write the rows as a JSON summary (CI artifact)")
    args = ap.parse_args()
    rows = run()
    print_rows(rows)
    if args.json:
        write_json(rows, args.json)
