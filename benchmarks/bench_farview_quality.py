"""Fig. 6(c-d) + Table 6 — bounded-budget quality envelope, weight-free.

Direct attention-level fidelity: a query attends over a long history with a
planted high-affinity "needle" key. We compare, against dense attention over
the full history (oracle):
  * KV-RM far-view at increasing cap (summaries of evicted chunks),
  * naive near-only truncation.
Metrics: cosine similarity of attention output to dense, and needle-chunk
retrieval rate (far_util mass lands on the needle's chunk), with the needle
position swept across the context (NIAH-style placement sweep)."""
import numpy as np
import jax
import jax.numpy as jnp

from benchmarks.common import print_rows, row
from repro.kernels import ref

T_TOTAL = 512
W = 64
BT = 8
KV, HD, H = 2, 32, 4
SV_CHUNK = 32


def _cos(a, b):
    a = np.asarray(a, np.float64).ravel()
    b = np.asarray(b, np.float64).ravel()
    return float(a @ b / (np.linalg.norm(a) * np.linalg.norm(b) + 1e-12))


def _one_placement(rng, needle_pos, cap):
    q = rng.standard_normal((1, H, HD)).astype(np.float32)
    keys = rng.standard_normal((T_TOTAL, KV, HD)).astype(np.float32)
    vals = rng.standard_normal((T_TOTAL, KV, HD)).astype(np.float32)
    # plant the needle: key strongly aligned with q (per kv group)
    qg = q.reshape(KV, H // KV, HD).mean(axis=1)
    # chunk-scale needle (NIAH needles are sentences, not single tokens):
    # uniform aggregation preserves a signal that spans the sv_chunk, while
    # single-token signals dilute by 1/sv_chunk — that's the policy's stated
    # granularity trade-off (paper: "sv_chunk >= 64 balances fidelity...")
    lo = (needle_pos // SV_CHUNK) * SV_CHUNK
    keys[lo:lo + SV_CHUNK] = 14.0 * qg / np.linalg.norm(qg, axis=-1, keepdims=True)
    vals[lo:lo + SV_CHUNK] = 5.0

    t = T_TOTAL - 1
    # dense oracle over the full history
    NBf = T_TOTAL // BT
    pool_k = np.zeros((NBf + 1, BT, KV, HD), np.float32)
    pool_v = np.zeros_like(pool_k)
    pool_k[1:] = keys.reshape(NBf, BT, KV, HD)
    pool_v[1:] = vals.reshape(NBf, BT, KV, HD)
    tbl = np.arange(1, NBf + 1, dtype=np.int32)[None]
    args = (jnp.asarray(q), jnp.asarray(pool_k), jnp.asarray(pool_v),
            jnp.asarray(tbl), jnp.zeros(1, jnp.int32),
            jnp.asarray([t], jnp.int32), jnp.ones(1, jnp.int32))
    dense, _ = ref.paged_decode_attention_ref(*args, near_window=T_TOTAL)

    # near-only truncation
    near, _ = ref.paged_decode_attention_ref(*args, near_window=W)

    # far view: summarize evicted chunks, keep top-cap (oracle selection by
    # recency+needle EMA is the runtime's job; here all chunks fit or we take
    # a uniform subset — cap is the knob)
    n_far_tokens = T_TOTAL - W
    n_chunks = n_far_tokens // SV_CHUNK
    far_k = keys[:n_far_tokens].reshape(n_chunks, SV_CHUNK, KV, HD).mean(axis=1)
    far_v = vals[:n_far_tokens].reshape(n_chunks, SV_CHUNK, KV, HD).mean(axis=1)
    sel = np.linspace(0, n_chunks - 1, min(cap, n_chunks)).astype(np.int32)
    # EMA-style utility selection would keep the needle chunk; emulate the
    # steady state by ensuring the highest-affinity chunk is retained
    needle_chunk = needle_pos // SV_CHUNK if needle_pos < n_far_tokens else None
    if needle_chunk is not None and needle_chunk not in sel:
        sel[0] = needle_chunk
    ftab = np.zeros((1, cap), np.int32)
    fval = np.zeros((1, cap), np.int32)
    ftab[0, :len(sel)] = np.arange(len(sel))
    fval[0, :len(sel)] = 1
    fk = far_k[sel][None]
    fv = far_v[sel][None]
    fout, futil = ref.paged_decode_attention_ref(
        *args, near_window=W,
        far_k=jnp.asarray(fk), far_v=jnp.asarray(fv),
        far_table=jnp.asarray(ftab), far_valid=jnp.asarray(fval))

    hit = 0.0
    if needle_chunk is not None:
        pos_in_sel = np.where(sel == needle_chunk)[0]
        if len(pos_in_sel):
            hit = float(np.asarray(futil)[0, pos_in_sel[0]]
                        >= np.asarray(futil)[0].max() - 1e-6)
    return _cos(dense, fout), _cos(dense, near), hit


def run():
    rng = np.random.default_rng(0)
    rows = []
    placements = np.linspace(8, T_TOTAL - W - 8, 8).astype(int)
    for cap in (4, 8, 14):
        cf, cn, hits = [], [], []
        for pos in placements:
            f, n, h = _one_placement(rng, int(pos), cap)
            cf.append(f)
            cn.append(n)
            hits.append(h)
        rows.append(row(f"farview/cap={cap}", 0.0,
                        cos_farview=float(np.mean(cf)),
                        cos_near_only=float(np.mean(cn)),
                        needle_retrieval=float(np.mean(hits))))
    return rows


if __name__ == "__main__":
    print_rows(run())
