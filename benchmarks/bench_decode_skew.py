"""Work-skipping decode under length skew (DESIGN.md §12).

Three sections, all riding the extent-predicated kernels:

* **kernel** — microbench of the trip-count contract on one compiled
  executable. The wall-clock rows drive a jitted ``lax.fori_loop`` twin
  of the kernel whose per-slot trip bounds ARE the runtime extents
  (skip on) vs pinned to the padded grid (skip off) — same executable,
  variable work, bitwise-identical outputs; the bimodal row must clear
  a >= 1.3x speedup. The Pallas kernel itself is A/B'd bitwise in
  interpret mode at prefetch depths 0 and 1 (its speedup row is
  reported but not gated: interpret emulation pays the per-grid-step
  block-copy machinery whether or not ``@pl.when`` predicates the body
  off, so copy elision — the compiled-backend win — is invisible here).
* **identity** — paired engine runs (``kernel_skip_extent`` on vs off)
  over the adversarial workloads (lockstep oversubscribed burst, warm
  radix prefix cache, fp8 quantized KV) at pipeline depths 0 and 1.
  Every pair must emit bitwise-identical tokens (``token_divergence``
  hard-failed by CI's diff_json gate): predication only ever drops
  fully-masked blocks.
* **skew** — engine-level uniform / bimodal / trace-replay sweeps
  reporting tokens/s plus the new audit counters as padded-block ratio
  and blocks-skipped share; the bimodal row must audit a nonzero
  ``kernel_blocks_skipped``. CI promotes the bimodal tokens/s row to a
  hard diff_json gate.
"""
import functools
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import engine, print_rows, record_audit, row, \
    run_workload, smoke_scale
from repro.core.descriptor import active_block_extents
from repro.core.scheduler import Request
from repro.data import traces
from repro.kernels.paged_attention import paged_decode_attention_pallas

MIN_KERNEL_SPEEDUP = 1.3     # acceptance: bimodal skew, skip on vs off


def _tokens(eng):
    return {r.rid: list(r.generated) for r in eng.sched.finished}


def _diverged(a, b):
    return sum(1 for rid in set(a) | set(b) if a.get(rid) != b.get(rid))


def _time(f, *a, iters=4):
    out = f(*a)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = f(*a)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


# ---------------------------------------------------------------------------
# section 1: trip-count kernel A/B — one executable, extent-bounded work
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("near_window", "bt", "nb_full"))
def _trip_count_kernel(q, pk, pv, tbl, wb, seq, act, ext_lo, ext_hi, *,
                       near_window, bt, nb_full=None):
    """Flash-style paged decode whose per-slot block loop runs
    ``fori_loop(ext_lo[b], ext_hi[b], ...)`` — the extents are RUNTIME
    operands of one compiled executable, exactly the kernel's trip-count
    contract. ``nb_full`` pins every trip to the padded grid (the
    always-run baseline; fully-masked steps are exact no-ops of the
    online-softmax update, so both bounds are bitwise identical)."""
    B, H, hd = q.shape
    KV = pk.shape[2]
    n_rep = H // KV
    scale = hd ** -0.5
    outs = []
    for b in range(B):
        def body(i, st, b=b):
            acc, m, l = st
            blk = tbl[b, i]
            kb = pk[blk].astype(jnp.float32)
            vb = pv[blk].astype(jnp.float32)
            pos = wb[b] + i * bt + jnp.arange(bt)
            valid = (pos <= seq[b]) & (pos > seq[b] - near_window) \
                & (pos >= 0) & (act[b] > 0)
            s = jnp.einsum("krd,tkd->krt",
                           q[b].reshape(KV, n_rep, hd).astype(jnp.float32),
                           kb) * scale
            s = jnp.where(valid[None, None, :], s, -1e30)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.where(valid[None, None, :],
                          jnp.exp(s - m_new[..., None]), 0.0)
            corr = jnp.exp(m - m_new)
            l = l * corr + p.sum(-1)
            acc = acc * corr[..., None] + jnp.einsum("krt,tkd->krd", p, vb)
            return acc, m_new, l
        acc0 = jnp.zeros((KV, n_rep, hd), jnp.float32)
        m0 = jnp.full((KV, n_rep), -1e30, jnp.float32)
        l0 = jnp.zeros((KV, n_rep), jnp.float32)
        lo = ext_lo[b] if nb_full is None else 0
        hi = ext_hi[b] if nb_full is None else nb_full
        acc, m, l = jax.lax.fori_loop(lo, hi, body, (acc0, m0, l0))
        outs.append((acc / jnp.maximum(l, 1e-30)[..., None]).reshape(H, hd))
    return jnp.stack(outs)


def _kernel_rows(rows):
    from repro.kernels.ref import paged_decode_attention_ref

    B, H, KV, hd, BT, W = 8, 64, 4, 64, 64, 1024
    NB = W // BT + 1                      # engine geometry: ceil(W/bt)+1
    P = B * NB + 1
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, hd), jnp.bfloat16)
    pk = jax.random.normal(ks[1], (P, BT, KV, hd), jnp.bfloat16)
    pv = jax.random.normal(ks[2], (P, BT, KV, hd), jnp.bfloat16)
    tbl = jnp.asarray(np.stack([1 + b * NB + np.arange(NB) for b in range(B)])
                      .astype(np.int32))
    wb = jnp.zeros(B, jnp.int32)
    act = jnp.ones(B, jnp.int32)

    rng = np.random.default_rng(3)
    dists = {
        "uniform": np.full(B, W - 1),
        "bimodal": np.array([W - 1] + [BT + 15] * (B - 1)),
        "trace": np.clip(rng.gamma(2.0, W / 8.0, size=B), 16, W - 1),
    }
    iters = 5 if smoke_scale() == 1.0 else 3

    for dist, lens in dists.items():
        seq = jnp.asarray(lens.astype(np.int32))
        lo, hi = active_block_extents(np.zeros(B, np.int64),
                                      lens.astype(np.int64),
                                      np.ones(B, np.int64),
                                      near_window=W, nb=NB, bt=BT)
        padded, active = B * NB, int((hi - lo).sum())
        jlo, jhi = jnp.asarray(lo), jnp.asarray(hi)

        def call(skip):
            return _trip_count_kernel(
                q, pk, pv, tbl, wb, seq, act, jlo, jhi,
                near_window=W, bt=BT, nb_full=None if skip else NB)
        o_on, o_off = call(True), call(False)
        assert jnp.array_equal(o_on, o_off), \
            f"{dist}: extent-bounded trips diverged from always-run"
        o_ref = paged_decode_attention_ref(q, pk, pv, tbl, wb, seq, act,
                                           near_window=W)[0]
        assert jnp.allclose(o_on, o_ref.astype(jnp.float32), atol=2e-2), \
            f"{dist}: trip-count kernel diverged from the jnp oracle"
        us_on = _time(call, True, iters=iters)
        us_off = _time(call, False, iters=iters)
        speedup = us_off / us_on
        rows.append(row(
            f"decode_skew/kernel_{dist}", us_on,
            tok_s=B / (us_on * 1e-6), us_always_run=us_off,
            speedup=speedup, padded_blocks=padded, active_blocks=active,
            padded_block_ratio=padded / max(1, active),
            blocks_skipped_share=1.0 - active / padded))
        if dist == "bimodal":
            assert speedup >= MIN_KERNEL_SPEEDUP, \
                f"kernel_{dist}: skip-extent speedup {speedup:.2f}x " \
                f"< {MIN_KERNEL_SPEEDUP}x on bimodal skew"

    # Pallas kernel bitwise A/B in interpret mode, both pipeline depths:
    # the same extents drive @pl.when predication + clamped index maps.
    # (Wall time reported, not gated — interpret emulation still pays the
    # per-grid-step copy machinery for predicated-off steps.)
    Bp, Hp, BTp, Wp = 8, 32, 32, 512
    NBp = Wp // BTp + 1
    Pp = Bp * NBp + 1
    qp = jax.random.normal(ks[0], (Bp, Hp, hd), jnp.bfloat16)
    pkp = jax.random.normal(ks[1], (Pp, BTp, KV, hd), jnp.bfloat16)
    pvp = jax.random.normal(ks[2], (Pp, BTp, KV, hd), jnp.bfloat16)
    tblp = jnp.asarray(np.stack([1 + b * NBp + np.arange(NBp)
                                 for b in range(Bp)]).astype(np.int32))
    wbp = jnp.zeros(Bp, jnp.int32)
    actp = jnp.ones(Bp, jnp.int32)
    seqp = jnp.asarray(np.array([Wp - 1] + [79] * (Bp - 1)).astype(np.int32))
    for depth in (0, 1):
        def pcall(skip, _d=depth):
            return paged_decode_attention_pallas(
                qp, pkp, pvp, tblp, wbp, seqp, actp, near_window=Wp,
                skip_extent=skip, prefetch_depth=_d)[0]
        o_on, o_off = pcall(True), pcall(False)
        assert jnp.array_equal(o_on, o_off), \
            f"pallas depth{depth}: skip-extent A/B not bitwise identical"
        us_on = _time(pcall, True, iters=2)
        us_off = _time(pcall, False, iters=2)
        rows.append(row(
            f"decode_skew/pallas_bimodal_depth{depth}", us_on,
            tok_s=Bp / (us_on * 1e-6), us_always_run=us_off,
            speedup=us_off / us_on, bitwise_identical=1))


# ---------------------------------------------------------------------------
# section 2: engine identity A/B — skip on vs off, bitwise tokens
# ---------------------------------------------------------------------------

def _burst_reqs():
    rng = np.random.default_rng(1)
    return [Request(rid=i, prompt=rng.integers(0, 256, size=8)
                    .astype(np.int32), gen_len=48) for i in range(6)]


def _prefix_reqs(n):
    tcfg = traces.TraceConfig(n_requests=n, vocab=256, seed=23,
                              shared_prefix_len=160, n_prefixes=3,
                              prompt_mean=8, gen_mean=18, window_s=0.0)
    reqs = traces.shared_prefix_workload(tcfg)
    for r in reqs:
        r.arrival = 0.0
    return reqs


def _mixed_reqs(n):
    tcfg = traces.TraceConfig(n_requests=n, token_scale=0.25, vocab=256,
                              seed=5)
    return traces.mixed_length_workload(tcfg)


def _identity_rows(rows):
    n = max(8, int(12 * smoke_scale()))
    workloads = {
        # lockstep oversubscribed burst: deterministic preempt/swap path
        "oversub": (_burst_reqs,
                    dict(batch=4, max_seq=64, near_window=32, block_tokens=8,
                         pool_budget=0.1, host_pool_blocks=40)),
        # warm radix prefix cache: COW-aliased blocks enter the window
        "prefix": (lambda: _prefix_reqs(n),
                   dict(batch=4, max_seq=256, near_window=128, block_tokens=8,
                        prefill_chunk=16, prefix_cache=True,
                        prefix_cache_blocks=96)),
        # fp8 KV tier: extents predicate the dequantizing kernel path
        "quant": (lambda: _mixed_reqs(n),
                  dict(batch=4, max_seq=256, near_window=128, block_tokens=8,
                       kv_dtype="fp8_e4m3")),
    }
    for depth in (0, 1):
        for wname, (mk, kw) in workloads.items():
            on = engine("paged_merge", pipeline_depth=depth,
                        kernel_skip_extent=True, **kw)
            run_workload(on, mk())
            off = engine("paged_merge", pipeline_depth=depth,
                         kernel_skip_extent=False, **kw)
            run_workload(off, mk())
            div = _diverged(_tokens(on), _tokens(off))
            a = on.audit()
            lat = on.latency_stats()
            tag = f"decode_skew/identity_{wname}_depth{depth}"
            rows.append(row(
                tag, lat["mean_ms"] * 1e3,
                tok_s=on.throughput(),
                kernel_blocks_total=a["kernel_blocks_total"],
                kernel_blocks_skipped=a["kernel_blocks_skipped"],
                token_divergence=div, alloc_failures=0,
                finished=len(on.sched.finished)))
            record_audit(tag, a)
            assert div == 0, \
                f"{tag}: {div} requests diverged with work-skipping on"
            assert off.audit()["kernel_blocks_skipped"] == 0, \
                f"{tag}: always-run engine audited skipped blocks"


# ---------------------------------------------------------------------------
# section 3: engine length-skew sweep + audit-counter reporting
# ---------------------------------------------------------------------------

def _skew_reqs(dist, n):
    rng = np.random.default_rng(7)
    reqs = []
    if dist == "replay":
        tcfg = traces.TraceConfig(n_requests=n, token_scale=0.5, vocab=256,
                                  seed=11)
        reqs = traces.mixed_length_workload(tcfg)
        for r in reqs:
            r.arrival = 0.0
        return reqs
    for i in range(n):
        gen = 112 if dist == "uniform" else (176 if i % 4 == 0 else 24)
        reqs.append(Request(rid=i, prompt=rng.integers(0, 256, size=8)
                            .astype(np.int32), gen_len=gen))
    return reqs


def _skew_rows(rows):
    n = max(8, int(16 * smoke_scale()))
    kw = dict(batch=8, max_seq=256, near_window=128, block_tokens=8)
    shares = {}
    for dist in ("uniform", "bimodal", "replay"):
        eng = engine("paged_merge", kernel_skip_extent=True, **kw)
        run_workload(eng, _skew_reqs(dist, n))
        a = eng.audit()
        lat = eng.latency_stats()
        total = a["kernel_blocks_total"]
        skipped = a["kernel_blocks_skipped"]
        shares[dist] = skipped / max(1, total)
        rows.append(row(
            f"decode_skew/{dist}", lat["mean_ms"] * 1e3,
            tok_s=eng.throughput(), step_p99_ms=lat["p99_ms"],
            kernel_blocks_total=total, kernel_blocks_skipped=skipped,
            padded_block_ratio=total / max(1, total - skipped),
            blocks_skipped_share=shares[dist],
            finished=len(eng.sched.finished)))
        record_audit(f"decode_skew/{dist}", a)
    assert shares["bimodal"] > 0, \
        "bimodal skew audited zero kernel_blocks_skipped"


def run():
    rows = []
    _kernel_rows(rows)
    _identity_rows(rows)
    _skew_rows(rows)
    return rows


if __name__ == "__main__":
    print_rows(run())
