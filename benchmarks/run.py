"""Benchmark harness — one module per paper table/figure (DESIGN.md §7).
Prints ``name,us_per_call,derived`` CSV per row.

    PYTHONPATH=src python -m benchmarks.run [--only idle_floor,mixed_length]
    PYTHONPATH=src python -m benchmarks.run --json BENCH_PR2.json
    PYTHONPATH=src python -m benchmarks.run --xla-profile latency_hiding ...

``--json PATH`` aggregates every module's rows PLUS the engine audits
recorded during the run into one JSON artifact — the per-PR perf
trajectory (BENCH_PR<n>.json committed at the repo root; CI uploads the
fresh file and diffs it against the committed previous one with
benchmarks/diff_json.py; selected tokens/s rows gate via ``--gate``).
Each artifact also records the provenance a perf number needs to be
comparable: the active XLA flag profile, the jax version, and the
per-kernel achieved-vs-peak roofline rows (BENCH_SCHEMA.md).

``--xla-profile NAME`` installs a launch/xla_flags.py profile. XLA reads
XLA_FLAGS when jax initializes, so the profile is applied from a
pre-import bootstrap below — before any bench module (and through them
jax) is imported.
"""
import argparse
import json
import os
import sys
import time
import traceback

# ---- pre-import bootstrap: XLA_FLAGS must be set before jax loads.
# Only sys/os may be imported above this point; repro.launch.xla_flags
# deliberately imports no jax.
if "--xla-profile" in sys.argv:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
    from repro.launch import xla_flags as _xf
    _xf.apply_profile(sys.argv[sys.argv.index("--xla-profile") + 1])

from benchmarks.common import collected_audits, print_rows, rows_to_json

MODULES = [
    ("idle_floor", "benchmarks.bench_idle_floor"),
    ("bandwidth_wall", "benchmarks.bench_bandwidth_wall"),
    ("mixed_length", "benchmarks.bench_mixed_length"),
    ("trace_replay", "benchmarks.bench_trace_replay"),
    ("oversubscribe", "benchmarks.bench_oversubscribe"),
    ("prefix_reuse", "benchmarks.bench_prefix_reuse"),
    ("kv_quant", "benchmarks.bench_kv_quant"),
    ("predictable", "benchmarks.bench_predictable"),
    ("transport_audit", "benchmarks.bench_transport_audit"),
    ("farview_quality", "benchmarks.bench_farview_quality"),
    ("boundary_stress", "benchmarks.bench_boundary_stress"),
    ("longcontext_budget", "benchmarks.bench_longcontext_budget"),
    ("decode_skew", "benchmarks.bench_decode_skew"),
    ("sampling_eos", "benchmarks.bench_sampling_eos"),
    ("gateway_slo", "benchmarks.bench_gateway_slo"),
    ("continuous", "benchmarks.bench_continuous"),
    ("kernels", "benchmarks.bench_kernels"),
    ("scaling", "benchmarks.bench_scaling"),
]


def main() -> None:
    from repro.launch import xla_flags

    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of bench names")
    ap.add_argument("--json", default=None,
                    help="aggregate all rows + engine audits into one JSON "
                         "artifact (perf trajectory)")
    ap.add_argument("--xla-profile", default=None,
                    choices=xla_flags.profile_names(),
                    help="launch/xla_flags.py profile to run under "
                         "(applied pre-jax-import by the bootstrap above)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    failed = []
    agg = {}
    print("name,us_per_call,derived")
    for name, modname in MODULES:
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            mod = __import__(modname, fromlist=["run"])
            rows = mod.run()
            print_rows(rows)
            agg[name] = rows_to_json(rows)
            print(f"# {name}: {time.time() - t0:.1f}s", file=sys.stderr)
        except Exception:
            failed.append(name)
            print(f"# {name}: FAILED", file=sys.stderr)
            traceback.print_exc()

    if args.json:
        import jax
        from repro.roofline import bench as roofline_bench
        payload = {"benches": agg, "audits": collected_audits(),
                   "failed": failed,
                   "xla_profile": xla_flags.active_profile(),
                   "jax_version": jax.__version__,
                   "roofline": roofline_bench.report()}
        with open(args.json, "w") as f:
            json.dump(payload, f, indent=1, sort_keys=True, default=float)
        print(f"# wrote {args.json}", file=sys.stderr)

    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
