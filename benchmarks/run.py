"""Benchmark harness — one module per paper table/figure (DESIGN.md §6).
Prints ``name,us_per_call,derived`` CSV per row.

    PYTHONPATH=src python -m benchmarks.run [--only idle_floor,mixed_length]
"""
import argparse
import sys
import time
import traceback

from benchmarks.common import print_rows

MODULES = [
    ("idle_floor", "benchmarks.bench_idle_floor"),
    ("bandwidth_wall", "benchmarks.bench_bandwidth_wall"),
    ("mixed_length", "benchmarks.bench_mixed_length"),
    ("trace_replay", "benchmarks.bench_trace_replay"),
    ("predictable", "benchmarks.bench_predictable"),
    ("transport_audit", "benchmarks.bench_transport_audit"),
    ("farview_quality", "benchmarks.bench_farview_quality"),
    ("boundary_stress", "benchmarks.bench_boundary_stress"),
    ("longcontext_budget", "benchmarks.bench_longcontext_budget"),
    ("kernels", "benchmarks.bench_kernels"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of bench names")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    failed = []
    print("name,us_per_call,derived")
    for name, modname in MODULES:
        if only and name not in only:
            continue
        t0 = time.time()
        try:
            mod = __import__(modname, fromlist=["run"])
            rows = mod.run()
            print_rows(rows)
            print(f"# {name}: {time.time() - t0:.1f}s", file=sys.stderr)
        except Exception:
            failed.append(name)
            print(f"# {name}: FAILED", file=sys.stderr)
            traceback.print_exc()
    if failed:
        print(f"# FAILED: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
