"""Fig. 6(a-b) — endpoint transport audit: DMA groups/step and average merged
transfer size, with vs without descriptor merging, same paged workload."""
from benchmarks.common import engine, print_rows, row, run_workload
from repro.data import traces


def run():
    rows = []
    for mode in ("paged", "paged_merge"):
        eng = engine(mode, batch=8, max_seq=256, pool_budget=0.6)
        reqs = traces.mixed_length_workload(traces.TraceConfig(
            n_requests=24, token_scale=0.3, vocab=eng.cfg.vocab_size, seed=7))
        run_workload(eng, reqs)
        st = eng.transport.stats
        rows.append(row(f"transport/{mode}", 0.0,
                        dma_groups_per_step=st.groups_per_step,
                        avg_dma_bytes=st.avg_group_bytes,
                        unmerged_groups_per_step=st.unmerged_groups_per_step,
                        max_groups=st.max_groups))
    return rows


if __name__ == "__main__":
    print_rows(run())
