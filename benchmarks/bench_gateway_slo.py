"""Open-loop serving through the asyncio gateway (DESIGN.md §14).

Four sections:

* **identity** — the gateway changes WHEN work is scheduled, never WHAT
  tokens a request produces: the same trace through the gateway and
  through the closed-loop ``run_lanes`` replay driver must emit bitwise
  identical per-request streams (``token_divergence``) with zero leaked
  blocks (``alloc_failures``). CI's diff_json correctness tier hard-fails
  either field nonzero.
* **bursty admission A/B** — equal offered load (a bursty interactive
  burst well beyond capacity) through (a) the naive baseline: round-robin
  lanes + admit-everything, and (b) SLO-aware admission that sheds past
  the class depth bound. Shedding bounds the admitted population's queue
  depth, so admitted-request p99 TTFT must drop to <= 0.5x the naive
  baseline's — the PR acceptance bar, asserted here.
* **poisson_mixed** — the headline goodput row (attained-within-SLO
  completions / offered) for a mixed interactive/standard/batch class
  stripe over Poisson arrivals; CI promotes this row's ``goodput`` to a
  hard perf gate.
* **affinity** — shared-prefix trace over two prefix-cache lanes:
  affinity routing (route to the lane whose radix index already holds
  the prompt's prefix) must yield a strictly higher prefix-hit rate than
  round-robin smearing every prefix into every lane's cache.
"""
import numpy as np

from benchmarks.common import print_rows, record_audit, row, smoke_scale
from repro.core.scheduler import Request
from repro.data import traces
from repro.launch.serve import build_lanes, run_gateway, run_lanes
from repro.serving.admission import AdmissionController
from repro.serving.router import AffinityRouter, RoundRobinRouter

KW = dict(mode="paged_merge", batch=4, max_seq=64, block_tokens=8)


def _lanes(n, **kw):
    return build_lanes("qwen2.5-32b", mesh_spec="1x1", lanes=n,
                       **{**KW, **kw})


def _warm(engines, vocab=256):
    """Pay each lane's one-time executor compile (seconds on CPU) before
    the timed open-loop run, so TTFT measures queueing, not compilation."""
    rng = np.random.default_rng(99)
    for eng in engines:
        eng.submit(Request(rid=10_000, prompt=rng.integers(0, vocab, size=8)
                           .astype(np.int32), gen_len=3))
        eng.run(max_steps=100)
        eng.sched.finished.clear()


def _leaks(engines):
    return sum(e.pager.reserved_blocks() + e.pager.host_used
               for e in engines)


def _goodput(slo: dict) -> float:
    att = sum(d["attained"] for d in slo.values())
    off = sum(d["offered"] for d in slo.values())
    return att / max(1, off)


def _hit_rate(out) -> float:
    audits = [out["audit"]] + out.get("lane_audits", [])
    hits = sum(a["prefix_hits"] for a in audits)
    miss = sum(a["prefix_misses"] for a in audits)
    return hits / max(1, hits + miss)


# ---------------------------------------------------------------------------
# section 1: gateway-vs-replay bitwise identity (CI hard gate)
# ---------------------------------------------------------------------------

def _identity_rows(rows):
    n = max(6, int(12 * smoke_scale()))
    tcfg = traces.TraceConfig(n_requests=n, token_scale=0.1, seed=5)

    replay_lanes = _lanes(2, pipeline_depth=1)
    _warm(replay_lanes)
    run_lanes(replay_lanes, traces.mixed_length_workload(tcfg))
    replay = {r.rid: list(map(int, r.generated))
              for e in replay_lanes for r in e.sched.finished}

    gw_lanes = _lanes(2, pipeline_depth=1)
    _warm(gw_lanes)
    out = run_gateway(gw_lanes, traces.mixed_length_workload(tcfg),
                      arrival_scale=0.0, router=RoundRobinRouter(),
                      admission=AdmissionController(unbounded=True))
    div = sum(1 for rid in set(replay) | set(out["results"])
              if replay.get(rid) != out["results"].get(rid))
    tag = "gateway_slo/identity"
    rows.append(row(tag, out["ttft_p50_ms"] * 1e3,
                    token_divergence=div,
                    alloc_failures=_leaks(gw_lanes) + _leaks(replay_lanes),
                    finished=out["finished"], tokens=out["tokens"],
                    ttft_p99_ms=out["ttft_p99_ms"],
                    tpot_p99_ms=out["tpot_p99_ms"]))
    record_audit(tag, out["audit"])
    assert out["finished"] == n and out["rejected"] == 0
    assert div == 0, f"{tag}: gateway re-scheduled WHAT, not just WHEN"
    for eng in gw_lanes + replay_lanes:
        eng.pager.check_invariants()


# ---------------------------------------------------------------------------
# section 2: SLO-aware admission vs naive baseline at equal offered load
# ---------------------------------------------------------------------------

def _admission_rows(rows):
    n = max(24, int(48 * smoke_scale()))
    tcfg = traces.TraceConfig(n_requests=n, token_scale=0.1, seed=6)

    def _burst_reqs():
        reqs = traces.mixed_length_workload(tcfg)
        traces.assign_arrivals(reqs, "bursty", tcfg)
        return reqs

    # 2 slots vs a ~n-deep burst: admit-everything queues the whole burst
    # (p99 TTFT ~ makespan); bounded admission keeps <= 6 outstanding, so
    # an ADMITTED request waits at most ~3 decode rounds
    arms = {}
    for name, router, adm in (
            ("naive_roundrobin", RoundRobinRouter(),
             AdmissionController(unbounded=True)),
            ("slo_admission", None, AdmissionController(max_outstanding=6))):
        lanes = _lanes(1, batch=2)
        _warm(lanes)
        arms[name] = run_gateway(lanes, _burst_reqs(), slo_class="interactive",
                                 arrival_scale=0.002, router=router,
                                 admission=adm)
        assert _leaks(lanes) == 0, name

    naive, slo = arms["naive_roundrobin"], arms["slo_admission"]
    ratio = slo["ttft_p99_ms"] / max(1e-9, naive["ttft_p99_ms"])
    for name, out in arms.items():
        tag = f"gateway_slo/{name}"
        rows.append(row(tag, out["ttft_p50_ms"] * 1e3,
                        ttft_p99_ms=out["ttft_p99_ms"],
                        tpot_p99_ms=out["tpot_p99_ms"],
                        finished=out["finished"], rejected=out["rejected"],
                        goodput=_goodput(out["slo"]),
                        token_divergence=0, alloc_failures=0))
        record_audit(tag, {**out["gateway_audit"],
                           "ttft_p99_ms": out["ttft_p99_ms"]})
    rows.append(row("gateway_slo/admission_ab", slo["ttft_p99_ms"] * 1e3,
                    ttft_p99_ratio=ratio, offered=n,
                    shed=slo["gateway_audit"]["admit_shed_slo"]
                    + slo["gateway_audit"]["admit_rejected_queue_full"],
                    token_divergence=0, alloc_failures=0))
    assert naive["finished"] == n, "naive baseline must admit everything"
    assert ratio <= 0.5, \
        f"admission p99 TTFT {slo['ttft_p99_ms']:.1f}ms not <= 0.5x naive " \
        f"{naive['ttft_p99_ms']:.1f}ms at equal offered load"


# ---------------------------------------------------------------------------
# section 3: mixed-class goodput over Poisson arrivals (CI perf gate)
# ---------------------------------------------------------------------------

def _poisson_rows(rows):
    n = max(8, int(32 * smoke_scale()))
    tcfg = traces.TraceConfig(n_requests=n, token_scale=0.1, seed=7)
    reqs = traces.mixed_length_workload(tcfg)
    traces.assign_arrivals(reqs, "poisson", tcfg)

    lanes = _lanes(2)
    _warm(lanes)
    out = run_gateway(lanes, reqs, slo_class="mixed", arrival_scale=0.025)
    slo = out["slo"]
    tag = "gateway_slo/poisson_mixed"
    per_class = {f"{cls}_goodput": d["goodput"] for cls, d in slo.items()}
    rows.append(row(tag, out["ttft_p50_ms"] * 1e3,
                    goodput=_goodput(slo),
                    ttft_p99_ms=out["ttft_p99_ms"],
                    tpot_p99_ms=out["tpot_p99_ms"],
                    finished=out["finished"], rejected=out["rejected"],
                    tokens=out["tokens"],
                    token_divergence=0, alloc_failures=_leaks(lanes),
                    **per_class))
    record_audit(tag, {**out["gateway_audit"], "slo": slo})
    assert out["finished"] + out["rejected"] == n
    assert _goodput(slo) > 0.5, f"{tag}: goodput collapsed: {slo}"


# ---------------------------------------------------------------------------
# section 4: affinity routing vs round-robin on a shared-prefix trace
# ---------------------------------------------------------------------------

def _affinity_rows(rows):
    n = max(16, int(32 * smoke_scale()))
    tcfg = traces.TraceConfig(n_requests=n, token_scale=0.25, seed=8,
                              shared_prefix_len=16, n_prefixes=4)

    arms = {}
    for name, router in (("rr", RoundRobinRouter()),
                         ("affinity", AffinityRouter())):
        lanes = _lanes(2, prefix_cache=True)
        _warm(lanes)      # else compile delay collapses arrivals into one
        arms[name] = run_gateway(  # cold burst and hit counts become noise
            lanes, traces.shared_prefix_workload(tcfg),
            arrival_scale=0.02, router=router,
            admission=AdmissionController(unbounded=True))
        # prefix-cache lanes legitimately retain committed blocks after
        # finish (the radix index holds them); the leak evidence is every
        # request completing + the pager's own refcount invariants
        assert arms[name]["finished"] == n
        for eng in lanes:
            eng.pager.check_invariants()

    rr_rate, aff_rate = _hit_rate(arms["rr"]), _hit_rate(arms["affinity"])
    out = arms["affinity"]
    tag = "gateway_slo/affinity"
    rows.append(row(tag, out["ttft_p50_ms"] * 1e3,
                    prefix_hit_rate=aff_rate, rr_hit_rate=rr_rate,
                    affinity_hits=out["gateway_audit"]["affinity_hits"],
                    tokens_reused=out["audit"]["prefix_tokens_reused"],
                    token_divergence=0, alloc_failures=0))
    record_audit(tag, out["gateway_audit"])
    assert aff_rate > rr_rate, \
        f"affinity hit rate {aff_rate:.3f} not above round-robin {rr_rate:.3f}"


def run():
    rows = []
    _identity_rows(rows)
    _admission_rows(rows)
    _poisson_rows(rows)
    _affinity_rows(rows)
    return rows


if __name__ == "__main__":
    print_rows(run())
