"""SPMD scaling table (DESIGN.md §4): tokens/s and per-device KV bytes vs
mesh size on a forced multi-device CPU topology (real accelerators when
present). Each row serves the SAME mixed-length workload through
serve.build_lanes/run_lanes:

  * 1x1 — single-device baseline (mesh plumbing off)
  * 1x2 — one lane, 2-way tensor-parallel decode (kv-head-sharded pools)
  * 2x1 — two data-parallel engine lanes, striped trace
  * 2x2 — two lanes x TP2

Reported per row: aggregate tokens/s, per-lane tokens/s, per-device
peak reserved/active KV bytes (the 1xM rows shrink these by M), TP degree.

When the calling process holds < 4 devices (benchmarks/run.py runs on the
default topology), the module re-execs itself in a child with
``--xla_force_host_platform_device_count=4`` and relays the rows — so the
aggregated --json artifact always includes the scaling table.

    XLA_FLAGS=--xla_force_host_platform_device_count=4 \
        PYTHONPATH=src python -m benchmarks.bench_scaling --json scaling.json
"""
import argparse
import json
import os
import subprocess
import sys

MESHES = ("1x1", "1x2", "2x1", "2x2")
NEED_DEVICES = 4
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_inline():
    import jax  # noqa: F401  (device count already forced by the caller)
    from benchmarks.common import record_audit, row, smoke_scale
    from repro.data import traces
    from repro.launch.serve import build_lanes, run_lanes

    scale = smoke_scale()
    n_req = max(8, int(24 * scale))
    rows = []
    for spec in MESHES:
        engines = build_lanes("qwen2.5-32b", "paged_merge", 8, 128, spec,
                              pool_budget_frac=0.5, pipeline_depth=1,
                              prefill_chunk=16)
        reqs = traces.mixed_length_workload(traces.TraceConfig(
            n_requests=n_req, token_scale=0.3,
            vocab=engines[0].cfg.vocab_size, seed=3))
        out = run_lanes(engines, reqs)
        a = out["audit"]
        record_audit(f"scaling/{spec}", a)
        rows.append(row(
            f"scaling/{spec}", 1e6 / max(out["aggregate_tok_s"], 1e-9),
            tok_s=out["aggregate_tok_s"],
            wall_tok_s=out["wall_tok_s"],
            lane_tok_s=float(sum(out["per_lane_tok_s"])),
            lanes=out["lanes"], tp=a["tp_degree"],
            per_device_peak_reserved_kv=a["per_device_peak_reserved_kv"],
            per_device_active_kv=a["per_device_active_kv"],
            peak_reserved_kv=a["peak_reserved_kv"],
            finished=out["finished"]))
    return rows


def run():
    """Harness entry (benchmarks/run.py). Re-exec with a forced device count
    when the current process can't host the meshes."""
    import jax
    if len(jax.devices()) >= NEED_DEVICES:
        return _run_inline()
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count="
                          f"{NEED_DEVICES}").strip()
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (os.path.join(ROOT, "src"), env.get("PYTHONPATH")) if p)
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.bench_scaling", "--emit-rows"],
        cwd=ROOT, env=env, capture_output=True, text=True, timeout=3600)
    if out.returncode != 0:
        raise RuntimeError(f"bench_scaling child failed:\n{out.stderr[-2000:]}")
    payload = json.loads(out.stdout.strip().splitlines()[-1])
    from benchmarks.common import record_audit
    for name, audit in payload["audits"].items():
        record_audit(name, audit)
    return [(r[0], r[1], r[2]) for r in payload["rows"]]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--emit-rows", action="store_true",
                    help="print rows+audits as one JSON line (child mode)")
    ap.add_argument("--json", default=None,
                    help="write rows as a JSON summary")
    args = ap.parse_args()

    if "xla_force_host_platform_device_count" not in \
            os.environ.get("XLA_FLAGS", ""):
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + f" --xla_force_host_platform_device_count={NEED_DEVICES}").strip()

    rows = _run_inline()
    from benchmarks.common import collected_audits, print_rows, write_json
    if args.emit_rows:
        print(json.dumps({"rows": rows, "audits": collected_audits()},
                         default=float))
    else:
        print_rows(rows)
        if args.json:
            write_json(rows, args.json)
