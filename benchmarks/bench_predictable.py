"""Table 4 — predictable-regime sanity: homogeneous lengths, steady width.
The static arena is expected to be at/near the frontier here; KV-RM must stay
within a small margin (the paper's balance check)."""
from benchmarks.common import engine, print_rows, row, run_workload
from repro.data import traces


def run():
    rows = []
    for mode in ("arena", "paged", "paged_merge", "full"):
        eng = engine(mode, batch=8, max_seq=128)
        reqs = traces.predictable_workload(traces.TraceConfig(
            n_requests=16, token_scale=0.25, vocab=eng.cfg.vocab_size, seed=5))
        run_workload(eng, reqs)
        lat = eng.latency_stats()
        rows.append(row(f"predictable/{mode}", lat["mean_ms"] * 1e3,
                        tok_s=eng.throughput(), p99_ms=lat["p99_ms"],
                        finished=len(eng.sched.finished)))
    return rows


if __name__ == "__main__":
    print_rows(run())
