"""Quantized KV-block storage tier (DESIGN.md §10).

Gates the memory tentpole: ``kv_dtype="fp8_e4m3" | "int8"`` stores K/V
blocks narrow with per-block per-head scales under the SAME fixed
descriptor interface. Three workload families, each vs a bf16 baseline:

* **mixed** — heavy-tailed mixed-length decode: reserved-KV reduction
  (must be >= 1.8x for fp8), tokens/s, greedy-token divergence vs bf16
  (reported as ``quant_token_divergence`` — quantization noise is
  EXPECTED to flip some argmaxes on this tiny random-init model, so it is
  not a correctness gate; the bf16 rows' ``token_divergence`` IS).
* **shared_prefix** — radix prefix cache on: a cache hit aliases data +
  scale chains atomically, so the quantized warm run must be bitwise
  identical to the quantized cold run (``token_divergence`` gated at 0).
* **burst** — bursty replay at ~1.5x device-KV oversubscription with the
  host tier: swap moves narrow blocks + scales in lockstep, must finish
  with ZERO ``alloc_failures`` and ZERO divergence vs the ample-pool
  quantized baseline (both CI-gated).

The bf16 identity row replays the mixed trace at pipeline depths 0 and 1
with ``kv_dtype="bf16"`` and gates ``token_divergence`` at 0 — the
default path must stay bitwise identical to seed.
"""
from benchmarks.common import engine, print_rows, record_audit, row, \
    run_workload, smoke_scale
from repro.data import traces

FP8_MIN_RATIO = 1.8       # acceptance: reserved-KV reduction vs bf16


def _tokens(eng):
    return {r.rid: list(r.generated) for r in eng.sched.finished}


def _diverged(a, b):
    """Requests whose token streams differ between two runs. A request
    missing from EITHER side counts as diverged — a dropped/unfinished
    request must not slip past the CI-gated token_divergence=0 check."""
    return sum(1 for rid in set(a) | set(b) if a.get(rid) != b.get(rid))


def _mixed_reqs(n):
    tcfg = traces.TraceConfig(n_requests=n, token_scale=0.25, vocab=256,
                              seed=5)
    return traces.mixed_length_workload(tcfg)


def _prefix_reqs(n, bt=8):
    # block-aligned shared prefixes: a hit aliases FULL blocks only, so the
    # quantized warm run reuses byte-identical (data, scale) chains
    tcfg = traces.TraceConfig(n_requests=n, token_scale=0.5, vocab=256,
                              seed=9, shared_prefix_len=4 * bt, n_prefixes=2,
                              prompt_mean=10, gen_mean=24, window_s=1.0)
    return traces.shared_prefix_workload(tcfg)


def _burst_reqs(n):
    tcfg = traces.TraceConfig(n_requests=n, token_scale=1.0, vocab=256,
                              seed=17, burstiness=2.0, prompt_mean=24)
    reqs = traces.azure_like_replay(tcfg)
    for r in reqs:
        r.gen_len = min(144 + (r.rid % 3) * 8, 224 - len(r.prompt))
    return reqs


def run():
    rows = []
    n = max(8, int(24 * smoke_scale()))
    kw = dict(batch=4, max_seq=256, near_window=128, block_tokens=8)

    # --- bf16 identity: depth A/B with the quant config knob present ---
    b0 = engine("paged_merge", pipeline_depth=0, kv_dtype="bf16", **kw)
    run_workload(b0, _mixed_reqs(n))
    b1 = engine("paged_merge", pipeline_depth=1, kv_dtype="bf16", **kw)
    run_workload(b1, _mixed_reqs(n))
    div = _diverged(_tokens(b0), _tokens(b1))
    lat = b1.latency_stats()
    rows.append(row("kv_quant/bf16_identity", lat["mean_ms"] * 1e3,
                    tok_s=b1.throughput(), step_p99_ms=lat["p99_ms"],
                    peak_reserved_kv=b1.peak_reserved_kv,
                    token_divergence=div, alloc_failures=0,
                    finished=len(b1.sched.finished)))
    record_audit("kv_quant/bf16_identity", b1.audit())
    assert div == 0, f"bf16 depth A/B diverged: {div}"
    t_bf16 = _tokens(b1)

    # --- narrow dtypes on the same mixed trace ------------------------
    for kvd in ("fp8_e4m3", "int8"):
        q = engine("paged_merge", kv_dtype=kvd, **kw)
        run_workload(q, _mixed_reqs(n))
        tq = _tokens(q)
        total = sum(len(v) for v in t_bf16.values())
        flipped = sum(sum(1 for a, b in zip(t_bf16[rid], toks) if a != b)
                      for rid, toks in tq.items() if rid in t_bf16)
        a = q.audit()
        lat = q.latency_stats()
        ratio = b1.peak_reserved_kv / max(1, q.peak_reserved_kv)
        rows.append(row(
            f"kv_quant/{kvd}_mixed", lat["mean_ms"] * 1e3,
            tok_s=q.throughput(), step_p99_ms=lat["p99_ms"],
            peak_reserved_kv=q.peak_reserved_kv,
            reserved_kv_ratio=ratio,
            quant_bytes_saved=a["quant_bytes_saved"],
            quant_scale_bytes=a["quant_scale_bytes"],
            quant_token_divergence=_diverged(t_bf16, tq),
            quant_token_flip_rate=flipped / max(1, total),
            alloc_failures=0, finished=len(q.sched.finished)))
        record_audit(f"kv_quant/{kvd}_mixed", a)
        if kvd == "fp8_e4m3":
            assert ratio >= FP8_MIN_RATIO, \
                f"fp8 reserved-KV reduction {ratio:.2f}x < {FP8_MIN_RATIO}x"

    # --- quantized shared-prefix reuse: warm bitwise == cold ----------
    cold = engine("paged_merge", kv_dtype="fp8_e4m3", **kw)
    run_workload(cold, _prefix_reqs(n), replay_scale=0.01)
    warm = engine("paged_merge", kv_dtype="fp8_e4m3", prefix_cache=True, **kw)
    run_workload(warm, _prefix_reqs(n), replay_scale=0.01)
    a = warm.audit()
    div = _diverged(_tokens(cold), _tokens(warm))
    lat = warm.latency_stats()
    rows.append(row("kv_quant/fp8_shared_prefix", lat["mean_ms"] * 1e3,
                    tok_s=warm.throughput(),
                    prefix_hits=a["prefix_hits"],
                    prefix_tokens_reused=a["prefix_tokens_reused"],
                    cow_copies=a["cow_copies"],
                    quant_bytes_saved=a["quant_bytes_saved"],
                    token_divergence=div, alloc_failures=0,
                    finished=len(warm.sched.finished)))
    record_audit("kv_quant/fp8_shared_prefix", a)
    assert div == 0, f"quant prefix warm/cold diverged: {div}"
    assert a["prefix_hits"] > 0, "shared-prefix trace produced no cache hits"

    # --- quantized burst at ~1.5x oversubscription --------------------
    burst_bf16 = engine("paged_merge", kv_dtype="bf16", pool_budget=1.0, **kw)
    run_workload(burst_bf16, _burst_reqs(n), replay_scale=0.01)
    base = engine("paged_merge", kv_dtype="fp8_e4m3", pool_budget=1.0, **kw)
    run_workload(base, _burst_reqs(n), replay_scale=0.01)
    n_layers = base.pool_bytes_total // ((base.num_blocks - 1)
                                         * base.block_bytes)
    peak_blocks = -(-base.peak_reserved_kv // (base.block_bytes * n_layers))
    worst = kw["batch"] * (-(-kw["max_seq"] // kw["block_tokens"]) + 1)
    dev_blocks = max(12, int(peak_blocks / 1.5))
    over = engine("paged_merge", kv_dtype="fp8_e4m3",
                  pool_budget=dev_blocks / worst,
                  host_pool_blocks=peak_blocks - dev_blocks + 8, **kw)
    alloc_failures = 0
    try:
        run_workload(over, _burst_reqs(n), replay_scale=0.01)
    except MemoryError:
        alloc_failures = 1
        raise
    finally:
        div = _diverged(_tokens(base), _tokens(over))
        a = over.audit()
        lat = over.latency_stats()
        burst_ratio = (burst_bf16.peak_reserved_kv
                       / max(1, base.peak_reserved_kv))
        rows.append(row(
            "kv_quant/fp8_burst_oversubscribed", lat["mean_ms"] * 1e3,
            tok_s=over.throughput(), step_p99_ms=lat["p99_ms"],
            peak_reserved_kv=over.peak_reserved_kv,
            reserved_kv_ratio=burst_ratio,
            preemptions=a["preemptions"], swap_bytes=a["swap_bytes"],
            quant_bytes_saved=a["quant_bytes_saved"],
            host_blocks_peak=a["host_blocks_peak"],
            alloc_failures=alloc_failures, token_divergence=div,
            finished=len(over.sched.finished)))
        record_audit("kv_quant/fp8_burst_oversubscribed", a)
    assert div == 0, f"quant burst oversubscription diverged: {div}"
    assert burst_ratio >= FP8_MIN_RATIO, \
        f"fp8 burst reserved-KV reduction {burst_ratio:.2f}x < {FP8_MIN_RATIO}x"
    return rows


if __name__ == "__main__":
    print_rows(run())
